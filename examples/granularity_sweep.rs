//! RQ2 (Table 6): effect of snapshot time granularity on DTDG link
//! prediction. TGM treats granularity as a one-line hyperparameter —
//! this sweep trains GCN / T-GCN / GCLSTM at hourly, daily, and weekly
//! snapshots and reports test MRR. Expected shape (per the paper): finer
//! granularity is generally better, and the gap is large for GCN.

use tgm::coordinator::{Pipeline, PipelineConfig, Split};
use tgm::io::gen;
use tgm::runtime::XlaEngine;
use tgm::util::TimeGranularity;

fn main() -> tgm::Result<()> {
    let engine = XlaEngine::cpu(
        std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let scale: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.25);

    println!("{:<10} {:<12} {:<8} {:>8}", "dataset", "model", "gran", "test MRR");
    for ds in ["wiki", "reddit"] {
        for model in ["gcn_link", "tgcn_link", "gclstm_link"] {
            for gran in [TimeGranularity::Hour, TimeGranularity::Day, TimeGranularity::Week] {
                let data = gen::by_name(ds, scale, 42)?;
                let mut cfg = PipelineConfig::new(model);
                cfg.granularity = gran; // <- the one-line hyperparameter
                let mut pipe = Pipeline::new(&engine, data, cfg)?;
                for _ in 0..3 {
                    pipe.train_epoch()?;
                }
                let r = pipe.evaluate(Split::Test)?;
                println!(
                    "{:<10} {:<12} {:<8} {:>8.4}",
                    ds,
                    model,
                    gran.as_str(),
                    r.mrr.unwrap_or(0.0)
                );
            }
        }
    }
    Ok(())
}
