//! End-to-end quickstart: the full three-layer stack on a real workload.
//!
//! Generates the Wikipedia surrogate, trains TPNet and TGAT link
//! predictors through the AOT artifacts (PJRT CPU), logs the loss curve,
//! and reports one-vs-many MRR on validation and test — proving the
//! L3 (Rust data path) / L2 (JAX model) / L1 (Pallas kernels) layers
//! compose. Run with:
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use tgm::coordinator::{evaluate_edgebank, Pipeline, PipelineConfig, Split};
use tgm::io::gen;
use tgm::models::EdgeBankMode;
use tgm::runtime::XlaEngine;

fn main() -> tgm::Result<()> {
    let artifacts = std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = XlaEngine::cpu(&artifacts)?;
    println!("engine: platform={}", engine.platform());

    // A small real workload: the Wikipedia surrogate at 40% scale
    // (~370 nodes, 6.4k events over one month).
    let data = gen::by_name("wiki", 0.4, 42)?;
    println!("dataset: {}", data.stats());

    for model in ["tpnet_link", "tgat_link"] {
        println!("\n=== {model} ===");
        let mut pipe = Pipeline::new(&engine, data.clone(), PipelineConfig::new(model))?;
        for epoch in 0..3 {
            let r = pipe.train_epoch()?;
            println!(
                "epoch {epoch}: loss={:.4} over {} batches in {:.2}s",
                r.mean_loss, r.batches, r.seconds
            );
        }
        let val = pipe.evaluate(Split::Val)?;
        let test = pipe.evaluate(Split::Test)?;
        println!(
            "val MRR = {:.4} ({} queries, {:.2}s) | test MRR = {:.4} ({} queries)",
            val.mrr.unwrap(),
            val.queries,
            val.seconds,
            test.mrr.unwrap(),
            test.queries
        );
        let first = *pipe.loss_history.first().unwrap();
        let last = *pipe.loss_history.last().unwrap();
        println!(
            "loss curve: {first:.4} -> {last:.4} ({})",
            if last < first { "improving" } else { "flat" }
        );
    }

    // Non-parametric baseline for reference.
    let splits = data.split()?;
    let eb = evaluate_edgebank(&data, &splits.test, EdgeBankMode::Unlimited, 10, 0)?;
    println!("\nEdgeBank test MRR = {:.4} ({} queries)", eb.mrr.unwrap(), eb.queries);
    println!("\nquickstart OK");
    Ok(())
}
