//! Durability end-to-end: ingest → kill → recover → byte-identical
//! serving.
//!
//! The orchestrator (default mode) spawns a child copy of itself
//! (`TGM_ROLE=ingest`) that ingests a surrogate event stream into a
//! durable `SegmentedStorage` — WAL on — and dies abruptly
//! (`std::process::abort`, the in-process equivalent of a SIGKILL: no
//! destructors, no flushes) after a configured number of acknowledged
//! appends, mid-active-segment. The orchestrator then:
//!
//! 1. **recovers** the store through the serving tier — one
//!    `ServingConfig::primary(..).mmap().group_commit()` registration
//!    whose sealed columns serve **zero-copy from an mmap** of the
//!    segment files — surfaces the recovery diagnostics via
//!    `TenantHandle::recovery_report`, and verifies the recovered
//!    publication holds *exactly the acknowledged prefix*
//!    (byte-compared against an in-memory store fed the same events);
//! 2. **resumes** ingestion of the remaining stream — appends
//!    group-committed per chunk by `TenantHandle::ingest` — while a
//!    background `Compactor` attached to the tenant merges **tiered**
//!    runs of sealed segment files off the write path, publishing
//!    generations through the tenant's cell;
//! 3. verifies the final snapshot is **byte-identical** to an
//!    uninterrupted run, and that the prequential EdgeBank MRR over the
//!    recovered store matches the uninterrupted run's exactly.
//!
//! The child's crash also demonstrates the directory lock's liveness
//! story: the `LOCK` file the child leaves behind never blocks the
//! orchestrator's recovery, because the kernel released the child's
//! flock the instant it died.
//!
//! ```text
//! cargo run --release --example durable_restart
//! TGM_SCALE=0.05 cargo run --release --example durable_restart   # CI smoke
//! ```
//!
//! Environment knobs: `TGM_SCALE` (default 0.2), `TGM_KILL_AT`
//! (acknowledged events before the kill; default 640 = 2.5 segments).

use std::sync::Arc;
use tgm::graph::{DGData, SealPolicy, SegmentedStorage, StorageSnapshot, Task};
use tgm::hooks::batch::attr;
use tgm::hooks::negatives::EvalNegativeSampler;
use tgm::hooks::{DstRange, HookManager};
use tgm::io::gen;
use tgm::io::stream::{EventSource, ReplaySource};
use tgm::loader::{BatchBy, DGDataLoader};
use tgm::models::{EdgeBank, EdgeBankMode};
use tgm::persist::{CompactorConfig, DurabilityPolicy};
use tgm::serving::{ServingConfig, TenantRouter};
use tgm::util::stats;

const SEAL_EVERY: usize = 256;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn dataset() -> tgm::Result<DGData> {
    gen::by_name("wiki", env_f64("TGM_SCALE", 0.2), 7)
}

fn fresh_store(data: &DGData) -> SegmentedStorage {
    SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::by_events(SEAL_EVERY))
        .with_granularity(data.storage().granularity())
}

/// Prequential (test-then-train) EdgeBank MRR over one snapshot: every
/// edge is scored against the pre-update bank, then learned. A pure
/// function of the snapshot bytes, so equal snapshots => equal MRR.
fn prequential_mrr(snap: Arc<StorageSnapshot>) -> tgm::Result<f64> {
    let data = DGData::from_snapshot(snap, "wiki-mrr", Task::LinkPrediction);
    let mut manager = HookManager::new();
    manager.register_stateless(
        "stream",
        Arc::new(EvalNegativeSampler::new(DstRange::InferFromData, 20, 0)),
    );
    manager.activate("stream")?;
    let mut loader = DGDataLoader::new(data.full(), BatchBy::Events(256), &mut manager)?;
    let mut bank = EdgeBank::new(EdgeBankMode::Unlimited);
    let mut rrs: Vec<f64> = Vec::new();
    while let Some(batch) = loader.next() {
        let batch = batch?;
        let negs = batch.get(attr::EVAL_NEGATIVES)?;
        let q = negs.shape()[1];
        let nv = negs.as_i32()?;
        for i in 0..batch.num_edges() {
            let pos = bank.score(batch.src[i], batch.dst[i], batch.ts[i]);
            let neg: Vec<f64> = (0..q)
                .map(|j| bank.score(batch.src[i], nv[i * q + j] as u32, batch.ts[i]))
                .collect();
            rrs.push(stats::reciprocal_rank(pos, &neg));
        }
        bank.update(&batch.src, &batch.dst, &batch.ts);
    }
    Ok(stats::mean(&rrs))
}

/// Child role: ingest durably, then die without warning.
fn ingest_and_die(dir: &str, kill_at: usize) -> tgm::Result<()> {
    let data = dataset()?;
    let mut store = fresh_store(&data).with_durability(DurabilityPolicy::new(dir))?;
    let mut source = ReplaySource::from_data(&data);
    let mut appended = 0usize;
    loop {
        let chunk = source.next_chunk(64);
        if chunk.is_empty() {
            break;
        }
        for ev in chunk {
            store.append(ev)?;
            appended += 1;
            if appended == kill_at {
                println!(
                    "child: {appended} events acknowledged ({} sealed segments, {} in WAL) — dying now",
                    store.num_sealed_segments(),
                    store.pending_edges() + store.pending_node_events()
                );
                // Simulated SIGKILL: no destructors, no flushes.
                std::process::abort();
            }
        }
    }
    Err(tgm::TgmError::Config(format!(
        "TGM_KILL_AT={kill_at} exceeds the stream length {appended}; lower it"
    )))
}

fn main() -> tgm::Result<()> {
    if std::env::var("TGM_ROLE").as_deref() == Ok("ingest") {
        let dir = std::env::var("TGM_DIR")
            .map_err(|_| tgm::TgmError::Config("child needs TGM_DIR".into()))?;
        let kill_at = env_usize("TGM_KILL_AT", 640);
        return ingest_and_die(&dir, kill_at);
    }

    let data = dataset()?;
    let total_events = data.storage().num_edges() + data.storage().num_node_events();
    let kill_at =
        env_usize("TGM_KILL_AT", 640).clamp(1, total_events.saturating_sub(1).max(1));
    println!(
        "stream: {} ({} events; child will be killed after {kill_at})",
        data.stats(),
        total_events
    );

    // Uninterrupted reference: the one-shot snapshot and its MRR.
    let reference = Arc::clone(data.storage());
    let reference_mrr = prequential_mrr(Arc::clone(&reference))?;

    // 1. Spawn the child ingester and let it die mid-ingest.
    let dir = std::env::temp_dir().join(format!("tgm_durable_restart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let exe = std::env::current_exe()?;
    let status = std::process::Command::new(exe)
        .env("TGM_ROLE", "ingest")
        .env("TGM_DIR", &dir)
        .env("TGM_KILL_AT", kill_at.to_string())
        .status()?;
    assert!(!status.success(), "the child must die abnormally, got {status}");
    println!("child died as planned ({status})");

    // 2. Recover through the serving tier: one registration rebuilds
    //    the store (sealed columns mmap-served, subsequent appends
    //    group-committed), republishes the pre-crash generation, and
    //    surfaces the recovery diagnostics — the child's stale LOCK
    //    file does not block, because the kernel released its flock at
    //    death.
    let mut router = TenantRouter::new();
    let tenant = router.add_primary(
        "wiki",
        ServingConfig::primary(data.storage().num_nodes(), &dir)
            .seal(SealPolicy::by_events(SEAL_EVERY))
            // The background compactor attached below owns compaction.
            .compact_after(usize::MAX)
            .mmap()
            .group_commit(),
    )?;
    let report = tenant
        .recovery_report()
        .expect("a tenant registered over an existing store carries a recovery report")
        .clone();
    println!(
        "recovery report: {} sealed segments, {} WAL events replayed, torn tail: {} \
         ({} bytes dropped)",
        report.sealed_segments, report.replayed_events, report.torn_tail, report.dropped_bytes
    );
    let mut expected_prefix = fresh_store(&data);
    let mut source = ReplaySource::from_data(&data);
    for ev in source.next_chunk(kill_at) {
        expected_prefix.append(ev)?;
    }
    {
        // The recovered generation is already published — pin it.
        let rec = tenant.pin()?;
        let exp = expected_prefix.snapshot()?;
        assert_eq!(rec.num_edges(), exp.num_edges(), "recovered edge count");
        assert_eq!(rec.edge_ts(), exp.edge_ts(), "recovered timestamps");
        assert_eq!(rec.edge_src(), exp.edge_src(), "recovered sources");
        assert_eq!(rec.edge_dst(), exp.edge_dst(), "recovered destinations");
        assert_eq!(rec.edge_feats(), exp.edge_feats(), "recovered features");
        assert_eq!(rec.num_node_events(), exp.num_node_events(), "recovered node events");
        println!(
            "recovered the acknowledged prefix: {} edges across {} segments + WAL tail",
            rec.num_edges(),
            tenant.num_sealed_segments(),
        );
    }

    // 3. Resume ingestion of the rest while a background compactor
    //    merges sealed segment files and publishes generations through
    //    the tenant's cell. Each ingest chunk is acknowledged by one
    //    group-commit fsync.
    let compactor = tenant.attach_compactor(
        // Low threshold so even the small CI-scale run compacts.
        CompactorConfig { min_sealed: 2, ..Default::default() },
    );
    loop {
        let chunk = source.next_chunk(512);
        if chunk.is_empty() {
            break;
        }
        tenant.ingest(chunk)?;
        tenant.publish()?;
    }
    // Give the compactor a moment to drain the sealed backlog so the
    // smoke run demonstrably exercises a background round.
    let t0 = std::time::Instant::now();
    while t0.elapsed() < std::time::Duration::from_secs(5) {
        if compactor.compactions() > 0 || tenant.num_sealed_segments() <= 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let rounds = compactor.compactions();
    if let Some(e) = compactor.last_error() {
        return Err(tgm::TgmError::Persist(format!("background compaction failed: {e}")));
    }
    compactor.stop();

    // 4. Byte-identical serving + identical MRR vs the uninterrupted run.
    let final_snap = tenant.publish()?;
    assert_eq!(final_snap.num_edges(), reference.num_edges());
    assert_eq!(final_snap.edge_ts(), reference.edge_ts());
    assert_eq!(final_snap.edge_src(), reference.edge_src());
    assert_eq!(final_snap.edge_dst(), reference.edge_dst());
    assert_eq!(final_snap.edge_feats(), reference.edge_feats());
    let recovered_mrr = prequential_mrr(Arc::clone(&final_snap))?;
    println!(
        "MRR uninterrupted = {reference_mrr:.6}, recovered+resumed = {recovered_mrr:.6} \
         ({rounds} background compaction rounds, {} segments at the end, {} mmap-served)",
        final_snap.num_segments(),
        final_snap.num_mapped_segments()
    );
    assert_eq!(
        reference_mrr.to_bits(),
        recovered_mrr.to_bits(),
        "recovered serving must reproduce the uninterrupted MRR bit-for-bit"
    );

    drop(router);
    drop(tenant);
    let _ = std::fs::remove_dir_all(&dir);
    println!("durable_restart OK");
    Ok(())
}
