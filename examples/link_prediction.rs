//! Dynamic link property prediction on a TGB-style workload.
//!
//! Trains a chosen CTDG model (default TGN) on the Reddit surrogate and
//! compares one-vs-many MRR against the EdgeBank heuristic — the
//! workflow of the paper's Fig. 5, driven end-to-end from Rust.
//!
//! ```text
//! cargo run --release --example link_prediction [model] [scale]
//! ```

use tgm::coordinator::{evaluate_edgebank, Pipeline, PipelineConfig, Split};
use tgm::io::gen;
use tgm::models::EdgeBankMode;
use tgm::runtime::XlaEngine;

fn main() -> tgm::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("tgn_link").to_string();
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.2);

    let engine = XlaEngine::cpu(
        std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let data = gen::by_name("reddit", scale, 7)?;
    println!("{}", data.stats());

    let mut pipe = Pipeline::new(&engine, data.clone(), PipelineConfig::new(&model))?;
    for e in 0..3 {
        let r = pipe.train_epoch()?;
        println!("[{model}] epoch {e}: loss={:.4} ({} batches, {:.2}s)", r.mean_loss, r.batches, r.seconds);
    }
    let test = pipe.evaluate(Split::Test)?;
    println!("[{model}] test MRR = {:.4} over {} queries", test.mrr.unwrap(), test.queries);

    let splits = data.split()?;
    let eb = evaluate_edgebank(&data, &splits.test, EdgeBankMode::Unlimited, 10, 0)?;
    let ebw = evaluate_edgebank(
        &data,
        &splits.test,
        EdgeBankMode::TimeWindow(7 * 86_400),
        10,
        0,
    )?;
    println!("[edgebank-unlimited] test MRR = {:.4}", eb.mrr.unwrap());
    println!("[edgebank-1week]     test MRR = {:.4}", ebw.mrr.unwrap());
    Ok(())
}
