//! Dynamic node property prediction (paper §3, Table 4 protocol).
//!
//! Trade surrogate: predict each country's next-year trade proportions
//! over property classes; Genre surrogate: next-week listening mix.
//! Compares TGN (CTDG, memory-based) against GCN (DTDG, snapshot-based)
//! and the Persistent Forecast baseline, reporting NDCG@10.

use tgm::coordinator::{targets, Pipeline, PipelineConfig, Split};
use tgm::io::gen;
use tgm::models::PersistentForecast;
use tgm::runtime::XlaEngine;
use tgm::util::stats;
use tgm::util::TimeGranularity;

fn persistent_ndcg(data: &tgm::graph::DGData, gran: TimeGranularity, p: usize) -> tgm::Result<f64> {
    // Walk snapshots chronologically: predict next period from the last
    // observed distribution.
    let storage = data.storage();
    let splits = data.split()?;
    let mut pf = PersistentForecast::new(p);
    let secs = gran.seconds().unwrap();
    let mut t = storage.start_time();
    let mut ndcgs = Vec::new();
    while t < storage.end_time() {
        let t1 = t + secs;
        for node in targets::active_sources(storage, t, t1, usize::MAX) {
            let truth: Vec<f64> =
                targets::node_target(storage, node, t, t1, p).iter().map(|&x| x as f64).collect();
            if t >= splits.test.start_time() {
                let pred = pf.predict(node);
                ndcgs.push(stats::ndcg_at_k(&pred, &truth, 10));
            }
            pf.observe(node, &truth);
        }
        t = t1;
    }
    Ok(stats::mean(&ndcgs))
}

fn main() -> tgm::Result<()> {
    let engine = XlaEngine::cpu(
        std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let cases = [
        ("trade", 0.5, TimeGranularity::Year),
        ("genre", 0.15, TimeGranularity::Week),
    ];
    for (ds, scale, gran) in cases {
        let data = gen::by_name(ds, scale, 11)?;
        println!("\n=== {} ===\n{}", ds, data.stats());
        let p = 16; // property classes (profile.p)
        println!("P.F. baseline test NDCG@10 = {:.4}", persistent_ndcg(&data, gran, p)?);
        for model in ["tgn_node", "gcn_node"] {
            let mut cfg = PipelineConfig::new(model);
            cfg.granularity = gran;
            let mut pipe = Pipeline::new(&engine, data.clone(), cfg)?;
            for e in 0..2 {
                let r = pipe.train_epoch()?;
                println!("[{model}] epoch {e}: loss={:.4}", r.mean_loss);
            }
            let t = pipe.evaluate(Split::Test)?;
            println!("[{model}] test NDCG@10 = {:.4} ({} queries)", t.ndcg.unwrap(), t.queries);
        }
    }
    Ok(())
}
