//! End-to-end streaming ingestion: append → seal → train → eval cycles
//! over a graph that grows while it learns.
//!
//! Replays the Wikipedia surrogate's event log through a
//! `SegmentedStorage` as if it were arriving live, and drives a
//! `StreamingTrainer`: each cycle ingests a chunk of events, seals the
//! active segment, snapshots, and trains over the newly revealed time
//! window. The model is EdgeBank (no compiled artifacts needed), scored
//! **prequentially** — every edge is first *tested* (one-vs-many MRR
//! against deterministic eval negatives) and then *learned*, so the
//! reported MRR is an honest online-learning metric. Sealed segments are
//! compacted periodically to bound read fan-out.
//!
//! ```text
//! cargo run --release --example streaming_ingestion
//! ```

use std::sync::Arc;
use tgm::coordinator::{StreamingConfig, StreamingTrainer};
use tgm::graph::{SealPolicy, SegmentedStorage};
use tgm::hooks::batch::attr;
use tgm::hooks::negatives::EvalNegativeSampler;
use tgm::hooks::{DstRange, HookManager};
use tgm::io::gen;
use tgm::io::stream::ReplaySource;
use tgm::models::{EdgeBank, EdgeBankMode};
use tgm::util::stats;

fn main() -> tgm::Result<()> {
    // The "live" stream: the wiki surrogate replayed in arrival order.
    let data = gen::by_name("wiki", 0.2, 42)?;
    let total = data.storage().num_edges();
    println!("stream: {} ({} edge events)", data.stats(), total);

    let store = SegmentedStorage::new(
        data.storage().num_nodes(),
        SealPolicy::by_events(512),
    )
    .with_granularity(data.storage().granularity());
    let source = ReplaySource::from_data(&data);

    // Recipe for the streaming pass: deterministic one-vs-many negatives
    // per positive edge (the TGB protocol), produced on the data path.
    let mut manager = HookManager::new();
    manager.register_stateless(
        "stream",
        Arc::new(EvalNegativeSampler::new(DstRange::InferFromData, 20, 0)),
    );

    let cfg = StreamingConfig {
        ingest_chunk: 1024,
        batch_events: 256,
        compact_after: 6,
        train_key: "stream".into(),
    };
    let mut trainer = StreamingTrainer::new(store, source, cfg);

    let mut bank = EdgeBank::new(EdgeBankMode::Unlimited);
    let mut rrs: Vec<f64> = Vec::new();
    let mut trained = 0usize;

    loop {
        let mut cycle_rrs: Vec<f64> = Vec::new();
        let report = trainer.run_cycle(&mut manager, |batch| {
            let negs = batch.get(attr::EVAL_NEGATIVES)?;
            let q = negs.shape()[1];
            let nv = negs.as_i32()?;
            for i in 0..batch.num_edges() {
                // Test-then-train: score against the pre-update bank.
                let pos = bank.score(batch.src[i], batch.dst[i], batch.ts[i]);
                let neg: Vec<f64> = (0..q)
                    .map(|j| bank.score(batch.src[i], nv[i * q + j] as u32, batch.ts[i]))
                    .collect();
                cycle_rrs.push(stats::reciprocal_rank(pos, &neg));
            }
            bank.update(&batch.src, &batch.dst, &batch.ts);
            Ok(())
        })?;
        let Some(report) = report else { break };
        trained += cycle_rrs.len();
        let cycle_mrr = if cycle_rrs.is_empty() {
            "     -".to_string()
        } else {
            format!("{:.4}", stats::mean(&cycle_rrs))
        };
        rrs.extend(cycle_rrs);
        println!(
            "cycle {:>3}: ingested {:>5}  window [{:>8}, {:>8})  batches {:>3}  \
             segments {}  gen {:>4}  cycle MRR {}",
            report.cycle,
            report.ingested,
            report.window.0,
            report.window.1,
            report.batches,
            report.sealed_segments,
            report.generation,
            cycle_mrr,
        );
    }

    assert_eq!(trained, total, "every streamed edge must be scored exactly once");
    println!(
        "\nstreamed {} edges over {} cycles | prequential MRR = {:.4} | bank size {}",
        trained,
        trainer.cycles(),
        stats::mean(&rrs),
        bank.len()
    );
    println!("streaming_ingestion OK");
    Ok(())
}
