//! Point queries and tenant QoS: mixed point-query + batch-scan +
//! ingest load over one shared, weighted-fair `ServingPool`.
//!
//! Two tenants with skewed scheduling weights (`bronze` weight 1,
//! `gold` weight 3) share one pool. While an ingestor thread drives
//! both tenants' event streams (chunked ingest + publish), each tenant
//! runs two serving loops concurrently:
//!
//! * a **batch-scan** loop: full hooked evaluation passes pinned to the
//!   latest published generation (`TenantRouter::serve`), and
//! * a **point-query** loop: a pipelined window of
//!   `neighbors_before` / `edge_lookup` requests against the tenant's
//!   memoized `PointReader` (`TenantHandle::submit_query`) — zero batch
//!   materialization, zero hook work.
//!
//! The pool's weighted-DRR scheduler keeps the scan backlog from
//! starving point queries, and per-tenant admission caps shed overload
//! as typed `Backpressure` errors (handled here by draining in-flight
//! tickets — load shedding, never a deadlock). At exit the example
//! prints per-class completion counts and the pool's per-class latency
//! histograms through the profiler, and asserts every tenant completed
//! requests of both classes.
//!
//! ```text
//! cargo run --release --example point_query_serving
//! TGM_SCALE=0.05 TGM_WORKERS=2 cargo run --release --example point_query_serving
//! ```
//!
//! Environment knobs: `TGM_SCALE` (default 0.1), `TGM_WORKERS` (default
//! 4), plus the scheduler's `TGM_QOS` / `TGM_QOS_DEPTH`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tgm::coordinator::{MultiTenantIngestor, Profiler};
use tgm::graph::{DGData, PointQuery, SealPolicy};
use tgm::hooks::{RecipeRegistry, RECIPE_TGB_LINK};
use tgm::io::gen;
use tgm::io::stream::ReplaySource;
use tgm::loader::{BatchBy, RequestClass, ServingPool, StreamConfig};
use tgm::serving::{ServingConfig, TenantId, TenantRouter};
use tgm::TgmError;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// In-flight point queries one tenant keeps pipelined at once.
const WINDOW: usize = 8;

fn main() -> tgm::Result<()> {
    let scale = env_f64("TGM_SCALE", 0.1);
    let workers = env_usize("TGM_WORKERS", 4).max(1);
    let tenants: [(&str, u32); 2] = [("bronze", 1), ("gold", 3)];

    let mut datasets: Vec<(TenantId, DGData)> = Vec::new();
    for (i, (name, weight)) in tenants.iter().enumerate() {
        let data = gen::by_name("wiki", scale, 42 + i as u64)?;
        println!(
            "  {name:<8} weight {weight}, {} edge events to ingest",
            data.storage().num_edges()
        );
        datasets.push((TenantId::from(*name), data));
    }

    let mut router = TenantRouter::new();
    for ((id, data), (_, weight)) in datasets.iter().zip(&tenants) {
        router.add_primary(
            id.clone(),
            ServingConfig::in_memory(data.storage().num_nodes())
                .seal(SealPolicy::by_events(512))
                .compact_after(6)
                .granularity(data.storage().granularity())
                .qos_weight(*weight)
                .admission_cap(256),
        )?;
    }
    let router = Arc::new(router);
    let pool = ServingPool::new(workers);
    println!("mixed load over one {}-worker pool (weighted DRR):", pool.workers());

    let mut ingestor = MultiTenantIngestor::new(Arc::clone(&router), 512);
    for (id, data) in &datasets {
        ingestor.add_stream(id.clone(), ReplaySource::from_data(data))?;
    }

    let stop = AtomicBool::new(false);
    let per_tenant: Vec<(u64, u64, usize)> =
        std::thread::scope(|scope| -> tgm::Result<Vec<(u64, u64, usize)>> {
            // Ingest load: chunked append + publish for both tenants
            // until the streams drain, then release the serving loops.
            let ingest = scope.spawn(|| {
                let res = ingestor.run_to_completion();
                stop.store(true, Ordering::SeqCst);
                res
            });

            let mut joins = Vec::new();
            for (id, data) in &datasets {
                let router = Arc::clone(&router);
                let pool = &pool;
                let stop = &stop;
                let num_nodes = data.storage().num_nodes() as u64;

                // Batch-scan loop: full hooked passes, pinned per pass.
                let scan_router = Arc::clone(&router);
                let scans = scope.spawn(move || -> tgm::Result<usize> {
                    let mut passes = 0usize;
                    loop {
                        let finished = stop.load(Ordering::SeqCst);
                        let handle = scan_router.tenant(id)?;
                        if handle.published_generation().is_none() {
                            if finished {
                                return Ok(passes);
                            }
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        }
                        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK)?;
                        manager.activate("val")?;
                        let mut stream = scan_router.serve(
                            pool,
                            id,
                            BatchBy::Events(200),
                            &mut manager,
                            StreamConfig::default(),
                        )?;
                        while let Some(b) = stream.next() {
                            b?;
                        }
                        passes += 1;
                        if finished {
                            return Ok(passes);
                        }
                    }
                });

                // Point-query loop: a pipelined window of small reads;
                // Backpressure sheds load by draining the window.
                let points = scope.spawn(move || -> tgm::Result<(u64, u64)> {
                    let handle = Arc::clone(router.tenant(id)?);
                    let mut outstanding = VecDeque::new();
                    let (mut completed, mut shed) = (0u64, 0u64);
                    let mut i = 0u64;
                    while !stop.load(Ordering::SeqCst) {
                        let Some(snap) = handle.pin().ok() else {
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        };
                        let end = snap.end_time() + 1;
                        let node = ((i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % num_nodes) as u32;
                        let query = if i % 4 == 0 {
                            let dst = ((i / 4 + 1) % num_nodes) as u32;
                            PointQuery::EdgeLookup { src: node, dst, t: end }
                        } else {
                            PointQuery::NeighborsBefore { node, t: end, k: 10 }
                        };
                        i += 1;
                        match handle.submit_query(pool, query) {
                            Ok(ticket) => outstanding.push_back(ticket),
                            // Admission cap hit: shed by draining the
                            // pipeline, never by spinning on submit.
                            Err(TgmError::Backpressure(_)) => shed += 1,
                            Err(e) => return Err(e),
                        }
                        if outstanding.len() >= WINDOW {
                            if let Some(t) = outstanding.pop_front() {
                                t.wait()?;
                                completed += 1;
                            }
                        }
                    }
                    for t in outstanding {
                        t.wait()?;
                        completed += 1;
                    }
                    Ok((completed, shed))
                });
                joins.push((scans, points));
            }

            let rows = ingest.join().expect("ingestor panicked")?;
            println!("\ningestion done: {} per-tenant cycle reports", rows.len());
            let mut out = Vec::new();
            for (scans, points) in joins {
                let passes = scans.join().expect("scan loop panicked")?;
                let (completed, shed) = points.join().expect("point loop panicked")?;
                out.push((completed, shed, passes));
            }
            Ok(out)
        })?;

    // Per-class accounting from the pool's scheduler, per tenant: under
    // mixed load every tenant must complete requests of BOTH classes —
    // point queries were never starved behind scan backlogs, and
    // admission control shed load instead of deadlocking.
    let stats = pool.qos_stats();
    for ((id, _), (completed, shed, passes)) in datasets.iter().zip(&per_tenant) {
        let points = stats.completed(id.as_str(), RequestClass::PointQuery);
        let scans = stats.completed(id.as_str(), RequestClass::BatchScan);
        println!(
            "  {:<8} {points:>7} point queries ({shed} shed), {scans:>5} batch jobs \
             across {passes} passes",
            id.to_string()
        );
        assert!(points > 0, "tenant {id} completed no point queries");
        assert!(scans > 0, "tenant {id} completed no batch jobs");
        assert_eq!(*completed, points, "ticket accounting must match pool stats");
    }

    let mut profiler = Profiler::new();
    profiler.add_request_latency("point", &stats.point);
    profiler.add_request_latency("scan", &stats.scan);
    print!("{profiler}");
    println!("point_query_serving OK");
    Ok(())
}
