//! Replicated serving end to end: one durable primary, two WAL-tailing
//! read replicas, one shared pool — all behind the unified `ReadHandle`
//! API.
//!
//! The primary ingests a surrogate event stream (group-committed WAL,
//! background **tiered compaction** running mid-stream) while two
//! replicas bootstrap from its live directory — no lock contention, the
//! store's write protocol makes unlocked reads safe — and tail its WAL.
//! Concurrently, reader threads fan point queries out over
//! `TenantRouter::read_handles` (primary + both replicas, round-robin),
//! so a nonzero share of reads is served by replicas while the data is
//! still moving. A sampler records replica lag throughout.
//!
//! At exit the example asserts the replication contract:
//!
//! * both replicas **converge to the primary's exact generation** after
//!   ingest stops (bounded lag), surviving the mid-run compactions via
//!   run-replacement deltas — never a re-bootstrap;
//! * the converged replicas serve **byte-identical** snapshots and
//!   hooked batch streams (`ReadHandle::serve`) vs the primary;
//! * replicas answered a **nonzero** number of the fanned-out reads;
//! * replica metrics (`tgm_replica_lag_us`,
//!   `tgm_replica_applied_generation`, shipped-bytes counters) are
//!   scrapeable over the `/metrics` endpoint printed below.
//!
//! ```text
//! cargo run --release --example replicated_serving
//! TGM_SCALE=0.05 TGM_WORKERS=2 cargo run --release --example replicated_serving
//! ```
//!
//! Environment knobs: `TGM_SCALE` (default 0.1), `TGM_WORKERS` (default
//! 2), `TGM_METRICS_ADDR` (default ephemeral localhost).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};
use tgm::graph::PointQuery;
use tgm::hooks::{MaterializedBatch, RecipeRegistry, RECIPE_TGB_LINK};
use tgm::io::gen;
use tgm::io::stream::{EventSource, ReplaySource};
use tgm::loader::{BatchBy, ServingPool, StreamConfig};
use tgm::obs::ObsServer;
use tgm::persist::CompactorConfig;
use tgm::serving::{ReadHandle, ServingConfig, TenantId, TenantRouter};
use tgm::TgmError;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Full structural equality between two hooked batch streams: windows,
/// seed columns, and every attribute tensor byte-for-byte.
fn assert_batches_identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
    assert_eq!(a.len(), b.len(), "batch counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!((x.start, x.end), (y.start, y.end), "batch {i} window");
        assert_eq!(x.src, y.src, "batch {i} src");
        assert_eq!(x.dst, y.dst, "batch {i} dst");
        assert_eq!(x.ts, y.ts, "batch {i} ts");
        assert_eq!(x.edge_indices, y.edge_indices, "batch {i} edge indices");
        assert_eq!(x.attr_names(), y.attr_names(), "batch {i} attribute sets");
        for name in x.attr_names() {
            assert_eq!(
                x.get(name).unwrap(),
                y.get(name).unwrap(),
                "batch {i} attribute `{name}` differs"
            );
        }
    }
}

fn main() -> tgm::Result<()> {
    let scale = env_f64("TGM_SCALE", 0.1);
    let workers = env_usize("TGM_WORKERS", 2).max(1);
    let data = gen::by_name("wiki", scale, 11)?;
    let num_nodes = data.storage().num_nodes();

    // Replica metrics land in the same registry as everything else, so
    // the standard endpoint serves them.
    let server = match ObsServer::from_env() {
        Some(s) => s,
        None => ObsServer::serve("127.0.0.1:0")
            .map_err(|e| TgmError::Io(format!("failed to bind metrics endpoint: {e}")))?,
    };
    println!("metrics endpoint: http://{}/metrics", server.local_addr());

    let base =
        std::env::temp_dir().join(format!("tgm_replicated_serving_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let dir = base.join("primary");

    let mut router = TenantRouter::new();
    let id = TenantId::from("wiki");
    let primary = router.add_primary(
        id.clone(),
        ServingConfig::primary(num_nodes, &dir)
            .seal(tgm::graph::SealPolicy::by_events(256))
            // The background compactor attached below owns compaction.
            .compact_after(usize::MAX)
            .granularity(data.storage().granularity())
            .group_commit(),
    )?;

    // Seed a quarter of the stream so the replicas bootstrap real
    // segment files, then let tiered compaction run for the whole ride.
    let mut source = ReplaySource::from_data(&data);
    let total = source.len();
    primary.ingest(source.next_chunk(total / 4))?;
    primary.publish()?;
    let compactor =
        primary.attach_compactor(CompactorConfig { min_sealed: 3, ..Default::default() });

    let mut replicas = Vec::new();
    for r in 0..2 {
        let replica = router.add_replica(
            id.clone(),
            ServingConfig::replica(&dir, base.join(format!("r{r}")))
                .poll_interval(Duration::from_millis(1)),
        )?;
        let b = replica.bootstrap_report();
        println!(
            "replica r{r} bootstrapped: gen {}, {} segments ({} reused), {} bytes shipped, \
             {} WAL events replayed, {:.1} ms",
            b.generation,
            b.segments,
            b.reused_segments,
            b.shipped_bytes,
            b.replayed_events,
            b.duration_us as f64 / 1e3
        );
        replicas.push(replica);
    }

    let pool = ServingPool::new(workers);
    println!(
        "serving {} events over a {}-worker pool, 1 primary + {} replicas:",
        total,
        pool.workers(),
        replicas.len()
    );

    let stop = AtomicBool::new(false);
    // Reads completed per handle slot (0 = primary, then replicas).
    let served: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
    let shed = AtomicU64::new(0);
    let max_lag_us = AtomicU64::new(0);

    std::thread::scope(|scope| -> tgm::Result<()> {
        // Sustained ingest: the rest of the stream, group-committed and
        // published per chunk, with compaction landing mid-run.
        let ingest = scope.spawn(|| {
            let res = (|| -> tgm::Result<usize> {
                let mut n = 0usize;
                loop {
                    let chunk = source.next_chunk(256);
                    if chunk.is_empty() {
                        return Ok(n);
                    }
                    n += primary.ingest(chunk)?;
                    primary.publish()?;
                }
            })();
            // Release the serving loops even when ingest fails, or the
            // scope would never join.
            stop.store(true, Ordering::SeqCst);
            res
        });

        // Lag sampler: the replication lag the tailers report while the
        // stream is moving.
        let sampler = scope.spawn(|| {
            while !stop.load(Ordering::SeqCst) {
                for r in &replicas {
                    if let Some(lag) = r.lag_us() {
                        max_lag_us.fetch_max(lag, Ordering::Relaxed);
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });

        // Read fan-out: round-robin point queries over every handle the
        // router knows for this id (primary + replicas), all under one
        // pool. Admission control sheds, never deadlocks.
        let readers: Vec<_> = (0..2)
            .map(|t| {
                let router = &router;
                let pool = &pool;
                let id = &id;
                let stop = &stop;
                let served = &served;
                let shed = &shed;
                scope.spawn(move || -> tgm::Result<()> {
                    let mut i = t as u64;
                    while !stop.load(Ordering::SeqCst) {
                        let handles = router.read_handles(id);
                        let slot = (i % handles.len() as u64) as usize;
                        let h = &handles[slot];
                        let node =
                            ((i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % num_nodes as u64) as u32;
                        let Ok(snap) = h.pin() else {
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        };
                        let q = PointQuery::NeighborsBefore {
                            node,
                            t: snap.end_time() + 1,
                            k: 8,
                        };
                        match h.query(pool, q) {
                            Ok(_) => {
                                served[slot].fetch_add(1, Ordering::Relaxed);
                            }
                            Err(TgmError::Backpressure(_)) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => return Err(e),
                        }
                        i += 1;
                    }
                    Ok(())
                })
            })
            .collect();

        let ingested = ingest.join().expect("ingest thread panicked")?;
        sampler.join().expect("sampler panicked");
        for r in readers {
            r.join().expect("reader panicked")?;
        }
        println!("ingest done: {ingested} events streamed in while replicas tailed");
        Ok(())
    })?;

    // Stop compaction, publish the final generation, and require both
    // replicas to converge to it (bounded lag after the stream drains).
    // Give the compactor a moment to finish a round first so even a
    // fast CI-scale run demonstrably compacts mid-stream.
    let t0 = Instant::now();
    while compactor.compactions() == 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(5));
    }
    let rounds = compactor.compactions();
    if let Some(e) = compactor.last_error() {
        return Err(TgmError::Persist(format!("background compaction failed: {e}")));
    }
    compactor.stop();
    let final_snap = primary.publish()?;
    let target = final_snap.generation();
    let deadline = Instant::now() + Duration::from_secs(30);
    for (r, replica) in replicas.iter().enumerate() {
        while replica.published_generation() != Some(target) {
            assert!(
                Instant::now() < deadline,
                "replica r{r} stuck at {:?}, primary at {target}",
                replica.published_generation()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    // Byte-identical serving from every replica, batches included.
    let streamed = |h: &dyn ReadHandle| -> tgm::Result<Vec<MaterializedBatch>> {
        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK)?;
        manager.activate("val")?;
        h.serve(&pool, BatchBy::Events(200), &mut manager, StreamConfig::default())?
            .collect_all()
    };
    let reference = streamed(primary.as_ref())?;
    for (r, replica) in replicas.iter().enumerate() {
        let snap = replica.pin()?;
        assert_eq!(snap.generation(), target, "r{r} generation");
        assert_eq!(snap.edge_ts(), final_snap.edge_ts(), "r{r} timestamps");
        assert_eq!(snap.edge_feats(), final_snap.edge_feats(), "r{r} features");
        assert_batches_identical(&reference, &streamed(replica.as_ref())?);
        println!(
            "replica r{r}: converged at gen {target}, {} bytes shipped total, {} resyncs, \
             {} segments ({} mmap-served), {} reads answered",
            replica.shipped_bytes(),
            replica.resyncs(),
            snap.num_segments(),
            snap.num_mapped_segments(),
            served[r + 1].load(Ordering::Relaxed)
        );
    }

    let replica_reads: u64 = served[1..].iter().map(|c| c.load(Ordering::Relaxed)).sum();
    let primary_reads = served[0].load(Ordering::Relaxed);
    println!(
        "read fan-out: {primary_reads} primary + {replica_reads} replica reads \
         ({} shed), {rounds} mid-run compaction rounds, max sampled lag {:.1} ms",
        shed.load(Ordering::Relaxed),
        max_lag_us.load(Ordering::Relaxed) as f64 / 1e3
    );
    assert!(replica_reads > 0, "replicas must serve a share of the reads");
    assert!(rounds > 0, "the run must exercise mid-stream compaction");

    for replica in &replicas {
        replica.stop_tailer();
    }
    drop(router);
    drop(primary);
    drop(replicas);
    let _ = std::fs::remove_dir_all(&base);
    println!("replicated_serving OK");
    Ok(())
}
