//! The observability layer end to end: multi-tenant ingest +
//! point-query load with the metrics registry, trace ring, and scrape
//! endpoint all live.
//!
//! Two tenants ingest concurrently (chunked append + publish through
//! `MultiTenantIngestor`) while per-tenant serving loops drive batch
//! scans and pipelined point queries over one shared `ServingPool`.
//! Every layer reports into the process-global registry and trace ring
//! as it works — no wiring in this file beyond reading the results:
//!
//! * a `/metrics` endpoint serves Prometheus text for the whole run
//!   (bound to `TGM_METRICS_ADDR`, or an ephemeral localhost port when
//!   unset — this example always serves); the example scrapes itself
//!   once over plain TCP to show the loop closes;
//! * at exit it prints the final registry snapshot (counters, gauges,
//!   and histogram percentiles) and the 10 slowest trace spans.
//!
//! ```text
//! cargo run --release --example observability
//! TGM_TRACE=1 TGM_TRACE_SLOW_US=1000 cargo run --release --example observability
//! ```
//!
//! Environment knobs: `TGM_TENANTS` (default 2), `TGM_SCALE` (default
//! 0.05), `TGM_WORKERS` (default 2), `TGM_METRICS_ADDR` (default
//! `127.0.0.1:0`), plus `TGM_TRACE` / `TGM_TRACE_SLOW_US` for the
//! stderr slow-op log.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tgm::coordinator::{MultiTenantIngestor, Profiler};
use tgm::graph::{DGData, PointQuery, SealPolicy};
use tgm::hooks::{RecipeRegistry, RECIPE_TGB_LINK};
use tgm::io::gen;
use tgm::io::stream::ReplaySource;
use tgm::loader::{BatchBy, ServingPool, StreamConfig};
use tgm::obs::{self, MetricValue, ObsServer};
use tgm::serving::{TenantConfig, TenantId, TenantRouter};
use tgm::TgmError;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// In-flight point queries one tenant keeps pipelined at once.
const WINDOW: usize = 8;

/// One plain-TCP `GET /metrics` against our own endpoint.
fn self_scrape(addr: std::net::SocketAddr) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    write!(stream, "GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut body = String::new();
    stream.read_to_string(&mut body)?;
    Ok(body.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or(body))
}

fn main() -> tgm::Result<()> {
    let tenants = env_usize("TGM_TENANTS", 2).clamp(1, 8);
    let scale = env_f64("TGM_SCALE", 0.05);
    let workers = env_usize("TGM_WORKERS", 2).max(1);

    // This example is about observability, so the endpoint is always
    // on: TGM_METRICS_ADDR when set, else an ephemeral localhost port.
    let server = match ObsServer::from_env() {
        Some(s) => s,
        None => ObsServer::serve("127.0.0.1:0")
            .map_err(|e| TgmError::Io(format!("failed to bind metrics endpoint: {e}")))?,
    };
    println!("metrics endpoint: http://{}/metrics", server.local_addr());

    let names = ["wiki", "reddit", "lastfm", "genre"];
    let mut datasets: Vec<(TenantId, DGData)> = Vec::with_capacity(tenants);
    for i in 0..tenants {
        let name = names[i % names.len()];
        let data = gen::by_name(name, scale, 42 + i as u64)?;
        datasets.push((TenantId::from(format!("{name}-{i}")), data));
    }

    let mut router = TenantRouter::new();
    for (i, (id, data)) in datasets.iter().enumerate() {
        router.add_tenant(
            id.clone(),
            TenantConfig::new(data.storage().num_nodes())
                .with_seal(SealPolicy::by_events(256 * (i + 1)))
                .with_compact_after(4)
                .with_granularity(data.storage().granularity()),
        )?;
    }
    let router = Arc::new(router);
    let pool = ServingPool::new(workers);

    let mut ingestor = MultiTenantIngestor::new(Arc::clone(&router), 256);
    for (id, data) in &datasets {
        ingestor.add_stream(id.clone(), ReplaySource::from_data(data))?;
        println!("  {:<12} {} edge events", id.to_string(), data.storage().num_edges());
    }

    let done = AtomicBool::new(false);
    std::thread::scope(|scope| -> tgm::Result<()> {
        let ingest = scope.spawn(|| {
            let res = ingestor.run_to_completion();
            done.store(true, Ordering::SeqCst);
            res
        });

        let mut joins = Vec::new();
        for (id, data) in &datasets {
            let router = Arc::clone(&router);
            let pool = &pool;
            let done = &done;
            let num_nodes = data.storage().num_nodes() as u64;

            // Batch-scan loop: full hooked passes until ingest drains.
            let scan_router = Arc::clone(&router);
            joins.push(scope.spawn(move || -> tgm::Result<()> {
                loop {
                    let finished = done.load(Ordering::SeqCst);
                    let handle = scan_router.tenant(id)?;
                    if handle.published_generation().is_none() {
                        if finished {
                            return Ok(());
                        }
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK)?;
                    manager.activate("val")?;
                    let mut stream = scan_router.serve(
                        pool,
                        id,
                        BatchBy::Events(200),
                        &mut manager,
                        StreamConfig::default(),
                    )?;
                    while let Some(b) = stream.next() {
                        b?;
                    }
                    if finished {
                        return Ok(());
                    }
                }
            }));

            // Point-query loop: pipelined window of small reads;
            // Backpressure sheds load by draining the window.
            joins.push(scope.spawn(move || -> tgm::Result<()> {
                let handle = Arc::clone(router.tenant(id)?);
                let mut outstanding = VecDeque::new();
                let mut i = 0u64;
                while !done.load(Ordering::SeqCst) {
                    let Some(snap) = handle.pin().ok() else {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    };
                    let end = snap.end_time() + 1;
                    let node = ((i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % num_nodes) as u32;
                    let query = if i % 4 == 0 {
                        let dst = ((i / 4 + 1) % num_nodes) as u32;
                        PointQuery::EdgeLookup { src: node, dst, t: end }
                    } else {
                        PointQuery::NeighborsBefore { node, t: end, k: 10 }
                    };
                    i += 1;
                    match handle.submit_query(pool, query) {
                        Ok(ticket) => outstanding.push_back(ticket),
                        Err(TgmError::Backpressure(_)) => {}
                        Err(e) => return Err(e),
                    }
                    if outstanding.len() >= WINDOW {
                        if let Some(t) = outstanding.pop_front() {
                            t.wait()?;
                        }
                    }
                }
                for t in outstanding {
                    t.wait()?;
                }
                Ok(())
            }));
        }

        let rows = ingest.join().expect("ingestor panicked")?;
        println!("ingestion done: {} per-tenant cycle reports", rows.len());
        for j in joins {
            j.join().expect("serving loop panicked")?;
        }
        Ok(())
    })?;

    // Scrape our own endpoint once: the same bytes Prometheus would see.
    let body = self_scrape(server.local_addr())
        .map_err(|e| TgmError::Io(format!("self-scrape failed: {e}")))?;
    let samples = obs::parse_prometheus(&body);
    println!(
        "\nself-scrape: {} bytes of Prometheus text, {} samples across {} families",
        body.len(),
        samples.len(),
        {
            let mut fams: Vec<&str> = body
                .lines()
                .filter_map(|l| l.strip_prefix("# TYPE "))
                .filter_map(|l| l.split_whitespace().next())
                .collect();
            fams.dedup();
            fams.len()
        }
    );
    assert!(
        samples.iter().any(|s| s.name == "tgm_ingest_events_total" && s.value > 0.0),
        "scrape must report ingested events"
    );
    assert!(
        samples.iter().any(|s| s.name == "tgm_point_latency_us_count" && s.value > 0.0),
        "scrape must report completed point queries"
    );

    // Final registry snapshot: one compact row per series.
    let snap = obs::registry().snapshot();
    println!("\nfinal registry snapshot ({} series):", snap.metrics.len());
    for m in &snap.metrics {
        let labels = if m.labels.is_empty() {
            String::new()
        } else {
            let pairs: Vec<String> =
                m.labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            format!("{{{}}}", pairs.join(","))
        };
        match &m.value {
            MetricValue::Counter(v) => println!("  {}{labels} {v}", m.name),
            MetricValue::Gauge(v) => println!("  {}{labels} {v}", m.name),
            MetricValue::Histogram(h) => println!(
                "  {}{labels} n={} p50={}us p99={}us max={}us",
                m.name,
                h.count(),
                h.percentile_us(50.0),
                h.percentile_us(99.0),
                h.max_us(),
            ),
        }
    }

    // The profiler folds the same snapshot into its familiar report.
    let mut profiler = Profiler::new();
    profiler.fold_registry(&snap);
    println!();
    print!("{profiler}");

    // The 10 slowest spans the trace ring retained.
    let mut spans = obs::trace_ring().snapshot();
    spans.retain(|e| e.dur_us > 0);
    spans.sort_by(|a, b| b.dur_us.cmp(&a.dur_us));
    println!("\n10 slowest trace spans:");
    for e in spans.iter().take(10) {
        println!(
            "  {:>8}us {}.{} tenant={} {}",
            e.dur_us,
            e.subsystem,
            e.kind,
            e.tenant.as_ref().map(|t| t.as_str()).unwrap_or("-"),
            e.detail,
        );
    }
    assert!(!spans.is_empty(), "the trace ring must have retained spans");

    drop(server);
    println!("\nobservability OK");
    Ok(())
}
