//! Time-driven training off a live DTDG materialized view: ingest a
//! CTDG event stream while training per-hour on its discretized form.
//!
//! Replays the Wikipedia surrogate's event log into a `SegmentedStorage`
//! with an **hourly materialized view** attached (`ReduceOp::Mean`).
//! Every seal discretizes just the newly sealed segment and merges it
//! into the view — no rescans — and the trainer's time-driven cycle
//! trains one batch per newly **completed** hour bucket, so each bucket
//! is seen exactly once, with its final reduced features. The trailing
//! partial bucket is held back until the stream provably drains. The
//! model is EdgeBank scored prequentially (test-then-train MRR against
//! deterministic eval negatives) over the coarse edges.
//!
//! ```text
//! cargo run --release --example time_driven_training
//! ```

use std::sync::Arc;
use tgm::coordinator::{StreamingConfig, StreamingTrainer};
use tgm::graph::{discretize, ReduceOp, SealPolicy, SegmentedStorage};
use tgm::hooks::batch::attr;
use tgm::hooks::negatives::EvalNegativeSampler;
use tgm::hooks::{DstRange, HookManager};
use tgm::io::gen;
use tgm::io::stream::ReplaySource;
use tgm::models::{EdgeBank, EdgeBankMode};
use tgm::util::{stats, TimeGranularity};

fn main() -> tgm::Result<()> {
    // The "live" CTDG stream: the wiki surrogate replayed in arrival order.
    let data = gen::by_name("wiki", 0.2, 42)?;
    println!("stream: {} ({} edge events)", data.stats(), data.storage().num_edges());

    let store = SegmentedStorage::new(
        data.storage().num_nodes(),
        SealPolicy::by_events(512),
    )
    .with_granularity(data.storage().granularity());
    let source = ReplaySource::from_data(&data);

    let mut manager = HookManager::new();
    manager.register_stateless(
        "stream",
        Arc::new(EvalNegativeSampler::new(DstRange::InferFromData, 20, 0)),
    );

    let cfg = StreamingConfig {
        ingest_chunk: 1024,
        batch_events: 256,
        compact_after: 6,
        train_key: "stream".into(),
    };
    let mut trainer = StreamingTrainer::new(store, source, cfg);
    // The derived layer: an hourly DTDG view maintained incrementally on
    // every seal the ingest loop triggers.
    let view = trainer.attach_dtdg(TimeGranularity::Hour, ReduceOp::Mean)?;

    let mut bank = EdgeBank::new(EdgeBankMode::Unlimited);
    let mut rrs: Vec<f64> = Vec::new();
    fn on_batch(
        batch: &tgm::hooks::MaterializedBatch,
        rrs: &mut Vec<f64>,
        bank: &mut EdgeBank,
    ) -> tgm::Result<()> {
        let negs = batch.get(attr::EVAL_NEGATIVES)?;
        let q = negs.shape()[1];
        let nv = negs.as_i32()?;
        for i in 0..batch.num_edges() {
            // Test-then-train on the coarse edge: score against the
            // pre-update bank, then learn it.
            let pos = bank.score(batch.src[i], batch.dst[i], batch.ts[i]);
            let neg: Vec<f64> = (0..q)
                .map(|j| bank.score(batch.src[i], nv[i * q + j] as u32, batch.ts[i]))
                .collect();
            rrs.push(stats::reciprocal_rank(pos, &neg));
        }
        bank.update(&batch.src, &batch.dst, &batch.ts);
        Ok(())
    }

    loop {
        let mut cycle_rrs: Vec<f64> = Vec::new();
        let report = trainer.run_cycle_time_driven(&mut manager, &view, |b| {
            on_batch(b, &mut cycle_rrs, &mut bank)
        })?;
        let Some(report) = report else { break };
        let cycle_mrr = if cycle_rrs.is_empty() {
            "     -".to_string()
        } else {
            format!("{:.4}", stats::mean(&cycle_rrs))
        };
        rrs.extend(cycle_rrs);
        println!(
            "cycle {:>3}: ingested {:>5}  hours [{:>8}, {:>8})  batches {:>3}  \
             view gen {:>3}  complete to {:>8}  cycle MRR {}",
            report.cycle,
            report.ingested,
            report.window.0,
            report.window.1,
            report.batches,
            report.generation,
            view.complete_until().map_or("-".into(), |t| t.to_string()),
            cycle_mrr,
        );
    }
    // Flush the trailing partial hour (its reduction is final now that
    // the stream is provably drained).
    let mut tail_rrs: Vec<f64> = Vec::new();
    if let Some(r) = trainer.finish_time_driven(&mut manager, &view, |b| {
        on_batch(b, &mut tail_rrs, &mut bank)
    })? {
        println!("tail : hours [{:>8}, {:>8})  batches {:>3}", r.window.0, r.window.1, r.batches);
    }
    rrs.extend(tail_rrs);

    // Every coarse edge of the fully-discretized stream was scored
    // exactly once: the incremental view tiled it without gaps or overlap.
    let full = discretize(
        &trainer.store_mut().snapshot()?,
        TimeGranularity::Hour,
        ReduceOp::Mean,
    )?;
    assert_eq!(rrs.len(), full.num_edges(), "one score per coarse edge, exactly once");
    println!(
        "\ntrained {} hourly coarse edges over {} cycles ({} view refreshes) | \
         prequential MRR = {:.4} | bank size {}",
        rrs.len(),
        trainer.cycles(),
        view.refreshes(),
        stats::mean(&rrs),
        bank.len()
    );
    println!("time_driven_training OK");
    Ok(())
}
