//! RQ1 (Table 7): dynamic *graph* property prediction — will the next
//! daily snapshot see more edges? A task only expressible with native
//! iterate-by-time support. Compares the Persistent Forecast baseline
//! against snapshot models (T-GCN, GCLSTM, GCN), reporting AUC.

use tgm::coordinator::{evaluate_persistent_graph, Pipeline, PipelineConfig, Split};
use tgm::graph::{discretize, DGData, ReduceOp, Task};
use tgm::io::gen;
use tgm::runtime::XlaEngine;
use tgm::util::TimeGranularity;

fn main() -> tgm::Result<()> {
    let engine = XlaEngine::cpu(
        std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let scale: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.25);

    for ds in ["wiki", "reddit"] {
        let raw = gen::by_name(ds, scale, 3)?;
        println!("\n=== {ds} === ({})", raw.stats());
        let splits = raw.split()?;
        let pf = evaluate_persistent_graph(&splits.test, TimeGranularity::Day)?;
        println!("[P.F.]         AUC = {:.4} over {} snapshots", pf.auc.unwrap(), pf.queries);

        for model in ["tgcn_graph", "gclstm_graph", "gcn_graph"] {
            // Hourly-discretized substrate keeps DTDG inputs within the
            // dtdg512 profile while preserving the daily growth signal.
            let data = DGData::new(
                discretize(raw.storage(), TimeGranularity::Hour, ReduceOp::Count)?,
                ds,
                Task::GraphProperty,
            );
            let mut cfg = PipelineConfig::new(model);
            cfg.granularity = TimeGranularity::Day;
            let mut pipe = Pipeline::new(&engine, data, cfg)?;
            for _ in 0..3 {
                pipe.train_epoch()?;
            }
            let r = pipe.evaluate(Split::Test)?;
            println!("[{model:<13}] AUC = {:.4} over {} snapshots", r.auc.unwrap(), r.queries);
        }
    }
    Ok(())
}
