//! Sharded multi-tenant serving: several tenant graphs ingesting and
//! serving **concurrently** from one process.
//!
//! One ingestor thread drives round-robin per-tenant ingest cycles
//! (`MultiTenantIngestor`): each cycle appends a chunk of each tenant's
//! event stream through that tenant's own `SegmentedStorage` writer
//! (with its own `SealPolicy` and compaction cadence) and publishes a
//! fresh snapshot generation. Meanwhile one serving thread per tenant
//! runs full evaluation passes in a loop: every pass **pins** the
//! tenant's latest published generation and streams hooked batches over
//! one shared `ServingPool`, so all tenants' materialization jobs
//! multiplex over a single fixed set of workers. A pass that pinned
//! generation *G* is untouched by the writer publishing *G+1* mid-pass —
//! the next pass picks the newer generation up.
//!
//! Each serving pass also fires a small burst of point queries against
//! the pinned snapshot, so both request classes flow through the pool.
//! With `TGM_METRICS_ADDR` set (e.g. `127.0.0.1:0`), the process serves
//! a Prometheus `/metrics` endpoint mid-run and prints the bound
//! address, so a smoke test can scrape ingest/serving/persist metric
//! families while load is live.
//!
//! ```text
//! cargo run --release --example multi_tenant_serving
//! TGM_TENANTS=3 TGM_SCALE=0.05 cargo run --release --example multi_tenant_serving
//! TGM_METRICS_ADDR=127.0.0.1:0 cargo run --release --example multi_tenant_serving
//! ```
//!
//! Environment knobs: `TGM_TENANTS` (default 3), `TGM_SCALE` (default
//! 0.1), `TGM_WORKERS` (default 4), `TGM_METRICS_ADDR` (off by
//! default).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use tgm::coordinator::MultiTenantIngestor;
use tgm::graph::{DGData, PointQuery, SealPolicy};
use tgm::hooks::{RecipeRegistry, RECIPE_TGB_LINK};
use tgm::io::gen;
use tgm::io::stream::ReplaySource;
use tgm::loader::{BatchBy, ServingPool, StreamConfig};
use tgm::obs::ObsServer;
use tgm::serving::{TenantConfig, TenantId, TenantRouter};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> tgm::Result<()> {
    let tenants = env_usize("TGM_TENANTS", 3).clamp(1, 8);
    let scale = env_f64("TGM_SCALE", 0.1);
    let workers = env_usize("TGM_WORKERS", 4).max(1);

    // Opt-in scrape endpoint; the printed line is what smoke tests
    // parse to curl an ephemeral port mid-run.
    let obs = ObsServer::from_env();
    if let Some(s) = &obs {
        println!("metrics endpoint: http://{}/metrics", s.local_addr());
    }

    // Each tenant is its own surrogate graph (distinct dataset + seed).
    let names = ["wiki", "reddit", "lastfm", "genre"];
    let mut datasets: Vec<(TenantId, DGData)> = Vec::with_capacity(tenants);
    for i in 0..tenants {
        let name = names[i % names.len()];
        let data = gen::by_name(name, scale, 42 + i as u64)?;
        datasets.push((TenantId::from(format!("{name}-{i}")), data));
    }

    // Per-tenant policies: staggered seal thresholds and one shared pool.
    let mut router = TenantRouter::new();
    for (i, (id, data)) in datasets.iter().enumerate() {
        router.add_tenant(
            id.clone(),
            TenantConfig::new(data.storage().num_nodes())
                .with_seal(SealPolicy::by_events(256 * (i + 1)))
                .with_compact_after(6)
                .with_granularity(data.storage().granularity()),
        )?;
    }
    let router = Arc::new(router);
    let pool = ServingPool::new(workers);

    let mut ingestor = MultiTenantIngestor::new(Arc::clone(&router), 512);
    for (id, data) in &datasets {
        ingestor.add_stream(id.clone(), ReplaySource::from_data(data))?;
    }

    println!(
        "serving {} tenants over one {}-worker pool:",
        datasets.len(),
        pool.workers()
    );
    for (id, data) in &datasets {
        println!("  {:<12} {} edge events", id.to_string(), data.storage().num_edges());
    }

    let done = AtomicBool::new(false);
    let total_batches = AtomicUsize::new(0);
    let total_points = AtomicUsize::new(0);

    let per_tenant: Vec<(usize, usize)> =
        std::thread::scope(|scope| -> tgm::Result<Vec<(usize, usize)>> {
        // Ingestor: cycles until every tenant's stream is drained. The
        // done flag is raised even on error so servers never hang.
        let ingest = scope.spawn(|| {
            let res = ingestor.run_to_completion();
            done.store(true, Ordering::SeqCst);
            res
        });

        // One serving loop per tenant: pin latest -> full pass -> repeat;
        // the pass that starts after `done` serves the final generation.
        let mut servers = Vec::new();
        for (id, data) in &datasets {
            let router = Arc::clone(&router);
            let pool = &pool;
            let done = &done;
            let total_batches = &total_batches;
            let total_points = &total_points;
            let num_nodes = data.storage().num_nodes() as u64;
            servers.push(scope.spawn(move || -> tgm::Result<(usize, usize)> {
                let handle = Arc::clone(router.tenant(id)?);
                let mut passes = 0usize;
                let mut final_edges = 0usize;
                let mut qi = 0u64;
                loop {
                    // Read the flag BEFORE pinning: if ingestion had
                    // already finished, this pin observes the final
                    // publication and the pass below is the last word.
                    let finished = done.load(Ordering::SeqCst);
                    if handle.published_generation().is_none() {
                        if finished {
                            // Drained without a single publication: the
                            // pin error is the real story.
                            router.pin(id)?;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        continue;
                    }
                    let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK)?;
                    manager.activate("val")?;
                    let mut stream = router.serve(
                        pool,
                        id,
                        BatchBy::Events(200),
                        &mut manager,
                        StreamConfig::default(),
                    )?;
                    let mut edges = 0usize;
                    let mut batches = 0usize;
                    while let Some(b) = stream.next() {
                        let b = b?;
                        edges += b.num_edges();
                        batches += 1;
                    }
                    total_batches.fetch_add(batches, Ordering::Relaxed);

                    // A small point-query burst against the same
                    // generation: both request classes share the pool,
                    // and the point-latency histogram fills for the
                    // mid-run scrape.
                    let snap = handle.pin()?;
                    let end = snap.end_time() + 1;
                    let mut tickets = Vec::with_capacity(16);
                    for _ in 0..16 {
                        let node =
                            ((qi.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % num_nodes) as u32;
                        qi += 1;
                        let query = PointQuery::NeighborsBefore { node, t: end, k: 10 };
                        tickets.push(handle.submit_query(pool, query)?);
                    }
                    for t in tickets {
                        t.wait()?;
                        total_points.fetch_add(1, Ordering::Relaxed);
                    }

                    passes += 1;
                    final_edges = edges;
                    if finished {
                        return Ok((passes, final_edges));
                    }
                }
            }));
        }

        let rows = ingest.join().expect("ingestor panicked")?;
        let cycles = rows.iter().map(|r| &r.tenant).collect::<std::collections::HashSet<_>>();
        println!(
            "\ningestion done: {} report rows across {} tenants",
            rows.len(),
            cycles.len()
        );
        let mut out = Vec::new();
        for h in servers {
            out.push(h.join().expect("server panicked")?);
        }
        Ok(out)
    })?;

    for ((id, data), (passes, final_edges)) in datasets.iter().zip(&per_tenant) {
        println!(
            "  {:<12} {:>3} serving passes, final pass saw {:>6} edges",
            id.to_string(),
            passes,
            final_edges
        );
        assert_eq!(
            *final_edges,
            data.storage().num_edges(),
            "the post-ingestion pass must see the tenant's whole graph"
        );
    }
    println!(
        "served {} hooked batches and {} point queries total across all tenants",
        total_batches.load(Ordering::Relaxed),
        total_points.load(Ordering::Relaxed)
    );
    drop(obs);
    println!("multi_tenant_serving OK");
    Ok(())
}
