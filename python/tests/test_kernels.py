"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; assert_allclose against ref.py is
the core correctness signal for everything the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def arr(rng, shape, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)


# ---------------------------------------------------------------------
# time_encode
# ---------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.integers(1, 70),
    k=st.integers(1, 9),
    dt_dim=st.integers(1, 33),
    seed=st.integers(0, 2**31),
)
def test_time_encode_matches_ref(s, k, dt_dim, seed):
    rng = np.random.default_rng(seed)
    dt = arr(rng, (s, k), 0.0, 1000.0)
    w = arr(rng, (dt_dim,))
    b = arr(rng, (dt_dim,))
    got = kernels.time_encode(dt, w, b)
    want = ref.time_encode(dt, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    assert got.shape == (s, k, dt_dim)


def test_time_encode_rank1_and_rank3():
    rng = np.random.default_rng(0)
    w, b = arr(rng, (8,)), arr(rng, (8,))
    for shape in [(5,), (3, 4, 2)]:
        dt = arr(rng, shape, 0.0, 10.0)
        np.testing.assert_allclose(
            kernels.time_encode(dt, w, b), ref.time_encode(dt, w, b), atol=1e-4
        )


def test_time_encode_grads_match_ref():
    rng = np.random.default_rng(1)
    dt, w, b = arr(rng, (17,), 0.0, 5.0), arr(rng, (6,)), arr(rng, (6,))
    f_k = lambda w, b: kernels.time_encode(dt, w, b).sum()
    f_r = lambda w, b: ref.time_encode(dt, w, b).sum()
    gk = jax.grad(f_k, argnums=(0, 1))(w, b)
    gr = jax.grad(f_r, argnums=(0, 1))(w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------
# neighbor_attention
# ---------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.integers(1, 150),
    k=st.integers(1, 12),
    d=st.integers(1, 24),
    dv=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_neighbor_attention_matches_ref(s, k, d, dv, seed):
    rng = np.random.default_rng(seed)
    q = arr(rng, (s, d))
    kk = arr(rng, (s, k, d))
    v = arr(rng, (s, k, dv))
    mask = jnp.asarray(rng.integers(0, 2, (s, k)), jnp.float32)
    got = kernels.neighbor_attention(q, kk, v, mask)
    want = ref.neighbor_attention(q, kk, v, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_fully_masked_rows_are_zero():
    rng = np.random.default_rng(2)
    q, k, v = arr(rng, (4, 8)), arr(rng, (4, 5, 8)), arr(rng, (4, 5, 6))
    mask = jnp.zeros((4, 5), jnp.float32)
    out = kernels.neighbor_attention(q, k, v, mask)
    np.testing.assert_allclose(out, jnp.zeros((4, 6)), atol=1e-7)


def test_attention_single_neighbor_passthrough():
    # With one valid neighbor the output must equal its value row.
    rng = np.random.default_rng(3)
    q, k, v = arr(rng, (3, 4)), arr(rng, (3, 2, 4)), arr(rng, (3, 2, 5))
    mask = jnp.asarray([[1, 0], [1, 0], [0, 1]], jnp.float32)
    out = kernels.neighbor_attention(q, k, v, mask)
    expect = jnp.stack([v[0, 0], v[1, 0], v[2, 1]])
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_attention_is_permutation_invariant_under_mask():
    # Shuffling padded slots must not change the output.
    rng = np.random.default_rng(4)
    q = arr(rng, (1, 4))
    k = arr(rng, (1, 3, 4))
    v = arr(rng, (1, 3, 4))
    mask = jnp.asarray([[1, 1, 0]], jnp.float32)
    out1 = kernels.neighbor_attention(q, k, v, mask)
    # Replace the masked slot with garbage.
    k2 = k.at[0, 2].set(99.0)
    v2 = v.at[0, 2].set(-99.0)
    out2 = kernels.neighbor_attention(q, k2, v2, mask)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_attention_grads_flow():
    rng = np.random.default_rng(5)
    q, k, v = arr(rng, (6, 4)), arr(rng, (6, 3, 4)), arr(rng, (6, 3, 4))
    mask = jnp.ones((6, 3), jnp.float32)
    g = jax.grad(lambda q: kernels.neighbor_attention(q, k, v, mask).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    gr = jax.grad(lambda q: ref.neighbor_attention(q, k, v, mask).sum())(q)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 80),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = arr(rng, (m, k)), arr(rng, (k, n))
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_large_blocks():
    # Exercise the multi-tile grid path (beyond one 128x512x128 block).
    rng = np.random.default_rng(6)
    a, b = arr(rng, (300, 600)), arr(rng, (600, 200))
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-2
    )


def test_matmul_identity():
    rng = np.random.default_rng(7)
    a = arr(rng, (33, 33))
    np.testing.assert_allclose(kernels.matmul(a, jnp.eye(33)), a, atol=1e-5)


# ---------------------------------------------------------------------
# decayed_propagate (TPNet)
# ---------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(4, 64),
    b=st.integers(1, 16),
    r=st.integers(2, 24),
    seed=st.integers(0, 2**31),
)
def test_decayed_propagate_matches_ref(n, b, r, seed):
    rng = np.random.default_rng(seed)
    reps = arr(rng, (n, r))
    gamma = arr(rng, (n, 1), 0.0, 1.0)
    src = rng.integers(0, n, b)
    dst = rng.integers(0, n, b)
    oh_s = jax.nn.one_hot(src, n, dtype=jnp.float32)
    oh_d = jax.nn.one_hot(dst, n, dtype=jnp.float32)
    w = arr(rng, (r, r))
    got = kernels.decayed_propagate(reps, gamma, oh_s, oh_d, w)
    want = ref.decayed_propagate(reps, gamma, oh_s, oh_d, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decayed_propagate_no_edges_is_pure_decay():
    rng = np.random.default_rng(8)
    reps = arr(rng, (10, 4))
    gamma = jnp.full((10, 1), 0.5)
    oh = jnp.zeros((3, 10), jnp.float32)
    w = arr(rng, (4, 4))
    out = kernels.decayed_propagate(reps, gamma, oh, oh, w)
    np.testing.assert_allclose(out, 0.5 * reps, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
