"""AOT pipeline checks: manifest structure, state blobs, HLO text."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import aot, model


def test_registry_names_and_specs():
    reg = model.registry()
    expected = {
        "tgat_link", "tgn_link", "tgn_node", "graphmixer_link",
        "dygformer_link", "dygformer_node", "tpnet_link",
        "gcn_link", "gcn_node", "gcn_graph",
        "gclstm_link", "gclstm_node", "gclstm_graph",
        "tgcn_link", "tgcn_node", "tgcn_graph",
    }
    assert set(reg) == expected
    for name, d in reg.items():
        assert "train" in d["fns"] and "predict" in d["fns"], name
        for kind, spec in d["specs"].items():
            if kind in d["fns"]:
                names = [n for n, _, _ in spec]
                assert len(names) == len(set(names)), f"{name}.{kind} dup input"


def test_state_leaves_all_f32():
    reg = model.registry()
    for name in ("tgat_link", "tgn_link", "gclstm_graph", "tpnet_link"):
        leaves, _ = model.state_leaves(reg[name])
        for leaf in leaves:
            assert str(leaf.dtype) == "float32", f"{name}: {leaf.dtype}"


def test_emit_model_writes_consistent_blob():
    reg = model.registry()
    mdef = reg["gcn_graph"]  # smallest
    with tempfile.TemporaryDirectory() as d:
        lines = []
        aot.emit_model(mdef, d, lines, verbose=False)
        text = "\n".join(lines)
        assert "model gcn_graph profile dtdg512" in text
        assert "artifact train gcn_graph.train.hlo.txt" in text
        assert "artifact update gcn_graph.update.hlo.txt" in text
        # Blob length == sum of declared state sizes.
        sizes = 0
        for ln in lines:
            if ln.startswith("state f32"):
                dims = ln.split()[-1]
                n = 1 if dims == "-" else int(np.prod([int(x) for x in dims.split(",")]))
                sizes += n
        blob = open(os.path.join(d, "gcn_graph.state.bin"), "rb").read()
        assert len(blob) == 4 * sizes
        # HLO text parses as HLO (sanity: module header present).
        hlo = open(os.path.join(d, "gcn_graph.train.hlo.txt")).read()
        assert hlo.startswith("HloModule"), hlo[:60]
        assert "parameter" in hlo


def test_built_artifacts_manifest_if_present():
    """When `make artifacts` has run, validate the real manifest."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(art, "manifest.txt")
    if not os.path.exists(man):
        pytest.skip("artifacts not built")
    text = open(man).read()
    assert text.startswith("# TGM artifact manifest v1")
    models = [ln.split()[1] for ln in text.splitlines() if ln.startswith("model ")]
    assert len(models) == 16
    for m in models:
        for token in (f"{m}.state.bin", f"{m}.train.hlo.txt"):
            assert token in text
            assert os.path.exists(os.path.join(art, token)), token


def test_cli_list():
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--list"],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0
    assert "tgat_link" in out.stdout


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
