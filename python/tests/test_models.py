"""L2 model sanity: shapes, loss decrease, state threading, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import Dims, Profile
from compile.models import dygformer, graphmixer, snapshot, tgat, tgn, tpnet

P = Profile(name="tiny", n=32, b=8, k=4, k2=2, seq=8, c=3, d_edge=4, d_static=4, p=4)
D = Dims(embed=16, time=8, memory=16, heads=2, hidden=16, patch=4, rp=16, lr=1e-2, lr_snapshot=1e-2)


def all_defs():
    defs = [
        tgat.build(P, D),
        tgn.build(P, D, "link"),
        tgn.build(P, D, "node"),
        graphmixer.build(P, D),
        dygformer.build(P, D, "link"),
        dygformer.build(P, D, "node"),
        tpnet.build(P, D),
    ]
    for arch in ("gcn", "gclstm", "tgcn"):
        for task in ("link", "node", "graph"):
            defs.append(snapshot.build(P, D, arch, task))
    return defs


def mk_batch(spec, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, dt, shape in spec:
        if dt == "i32":
            out[name] = jnp.asarray(rng.integers(0, P.n, shape), jnp.int32)
        else:
            lo, hi = (0.0, 1.0)
            out[name] = jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)
    # Plausible targets: normalized distributions.
    if "target" in out:
        t = out["target"]
        out["target"] = t / t.sum(-1, keepdims=True)
    if "label" in out:
        out["label"] = jnp.round(out["label"])
    return out


@pytest.mark.parametrize("mdef", all_defs(), ids=lambda d: d["name"])
def test_train_step_runs_and_returns_finite_loss(mdef):
    state = mdef["init_state"](0)
    batch = mk_batch(mdef["specs"]["train"])
    state2, loss = mdef["fns"]["train"](state, batch)
    assert np.isfinite(float(loss)), mdef["name"]
    # State structure preserved.
    l1 = jax.tree_util.tree_leaves(state)
    l2 = jax.tree_util.tree_leaves(state2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("mdef", all_defs(), ids=lambda d: d["name"])
def test_predict_shapes(mdef):
    state = mdef["init_state"](0)
    out = mdef["fns"]["predict"](state, mk_batch(mdef["specs"]["predict"]))
    task = mdef["name"].split("_")[-1]
    if task == "link":
        assert out.shape == (P.b, P.c)
    elif task == "node":
        assert out.shape == (P.b, P.p)
    else:
        assert out.shape == (1,)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("mdef", all_defs(), ids=lambda d: d["name"])
def test_repeated_training_reduces_loss(mdef):
    # Same batch, 30 steps: loss must go down (overfit one batch).
    state = mdef["init_state"](0)
    batch = mk_batch(mdef["specs"]["train"], seed=1)
    train = jax.jit(mdef["fns"]["train"])
    first = None
    for i in range(30):
        state, loss = train(state, batch)
        if i == 0:
            first = float(loss)
    assert float(loss) < first, f"{mdef['name']}: {first} -> {float(loss)}"


def test_tgn_memory_updates_only_touched_nodes():
    mdef = tgn.build(P, D, "link")
    state = mdef["init_state"](0)
    batch = mk_batch(mdef["specs"]["update"], seed=2)
    state2 = mdef["fns"]["update"](state, batch)
    mem1 = np.asarray(state["extra"]["memory"])
    mem2 = np.asarray(state2["extra"]["memory"])
    touched = set(np.asarray(batch["src"]).tolist()) | set(np.asarray(batch["dst"]).tolist())
    for n in range(P.n):
        changed = not np.allclose(mem1[n], mem2[n])
        assert changed == (n in touched) or not changed, f"node {n}"
        if n not in touched:
            assert not changed, f"untouched node {n} changed"


def test_tpnet_update_decays_and_propagates():
    mdef = tpnet.build(P, D)
    state = mdef["init_state"](0)
    batch = mk_batch(mdef["specs"]["update"], seed=3)
    state2 = mdef["fns"]["update"](state, batch)
    assert not np.allclose(
        np.asarray(state["extra"]["reps"]), np.asarray(state2["extra"]["reps"])
    )
    # Fixed projection untouched.
    np.testing.assert_array_equal(
        np.asarray(state["extra"]["rp_w"]), np.asarray(state2["extra"]["rp_w"])
    )


def test_snapshot_update_advances_recurrent_state():
    mdef = snapshot.build(P, D, "tgcn", "link")
    state = mdef["init_state"](0)
    batch = mk_batch(mdef["specs"]["update"], seed=4)
    state2 = mdef["fns"]["update"](state, batch)
    assert not np.allclose(np.asarray(state["extra"]["h"]), np.asarray(state2["extra"]["h"]))
    # Params untouched by update.
    for a, b in zip(
        jax.tree_util.tree_leaves(state["params"]), jax.tree_util.tree_leaves(state2["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_is_deterministic():
    for mdef in (tgat.build(P, D), tpnet.build(P, D)):
        a = jax.tree_util.tree_leaves(mdef["init_state"](0))
        b = jax.tree_util.tree_leaves(mdef["init_state"](0))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        c = jax.tree_util.tree_leaves(mdef["init_state"](1))
        assert any(
            not np.array_equal(np.asarray(x), np.asarray(z)) for x, z in zip(a, c)
        )


def test_dygformer_cooccurrence():
    from compile.models.dygformer import _cooccurrence

    a_ids = jnp.asarray([[1, 2, 1, 0]], jnp.int32)
    a_mask = jnp.asarray([[1, 1, 1, 0]], jnp.float32)
    b_ids = jnp.asarray([[2, 2, 9, 0]], jnp.int32)
    b_mask = jnp.asarray([[1, 1, 1, 0]], jnp.float32)
    c = np.asarray(_cooccurrence(a_ids, a_mask, b_ids, b_mask))[0]
    # position 0: id 1 appears twice in a, zero times in b (valid slots).
    np.testing.assert_allclose(c[0], [2.0, 0.0])
    # position 1: id 2 appears once in a, twice in b.
    np.testing.assert_allclose(c[1], [1.0, 2.0])
    # masked position contributes zeros.
    np.testing.assert_allclose(c[3], [0.0, 0.0])


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
