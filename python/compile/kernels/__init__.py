"""Public kernel API: Pallas forward, reference-vjp backward.

Each op is a ``jax.custom_vjp`` whose forward runs the Pallas kernel
(``interpret=True``) and whose backward is the vjp of the pure-jnp
reference. The pytest suite asserts forward(kernel) == forward(ref), so
the pairing is numerically consistent. This sidesteps Pallas interpret
mode's limited autodiff while keeping the kernels on the lowered HLO
path that Rust executes.

Wrappers also pad leading dims to kernel block multiples and reshape
arbitrary-rank inputs to the kernels' canonical ranks, so model code can
call these with natural shapes.
"""

import jax
import jax.numpy as jnp

from . import ref
from .pallas_kernels import (
    ELT_BLOCK,
    SEED_BLOCK,
    matmul_pallas,
    neighbor_attention_pallas,
    time_encode_pallas,
)


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    target = max(-(-size // multiple) * multiple, multiple)
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size


# ---------------------------------------------------------------------
# time_encode
# ---------------------------------------------------------------------


@jax.custom_vjp
def time_encode(dt, w, b):
    """cos(dt * w + b); Pallas forward, ref-vjp backward.

    dt: [...], w/b: [Dt] -> [..., Dt].
    """
    shape = dt.shape
    flat = dt.reshape(-1).astype(jnp.float32)
    padded, size = _pad_to(flat, 0, ELT_BLOCK)
    out = time_encode_pallas(padded, w, b)[:size]
    return out.reshape(*shape, w.shape[0])


def _te_fwd(dt, w, b):
    return time_encode(dt, w, b), (dt, w, b)


def _te_bwd(res, g):
    _, vjp = jax.vjp(ref.time_encode, *res)
    return vjp(g)


time_encode.defvjp(_te_fwd, _te_bwd)


# ---------------------------------------------------------------------
# neighbor_attention
# ---------------------------------------------------------------------


@jax.custom_vjp
def neighbor_attention(q, k, v, mask):
    """Masked attention over sampled neighbors; see ref.neighbor_attention.

    q: [S, D], k: [S, K, D], v: [S, K, Dv], mask: [S, K] -> [S, Dv].
    """
    qp, s = _pad_to(q, 0, SEED_BLOCK)
    kp, _ = _pad_to(k, 0, SEED_BLOCK)
    vp, _ = _pad_to(v, 0, SEED_BLOCK)
    mp, _ = _pad_to(mask, 0, SEED_BLOCK)
    return neighbor_attention_pallas(qp, kp, vp, mp)[:s]


def _na_fwd(q, k, v, mask):
    return neighbor_attention(q, k, v, mask), (q, k, v, mask)


def _na_bwd(res, g):
    _, vjp = jax.vjp(ref.neighbor_attention, *res)
    return vjp(g)


neighbor_attention.defvjp(_na_fwd, _na_bwd)


# ---------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------


def _mm_pad(size, block):
    """Pad target: a multiple of `block` when the dim exceeds one block
    (so the grid tiles evenly), else a multiple of 8 (one block)."""
    return block if size > block else 8


@jax.custom_vjp
def matmul(a, b):
    """Blocked Pallas matmul with ref-vjp backward: [M,K] @ [K,N]."""
    from .pallas_kernels import MM_BLOCK_K, MM_BLOCK_M, MM_BLOCK_N

    m, kdim = a.shape
    n = b.shape[1]
    ap, _ = _pad_to(a, 0, _mm_pad(m, MM_BLOCK_M))
    ap, _ = _pad_to(ap, 1, _mm_pad(kdim, MM_BLOCK_K))
    bp, _ = _pad_to(b, 0, _mm_pad(kdim, MM_BLOCK_K))
    bp, _ = _pad_to(bp, 1, _mm_pad(n, MM_BLOCK_N))
    return matmul_pallas(ap, bp)[:m, :n]


def _mm_fwd(a, b):
    return matmul(a, b), (a, b)


def _mm_bwd(res, g):
    a, b = res
    return (
        jnp.dot(g, b.T, preferred_element_type=jnp.float32),
        jnp.dot(a.T, g, preferred_element_type=jnp.float32),
    )


matmul.defvjp(_mm_fwd, _mm_bwd)


def decayed_propagate(reps, gamma, onehot_src, onehot_dst, w):
    """TPNet propagation composed from the Pallas matmul (see ref)."""
    gathered = matmul(onehot_dst, reps)
    msg = matmul(gathered, w)
    scattered = matmul(onehot_src.T, msg)
    return gamma * reps + scattered
