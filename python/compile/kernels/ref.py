"""Pure-jnp oracles for the Pallas kernels (L1 correctness contract).

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops. The pytest suite asserts kernel == ref
under ``assert_allclose``; the kernels' backward passes are *defined* as
the vjp of these references (see ``kernels/__init__.py``), so matching
forwards guarantee consistent training behaviour.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def time_encode(dt, w, b):
    """Bochner/Time2Vec encoding: cos(dt * w + b).

    Args:
      dt: [...] non-negative time deltas.
      w:  [Dt] trainable frequencies.
      b:  [Dt] trainable phases.
    Returns:
      [..., Dt] encoding.
    """
    return jnp.cos(dt[..., None] * w + b)


def neighbor_attention(q, k, v, mask):
    """Masked single-head attention over K sampled neighbors.

    Args:
      q:    [S, D]      per-seed query.
      k:    [S, K, D]   per-neighbor keys.
      v:    [S, K, Dv]  per-neighbor values.
      mask: [S, K]      1.0 = valid neighbor, 0.0 = padding.
    Returns:
      [S, Dv] attention output; rows with no valid neighbor are zero.
    """
    d = q.shape[-1]
    scores = jnp.einsum("sd,skd->sk", q, k) / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(mask > 0, scores, NEG_INF)
    # Stable softmax that yields exact zeros for fully-masked rows.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m)) * (mask > 0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    attn = e / jnp.maximum(denom, 1e-9)
    return jnp.einsum("sk,skv->sv", attn, v)


def matmul(a, b):
    """Plain f32 matmul: [M, K] @ [K, N] -> [M, N]."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def decayed_propagate(reps, gamma, onehot_src, onehot_dst, w):
    """TPNet-style random-feature propagation step.

    new_reps = gamma ⊙ reps + onehot_srcᵀ @ ((onehot_dst @ reps) @ w)

    Args:
      reps:       [N, R] node representation matrix.
      gamma:      [N, 1] per-node time-decay factors.
      onehot_src: [B, N] one-hot rows selecting update targets.
      onehot_dst: [B, N] one-hot rows selecting propagation sources.
      w:          [R, R] projection.
    Returns:
      [N, R] updated representations.
    """
    gathered = matmul(onehot_dst, reps)  # [B, R]
    msg = matmul(gathered, w)  # [B, R]
    scattered = matmul(onehot_src.T, msg)  # [N, R]
    return gamma * reps + scattered
