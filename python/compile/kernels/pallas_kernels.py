"""Layer-1 Pallas kernels for the TGM compute hot-spots.

All kernels run with ``interpret=True``: the image's CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode lowers each kernel
to plain HLO that any backend runs (see /opt/xla-example/README.md).
Block shapes are nevertheless chosen for the *TPU* memory system — tiles
sized for VMEM (<16 MiB), last dims padded toward the 128-lane registers,
matmul tiles in 128-multiples for the MXU systolic array — so the same
BlockSpecs compile for real hardware. DESIGN.md §Hardware-Adaptation
records the VMEM/MXU estimates per kernel.

Shape contract: wrappers in ``kernels/__init__.py`` pad leading dims to
block multiples and slice the result, so callers may pass any shape.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Block of seeds processed per grid step. 128 keeps every operand tile
# well under VMEM: the largest (DyGFormer K=32, D=64 keys) is
# 128*32*64*4B = 1 MiB.
SEED_BLOCK = 128
# MXU-friendly matmul tiles.
MM_BLOCK_M = 128
MM_BLOCK_K = 512
MM_BLOCK_N = 128
# 1-D elementwise block (time encoding).
ELT_BLOCK = 512


def _time_encode_kernel(dt_ref, w_ref, b_ref, o_ref):
    """o[s, :] = cos(dt[s] * w + b) for a block of S positions."""
    dt = dt_ref[...]  # [bs]
    w = w_ref[...]  # [Dt]
    b = b_ref[...]  # [Dt]
    o_ref[...] = jnp.cos(dt[:, None] * w[None, :] + b[None, :])


def time_encode_pallas(dt, w, b):
    """Pallas forward of ref.time_encode for 1-D dt: [S] -> [S, Dt]."""
    s = dt.shape[0]
    dt_dim = w.shape[0]
    grid = (s // ELT_BLOCK,) if s >= ELT_BLOCK else (1,)
    bs = s // grid[0]
    return pl.pallas_call(
        _time_encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((dt_dim,), lambda i: (0,)),
            pl.BlockSpec((dt_dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs, dt_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dt_dim), jnp.float32),
        interpret=True,
    )(dt, w, b)


def _neighbor_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    """Fused masked attention for a block of seeds.

    The (seeds x K) score matrix lives entirely in VMEM; softmax and the
    weighted value sum are fused so scores never round-trip to HBM — the
    TPU rethink of the paper's GPU per-threadblock neighborhood gather.
    """
    q = q_ref[...]  # [bs, D]
    k = k_ref[...]  # [bs, K, D]
    v = v_ref[...]  # [bs, K, Dv]
    mask = mask_ref[...]  # [bs, K]
    d = q.shape[-1]
    scores = jnp.einsum("sd,skd->sk", q, k) / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(mask > 0, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * (mask > 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-9)
    o_ref[...] = jnp.einsum("sk,skv->sv", e / denom, v)


def neighbor_attention_pallas(q, k, v, mask):
    """Pallas forward of ref.neighbor_attention (shapes pre-padded)."""
    s, d = q.shape
    kk = k.shape[1]
    dv = v.shape[2]
    grid = (s // SEED_BLOCK,) if s >= SEED_BLOCK else (1,)
    bs = s // grid[0]
    return pl.pallas_call(
        _neighbor_attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((bs, kk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, kk, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, kk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dv), jnp.float32),
        interpret=True,
    )(q, k, v, mask)


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps):
    """Accumulating [bm, bk] @ [bk, bn] tile matmul (MXU tile shape)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )
    del k_steps


def matmul_pallas(a, b):
    """Blocked Pallas matmul: [M, K] @ [K, N] (shapes pre-padded)."""
    m, kdim = a.shape
    n = b.shape[1]
    bm = min(m, MM_BLOCK_M)
    bk = min(kdim, MM_BLOCK_K)
    bn = min(n, MM_BLOCK_N)
    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
