"""AOT lowering driver: model registry -> artifacts/ (HLO text + manifest).

Runs ONCE at build time (`make artifacts`); Python never executes on the
Rust request path. For every model variant this emits:

* ``<model>.<kind>.hlo.txt`` — HLO *text* per artifact kind
  (train/predict/update). Text, not serialized proto: jax >= 0.5 emits
  64-bit instruction ids that xla_extension 0.5.1 rejects; the text
  parser reassigns ids (see /opt/xla-example/README.md).
* ``<model>.state.bin`` — the initial state tensors (params + Adam slots
  + model state), concatenated f32 little-endian in canonical
  tree_flatten order.
* ``manifest.txt`` — profiles, per-model state shapes, and per-artifact
  input/output specs, in the line format ``rust/src/runtime/manifest.rs``
  parses.

Usage: python -m compile.aot [--out DIR] [--models a,b,c] [--list]
"""

import argparse
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .config import PROFILES
from .model import batch_shape_structs, flatten_model, registry, state_leaves


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(shape) -> str:
    return ",".join(str(d) for d in shape) if len(shape) else "-"


def emit_model(model_def, out_dir, manifest_lines, verbose=True):
    name = model_def["name"]
    leaves, treedef = state_leaves(model_def, seed=0)
    n_state = len(leaves)

    # State blob: canonical order, f32 LE.
    blob = b"".join(np.asarray(leaf, np.float32).tobytes() for leaf in leaves)
    state_file = f"{name}.state.bin"
    with open(os.path.join(out_dir, state_file), "wb") as f:
        f.write(blob)

    manifest_lines.append(f"model {name} profile {model_def['profile'].name}")
    manifest_lines.append(f"state_file {state_file}")
    for leaf in leaves:
        manifest_lines.append(f"state f32 {shape_str(leaf.shape)}")

    state_structs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    for kind in ("train", "predict", "update"):
        if kind not in model_def["fns"]:
            continue
        spec = model_def["specs"][kind]
        flat = flatten_model(model_def, kind, treedef, n_state)
        args = state_structs + batch_shape_structs(spec)
        if verbose:
            print(f"  lowering {name}.{kind} ({len(args)} inputs)...", flush=True)
        # keep_unused=True: the Rust runtime passes the full state list to
        # every artifact; without it jit prunes unused parameters and the
        # compiled program's arity diverges from the manifest.
        lowered = jax.jit(flat, keep_unused=True).lower(*args)
        hlo = to_hlo_text(lowered)
        hlo_file = f"{name}.{kind}.hlo.txt"
        with open(os.path.join(out_dir, hlo_file), "w") as f:
            f.write(hlo)

        manifest_lines.append(f"artifact {kind} {hlo_file}")
        for in_name, dt, shape in spec:
            manifest_lines.append(f"in {in_name} {dt} {shape_str(shape)}")
        if kind == "train":
            manifest_lines.append("out state")
            manifest_lines.append("out loss f32 -")
        elif kind == "predict":
            out_aval = jax.eval_shape(flat, *args)[0]
            manifest_lines.append(f"out scores f32 {shape_str(out_aval.shape)}")
        else:
            manifest_lines.append("out state")
        manifest_lines.append("end")
    manifest_lines.append("endmodel")
    manifest_lines.append("")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--models", default="", help="comma-separated subset")
    ap.add_argument("--list", action="store_true", help="list model names")
    args = ap.parse_args()

    reg = registry()
    if args.list:
        print("\n".join(sorted(reg)))
        return
    selected = sorted(reg) if not args.models else args.models.split(",")
    for m in selected:
        if m not in reg:
            sys.exit(f"unknown model `{m}` (use --list)")

    os.makedirs(args.out, exist_ok=True)
    manifest = ["# TGM artifact manifest v1"]
    for p in PROFILES.values():
        manifest.append(
            f"profile {p.name} n {p.n} b {p.b} k {p.k} k2 {p.k2} seq {p.seq} "
            f"c {p.c} d_edge {p.d_edge} d_static {p.d_static} p {p.p}"
        )
    manifest.append("")

    for i, m in enumerate(selected):
        print(f"[{i + 1}/{len(selected)}] {m}", flush=True)
        emit_model(reg[m], args.out, manifest)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(selected)} models to {args.out}")


if __name__ == "__main__":
    main()
