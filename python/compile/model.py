"""Model registry: (model, task) variants -> AOT-lowerable flat functions.

Each model def (from ``models/*.build``) carries:

* ``init_state(seed)`` — the canonical state pytree (params + Adam slots
  + model state),
* ``specs`` — ordered batch-input specs per function kind
  (``train``/``predict``/``update``): ``(name, dtype, shape)`` tuples,
* ``fns`` — the pytree-level functions.

``flatten_model`` turns these into positional-argument functions whose
signature is ``(state..., batch...)`` in manifest order, ready for
``jax.jit(...).lower`` with static shapes. Outputs are ``(*state, loss)``
for train, ``(scores,)`` for predict, ``(*state,)`` for update.
"""

import jax
import jax.numpy as jnp

from .config import CTDG, DIMS, DTDG
from .models import dygformer, graphmixer, snapshot, tgat, tgn, tpnet


def registry():
    """All model variants keyed by name (16 models, 44 artifacts)."""
    defs = [
        tgat.build(CTDG, DIMS),
        tgn.build(CTDG, DIMS, "link"),
        tgn.build(CTDG, DIMS, "node"),
        graphmixer.build(CTDG, DIMS),
        dygformer.build(CTDG, DIMS, "link"),
        dygformer.build(CTDG, DIMS, "node"),
        tpnet.build(CTDG, DIMS),
    ]
    for arch in ("gcn", "gclstm", "tgcn"):
        for task in ("link", "node", "graph"):
            defs.append(snapshot.build(DTDG, DIMS, arch, task))
    return {d["name"]: d for d in defs}


def state_leaves(model_def, seed=0):
    """Canonical flat state tensors (tree_flatten order) and treedef."""
    state = model_def["init_state"](seed)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def batch_shape_structs(spec):
    """ShapeDtypeStructs for a batch spec list."""
    return [jax.ShapeDtypeStruct(shape, _DTYPES[dt]) for (_, dt, shape) in spec]


def flatten_model(model_def, kind, treedef, n_state):
    """Positional wrapper for one artifact kind."""
    spec = model_def["specs"][kind]
    fn = model_def["fns"][kind]
    names = [name for (name, _, _) in spec]

    def flat(*args):
        state = jax.tree_util.tree_unflatten(treedef, args[:n_state])
        batch = dict(zip(names, args[n_state:]))
        if kind == "train":
            new_state, loss = fn(state, batch)
            return tuple(jax.tree_util.tree_flatten(new_state)[0]) + (loss,)
        if kind == "predict":
            return (fn(state, batch),)
        new_state = fn(state, batch)
        return tuple(jax.tree_util.tree_flatten(new_state)[0])

    return flat
