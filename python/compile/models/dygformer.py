"""DyGFormer (Yu et al., 2023): transformer over recent-neighbor sequences.

Per endpoint, the L most recent interactions form a sequence of tokens:
edge features + time encoding + *neighbor co-occurrence* counts between
the two endpoints' sequences (the model's key inductive signal). Patches
of consecutive tokens are projected and fed through a small transformer
encoder; mean pooling yields the endpoint embedding.

Supports link prediction and node property prediction.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _mha_tokens_init(rng, d, heads):
    del heads
    return {
        "wq": cm.linear_init(rng, d, d),
        "wk": cm.linear_init(rng, d, d),
        "wv": cm.linear_init(rng, d, d),
        "wo": cm.linear_init(rng, d, d),
    }


def _mha_tokens(p, x, heads):
    """Standard self-attention over a short token axis: [S, T, D]."""
    s, t, d = x.shape
    dh = d // heads
    q = cm.linear(p["wq"], x).reshape(s, t, heads, dh)
    k = cm.linear(p["wk"], x).reshape(s, t, heads, dh)
    v = cm.linear(p["wv"], x).reshape(s, t, heads, dh)
    scores = jnp.einsum("sthd,suhd->shtu", q, k) / jnp.sqrt(jnp.float32(dh))
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("shtu,suhd->sthd", attn, v).reshape(s, t, d)
    return cm.linear(p["wo"], out)


def _encoder_layer_init(rng, d, heads):
    return {
        "attn": _mha_tokens_init(rng, d, heads),
        "ffn": cm.mlp2_init(rng, d, 2 * d, d),
    }


def _encoder_layer(p, x, heads):
    x = x + _mha_tokens(p["attn"], cm.layer_norm(x), heads)
    return x + cm.mlp2(p["ffn"], cm.layer_norm(x))


def _cooccurrence(a_ids, a_mask, b_ids, b_mask):
    """Per-position co-occurrence counts of a's neighbors in a and in b.

    a_ids/b_ids: [S, L]; returns [S, L, 2] (count in own seq, in other).
    """
    eq_aa = (a_ids[:, :, None] == a_ids[:, None, :]).astype(jnp.float32)
    eq_ab = (a_ids[:, :, None] == b_ids[:, None, :]).astype(jnp.float32)
    in_a = (eq_aa * a_mask[:, None, :]).sum(-1)
    in_b = (eq_ab * b_mask[:, None, :]).sum(-1)
    return jnp.stack([in_a, in_b], axis=-1) * a_mask[..., None]


def _init_params(profile, dims, seed, task):
    rng = np.random.default_rng(seed)
    d = dims.embed
    tok_in = (profile.d_edge + dims.time + profile.d_static + 2) * dims.patch
    params = {
        "te": cm.time_encoder_init(rng, dims.time),
        "patch_proj": cm.linear_init(rng, tok_in, d),
        "enc1": _encoder_layer_init(rng, d, dims.heads),
        "enc2": _encoder_layer_init(rng, d, dims.heads),
        "out": cm.linear_init(rng, d, d),
    }
    if task == "link":
        params["dec"] = cm.link_decoder_init(rng, d)
    else:
        params["head"] = cm.mlp2_init(rng, d, d, profile.p)
    return params


def _encode(params, dims, profile, node_feats, nbr, cooc):
    """Sequence encoding of S endpoints: nbr arrays [S, L, ...]."""
    ids, dt, mask, feats = nbr
    s, length = ids.shape
    te = kernels.time_encode(dt, params["te"]["w"], params["te"]["b"])
    nf = node_feats[ids.reshape(-1)].reshape(s, length, -1)
    x = jnp.concatenate([feats, te, nf, cooc], axis=-1) * mask[..., None]
    # Patching: group `patch` consecutive tokens.
    t = length // dims.patch
    x = x.reshape(s, t, -1)
    x = cm.linear(params["patch_proj"], x)
    x = _encoder_layer(params["enc1"], x, dims.heads)
    x = _encoder_layer(params["enc2"], x, dims.heads)
    return cm.linear(params["out"], x.mean(axis=1))


def _nbr_block(prefix, p, rows):
    return [
        (f"{prefix}ids", "i32", (rows, p.seq)),
        (f"{prefix}dt", "f32", (rows, p.seq)),
        (f"{prefix}mask", "f32", (rows, p.seq)),
        (f"{prefix}feats", "f32", (rows, p.seq, p.d_edge)),
    ]


def build(profile, dims, task="link"):
    """DyGFormer model definition (task = "link" | "node")."""
    p = profile

    if task == "link":
        specs = {
            "train": [
                ("node_feats", "f32", (p.n, p.d_static)),
                ("src", "i32", (p.b,)),
                ("dst", "i32", (p.b,)),
                ("neg", "i32", (p.b,)),
                ("t", "f32", (p.b,)),
                ("valid", "f32", (p.b,)),
            ]
            + _nbr_block("nbr_", p, 3 * p.b),
            "predict": [
                ("node_feats", "f32", (p.n, p.d_static)),
                ("src", "i32", (p.b,)),
                ("cand", "i32", (p.b, p.c)),
                ("t", "f32", (p.b,)),
                ("valid", "f32", (p.b,)),
            ]
            + _nbr_block("src_nbr_", p, p.b)
            + _nbr_block("cand_nbr_", p, p.b * p.c),
        }
    else:
        specs = {
            "train": [
                ("node_feats", "f32", (p.n, p.d_static)),
                ("nodes", "i32", (p.b,)),
                ("target", "f32", (p.b, p.p)),
                ("t", "f32", (p.b,)),
                ("valid", "f32", (p.b,)),
            ]
            + _nbr_block("nbr_", p, p.b),
            "predict": [
                ("node_feats", "f32", (p.n, p.d_static)),
                ("nodes", "i32", (p.b,)),
                ("t", "f32", (p.b,)),
                ("valid", "f32", (p.b,)),
            ]
            + _nbr_block("nbr_", p, p.b),
        }

    def init_state(seed):
        return cm.make_state(_init_params(profile, dims, seed, task))

    def nbr_slice(batch, prefix, lo, hi):
        return tuple(batch[f"{prefix}{f}"][lo:hi] for f in ("ids", "dt", "mask", "feats"))

    def pair_embed(params, node_feats, nbr_a, nbr_b):
        """Joint (a|b) and (b|a) embeddings with cross co-occurrence."""
        cooc_a = _cooccurrence(nbr_a[0], nbr_a[2], nbr_b[0], nbr_b[2])
        cooc_b = _cooccurrence(nbr_b[0], nbr_b[2], nbr_a[0], nbr_a[2])
        ha = _encode(params, dims, p, node_feats, nbr_a, cooc_a)
        hb = _encode(params, dims, p, node_feats, nbr_b, cooc_b)
        return ha, hb

    def loss_fn(params, batch):
        b = p.b
        if task == "link":
            nbr_src = nbr_slice(batch, "nbr_", 0, b)
            nbr_dst = nbr_slice(batch, "nbr_", b, 2 * b)
            nbr_neg = nbr_slice(batch, "nbr_", 2 * b, 3 * b)
            hs, hd = pair_embed(params, batch["node_feats"], nbr_src, nbr_dst)
            hs2, hn = pair_embed(params, batch["node_feats"], nbr_src, nbr_neg)
            pos = cm.link_decode(params["dec"], hs, hd)
            neg = cm.link_decode(params["dec"], hs2, hn)
            return cm.bce_link_loss(pos, neg, batch["valid"])
        nbr = nbr_slice(batch, "nbr_", 0, b)
        cooc = _cooccurrence(nbr[0], nbr[2], nbr[0], nbr[2])
        h = _encode(params, dims, p, batch["node_feats"], nbr, cooc)
        logits = cm.mlp2(params["head"], h)
        return cm.node_property_loss(logits, batch["target"], batch["valid"])

    def train(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        return cm.adam_step(state, grads, dims.lr), loss

    def predict(state, batch):
        params = state["params"]
        if task == "link":
            b, c = p.b, p.c
            nbr_src = nbr_slice(batch, "src_nbr_", 0, b)
            nbr_cand = nbr_slice(batch, "cand_nbr_", 0, b * c)
            # Tile src sequences against every candidate.
            tiled = tuple(
                jnp.repeat(x, c, axis=0) for x in nbr_src
            )  # [B*C, L, ...]
            hs, hc = pair_embed(params, batch["node_feats"], tiled, nbr_cand)
            return cm.link_decode(params["dec"], hs, hc).reshape(b, c)
        nbr = nbr_slice(batch, "nbr_", 0, p.b)
        cooc = _cooccurrence(nbr[0], nbr[2], nbr[0], nbr[2])
        h = _encode(params, dims, p, batch["node_feats"], nbr, cooc)
        return cm.mlp2(params["head"], h)

    return {
        "name": f"dygformer_{task}",
        "profile": profile,
        "init_state": init_state,
        "specs": specs,
        "fns": {"train": train, "predict": predict},
    }
