"""Shared L2 building blocks: initializers, MLPs, Adam, losses.

State layout contract (consumed by aot.py and the Rust runtime):
every model's state is a pytree ``dict`` —

    {"params": {...}, "m": {...}, "v": {...}, "step": f32[],
     "extra": {...model state: memory, recurrent h/c, reps...}}

``jax.tree_util.tree_flatten`` over this dict (sorted keys) defines the
canonical tensor order written to the manifest and the ``.state.bin``
blob; the Rust side threads the same flat list through every call.
"""

import jax
import jax.numpy as jnp
import numpy as np

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------
# init
# ---------------------------------------------------------------------


def glorot(rng: np.random.Generator, shape):
    """Glorot-uniform init as f32 (numpy so init is jit-free)."""
    fan_in, fan_out = shape[0], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jnp.asarray(rng.uniform(-lim, lim, shape), jnp.float32)


def zeros(shape):
    return jnp.zeros(shape, jnp.float32)


def linear_init(rng, d_in, d_out):
    return {"w": glorot(rng, (d_in, d_out)), "b": zeros((d_out,))}


def mlp2_init(rng, d_in, d_hidden, d_out):
    return {"l1": linear_init(rng, d_in, d_hidden), "l2": linear_init(rng, d_hidden, d_out)}


def time_encoder_init(rng, d_time):
    """Bochner time encoder: log-spaced frequencies (TGAT init)."""
    freqs = 1.0 / (10.0 ** np.linspace(0, 6, d_time))
    del rng
    return {"w": jnp.asarray(freqs, jnp.float32), "b": zeros((d_time,))}


def make_state(params, extra=None):
    """Wrap params (+model state) with fresh Adam slots."""
    zeros_like = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "params": params,
        "m": zeros_like,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.float32),
        "extra": extra or {},
    }


# ---------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------


def linear(p, x):
    return jnp.dot(x, p["w"]) + p["b"]


def mlp2(p, x):
    return linear(p["l2"], jax.nn.relu(linear(p["l1"], x)))


def layer_norm(x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


# ---------------------------------------------------------------------
# optimizer (inside the AOT train step)
# ---------------------------------------------------------------------


def adam_step(state, grads, lr):
    """One Adam update over state['params']; returns the new state."""
    step = state["step"] + 1.0
    b1c = 1.0 - ADAM_B1**step
    b2c = 1.0 - ADAM_B2**step
    m = jax.tree_util.tree_map(
        lambda m_, g: ADAM_B1 * m_ + (1 - ADAM_B1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: ADAM_B2 * v_ + (1 - ADAM_B2) * g * g, state["v"], grads
    )
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / b1c) / (jnp.sqrt(v_ / b2c) + ADAM_EPS),
        state["params"],
        m,
        v,
    )
    return {**state, "params": params, "m": m, "v": v, "step": step}


# ---------------------------------------------------------------------
# losses & decoders
# ---------------------------------------------------------------------


def bce_link_loss(pos_logits, neg_logits, valid):
    """Masked binary cross-entropy on positive vs negative link logits."""
    ls = jax.nn.log_sigmoid
    per_edge = -(ls(pos_logits) + ls(-neg_logits))
    return jnp.sum(per_edge * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def node_property_loss(logits, target, valid):
    """Masked cross-entropy between predicted logits [B,P] and a target
    distribution [B,P] (Trade/Genre-style proportion prediction)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_node = -jnp.sum(target * logp, axis=-1)
    return jnp.sum(per_node * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def graph_property_loss(logit, label):
    """BCE for snapshot-level binary prediction (RQ1 growth task)."""
    return -(
        label * jax.nn.log_sigmoid(logit) + (1.0 - label) * jax.nn.log_sigmoid(-logit)
    )


def link_decoder_init(rng, d):
    return mlp2_init(rng, 2 * d, d, 1)


def link_decode(p, h_src, h_dst):
    """MLP link decoder on concatenated endpoint embeddings -> logit."""
    return mlp2(p, jnp.concatenate([h_src, h_dst], axis=-1))[..., 0]


def onehot(idx, n):
    """Dense one-hot rows [B, N] (scatter-free, MXU-friendly)."""
    return jax.nn.one_hot(idx, n, dtype=jnp.float32)


# ---------------------------------------------------------------------
# multi-head attention over sampled neighbors (Pallas-backed)
# ---------------------------------------------------------------------


def mha_init(rng, d_q, d_kv, d_model):
    return {
        "wq": linear_init(rng, d_q, d_model),
        "wk": linear_init(rng, d_kv, d_model),
        "wv": linear_init(rng, d_kv, d_model),
        "wo": linear_init(rng, d_model, d_model),
    }


def mha_neighbors(p, q_in, kv_in, mask, heads):
    """Multi-head attention of each seed over its K sampled neighbors.

    q_in: [S, Dq], kv_in: [S, K, Dkv], mask: [S, K] -> [S, D].
    Heads are folded into the seed axis so the Pallas kernel stays
    single-head ([S*H, K, Dh] tiles in VMEM).
    """
    from .. import kernels  # local import: keep module import-light

    q = linear(p["wq"], q_in)
    k = linear(p["wk"], kv_in)
    v = linear(p["wv"], kv_in)
    s, kk, d = k.shape
    h = heads
    dh = d // h
    qf = q.reshape(s, h, dh).swapaxes(0, 1).reshape(s * h, dh)
    kf = k.reshape(s, kk, h, dh).transpose(2, 0, 1, 3).reshape(s * h, kk, dh)
    vf = v.reshape(s, kk, h, dh).transpose(2, 0, 1, 3).reshape(s * h, kk, dh)
    mf = jnp.tile(mask, (h, 1))
    out = kernels.neighbor_attention(qf, kf, vf, mf)
    out = out.reshape(h, s, dh).swapaxes(0, 1).reshape(s, d)
    return linear(p["wo"], out)
