"""Snapshot (DTDG) models: GCN, GCLSTM, T-GCN (paper §B.1, Table 14).

All three share the spatial encoder — a two-layer GCN over the dense
symmetric-normalized snapshot adjacency produced by the Rust
`SnapshotAdjHook` — with the `Â @ X` aggregations running through the
Pallas blocked matmul (the MXU-oriented rethink of GPU SpMM, see
DESIGN.md §Hardware-Adaptation). They differ in the temporal encoder:

* **GCN** — none (each snapshot independent),
* **T-GCN** — GRU over snapshot embeddings,
* **GCLSTM** — LSTM over snapshot embeddings.

Each supports three tasks: `link` (predict next-snapshot edges), `node`
(next-period property distribution), `graph` (binary growth label, RQ1).
Recurrent state advances with truncated BPTT-1 (carried state is
stop-gradiented), and `update` advances state during evaluation.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _gcn2_init(rng, d_in, d_h, d_out):
    return {
        "w1": cm.linear_init(rng, d_in, d_h),
        "w2": cm.linear_init(rng, d_h, d_out),
    }


def _gcn2(p, adj, x):
    """Two-layer GCN: relu(Â relu(Â X W1) W2), Pallas matmuls."""
    h = jax.nn.relu(kernels.matmul(adj, cm.linear(p["w1"], x)))
    return jax.nn.relu(kernels.matmul(adj, cm.linear(p["w2"], h)))


def _lstm_init(rng, d_in, d_h):
    return {"w": cm.linear_init(rng, d_in + d_h, 4 * d_h)}


def _lstm(p, x, h, c):
    gates = cm.linear(p["w"], jnp.concatenate([x, h], axis=-1))
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c2 = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
    return h2, c2


def _gru_init(rng, d_in, d_h):
    return {
        "wz": cm.linear_init(rng, d_in + d_h, d_h),
        "wr": cm.linear_init(rng, d_in + d_h, d_h),
        "wh": cm.linear_init(rng, d_in + d_h, d_h),
    }


def _gru(p, x, h):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(cm.linear(p["wz"], xh))
    r = jax.nn.sigmoid(cm.linear(p["wr"], xh))
    hh = jnp.tanh(cm.linear(p["wh"], jnp.concatenate([x, r * h], axis=-1)))
    return (1.0 - z) * h + z * hh


def _init_params(profile, dims, seed, arch, task):
    rng = np.random.default_rng(seed)
    d = dims.embed
    params = {"gcn": _gcn2_init(rng, profile.d_static, dims.hidden, d)}
    if arch == "gclstm":
        params["cell"] = _lstm_init(rng, d, d)
    elif arch == "tgcn":
        params["cell"] = _gru_init(rng, d, d)
    if task == "link":
        params["dec"] = cm.link_decoder_init(rng, d)
    elif task == "node":
        params["head"] = cm.mlp2_init(rng, d, d, profile.p)
    else:
        params["graph_head"] = cm.mlp2_init(rng, d, d, 1)
    return params


def _advance(params, arch, extra, adj, node_feats):
    """Run one snapshot through the spatial+temporal encoders."""
    x = _gcn2(params["gcn"], adj, node_feats)
    if arch == "gcn":
        return x, extra
    h = jax.lax.stop_gradient(extra["h"])
    if arch == "gclstm":
        c = jax.lax.stop_gradient(extra["c"])
        h2, c2 = _lstm(params["cell"], x, h, c)
        return h2, {**extra, "h": h2, "c": c2}
    h2 = _gru(params["cell"], x, h)
    return h2, {**extra, "h": h2}


def build(profile, dims, arch, task):
    """Snapshot model definition (arch ∈ {gcn,gclstm,tgcn}, task ∈
    {link,node,graph})."""
    p = profile
    d = dims.embed

    base = [("node_feats", "f32", (p.n, p.d_static)), ("adj", "f32", (p.n, p.n))]
    if task == "link":
        train_q = [
            ("src", "i32", (p.b,)),
            ("dst", "i32", (p.b,)),
            ("neg", "i32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ]
        pred_q = [("src", "i32", (p.b,)), ("cand", "i32", (p.b, p.c)), ("valid", "f32", (p.b,))]
    elif task == "node":
        train_q = [("nodes", "i32", (p.b,)), ("target", "f32", (p.b, p.p)), ("valid", "f32", (p.b,))]
        pred_q = [("nodes", "i32", (p.b,)), ("valid", "f32", (p.b,))]
    else:
        train_q = [("label", "f32", ())]
        pred_q = []

    specs = {
        "train": base + train_q,
        # predict reads the stored embedding (advanced by train/update).
        "predict": pred_q,
        "update": base,
    }

    def init_state(seed):
        params = _init_params(p, dims, seed, arch, task)
        extra = {"emb": jnp.zeros((p.n, d), jnp.float32)}
        if arch == "gclstm":
            extra["h"] = jnp.zeros((p.n, d), jnp.float32)
            extra["c"] = jnp.zeros((p.n, d), jnp.float32)
        elif arch == "tgcn":
            extra["h"] = jnp.zeros((p.n, d), jnp.float32)
        return cm.make_state(params, extra)

    def task_loss(params, emb, batch):
        if task == "link":
            pos = cm.link_decode(params["dec"], emb[batch["src"]], emb[batch["dst"]])
            neg = cm.link_decode(params["dec"], emb[batch["src"]], emb[batch["neg"]])
            return cm.bce_link_loss(pos, neg, batch["valid"])
        if task == "node":
            logits = cm.mlp2(params["head"], emb[batch["nodes"]])
            return cm.node_property_loss(logits, batch["target"], batch["valid"])
        logit = cm.mlp2(params["graph_head"], emb.mean(axis=0))[0]
        return cm.graph_property_loss(logit, batch["label"])

    def loss_fn(params, extra, batch):
        emb, extra2 = _advance(params, arch, extra, batch["adj"], batch["node_feats"])
        return task_loss(params, emb, batch), (emb, extra2)

    def train(state, batch):
        (loss, (emb, extra2)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], state["extra"], batch
        )
        state = cm.adam_step(state, grads, dims.lr_snapshot)
        extra2 = jax.tree_util.tree_map(jax.lax.stop_gradient, {**extra2, "emb": emb})
        return {**state, "extra": extra2}, loss

    def predict(state, batch):
        params, emb = state["params"], state["extra"]["emb"]
        if task == "link":
            b, c = p.b, p.c
            h_src = jnp.broadcast_to(emb[batch["src"]][:, None, :], (b, c, d))
            h_cand = emb[batch["cand"].reshape(-1)].reshape(b, c, d)
            return cm.link_decode(params["dec"], h_src, h_cand)
        if task == "node":
            return cm.mlp2(params["head"], emb[batch["nodes"]])
        return cm.mlp2(params["graph_head"], emb.mean(axis=0))

    def update(state, batch):
        emb, extra2 = _advance(state["params"], arch, state["extra"], batch["adj"], batch["node_feats"])
        return {**state, "extra": {**extra2, "emb": emb}}

    return {
        "name": f"{arch}_{task}",
        "profile": p,
        "init_state": init_state,
        "specs": specs,
        "fns": {"train": train, "predict": predict, "update": update},
    }
