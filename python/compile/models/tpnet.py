"""TPNet (Lu et al., 2024): temporal walk matrices via random feature
propagation with time decay.

State holds a node-representation matrix `reps` (the random-feature
sketch of the temporal walk matrix) and per-node last-update times. On
every batch the sketch decays by `exp(-λ Δt)` and propagates across the
batch edges through a *fixed* random projection — expressed scatter-free
with one-hot matmuls through the Pallas matmul kernel. Link likelihood
is an MLP over the endpoint sketches and their Hadamard product (the
implicit walk-count inner product).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _init_params(profile, dims, seed):
    rng = np.random.default_rng(seed)
    r = dims.rp
    return {"dec": cm.mlp2_init(rng, 3 * r, dims.hidden, 1)}


def _init_extra(profile, dims, seed):
    rng = np.random.default_rng(seed + 1)
    r = dims.rp
    # Fixed random features (±1/sqrt(R)) and projection, per the paper's
    # random feature propagation mechanism — not trained.
    reps = rng.choice([-1.0, 1.0], (profile.n, r)).astype(np.float32) / np.sqrt(r)
    w = rng.normal(0.0, 1.0 / np.sqrt(r), (r, r)).astype(np.float32)
    return {
        "reps": jnp.asarray(reps),
        "rp_w": jnp.asarray(w),
        "last_t": jnp.zeros((profile.n,), jnp.float32),
    }


def _propagate(profile, dims, extra, src, dst, t, valid):
    reps, last_t, w = extra["reps"], extra["last_t"], extra["rp_w"]
    n = profile.n
    t_now = jnp.max(t * valid)
    gamma = jnp.exp(-dims.rp_decay * jnp.maximum(t_now - last_t, 0.0))[:, None]
    oh_src = cm.onehot(src, n) * valid[:, None]
    oh_dst = cm.onehot(dst, n) * valid[:, None]
    reps1 = kernels.decayed_propagate(reps, gamma, oh_src, oh_dst, w)
    reps2 = kernels.decayed_propagate(reps1, jnp.ones_like(gamma), oh_dst, oh_src, w)
    # Row-norm control: repeated propagation compounds ||W|| per touch,
    # which overflows f32 on long streams. Soft-clip row norms (the
    # sketch's inner products only matter up to scale).
    norms = jnp.sqrt(jnp.sum(reps2 * reps2, axis=1, keepdims=True))
    reps2 = reps2 / jnp.maximum(1.0, norms / 3.0)
    touched = jnp.minimum(oh_src.sum(0) + oh_dst.sum(0), 1.0)
    last_t2 = last_t * (1.0 - touched) + t_now * touched
    return {**extra, "reps": reps2, "last_t": last_t2}


def _score(params, reps, a_ids, b_ids):
    ha, hb = reps[a_ids], reps[b_ids]
    x = jnp.concatenate([ha, hb, ha * hb], axis=-1)
    return cm.mlp2(params["dec"], x)[..., 0]


def build(profile, dims):
    """TPNet link-prediction model definition."""
    p = profile

    specs = {
        "train": [
            ("src", "i32", (p.b,)),
            ("dst", "i32", (p.b,)),
            ("neg", "i32", (p.b,)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ],
        "predict": [
            ("src", "i32", (p.b,)),
            ("cand", "i32", (p.b, p.c)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ],
        "update": [
            ("src", "i32", (p.b,)),
            ("dst", "i32", (p.b,)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ],
    }

    def init_state(seed):
        return cm.make_state(_init_params(p, dims, seed), _init_extra(p, dims, seed))

    def loss_fn(params, reps, batch):
        pos = _score(params, reps, batch["src"], batch["dst"])
        neg = _score(params, reps, batch["src"], batch["neg"])
        return cm.bce_link_loss(pos, neg, batch["valid"])

    def train(state, batch):
        reps = jax.lax.stop_gradient(state["extra"]["reps"])
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], reps, batch)
        state = cm.adam_step(state, grads, dims.lr)
        extra = _propagate(p, dims, state["extra"], batch["src"], batch["dst"], batch["t"], batch["valid"])
        return {**state, "extra": extra}, loss

    def predict(state, batch):
        reps = state["extra"]["reps"]
        b, c = p.b, p.c
        src = jnp.repeat(batch["src"], c)
        return _score(state["params"], reps, src, batch["cand"].reshape(-1)).reshape(b, c)

    def update(state, batch):
        extra = _propagate(p, dims, state["extra"], batch["src"], batch["dst"], batch["t"], batch["valid"])
        return {**state, "extra": extra}

    return {
        "name": "tpnet_link",
        "profile": p,
        "init_state": init_state,
        "specs": specs,
        "fns": {"train": train, "predict": predict, "update": update},
    }
