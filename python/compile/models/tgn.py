"""TGN (Rossi et al., 2020): memory-based temporal graph network.

State carries a per-node memory matrix and last-update times. The
embedding module is one temporal-attention layer over sampled neighbors
(memory + projected static features). Memory updates use a GRU cell over
mean-aggregated messages and are expressed scatter-free as one-hot
matmuls so the AOT graph keeps static shapes (MXU-friendly — see
DESIGN.md §Hardware-Adaptation).

Supports both link prediction and node property prediction (Table 4).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _gru_init(rng, d_in, d_h):
    return {
        "wz": cm.linear_init(rng, d_in + d_h, d_h),
        "wr": cm.linear_init(rng, d_in + d_h, d_h),
        "wh": cm.linear_init(rng, d_in + d_h, d_h),
    }


def _gru(p, x, h):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(cm.linear(p["wz"], xh))
    r = jax.nn.sigmoid(cm.linear(p["wr"], xh))
    hh = jnp.tanh(cm.linear(p["wh"], jnp.concatenate([x, r * h], axis=-1)))
    return (1.0 - z) * h + z * hh


def _init_params(profile, dims, seed, task):
    rng = np.random.default_rng(seed)
    d, m = dims.embed, dims.memory
    msg_dim = 2 * m + dims.time + profile.d_edge
    kv_dim = m + d + dims.time + profile.d_edge
    params = {
        "proj": cm.linear_init(rng, profile.d_static, d),
        "te": cm.time_encoder_init(rng, dims.time),
        "msg": cm.linear_init(rng, msg_dim, m),
        "gru": _gru_init(rng, m, m),
        "attn": cm.mha_init(rng, m + d + dims.time, kv_dim, d),
        "merge": cm.mlp2_init(rng, d + m, d, d),
    }
    if task == "link":
        params["dec"] = cm.link_decoder_init(rng, d)
    else:
        params["head"] = cm.mlp2_init(rng, d, d, profile.p)
    return params


def _embed(params, dims, memory, node_feats, seed_ids, nbr):
    """One temporal-attention layer over memory-augmented neighbors."""
    ids, dt, mask, feats = nbr
    s, k = ids.shape
    self_in = jnp.concatenate(
        [
            memory[seed_ids],
            cm.linear(params["proj"], node_feats[seed_ids]),
            kernels.time_encode(jnp.zeros(s, jnp.float32), params["te"]["w"], params["te"]["b"]),
        ],
        axis=-1,
    )
    te_n = kernels.time_encode(dt, params["te"]["w"], params["te"]["b"])
    nbr_in = jnp.concatenate(
        [
            memory[ids.reshape(-1)].reshape(s, k, -1),
            cm.linear(params["proj"], node_feats[ids.reshape(-1)]).reshape(s, k, -1),
            te_n,
            feats,
        ],
        axis=-1,
    )
    attn = cm.mha_neighbors(params["attn"], self_in, nbr_in, mask, dims.heads)
    return cm.mlp2(params["merge"], jnp.concatenate([attn, memory[seed_ids]], axis=-1))


def _memory_update(params, profile, extra, src, dst, t, valid, edge_feats):
    """GRU memory update with mean message aggregation (scatter-free)."""
    mem, last = extra["memory"], extra["last_update"]
    n = profile.n

    def messages(a_ids, b_ids):
        dt = jnp.maximum(t - last[a_ids], 0.0)
        te = kernels.time_encode(dt, params["te"]["w"], params["te"]["b"])
        raw = jnp.concatenate([mem[a_ids], mem[b_ids], te, edge_feats], axis=-1)
        return cm.linear(params["msg"], raw)

    def apply(mem_in, ids, msg):
        oh = cm.onehot(ids, n) * valid[:, None]  # [B, N]
        count = oh.sum(axis=0)[:, None]  # [N, 1]
        agg = kernels.matmul(oh.T, msg) / jnp.maximum(count, 1.0)
        updated = _gru(params["gru"], agg, mem_in)
        touched = jnp.minimum(count, 1.0)
        return mem_in + touched * (updated - mem_in)

    mem1 = apply(mem, src, messages(src, dst))
    mem2 = apply(mem1, dst, messages(dst, src))
    t_masked = t * valid - 1e30 * (1.0 - valid)
    contrib = jnp.maximum(
        (cm.onehot(src, n) * t_masked[:, None]).max(axis=0),
        (cm.onehot(dst, n) * t_masked[:, None]).max(axis=0),
    )
    last2 = jnp.maximum(last, contrib)
    return {"memory": mem2, "last_update": last2}


def _nbr_block(prefix, p, rows):
    return [
        (f"{prefix}ids", "i32", (rows, p.k)),
        (f"{prefix}dt", "f32", (rows, p.k)),
        (f"{prefix}mask", "f32", (rows, p.k)),
        (f"{prefix}feats", "f32", (rows, p.k, p.d_edge)),
    ]


def _specs(profile, task):
    p = profile
    base = [("node_feats", "f32", (p.n, p.d_static))]
    update = [
        ("src", "i32", (p.b,)),
        ("dst", "i32", (p.b,)),
        ("t", "f32", (p.b,)),
        ("valid", "f32", (p.b,)),
        ("edge_feats", "f32", (p.b, p.d_edge)),
    ]
    if task == "link":
        train = base + [
            ("src", "i32", (p.b,)),
            ("dst", "i32", (p.b,)),
            ("neg", "i32", (p.b,)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
            ("edge_feats", "f32", (p.b, p.d_edge)),
        ] + _nbr_block("nbr_", p, 3 * p.b)
        predict = base + [
            ("src", "i32", (p.b,)),
            ("cand", "i32", (p.b, p.c)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ] + _nbr_block("src_nbr_", p, p.b) + _nbr_block("cand_nbr_", p, p.b * p.c)
    else:
        train = base + [
            ("nodes", "i32", (p.b,)),
            ("target", "f32", (p.b, p.p)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ] + _nbr_block("nbr_", p, p.b)
        predict = base + [
            ("nodes", "i32", (p.b,)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ] + _nbr_block("nbr_", p, p.b)
    return {"train": train, "predict": predict, "update": update}


def build(profile, dims, task="link"):
    """TGN model definition (task = "link" | "node")."""

    def init_state(seed):
        params = _init_params(profile, dims, seed, task)
        extra = {
            "memory": jnp.zeros((profile.n, dims.memory), jnp.float32),
            "last_update": jnp.zeros((profile.n,), jnp.float32),
        }
        return cm.make_state(params, extra)

    def nbr(batch, prefix="nbr_"):
        return (
            batch[f"{prefix}ids"],
            batch[f"{prefix}dt"],
            batch[f"{prefix}mask"],
            batch[f"{prefix}feats"],
        )

    def loss_fn(params, extra, batch):
        mem = jax.lax.stop_gradient(extra["memory"])
        if task == "link":
            seeds = jnp.concatenate([batch["src"], batch["dst"], batch["neg"]])
            h = _embed(params, dims, mem, batch["node_feats"], seeds, nbr(batch))
            b = profile.b
            pos = cm.link_decode(params["dec"], h[:b], h[b : 2 * b])
            neg = cm.link_decode(params["dec"], h[:b], h[2 * b :])
            return cm.bce_link_loss(pos, neg, batch["valid"])
        h = _embed(params, dims, mem, batch["node_feats"], batch["nodes"], nbr(batch))
        logits = cm.mlp2(params["head"], h)
        return cm.node_property_loss(logits, batch["target"], batch["valid"])

    def train(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], state["extra"], batch)
        state = cm.adam_step(state, grads, dims.lr)
        if task == "link":
            extra = _memory_update(
                state["params"], profile, state["extra"],
                batch["src"], batch["dst"], batch["t"], batch["valid"], batch["edge_feats"],
            )
            state = {**state, "extra": jax.tree_util.tree_map(jax.lax.stop_gradient, extra)}
        return state, loss

    def predict(state, batch):
        params, mem = state["params"], state["extra"]["memory"]
        if task == "link":
            b, c = profile.b, profile.c
            h_src = _embed(params, dims, mem, batch["node_feats"], batch["src"], nbr(batch, "src_nbr_"))
            h_cand = _embed(
                params, dims, mem, batch["node_feats"], batch["cand"].reshape(-1), nbr(batch, "cand_nbr_")
            ).reshape(b, c, dims.embed)
            h_src_t = jnp.broadcast_to(h_src[:, None, :], (b, c, dims.embed))
            return cm.link_decode(params["dec"], h_src_t, h_cand)
        h = _embed(params, dims, mem, batch["node_feats"], batch["nodes"], nbr(batch))
        return cm.mlp2(params["head"], h)

    def update(state, batch):
        extra = _memory_update(
            state["params"], profile, state["extra"],
            batch["src"], batch["dst"], batch["t"], batch["valid"], batch["edge_feats"],
        )
        return {**state, "extra": extra}

    return {
        "name": f"tgn_{task}",
        "profile": profile,
        "init_state": init_state,
        "specs": _specs(profile, task),
        "fns": {"train": train, "predict": predict, "update": update},
    }
