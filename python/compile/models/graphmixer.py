"""GraphMixer (Sarıgün, 2023 adaptation): MLP-Mixer over recent neighbors.

Tokens are the K most recent neighbor interactions (edge features +
Bochner time encoding); mixer blocks alternate token-mixing and
channel-mixing MLPs, followed by mean pooling and a static-feature
branch. Parameter-efficient and attention-free.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _mixer_block_init(rng, tokens, channels, t_hidden, c_hidden):
    return {
        "tok": cm.mlp2_init(rng, tokens, t_hidden, tokens),
        "chan": cm.mlp2_init(rng, channels, c_hidden, channels),
    }


def _mixer_block(p, x):
    """x: [S, K, C] -> token-mix over K, then channel-mix over C."""
    y = x + cm.mlp2(p["tok"], cm.layer_norm(x).swapaxes(-1, -2)).swapaxes(-1, -2)
    return y + cm.mlp2(p["chan"], cm.layer_norm(y))


def _init_params(profile, dims, seed):
    rng = np.random.default_rng(seed)
    d = dims.embed
    chan = profile.d_edge + dims.time
    # Table 14: token-dim factor 0.5, channel-dim factor 4.0.
    t_hidden = max(int(profile.k * 0.5), 4)
    c_hidden = chan * 4
    return {
        "te": cm.time_encoder_init(rng, dims.time),
        "block1": _mixer_block_init(rng, profile.k, chan, t_hidden, c_hidden),
        "block2": _mixer_block_init(rng, profile.k, chan, t_hidden, c_hidden),
        "out": cm.linear_init(rng, chan, d),
        "node": cm.linear_init(rng, profile.d_static, d),
        "dec": cm.link_decoder_init(rng, d),
    }


def _embed(params, node_feats, seed_ids, nbr):
    ids, dt, mask, feats = nbr
    del ids
    te = kernels.time_encode(dt, params["te"]["w"], params["te"]["b"])
    x = jnp.concatenate([feats, te], axis=-1) * mask[..., None]
    x = _mixer_block(params["block1"], x)
    x = _mixer_block(params["block2"], x)
    pooled = x.sum(axis=1) / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return cm.linear(params["out"], pooled) + cm.linear(params["node"], node_feats[seed_ids])


def _nbr_block(prefix, p, rows):
    return [
        (f"{prefix}ids", "i32", (rows, p.k)),
        (f"{prefix}dt", "f32", (rows, p.k)),
        (f"{prefix}mask", "f32", (rows, p.k)),
        (f"{prefix}feats", "f32", (rows, p.k, p.d_edge)),
    ]


def build(profile, dims):
    """GraphMixer link-prediction model definition."""
    p = profile

    specs = {
        "train": [
            ("node_feats", "f32", (p.n, p.d_static)),
            ("src", "i32", (p.b,)),
            ("dst", "i32", (p.b,)),
            ("neg", "i32", (p.b,)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ]
        + _nbr_block("nbr_", p, 3 * p.b),
        "predict": [
            ("node_feats", "f32", (p.n, p.d_static)),
            ("src", "i32", (p.b,)),
            ("cand", "i32", (p.b, p.c)),
            ("t", "f32", (p.b,)),
            ("valid", "f32", (p.b,)),
        ]
        + _nbr_block("src_nbr_", p, p.b)
        + _nbr_block("cand_nbr_", p, p.b * p.c),
    }

    def init_state(seed):
        return cm.make_state(_init_params(profile, dims, seed))

    def nbr(batch, prefix="nbr_"):
        return (
            batch[f"{prefix}ids"],
            batch[f"{prefix}dt"],
            batch[f"{prefix}mask"],
            batch[f"{prefix}feats"],
        )

    def loss_fn(params, batch):
        seeds = jnp.concatenate([batch["src"], batch["dst"], batch["neg"]])
        h = _embed(params, batch["node_feats"], seeds, nbr(batch))
        b = p.b
        pos = cm.link_decode(params["dec"], h[:b], h[b : 2 * b])
        neg = cm.link_decode(params["dec"], h[:b], h[2 * b :])
        return cm.bce_link_loss(pos, neg, batch["valid"])

    def train(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        # Table 14: GraphMixer lr 2e-4.
        return cm.adam_step(state, grads, 2e-4), loss

    def predict(state, batch):
        params = state["params"]
        h_src = _embed(params, batch["node_feats"], batch["src"], nbr(batch, "src_nbr_"))
        h_cand = _embed(
            params, batch["node_feats"], batch["cand"].reshape(-1), nbr(batch, "cand_nbr_")
        ).reshape(p.b, p.c, dims.embed)
        h_src_t = jnp.broadcast_to(h_src[:, None, :], (p.b, p.c, dims.embed))
        return cm.link_decode(params["dec"], h_src_t, h_cand)

    return {
        "name": "graphmixer_link",
        "profile": profile,
        "init_state": init_state,
        "specs": specs,
        "fns": {"train": train, "predict": predict},
    }
