"""TGAT (da Xu et al., 2020): two-layer temporal graph attention.

Each seed embedding is computed by attending over its K sampled
temporal neighbors, whose own embeddings come from a first attention
layer over their K2 neighbors (hop-2). Time deltas enter through the
Bochner time encoder; both the encoder and the masked attention run as
Pallas kernels (L1).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import common as cm


def _specs_train(p):
    s = 3 * p.b
    return [
        ("node_feats", "f32", (p.n, p.d_static)),
        ("src", "i32", (p.b,)),
        ("dst", "i32", (p.b,)),
        ("neg", "i32", (p.b,)),
        ("t", "f32", (p.b,)),
        ("valid", "f32", (p.b,)),
        ("nbr_ids", "i32", (s, p.k)),
        ("nbr_dt", "f32", (s, p.k)),
        ("nbr_mask", "f32", (s, p.k)),
        ("nbr_feats", "f32", (s, p.k, p.d_edge)),
        ("nbr2_ids", "i32", (s * p.k, p.k2)),
        ("nbr2_dt", "f32", (s * p.k, p.k2)),
        ("nbr2_mask", "f32", (s * p.k, p.k2)),
        ("nbr2_feats", "f32", (s * p.k, p.k2, p.d_edge)),
    ]


def _specs_predict(p):
    bc = p.b * p.c
    return [
        ("node_feats", "f32", (p.n, p.d_static)),
        ("src", "i32", (p.b,)),
        ("cand", "i32", (p.b, p.c)),
        ("t", "f32", (p.b,)),
        ("valid", "f32", (p.b,)),
        ("src_nbr_ids", "i32", (p.b, p.k)),
        ("src_nbr_dt", "f32", (p.b, p.k)),
        ("src_nbr_mask", "f32", (p.b, p.k)),
        ("src_nbr_feats", "f32", (p.b, p.k, p.d_edge)),
        ("src_nbr2_ids", "i32", (p.b * p.k, p.k2)),
        ("src_nbr2_dt", "f32", (p.b * p.k, p.k2)),
        ("src_nbr2_mask", "f32", (p.b * p.k, p.k2)),
        ("src_nbr2_feats", "f32", (p.b * p.k, p.k2, p.d_edge)),
        ("cand_nbr_ids", "i32", (bc, p.k)),
        ("cand_nbr_dt", "f32", (bc, p.k)),
        ("cand_nbr_mask", "f32", (bc, p.k)),
        ("cand_nbr_feats", "f32", (bc, p.k, p.d_edge)),
        ("cand_nbr2_ids", "i32", (bc * p.k, p.k2)),
        ("cand_nbr2_dt", "f32", (bc * p.k, p.k2)),
        ("cand_nbr2_mask", "f32", (bc * p.k, p.k2)),
        ("cand_nbr2_feats", "f32", (bc * p.k, p.k2, p.d_edge)),
    ]


def _init_params(profile, dims, seed):
    rng = np.random.default_rng(seed)
    d = dims.embed
    kv_dim = d + dims.time + profile.d_edge
    return {
        "proj": cm.linear_init(rng, profile.d_static, d),
        "te": cm.time_encoder_init(rng, dims.time),
        "attn1": cm.mha_init(rng, d + dims.time, kv_dim, d),
        "attn2": cm.mha_init(rng, d + dims.time, kv_dim, d),
        "merge1": cm.mlp2_init(rng, 2 * d, d, d),
        "merge2": cm.mlp2_init(rng, 2 * d, d, d),
        "dec": cm.link_decoder_init(rng, d),
    }


def _layer(params, attn_key, merge_key, self_emb, nbr_emb, nbr_dt, nbr_mask, nbr_feats, heads):
    """One TGAT layer: self_emb [S,D] attends over nbr_emb [S,K,D]."""
    te0 = kernels.time_encode(jnp.zeros(self_emb.shape[0], jnp.float32), params["te"]["w"], params["te"]["b"])
    q_in = jnp.concatenate([self_emb, te0], axis=-1)
    te_n = kernels.time_encode(nbr_dt, params["te"]["w"], params["te"]["b"])
    kv_in = jnp.concatenate([nbr_emb, te_n, nbr_feats], axis=-1)
    attn = cm.mha_neighbors(params[attn_key], q_in, kv_in, nbr_mask, heads)
    return cm.mlp2(params[merge_key], jnp.concatenate([attn, self_emb], axis=-1))


def _embed(params, dims, node_feats, seed_ids, nbr, nbr2):
    """Two-layer TGAT embedding for S seeds.

    nbr = (ids [S,K], dt, mask, feats); nbr2 = (ids [S*K,K2], dt, mask, feats).
    """
    ids1, dt1, mask1, feats1 = nbr
    ids2, dt2, mask2, feats2 = nbr2
    s, k = ids1.shape
    proj = lambda ids: cm.linear(params["proj"], node_feats[ids])

    # Layer 1: embed every hop-1 neighbor by attending over its hop-2 ring.
    h1_self = proj(ids1.reshape(-1))  # [S*K, D]
    h1_nbr = proj(ids2.reshape(-1)).reshape(s * k, -1, dims.embed)  # [S*K, K2, D]
    h1 = _layer(params, "attn1", "merge1", h1_self, h1_nbr, dt2, mask2, feats2, dims.heads)

    # Layer 2: seeds attend over embedded hop-1 neighbors.
    h2_self = proj(seed_ids)
    h2_nbr = h1.reshape(s, k, dims.embed)
    return _layer(params, "attn2", "merge2", h2_self, h2_nbr, dt1, mask1, feats1, dims.heads)


def build(profile, dims):
    """TGAT link-prediction model definition for `aot.py`."""

    def init_state(seed):
        return cm.make_state(_init_params(profile, dims, seed))

    def loss_fn(params, batch):
        seeds = jnp.concatenate([batch["src"], batch["dst"], batch["neg"]])
        h = _embed(
            params,
            dims,
            batch["node_feats"],
            seeds,
            (batch["nbr_ids"], batch["nbr_dt"], batch["nbr_mask"], batch["nbr_feats"]),
            (batch["nbr2_ids"], batch["nbr2_dt"], batch["nbr2_mask"], batch["nbr2_feats"]),
        )
        b = profile.b
        h_src, h_dst, h_neg = h[:b], h[b : 2 * b], h[2 * b :]
        pos = cm.link_decode(params["dec"], h_src, h_dst)
        neg = cm.link_decode(params["dec"], h_src, h_neg)
        return cm.bce_link_loss(pos, neg, batch["valid"])

    def train(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        return cm.adam_step(state, grads, dims.lr), loss

    def predict(state, batch):
        params = state["params"]
        b, c, k = profile.b, profile.c, profile.k
        h_src = _embed(
            params,
            dims,
            batch["node_feats"],
            batch["src"],
            (batch["src_nbr_ids"], batch["src_nbr_dt"], batch["src_nbr_mask"], batch["src_nbr_feats"]),
            (batch["src_nbr2_ids"], batch["src_nbr2_dt"], batch["src_nbr2_mask"], batch["src_nbr2_feats"]),
        )
        h_cand = _embed(
            params,
            dims,
            batch["node_feats"],
            batch["cand"].reshape(-1),
            (batch["cand_nbr_ids"], batch["cand_nbr_dt"], batch["cand_nbr_mask"], batch["cand_nbr_feats"]),
            (batch["cand_nbr2_ids"], batch["cand_nbr2_dt"], batch["cand_nbr2_mask"], batch["cand_nbr2_feats"]),
        ).reshape(b, c, dims.embed)
        h_src_tiled = jnp.broadcast_to(h_src[:, None, :], (b, c, dims.embed))
        return cm.link_decode(params["dec"], h_src_tiled, h_cand)

    return {
        "name": "tgat_link",
        "profile": profile,
        "init_state": init_state,
        "specs": {"train": _specs_train(profile), "predict": _specs_predict(profile)},
        "fns": {"train": train, "predict": predict},
    }
