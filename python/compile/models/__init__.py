"""L2 model zoo. Every module exposes ``build(...) -> model def dict``
with keys: name, profile, init_state, specs, fns (see model.py)."""
