"""Artifact size profiles and model hyperparameters.

AOT compilation via PJRT requires static shapes, so every artifact is
compiled against a *profile*: the padded node count ``n``, batch size
``b``, neighbor fan-outs, and feature dims. The Rust coordinator pads
host batches to the profile and masks the padding in-graph.

Hyperparameters follow the paper's Table 14, scaled down for the CPU
test bed (documented in DESIGN.md "Environment deviations").
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Profile:
    """Static-shape envelope one artifact family is compiled against."""

    name: str
    n: int  # padded node count
    b: int = 200  # batch size (Table 14)
    k: int = 10  # one-hop neighbors
    k2: int = 5  # two-hop fan-out (TGAT)
    seq: int = 32  # sequence length (DyGFormer; Table 14 "# Neighbors" = 32)
    c: int = 11  # eval candidates per positive (1 pos + 10 negatives)
    d_edge: int = 16  # edge feature dim
    d_static: int = 8  # static node feature dim
    p: int = 16  # node-property classes


# CTDG models operate on event streams with up to 1024 nodes.
CTDG = Profile(name="ctdg1k", n=1024)
# DTDG models build dense NxN snapshot adjacencies; keep N at 512.
DTDG = Profile(name="dtdg512", n=512)

PROFILES = {p.name: p for p in (CTDG, DTDG)}


@dataclass(frozen=True)
class Dims:
    """Model dims (Table 14, scaled: embed 100->64, time 100->32)."""

    embed: int = 64
    time: int = 32
    memory: int = 64
    heads: int = 2
    hidden: int = 64
    # DyGFormer
    patch: int = 4
    channel: int = 32
    layers: int = 2
    # TPNet random-projection dim
    rp: int = 64
    rp_decay: float = 1e-6
    # Optimizer (Table 14)
    lr: float = 1e-4
    lr_snapshot: float = 1e-3


DIMS = Dims()
