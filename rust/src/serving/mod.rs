//! Sharded multi-tenant serving over versioned storage snapshots.
//!
//! Many tenant graphs, one machine: each tenant owns an append-only
//! [`SegmentedStorage`] writer with its **own** [`SealPolicy`] and
//! compaction cadence, and publishes immutable [`StorageSnapshot`]
//! generations independently through a [`crate::graph::SnapshotCell`].
//! Serving requests **pin** the latest published generation atomically:
//! a request that pinned generation *G* streams byte-stable batches from
//! *G* forever, while the next request observes *G+1* — there is no torn
//! read across a swap, because a snapshot is immutable and the cell swap
//! is a single `Arc` exchange behind an `RwLock`.
//!
//! The [`TenantRouter`] maps [`TenantId`]s to [`TenantHandle`]s and
//! multiplexes batch-materialization over one shared
//! [`crate::loader::ServingPool`]: [`TenantRouter::serve`] opens a
//! [`crate::loader::PooledStream`] over the tenant's pinned snapshot, so
//! all tenants' materialization jobs interleave over one fixed set of
//! worker threads while each tenant's *stateful* hook phase still runs
//! in batch order on its own consumer (the stream borrows the caller's
//! [`HookManager`]). Per-segment CSR indices in the shared
//! [`crate::graph::AdjacencyCache`] key on never-reused snapshot/segment
//! ids, so generations and tenants reuse indices without
//! cross-contamination.
//!
//! Writer and readers never contend: ingestion takes the tenant's
//! writer lock, serving only touches the published cell and the pinned
//! `Arc`s. `examples/multi_tenant_serving.rs` runs ≥3 tenants ingesting
//! and serving concurrently; the `ablation.sharded` bench compares one
//! shared pool against per-tenant dedicated prefetch loaders.
//!
//! Reads scale out past one store through the [`ReadHandle`] trait: a
//! writable [`TenantHandle`] and a WAL-tailing [`ReplicaHandle`] (see
//! [`crate::replica`]) serve the identical pin / batch-stream /
//! point-query surface, [`ServingConfig`] is the single entry point
//! that decides which one a config builds
//! ([`ServingConfig::primary`] / [`ServingConfig::replica`]), and
//! [`TenantRouter::read_handle`] picks the freshest registered handle
//! for an id while [`TenantRouter::read_handles`] exposes the whole
//! fan-out set. `examples/replicated_serving.rs` runs one primary and
//! two tailing replicas over a shared pool.

use crate::error::{Result, TgmError};
use crate::graph::{
    AdjacencyCache, DGraph, DtdgHandle, Event, PointQuery, PointReader, PointResponse, ReduceOp,
    SealPolicy, SegmentedStorage, SnapshotCell, StorageSnapshot,
};
use crate::hooks::manager::HookManager;
use crate::loader::{
    BatchBy, PointTicket, PooledStream, QosTag, RequestClass, ServingPool, StreamConfig,
};
use crate::obs::{self, Counter, Gauge, Label};
use crate::persist::{
    self, Compactor, CompactorConfig, DurabilityPolicy, RecoveryReport, SegmentBacking,
};
use crate::replica::{
    BootstrapReport, DirTransport, Replica, ReplicaConfig, ReplicaShared, ReplicaTailer,
    ReplicationLog,
};
use crate::util::TimeGranularity;
use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Name of one tenant graph (routing key).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(String);

impl TenantId {
    /// Wrap a tenant name.
    pub fn new(id: impl Into<String>) -> TenantId {
        TenantId(id.into())
    }

    /// The raw name.
    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for TenantId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for TenantId {
    fn from(s: &str) -> TenantId {
        TenantId(s.to_string())
    }
}

impl From<String> for TenantId {
    fn from(s: String) -> TenantId {
        TenantId(s)
    }
}

/// Per-tenant scheduling policy: how the shared pool's scheduler
/// weighs this tenant's requests and how deep its queues may grow
/// before admission control sheds load (see [`crate::loader::sched`]).
#[derive(Debug, Clone, Copy)]
pub struct QosPolicy {
    /// Relative service share under the weighted-DRR scheduler
    /// (clamped to `1..=1024` at the scheduler).
    pub weight: u32,
    /// Per-`(tenant, class)` admission cap; `None` uses the scheduler
    /// default (`TGM_QOS_DEPTH` or its built-in cap). A full queue
    /// rejects new requests with [`TgmError::Backpressure`].
    pub max_queued: Option<usize>,
}

impl Default for QosPolicy {
    fn default() -> QosPolicy {
        QosPolicy { weight: 1, max_queued: None }
    }
}

/// Per-tenant storage policy: every tenant gets its own writer, seal
/// policy and compaction cadence.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    /// Node-id space of the tenant's graph.
    pub num_nodes: usize,
    /// When the tenant's active segment auto-seals.
    pub seal: SealPolicy,
    /// Compact once more than this many sealed segments pile up (bounds
    /// per-request segment fan-out); `usize::MAX` disables the
    /// synchronous path (e.g. when a background
    /// [`TenantHandle::attach_compactor`] owns compaction instead).
    pub compact_after: usize,
    /// Fixed native granularity; `None` infers from the stream.
    pub granularity: Option<TimeGranularity>,
    /// Durable backing for the tenant's store (see [`crate::persist`]):
    /// `None` keeps it in memory only. When the directory already holds
    /// a store, [`TenantRouter::add_tenant`] **recovers** it and
    /// publishes the recovered generation so serving resumes
    /// immediately; `granularity` then defers to the persisted
    /// metadata, while a `num_nodes` mismatch is rejected with a typed
    /// [`TgmError::Serving`]. Directories must be exclusive to one
    /// tenant — the router rejects a duplicate within itself (one
    /// writer per directory across processes is the operator's
    /// contract).
    pub durable: Option<DurabilityPolicy>,
    /// Scheduling weight and admission cap for this tenant's requests
    /// on the shared pool (weight 1, default cap unless overridden).
    pub qos: QosPolicy,
}

impl TenantConfig {
    /// Defaults: default seal policy, compaction past 8 sealed segments,
    /// inferred granularity, weight-1 QoS.
    pub fn new(num_nodes: usize) -> TenantConfig {
        TenantConfig {
            num_nodes,
            seal: SealPolicy::default(),
            compact_after: 8,
            granularity: None,
            durable: None,
            qos: QosPolicy::default(),
        }
    }

    /// Set the seal policy.
    pub fn with_seal(mut self, seal: SealPolicy) -> TenantConfig {
        self.seal = seal;
        self
    }

    /// Set the compaction threshold.
    pub fn with_compact_after(mut self, n: usize) -> TenantConfig {
        self.compact_after = n;
        self
    }

    /// Fix the native granularity up front.
    pub fn with_granularity(mut self, g: TimeGranularity) -> TenantConfig {
        self.granularity = Some(g);
        self
    }

    /// Persist the tenant's store under `policy.dir` (recovering an
    /// existing store on restart).
    #[deprecated(note = "build through `ServingConfig::primary` instead")]
    pub fn with_durability(mut self, policy: DurabilityPolicy) -> TenantConfig {
        self.durable = Some(policy);
        self
    }

    /// Set the tenant's scheduling weight (relative service share on
    /// the shared pool).
    #[deprecated(note = "use `ServingConfig::qos_weight` instead")]
    pub fn with_qos_weight(mut self, weight: u32) -> TenantConfig {
        self.qos.weight = weight;
        self
    }

    /// Cap the tenant's per-class queues: beyond `cap` queued requests,
    /// new ones are rejected with [`TgmError::Backpressure`].
    #[deprecated(note = "use `ServingConfig::admission_cap` instead")]
    pub fn with_admission_cap(mut self, cap: usize) -> TenantConfig {
        self.qos.max_queued = Some(cap.max(1));
        self
    }
}

/// Where a [`ServingConfig`] puts the graph's bytes.
#[derive(Clone)]
enum ServingRole {
    /// In-memory writer, no durable backing.
    InMemory,
    /// Durable writer over this directory (recovered when it exists).
    Primary(PathBuf),
    /// WAL-tailing read replica: bootstrap from `log`, keep local copies
    /// under `dir` (see [`crate::replica`]).
    Replica { log: Arc<dyn ReplicationLog>, dir: PathBuf },
}

/// Single entry point for serving configuration: the storage role
/// (in-memory, durable primary, or read replica) is fixed by the
/// constructor, every knob that used to be scattered across
/// [`TenantConfig`], [`DurabilityPolicy`] and [`QosPolicy`] builders
/// hangs off one value, and the router consumes it directly via
/// [`TenantRouter::add_primary`] / [`TenantRouter::add_replica`].
///
/// ```no_run
/// use tgm::serving::{ServingConfig, TenantRouter};
/// let mut router = TenantRouter::new();
/// let _primary = router.add_primary(
///     "events",
///     ServingConfig::primary(1024, "/var/lib/tgm/events")
///         .group_commit()
///         .qos_weight(4),
/// )?;
/// let _replica = router.add_replica(
///     "events",
///     ServingConfig::replica("/var/lib/tgm/events", "/var/lib/tgm/events-r0"),
/// )?;
/// # Ok::<(), tgm::TgmError>(())
/// ```
#[derive(Clone)]
pub struct ServingConfig {
    role: ServingRole,
    num_nodes: usize,
    seal: SealPolicy,
    compact_after: usize,
    granularity: Option<TimeGranularity>,
    qos: QosPolicy,
    fsync: bool,
    group_commit: bool,
    mmap: bool,
    poll_interval: Duration,
}

impl ServingConfig {
    fn base(role: ServingRole, num_nodes: usize, mmap: bool) -> ServingConfig {
        ServingConfig {
            role,
            num_nodes,
            seal: SealPolicy::default(),
            compact_after: 8,
            granularity: None,
            qos: QosPolicy::default(),
            fsync: false,
            group_commit: false,
            mmap,
            poll_interval: Duration::from_millis(10),
        }
    }

    /// In-memory tenant (no durable backing), default policies.
    pub fn in_memory(num_nodes: usize) -> ServingConfig {
        ServingConfig::base(ServingRole::InMemory, num_nodes, false)
    }

    /// Durable primary persisting under `dir` (recovering an existing
    /// store on restart). Heap-backed, no fsync per append by default —
    /// opt into [`ServingConfig::fsync`], [`ServingConfig::group_commit`]
    /// or [`ServingConfig::mmap`].
    pub fn primary(num_nodes: usize, dir: impl Into<PathBuf>) -> ServingConfig {
        ServingConfig::base(ServingRole::Primary(dir.into()), num_nodes, false)
    }

    /// Read replica of the primary persisting at `primary_dir`, keeping
    /// its local segment copies under `replica_dir`. Mmap-backed by
    /// default (the replica's working set is read-only file bytes). The
    /// node-id space, granularity and seal policy all come from the
    /// primary's manifest, so only QoS and replication knobs apply.
    pub fn replica(
        primary_dir: impl Into<PathBuf>,
        replica_dir: impl Into<PathBuf>,
    ) -> ServingConfig {
        ServingConfig::replica_over(Arc::new(DirTransport::new(primary_dir)), replica_dir)
    }

    /// Read replica over an arbitrary [`ReplicationLog`] transport
    /// (socket-ready variant of [`ServingConfig::replica`]).
    pub fn replica_over(
        log: Arc<dyn ReplicationLog>,
        replica_dir: impl Into<PathBuf>,
    ) -> ServingConfig {
        ServingConfig::base(ServingRole::Replica { log, dir: replica_dir.into() }, 0, true)
    }

    /// Set the primary's seal policy.
    pub fn seal(mut self, seal: SealPolicy) -> ServingConfig {
        self.seal = seal;
        self
    }

    /// Set the primary's synchronous compaction threshold.
    pub fn compact_after(mut self, n: usize) -> ServingConfig {
        self.compact_after = n;
        self
    }

    /// Fix the native granularity up front (primaries only; replicas
    /// inherit it from the manifest).
    pub fn granularity(mut self, g: TimeGranularity) -> ServingConfig {
        self.granularity = Some(g);
        self
    }

    /// Scheduling weight on the shared pool (relative service share).
    pub fn qos_weight(mut self, weight: u32) -> ServingConfig {
        self.qos.weight = weight;
        self
    }

    /// Per-class admission cap: beyond `cap` queued requests, new ones
    /// are rejected with [`TgmError::Backpressure`].
    pub fn admission_cap(mut self, cap: usize) -> ServingConfig {
        self.qos.max_queued = Some(cap.max(1));
        self
    }

    /// Fsync every WAL append before acknowledging it (primaries).
    pub fn fsync(mut self) -> ServingConfig {
        self.fsync = true;
        self
    }

    /// Group-commit the WAL: appends buffer and one fsync acknowledges
    /// the whole commit window (primaries; implies fsync-on-ack).
    pub fn group_commit(mut self) -> ServingConfig {
        self.fsync = true;
        self.group_commit = true;
        self
    }

    /// Mmap sealed segment files instead of heap-copying them
    /// (degrades to heap where unsupported; replicas default to this).
    pub fn mmap(mut self) -> ServingConfig {
        self.mmap = true;
        self
    }

    /// How often a replica polls its primary for new state.
    pub fn poll_interval(mut self, interval: Duration) -> ServingConfig {
        self.poll_interval = interval;
        self
    }

    /// Lower to the per-tenant storage config. Typed error for a
    /// replica-role config (register those with
    /// [`TenantRouter::add_replica`]).
    pub fn into_tenant_config(self) -> Result<TenantConfig> {
        let backing = if self.mmap { SegmentBacking::Mmap } else { SegmentBacking::Heap };
        let durable = match self.role {
            ServingRole::InMemory => None,
            ServingRole::Primary(dir) => Some(DurabilityPolicy {
                dir,
                fsync_appends: self.fsync,
                group_commit: self.group_commit,
                backing,
            }),
            ServingRole::Replica { .. } => {
                return Err(TgmError::Serving(
                    "a replica ServingConfig cannot build a tenant; register it \
                     with TenantRouter::add_replica"
                        .into(),
                ))
            }
        };
        Ok(TenantConfig {
            num_nodes: self.num_nodes,
            seal: self.seal,
            compact_after: self.compact_after,
            granularity: self.granularity,
            durable,
            qos: self.qos,
        })
    }

    /// Lower to the replica transport + config. Typed error for a
    /// non-replica role.
    fn into_replica_parts(self) -> Result<(Arc<dyn ReplicationLog>, ReplicaConfig, QosPolicy)> {
        match self.role {
            ServingRole::Replica { log, dir } => {
                let backing =
                    if self.mmap { SegmentBacking::Mmap } else { SegmentBacking::Heap };
                let cfg = ReplicaConfig::new(dir)
                    .with_backing(backing)
                    .with_poll_interval(self.poll_interval);
                Ok((log, cfg, self.qos))
            }
            _ => Err(TgmError::Serving(
                "this ServingConfig builds a tenant (primary); register it with \
                 TenantRouter::add_tenant or TenantRouter::add_primary"
                    .into(),
            )),
        }
    }
}

/// One tenant: a locked writer plus the atomic publication cell. Shared
/// as an `Arc` so ingestors and servers hold it across threads (the
/// writer itself is `Arc`'d so a background [`Compactor`] can share it
/// without going through the handle).
pub struct TenantHandle {
    id: TenantId,
    writer: Arc<Mutex<SegmentedStorage>>,
    published: SnapshotCell,
    compact_after: usize,
    qos: QosPolicy,
    /// Per-tenant CSR index cache: readers for successive generations
    /// rebuild only the segments that changed.
    adjacency: AdjacencyCache,
    /// Memoized [`PointReader`] for the currently-published generation.
    reader: Mutex<Option<PointReader>>,
    /// `tgm_ingest_events_total{tenant}` (cached registry handle).
    ingested: Counter,
    /// `tgm_published_generation{tenant}`.
    generation_gauge: Gauge,
    /// `tgm_snapshot_age_us{tenant}`: µs between the last publish and
    /// the most recent pin (0 right after a publish).
    snapshot_age: Gauge,
    /// Monotonic µs timestamp of the last publish (0 before the first).
    published_at_us: AtomicU64,
    /// What recovery found on disk when this tenant was registered over
    /// an existing durable directory (`None` for fresh/in-memory
    /// tenants). Surfaced so operators can alert on torn tails or
    /// unexpectedly large dropped byte counts instead of recovery
    /// silently swallowing them.
    recovery: Option<RecoveryReport>,
}

impl TenantHandle {
    fn build(id: TenantId, cfg: TenantConfig) -> Result<TenantHandle> {
        let (store, recovery) = match &cfg.durable {
            Some(policy) if persist::store_exists(&policy.dir) => {
                let (store, report) = persist::recover_with_report(cfg.seal.clone(), policy.clone())?;
                if store.num_nodes() != cfg.num_nodes {
                    return Err(TgmError::Serving(format!(
                        "tenant `{id}` recovered {} nodes from {} but was configured \
                         with num_nodes={}",
                        store.num_nodes(),
                        policy.dir.display(),
                        cfg.num_nodes
                    )));
                }
                (store, Some(report))
            }
            durable => {
                let mut store = SegmentedStorage::new(cfg.num_nodes, cfg.seal.clone());
                if let Some(g) = cfg.granularity {
                    store = store.with_granularity(g);
                }
                if let Some(policy) = durable {
                    store = store.with_durability(policy.clone())?;
                }
                (store, None)
            }
        };
        let tenant = Label::from(id.as_str());
        let registry = obs::registry();
        let handle = TenantHandle {
            id,
            writer: Arc::new(Mutex::new(store)),
            published: SnapshotCell::new(),
            compact_after: cfg.compact_after,
            qos: cfg.qos,
            adjacency: AdjacencyCache::new(),
            reader: Mutex::new(None),
            ingested: registry
                .counter("tgm_ingest_events_total", &[("tenant", tenant.clone())]),
            generation_gauge: registry
                .gauge("tgm_published_generation", &[("tenant", tenant.clone())]),
            snapshot_age: registry.gauge("tgm_snapshot_age_us", &[("tenant", tenant)]),
            published_at_us: AtomicU64::new(0),
            recovery,
        };
        // A recovered tenant serves its pre-crash data immediately.
        {
            let mut w = handle.writer();
            if w.total_edges() > 0 {
                let snap = w.publish_to(&handle.published)?;
                handle.note_publish(snap.generation());
            }
        }
        Ok(handle)
    }

    /// Record a publish in the registry: generation gauge, publish
    /// timestamp (for the snapshot-age gauge), age reset to 0.
    fn note_publish(&self, generation: u64) {
        self.generation_gauge.set(generation.min(i64::MAX as u64) as i64);
        self.published_at_us.store(obs::trace::now_us().max(1), Ordering::Relaxed);
        self.snapshot_age.set(0);
    }

    fn writer(&self) -> std::sync::MutexGuard<'_, SegmentedStorage> {
        self.writer.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The routing key.
    pub fn id(&self) -> &TenantId {
        &self.id
    }

    /// Append a batch of events into this tenant's writer (auto-sealing
    /// per its policy) and return how many were appended. On error the
    /// events before the offending one remain appended — the stream
    /// position is the caller's to manage, exactly as with
    /// [`SegmentedStorage::append`].
    ///
    /// Under `DurabilityPolicy::with_group_commit` the chunk is
    /// acknowledged only after its group fsync lands: the appends
    /// buffer under the writer lock, then the barrier waits **outside**
    /// it — so concurrent ingest threads on one tenant share a single
    /// fsync per commit window instead of paying one each, and the
    /// writer lock is never held across disk latency.
    pub fn ingest(&self, events: impl IntoIterator<Item = Event>) -> Result<usize> {
        let (n, sync) = {
            let mut w = self.writer();
            let mut n = 0usize;
            for ev in events {
                w.append(ev)?;
                n += 1;
            }
            (n, w.wal_sync())
        };
        if let Some(sync) = sync {
            if let Err(e) = sync.barrier() {
                // The chunk's fsync outcome is unknown: poison the
                // store so nothing further is falsely acknowledged, and
                // report the chunk as not ingested durably.
                self.writer().poison_durability("a group-commit fsync failed during ingest");
                return Err(e);
            }
        }
        self.ingested.add(n as u64);
        Ok(n)
    }

    /// Compact if due, snapshot the current generation, and publish it:
    /// readers pinned to older generations keep them, new pins observe
    /// this one. The snapshot includes the frozen active tail, so
    /// nothing ingested so far is missing from it.
    pub fn publish(&self) -> Result<Arc<StorageSnapshot>> {
        let mut w = self.writer();
        w.maybe_compact(self.compact_after)?;
        let snap = w.publish_to(&self.published)?;
        self.note_publish(snap.generation());
        Ok(snap)
    }

    /// Pin the latest published generation. Typed error before the first
    /// [`TenantHandle::publish`].
    pub fn pin(&self) -> Result<Arc<StorageSnapshot>> {
        let snap = self.published.pin().ok_or_else(|| {
            TgmError::Serving(format!("tenant `{}` has not published a snapshot yet", self.id))
        })?;
        let published_at = self.published_at_us.load(Ordering::Relaxed);
        if published_at != 0 {
            let age = obs::trace::now_us().saturating_sub(published_at);
            self.snapshot_age.set(age.min(i64::MAX as u64) as i64);
        }
        Ok(snap)
    }

    /// Generation currently published (`None` before the first publish).
    pub fn published_generation(&self) -> Option<u64> {
        self.published.generation()
    }

    /// What recovery found on disk when this tenant was registered over
    /// an existing durable directory: sealed segments reopened, WAL
    /// records replayed, whether a torn trailing record was dropped and
    /// how many bytes went with it. `None` when the tenant started
    /// fresh (in-memory, or an empty durable directory).
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// This tenant's scheduling policy.
    pub fn qos(&self) -> QosPolicy {
        self.qos
    }

    /// The [`QosTag`] this tenant's requests of `class` carry on the
    /// shared pool's scheduler.
    pub fn qos_tag(&self, class: RequestClass) -> QosTag {
        let tag = QosTag::new(self.id.as_str(), class, self.qos.weight);
        match self.qos.max_queued {
            Some(cap) => tag.with_max_queued(cap),
            None => tag,
        }
    }

    /// A [`PointReader`] pinned to the latest published generation.
    /// Memoized per generation: repeated calls between publishes reuse
    /// the same reader, and advancing a generation re-indexes only the
    /// segments that changed (via the tenant's [`AdjacencyCache`]).
    /// Typed error before the first publish.
    pub fn reader(&self) -> Result<PointReader> {
        let snap = self.pin()?;
        let mut cached = self.reader.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(r) = cached.as_ref() {
            if r.snapshot().id() == snap.id() {
                return Ok(r.clone());
            }
        }
        let r = PointReader::with_cache(snap, &self.adjacency);
        *cached = Some(r.clone());
        Ok(r)
    }

    /// Answer one point query on the shared pool under this tenant's
    /// QoS tag, blocking for the response. The query runs against the
    /// latest published generation (pinned for the duration, so a
    /// concurrent publish cannot tear it). Admission control applies.
    pub fn query(&self, pool: &ServingPool, query: PointQuery) -> Result<PointResponse> {
        let reader = self.reader()?;
        pool.point_query(&reader, &self.qos_tag(RequestClass::PointQuery), query)
    }

    /// Submit one point query without blocking for the response (pair
    /// with [`PointTicket::wait`] to pipeline many queries).
    pub fn submit_query(&self, pool: &ServingPool, query: PointQuery) -> Result<PointTicket> {
        let reader = self.reader()?;
        pool.submit_point(&reader, &self.qos_tag(RequestClass::PointQuery), query)
    }

    /// Edge events ingested so far (sealed + active).
    pub fn total_edges(&self) -> usize {
        self.writer().total_edges()
    }

    /// Edge events buffered in the active segment.
    pub fn pending_edges(&self) -> usize {
        self.writer().pending_edges()
    }

    /// Sealed segments currently behind the writer.
    pub fn num_sealed_segments(&self) -> usize {
        self.writer().num_sealed_segments()
    }

    /// Directory backing this tenant's store when durability is on.
    pub fn durable_dir(&self) -> Option<std::path::PathBuf> {
        self.writer().durable_dir().map(|p| p.to_path_buf())
    }

    /// Spawn a background compactor for this tenant: sealed segments
    /// merge off the write path, and each compacted generation is
    /// published through the tenant's cell (readers pinned to older
    /// generations keep them). Pair with
    /// [`TenantConfig::with_compact_after`]`(usize::MAX)` to disable the
    /// synchronous path. The compactor stops when the returned handle is
    /// dropped.
    pub fn attach_compactor(&self, cfg: CompactorConfig) -> Compactor {
        Compactor::spawn(Arc::clone(&self.writer), self.published.clone(), cfg)
    }

    /// Register an incrementally-maintained DTDG materialized view on
    /// this tenant's writer (see [`crate::graph::dtdg`]). The view
    /// refreshes on every seal the tenant's ingest triggers and
    /// publishes generations through the returned handle's own cell —
    /// independent of the tenant's main publish cadence, so a coarse
    /// time-driven reader and the CTDG serving path coexist without
    /// coordinating.
    pub fn register_dtdg_view(
        &self,
        target: TimeGranularity,
        reduce: ReduceOp,
    ) -> Result<DtdgHandle> {
        self.writer().register_dtdg_view(target, reduce)
    }
}

/// Uniform read surface over anything that publishes snapshot
/// generations and serves under a QoS identity: a writable
/// [`TenantHandle`] (primary) and a WAL-tailing [`ReplicaHandle`]
/// expose the **same** pin / batch-stream / point-query API, so serving
/// code programs against `&dyn ReadHandle` (or `Arc<dyn ReadHandle>`
/// from [`TenantRouter::read_handle`]) and never branches on where the
/// bytes came from. Pinned reads are generation-stable on both: a
/// request that pinned generation *G* streams byte-identical batches
/// from *G* regardless of concurrent publishes or replica catch-up.
pub trait ReadHandle: Send + Sync {
    /// The serving identity requests run under (routing key, QoS
    /// tenant, metrics label).
    fn id(&self) -> &TenantId;

    /// Pin the latest published generation. Typed error before the
    /// first publish (primary) or first applied round (replica).
    fn pin(&self) -> Result<Arc<StorageSnapshot>>;

    /// Generation currently published (`None` before the first).
    fn published_generation(&self) -> Option<u64>;

    /// The [`QosTag`] this handle's requests of `class` carry on the
    /// shared pool's scheduler.
    fn qos_tag(&self, class: RequestClass) -> QosTag;

    /// A [`PointReader`] pinned to the latest published generation,
    /// memoized per generation.
    fn reader(&self) -> Result<PointReader>;

    /// Open a pooled batch stream over the latest published generation
    /// under this handle's QoS tag; the stream stays pinned to that
    /// generation even as newer ones publish mid-iteration.
    fn serve<'a>(
        &self,
        pool: &ServingPool,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: StreamConfig,
    ) -> Result<PooledStream<'a>> {
        let snap = self.pin()?;
        let cfg = cfg.with_qos(self.qos_tag(RequestClass::BatchScan));
        pool.stream(DGraph::full(snap), by, manager, cfg)
    }

    /// Answer one point query on the shared pool under this handle's
    /// QoS tag, blocking for the response.
    fn query(&self, pool: &ServingPool, query: PointQuery) -> Result<PointResponse> {
        let reader = self.reader()?;
        pool.point_query(&reader, &self.qos_tag(RequestClass::PointQuery), query)
    }

    /// Submit one point query without blocking for the response (pair
    /// with [`PointTicket::wait`] to pipeline many queries).
    fn submit_query(&self, pool: &ServingPool, query: PointQuery) -> Result<PointTicket> {
        let reader = self.reader()?;
        pool.submit_point(&reader, &self.qos_tag(RequestClass::PointQuery), query)
    }
}

impl ReadHandle for TenantHandle {
    fn id(&self) -> &TenantId {
        TenantHandle::id(self)
    }

    fn pin(&self) -> Result<Arc<StorageSnapshot>> {
        TenantHandle::pin(self)
    }

    fn published_generation(&self) -> Option<u64> {
        TenantHandle::published_generation(self)
    }

    fn qos_tag(&self, class: RequestClass) -> QosTag {
        TenantHandle::qos_tag(self, class)
    }

    fn reader(&self) -> Result<PointReader> {
        TenantHandle::reader(self)
    }
}

/// One read replica: a background tailer keeps a local
/// [`crate::replica::Replica`] in sync with its primary, and this
/// handle serves generation-pinned reads from the replica's publication
/// cell under its own QoS identity — the read-only sibling of
/// [`TenantHandle`], unified with it behind [`ReadHandle`].
pub struct ReplicaHandle {
    id: TenantId,
    cell: SnapshotCell,
    shared: Arc<ReplicaShared>,
    qos: QosPolicy,
    /// Per-replica CSR index cache (same reuse story as a tenant's).
    adjacency: AdjacencyCache,
    /// Memoized [`PointReader`] for the currently-published generation.
    reader: Mutex<Option<PointReader>>,
    /// Keeps the tailing thread alive; dropping the handle stops it.
    tailer: Mutex<Option<ReplicaTailer>>,
    report: BootstrapReport,
}

impl ReplicaHandle {
    fn build(id: TenantId, name: String, cfg: ServingConfig) -> Result<ReplicaHandle> {
        let (log, rcfg, qos) = cfg.into_replica_parts()?;
        let poll = rcfg.poll_interval;
        let (replica, report) = Replica::bootstrap(name.as_str(), log, rcfg)?;
        let cell = replica.cell();
        let shared = replica.shared();
        let tailer = replica.spawn_tailer(poll);
        Ok(ReplicaHandle {
            id,
            cell,
            shared,
            qos,
            adjacency: AdjacencyCache::new(),
            reader: Mutex::new(None),
            tailer: Mutex::new(Some(tailer)),
            report,
        })
    }

    /// The serving identity (shared with the primary it replicates, so
    /// the scheduler treats primary + replicas as one tenant).
    pub fn id(&self) -> &TenantId {
        &self.id
    }

    /// Pin the latest applied generation. Typed error before the first
    /// applied round.
    pub fn pin(&self) -> Result<Arc<StorageSnapshot>> {
        self.cell.pin().ok_or_else(|| {
            TgmError::Serving(format!(
                "replica of `{}` has not applied a publishable generation yet",
                self.id
            ))
        })
    }

    /// Generation currently published (`None` before the first round).
    pub fn published_generation(&self) -> Option<u64> {
        self.cell.generation()
    }

    /// The [`QosTag`] this replica's requests of `class` carry.
    pub fn qos_tag(&self, class: RequestClass) -> QosTag {
        let tag = QosTag::new(self.id.as_str(), class, self.qos.weight);
        match self.qos.max_queued {
            Some(cap) => tag.with_max_queued(cap),
            None => tag,
        }
    }

    /// A [`PointReader`] pinned to the latest applied generation,
    /// memoized per generation (see [`TenantHandle::reader`]).
    pub fn reader(&self) -> Result<PointReader> {
        let snap = self.pin()?;
        let mut cached = self.reader.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(r) = cached.as_ref() {
            if r.snapshot().id() == snap.id() {
                return Ok(r.clone());
            }
        }
        let r = PointReader::with_cache(snap, &self.adjacency);
        *cached = Some(r.clone());
        Ok(r)
    }

    /// What bootstrap copied, reused and replayed (see
    /// [`BootstrapReport`]) — the replica-side analogue of
    /// [`TenantHandle::recovery_report`].
    pub fn bootstrap_report(&self) -> &BootstrapReport {
        &self.report
    }

    /// Replication lag in µs (now − the manifest freshness of the last
    /// applied round); `None` before the first round.
    pub fn lag_us(&self) -> Option<u64> {
        self.shared.lag_us()
    }

    /// Generation of the last fully-applied round.
    pub fn applied_generation(&self) -> u64 {
        self.shared.applied_generation()
    }

    /// Segment/static bytes shipped from the primary so far (bootstrap
    /// plus compaction deltas; cached reuse ships nothing).
    pub fn shipped_bytes(&self) -> u64 {
        self.shared.shipped_bytes()
    }

    /// Wholesale resyncs taken so far (0 on the incremental fast path).
    pub fn resyncs(&self) -> u64 {
        self.shared.resyncs()
    }

    /// Stop the background tailer and return the underlying replica
    /// (e.g. to poll it manually); `None` if already stopped. Reads
    /// keep serving the last applied generation.
    pub fn stop_tailer(&self) -> Option<Replica> {
        let mut tailer = self.tailer.lock().unwrap_or_else(|e| e.into_inner());
        tailer.take().map(|t| t.stop())
    }
}

impl ReadHandle for ReplicaHandle {
    fn id(&self) -> &TenantId {
        ReplicaHandle::id(self)
    }

    fn pin(&self) -> Result<Arc<StorageSnapshot>> {
        ReplicaHandle::pin(self)
    }

    fn published_generation(&self) -> Option<u64> {
        ReplicaHandle::published_generation(self)
    }

    fn qos_tag(&self, class: RequestClass) -> QosTag {
        ReplicaHandle::qos_tag(self, class)
    }

    fn reader(&self) -> Result<PointReader> {
        ReplicaHandle::reader(self)
    }
}

/// Routing layer: tenant ids to handles, plus serving entry points that
/// multiplex all tenants over one shared [`ServingPool`].
#[derive(Default)]
pub struct TenantRouter {
    tenants: HashMap<TenantId, Arc<TenantHandle>>,
    /// Read replicas keyed by the logical tenant id they replicate
    /// (which may have no local primary — e.g. replicating another
    /// process's store).
    replicas: HashMap<TenantId, Vec<Arc<ReplicaHandle>>>,
}

impl TenantRouter {
    /// Empty router.
    pub fn new() -> TenantRouter {
        TenantRouter::default()
    }

    /// Register a tenant. Typed error on a duplicate id.
    pub fn add_tenant(
        &mut self,
        id: impl Into<TenantId>,
        cfg: TenantConfig,
    ) -> Result<Arc<TenantHandle>> {
        let id = id.into();
        if self.tenants.contains_key(&id) {
            return Err(TgmError::Serving(format!("tenant `{id}` already registered")));
        }
        // Two writers over one directory would silently destroy each
        // other's WAL; reject the misconfiguration at registration.
        // Paths are canonicalized (when they exist) so non-canonical
        // spellings of one directory cannot slip past the check.
        if let Some(policy) = &cfg.durable {
            let canonical = |p: &std::path::Path| {
                std::fs::canonicalize(p).unwrap_or_else(|_| p.to_path_buf())
            };
            let new_dir = canonical(&policy.dir);
            for handle in self.tenants.values() {
                if handle.durable_dir().map(|d| canonical(&d)) == Some(new_dir.clone()) {
                    return Err(TgmError::Serving(format!(
                        "tenant `{}` already persists to {}; durable directories must \
                         be exclusive to one tenant",
                        handle.id(),
                        policy.dir.display()
                    )));
                }
            }
        }
        let handle = Arc::new(TenantHandle::build(id.clone(), cfg)?);
        self.tenants.insert(id, Arc::clone(&handle));
        Ok(handle)
    }

    /// Drop a tenant from routing (in-flight pins stay valid — they own
    /// their snapshot `Arc`s).
    pub fn remove_tenant(&mut self, id: &TenantId) -> Result<Arc<TenantHandle>> {
        self.tenants
            .remove(id)
            .ok_or_else(|| TgmError::Serving(format!("unknown tenant `{id}`")))
    }

    /// Look up a tenant. Typed error on an unknown id.
    pub fn tenant(&self, id: &TenantId) -> Result<&Arc<TenantHandle>> {
        self.tenants
            .get(id)
            .ok_or_else(|| TgmError::Serving(format!("unknown tenant `{id}`")))
    }

    /// Register a primary (writable) tenant from a [`ServingConfig`]
    /// built with [`ServingConfig::in_memory`] or
    /// [`ServingConfig::primary`]. Typed error for a replica-role
    /// config (use [`TenantRouter::add_replica`]).
    pub fn add_primary(
        &mut self,
        id: impl Into<TenantId>,
        cfg: ServingConfig,
    ) -> Result<Arc<TenantHandle>> {
        self.add_tenant(id, cfg.into_tenant_config()?)
    }

    /// Register a read replica of logical tenant `id` from a
    /// [`ServingConfig::replica`] config. The replica bootstraps from
    /// the primary's durable state, spawns its background tailer, and
    /// joins the router's read fan-out for `id` — the id does **not**
    /// need a local primary (replicating another process's store is the
    /// point), and several replicas may serve one id. Typed error for a
    /// non-replica config.
    pub fn add_replica(
        &mut self,
        id: impl Into<TenantId>,
        cfg: ServingConfig,
    ) -> Result<Arc<ReplicaHandle>> {
        let id = id.into();
        let slot = self.replicas.entry(id.clone()).or_default();
        // Unique metrics identity per replica of one logical tenant.
        let name = format!("{id}#r{}", slot.len());
        let handle = Arc::new(ReplicaHandle::build(id, name, cfg)?);
        slot.push(Arc::clone(&handle));
        Ok(handle)
    }

    /// The freshest read handle for `id`: the registered handle
    /// (primary or replica) with the highest published generation, the
    /// primary winning ties. Typed error when `id` has neither a
    /// primary nor replicas.
    pub fn read_handle(&self, id: &TenantId) -> Result<Arc<dyn ReadHandle>> {
        let mut best: Option<Arc<dyn ReadHandle>> =
            self.tenants.get(id).map(|p| Arc::clone(p) as Arc<dyn ReadHandle>);
        let mut best_gen = best.as_ref().and_then(|h| h.published_generation());
        for r in self.replicas.get(id).map(|v| v.as_slice()).unwrap_or(&[]) {
            let g = r.published_generation();
            let fresher = match (g, best_gen) {
                (Some(g), Some(b)) => g > b,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if fresher || best.is_none() {
                best_gen = g;
                best = Some(Arc::clone(r) as Arc<dyn ReadHandle>);
            }
        }
        best.ok_or_else(|| {
            TgmError::Serving(format!("unknown tenant `{id}` (no primary or replicas)"))
        })
    }

    /// Every read handle registered for `id` (primary first, then
    /// replicas in registration order) — the fan-out set for spreading
    /// read load. Empty when `id` is unknown.
    pub fn read_handles(&self, id: &TenantId) -> Vec<Arc<dyn ReadHandle>> {
        let mut out: Vec<Arc<dyn ReadHandle>> = Vec::new();
        if let Some(p) = self.tenants.get(id) {
            out.push(Arc::clone(p) as Arc<dyn ReadHandle>);
        }
        if let Some(rs) = self.replicas.get(id) {
            out.extend(rs.iter().map(|r| Arc::clone(r) as Arc<dyn ReadHandle>));
        }
        out
    }

    /// Replicas registered for `id` (empty when none).
    pub fn replicas(&self, id: &TenantId) -> &[Arc<ReplicaHandle>] {
        self.replicas.get(id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Registered tenant ids, sorted for deterministic iteration.
    pub fn ids(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self.tenants.keys().cloned().collect();
        ids.sort();
        ids
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// True when no tenant is registered.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// [`TenantHandle::ingest`] by id.
    pub fn ingest(&self, id: &TenantId, events: impl IntoIterator<Item = Event>) -> Result<usize> {
        self.tenant(id)?.ingest(events)
    }

    /// [`TenantHandle::publish`] by id.
    pub fn publish(&self, id: &TenantId) -> Result<Arc<StorageSnapshot>> {
        self.tenant(id)?.publish()
    }

    /// [`TenantHandle::pin`] by id.
    pub fn pin(&self, id: &TenantId) -> Result<Arc<StorageSnapshot>> {
        self.tenant(id)?.pin()
    }

    /// Open a pooled batch stream over the tenant's **latest published**
    /// generation: the stream stays pinned to it even if the tenant
    /// publishes newer generations mid-iteration. The caller's manager
    /// must be activated (its stateful phase runs on the caller's
    /// thread, in batch order, exactly as with a dedicated loader).
    pub fn serve<'a>(
        &self,
        pool: &ServingPool,
        id: &TenantId,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: StreamConfig,
    ) -> Result<PooledStream<'a>> {
        let handle = self.tenant(id)?;
        let snap = handle.pin()?;
        // The stream's jobs are scheduled under the tenant's identity
        // and weight, so its scans compete fairly with other tenants.
        let cfg = cfg.with_qos(handle.qos_tag(RequestClass::BatchScan));
        pool.stream(DGraph::full(snap), by, manager, cfg)
    }

    /// [`TenantHandle::query`] by id: one point query on the shared
    /// pool under the tenant's QoS tag.
    pub fn query(
        &self,
        pool: &ServingPool,
        id: &TenantId,
        query: PointQuery,
    ) -> Result<PointResponse> {
        self.tenant(id)?.query(pool, query)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::batch::assert_batches_identical as identical;
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::io::stream::{EventSource, ReplaySource};
    use crate::loader::DGDataLoader;

    fn loaded_tenant(router: &mut TenantRouter, name: &str, seed: u64) -> TenantId {
        let data = gen::by_name("wiki", 0.05, seed).unwrap();
        let id = TenantId::from(name);
        router
            .add_tenant(
                id.clone(),
                TenantConfig::new(data.storage().num_nodes())
                    .with_seal(SealPolicy::by_events(200))
                    .with_granularity(data.storage().granularity()),
            )
            .unwrap();
        let mut source = ReplaySource::from_data(&data);
        let events = source.next_chunk(usize::MAX);
        router.ingest(&id, events).unwrap();
        router.publish(&id).unwrap();
        id
    }

    #[test]
    fn routing_errors_are_typed() {
        let mut router = TenantRouter::new();
        assert!(router.is_empty());
        router.add_tenant("a", TenantConfig::new(8)).unwrap();
        let dup = router.add_tenant("a", TenantConfig::new(8)).unwrap_err();
        assert!(matches!(dup, TgmError::Serving(_)), "{dup}");
        let missing = router.pin(&TenantId::from("nope")).unwrap_err();
        assert!(matches!(missing, TgmError::Serving(_)), "{missing}");
        // Registered but never published: typed error, not a panic.
        let unpublished = router.pin(&TenantId::from("a")).unwrap_err();
        assert!(unpublished.to_string().contains("not published"), "{unpublished}");
        assert_eq!(router.ids(), vec![TenantId::from("a")]);
        router.remove_tenant(&TenantId::from("a")).unwrap();
        assert!(router.remove_tenant(&TenantId::from("a")).is_err());
    }

    #[test]
    fn tenants_publish_generations_independently() {
        let mut router = TenantRouter::new();
        let a = loaded_tenant(&mut router, "a", 1);
        let b = loaded_tenant(&mut router, "b", 2);
        let snap_a = router.pin(&a).unwrap();
        let snap_b = router.pin(&b).unwrap();
        assert_ne!(snap_a.id(), snap_b.id(), "tenants never share snapshot identity");

        // Tenant `a` keeps ingesting and republishing; `b` is untouched.
        let ha = Arc::clone(router.tenant(&a).unwrap());
        let last = snap_a.end_time();
        ha.ingest(vec![Event::Edge(crate::graph::EdgeEvent {
            t: last + 60,
            src: 0,
            dst: 1,
            features: vec![0.0; snap_a.edge_feat_dim()],
        })])
        .unwrap();
        let newer = ha.publish().unwrap();
        assert!(newer.generation() > snap_a.generation());
        assert_eq!(router.pin(&a).unwrap().generation(), newer.generation());
        assert_eq!(router.pin(&b).unwrap().generation(), snap_b.generation());
        // The older pin still reads its own bytes.
        assert_eq!(snap_a.num_edges() + 1, newer.num_edges());
    }

    #[test]
    fn served_stream_matches_dedicated_serial_loader() {
        let mut router = TenantRouter::new();
        let id = loaded_tenant(&mut router, "wiki", 7);
        let pool = ServingPool::new(3);

        let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mp.activate("val").unwrap();
        let mut stream = router
            .serve(&pool, &id, BatchBy::Events(100), &mut mp, StreamConfig::default())
            .unwrap();
        let served = stream.collect_all().unwrap();

        let data = crate::graph::DGData::from_snapshot(
            router.pin(&id).unwrap(),
            "wiki",
            crate::graph::Task::LinkPrediction,
        );
        let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ms.activate("val").unwrap();
        let serial =
            DGDataLoader::new(data.full(), BatchBy::Events(100), &mut ms).unwrap().collect_all().unwrap();
        identical(&serial, &served);
    }

    #[test]
    fn point_queries_serve_from_the_published_generation() {
        let mut router = TenantRouter::new();
        let id = loaded_tenant(&mut router, "wiki", 5);
        let pool = ServingPool::new(2);
        let handle = Arc::clone(router.tenant(&id).unwrap());
        let snap = router.pin(&id).unwrap();
        let end = snap.end_time() + 1;

        // Router-level query matches a direct reader execution.
        let q = PointQuery::NeighborsBefore { node: 0, t: end, k: 4 };
        let got = router.query(&pool, &id, q).unwrap();
        let direct = handle.reader().unwrap().execute(&q);
        assert_eq!(got, direct);
        match got {
            PointResponse::Neighbors(ref n) => assert!(!n.is_empty()),
            ref other => panic!("unexpected response {other:?}"),
        }

        // The memoized reader is reused between publishes...
        let r1 = handle.reader().unwrap();
        let r2 = handle.reader().unwrap();
        assert_eq!(r1.snapshot().id(), r2.snapshot().id());

        // ...and a publish advances it: a new edge becomes visible to
        // queries only after publish.
        let (src, dst) = (0u32, 1u32);
        handle
            .ingest(vec![Event::Edge(crate::graph::EdgeEvent {
                t: end + 60,
                src,
                dst,
                features: vec![0.0; snap.edge_feat_dim()],
            })])
            .unwrap();
        let before = handle.query(&pool, PointQuery::EdgeLookup { src, dst, t: end + 120 });
        handle.publish().unwrap();
        let after =
            handle.query(&pool, PointQuery::EdgeLookup { src, dst, t: end + 120 }).unwrap();
        match (before.unwrap(), after) {
            (PointResponse::Edge(b), PointResponse::Edge(Some(hit))) => {
                assert_eq!(hit.t, end + 60);
                assert!(b.map(|h| h.t != end + 60).unwrap_or(true), "pre-publish leak");
            }
            other => panic!("unexpected responses {other:?}"),
        }
        // An unpublished tenant yields a typed error, not a panic.
        let mut empty = TenantRouter::new();
        empty.add_tenant("fresh", TenantConfig::new(8)).unwrap();
        let err = empty.query(&pool, &TenantId::from("fresh"), q).unwrap_err();
        assert!(matches!(err, TgmError::Serving(_)), "{err}");
    }

    #[test]
    fn tenant_qos_policy_stamps_tags() {
        let mut router = TenantRouter::new();
        router
            .add_primary("vip", ServingConfig::in_memory(8).qos_weight(9).admission_cap(17))
            .unwrap();
        let h = router.tenant(&TenantId::from("vip")).unwrap();
        assert_eq!(h.qos().weight, 9);
        let tag = h.qos_tag(RequestClass::PointQuery);
        assert_eq!(tag.tenant.as_ref(), "vip");
        assert_eq!(tag.weight, 9);
        assert_eq!(tag.max_queued, 17);
        assert_eq!(tag.class, RequestClass::PointQuery);
        // Default policy: weight 1, scheduler-default cap.
        router.add_tenant("std", TenantConfig::new(8)).unwrap();
        let std_tag =
            router.tenant(&TenantId::from("std")).unwrap().qos_tag(RequestClass::BatchScan);
        assert_eq!(std_tag.weight, 1);
        assert!(std_tag.max_queued >= 1);
    }

    #[test]
    fn durable_tenant_recovers_and_serves_on_restart() {
        let dir = std::env::temp_dir()
            .join(format!("tgm_serving_durable_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let data = gen::by_name("wiki", 0.05, 17).unwrap();
        let cfg = || {
            ServingConfig::primary(data.storage().num_nodes(), &dir)
                .seal(SealPolicy::by_events(150))
                .granularity(data.storage().granularity())
        };

        // First life: ingest + publish, then "crash" (drop everything).
        {
            let mut router = TenantRouter::new();
            let id = TenantId::from("w");
            let fresh = router.add_primary(id.clone(), cfg()).unwrap();
            // A fresh directory has nothing to recover — no report.
            assert!(fresh.recovery_report().is_none());
            let mut source = ReplaySource::from_data(&data);
            router.ingest(&id, source.next_chunk(usize::MAX)).unwrap();
            router.publish(&id).unwrap();
        }

        // Second life: the tenant recovers from the directory and is
        // already published — serving resumes without re-ingestion.
        let mut router = TenantRouter::new();
        let id = TenantId::from("w");
        let handle = router.add_primary(id.clone(), cfg()).unwrap();
        assert!(handle.published_generation().is_some());
        // The recovery diagnostics surface through registration.
        let report = handle.recovery_report().expect("recovered tenant carries a report");
        assert!(
            report.sealed_segments > 0 || report.replayed_events > 0,
            "recovery saw data: {report:?}"
        );
        assert!(!report.torn_tail, "clean shutdown must not report a torn tail");
        let snap = router.pin(&id).unwrap();
        assert_eq!(snap.num_edges(), data.storage().num_edges());
        assert_eq!(snap.edge_ts(), data.storage().edge_ts());
        assert_eq!(snap.edge_feats(), data.storage().edge_feats());

        // A second tenant over the same directory is rejected up front
        // (two writers would destroy each other's WAL).
        let err = router.add_primary("w-dup", cfg()).unwrap_err();
        assert!(err.to_string().contains("exclusive"), "{err}");

        // A second *router* (stand-in for a second process) is fenced by
        // the directory lock while the first tenant's store is alive.
        let mut router2 = TenantRouter::new();
        let err = router2.add_primary("w2", cfg()).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("already holds"), "{err}");

        // Once the first tenant is gone the lock is free, and a
        // num_nodes mismatch on recovery is a typed serving error.
        drop(snap);
        drop(handle);
        drop(router);
        let err = router2.add_primary("w3", ServingConfig::primary(3, &dir)).unwrap_err();
        assert!(matches!(err, TgmError::Serving(_)), "{err}");
    }

    #[test]
    fn per_tenant_policies_shape_per_tenant_segments() {
        let mut router = TenantRouter::new();
        let data = gen::by_name("wiki", 0.05, 3).unwrap();
        for (name, seal) in
            [("fine", SealPolicy::by_events(50)), ("coarse", SealPolicy::by_events(100_000))]
        {
            let id = TenantId::from(name);
            router
                .add_tenant(
                    id.clone(),
                    TenantConfig::new(data.storage().num_nodes())
                        .with_seal(seal)
                        .with_compact_after(usize::MAX)
                        .with_granularity(data.storage().granularity()),
                )
                .unwrap();
            let mut source = ReplaySource::from_data(&data);
            router.ingest(&id, source.next_chunk(usize::MAX)).unwrap();
            router.publish(&id).unwrap();
        }
        let fine = router.tenant(&TenantId::from("fine")).unwrap();
        let coarse = router.tenant(&TenantId::from("coarse")).unwrap();
        assert!(fine.num_sealed_segments() > 5, "{}", fine.num_sealed_segments());
        assert_eq!(coarse.num_sealed_segments(), 0, "coarse policy never hit its threshold");
        // Same logical content regardless of segmentation.
        assert_eq!(
            router.pin(&TenantId::from("fine")).unwrap().edge_ts(),
            router.pin(&TenantId::from("coarse")).unwrap().edge_ts()
        );
    }

    /// The deprecated builders remain thin shims: a `ServingConfig`
    /// lowers to exactly the `TenantConfig`/`DurabilityPolicy` the old
    /// builder chain produced.
    #[test]
    #[allow(deprecated)]
    fn serving_config_lowers_to_what_the_deprecated_builders_built() {
        let dir = std::env::temp_dir().join("tgm_serving_cfg_lowering");
        let new = ServingConfig::primary(64, &dir)
            .seal(SealPolicy::by_events(9))
            .compact_after(3)
            .granularity(TimeGranularity::Second)
            .qos_weight(7)
            .admission_cap(5)
            .group_commit()
            .mmap()
            .into_tenant_config()
            .unwrap();
        let old = TenantConfig::new(64)
            .with_seal(SealPolicy::by_events(9))
            .with_compact_after(3)
            .with_granularity(TimeGranularity::Second)
            .with_qos_weight(7)
            .with_admission_cap(5)
            .with_durability(DurabilityPolicy::new(&dir).with_group_commit().with_mmap());
        assert_eq!(new.num_nodes, old.num_nodes);
        assert_eq!(new.compact_after, old.compact_after);
        assert_eq!(new.granularity, old.granularity);
        assert_eq!(new.qos.weight, old.qos.weight);
        assert_eq!(new.qos.max_queued, old.qos.max_queued);
        let (nd, od) = (new.durable.unwrap(), old.durable.unwrap());
        assert_eq!(nd.dir, od.dir);
        assert_eq!(nd.fsync_appends, od.fsync_appends);
        assert_eq!(nd.group_commit, od.group_commit);
        assert_eq!(nd.backing, od.backing);

        // Role mismatches are typed errors, not silent misconfigs.
        let err = ServingConfig::replica("/nope/p", "/nope/r").into_tenant_config().unwrap_err();
        assert!(matches!(err, TgmError::Serving(_)), "{err}");
        let err =
            ServingConfig::in_memory(8).into_replica_parts().unwrap_err();
        assert!(matches!(err, TgmError::Serving(_)), "{err}");
    }

    /// Tentpole: a WAL-tailing replica joins the router's read fan-out
    /// and serves byte-identical generation-pinned reads through the
    /// same [`ReadHandle`] surface as the primary.
    #[test]
    fn replica_serves_identical_reads_behind_the_unified_handle() {
        let base =
            std::env::temp_dir().join(format!("tgm_serving_replica_{}", std::process::id()));
        let (dir, rdir) = (base.join("primary"), base.join("r0"));
        let _ = std::fs::remove_dir_all(&base);
        let data = gen::by_name("wiki", 0.05, 23).unwrap();
        let pool = ServingPool::new(2);

        let mut router = TenantRouter::new();
        let id = TenantId::from("w");
        let primary = router
            .add_primary(
                id.clone(),
                ServingConfig::primary(data.storage().num_nodes(), &dir)
                    .seal(SealPolicy::by_events(500))
                    .granularity(data.storage().granularity()),
            )
            .unwrap();
        let mut source = ReplaySource::from_data(&data);
        router.ingest(&id, source.next_chunk(usize::MAX)).unwrap();
        router.publish(&id).unwrap();
        let primary_gen = primary.published_generation().unwrap();

        // Replica bootstraps from the primary's live directory (no lock
        // contention) and catches up to the same generation.
        let replica = router
            .add_replica(
                id.clone(),
                ServingConfig::replica(&dir, &rdir)
                    .poll_interval(std::time::Duration::from_millis(1)),
            )
            .unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        while replica.published_generation() != Some(primary_gen) {
            assert!(
                std::time::Instant::now() < deadline,
                "replica stuck at {:?} (primary at {primary_gen})",
                replica.published_generation()
            );
            std::thread::sleep(std::time::Duration::from_millis(2));
        }

        // Same generation, same bytes.
        let (ps, rs) = (primary.pin().unwrap(), replica.pin().unwrap());
        assert_eq!(ps.generation(), rs.generation());
        assert_eq!(ps.edge_ts(), rs.edge_ts());
        assert_eq!(ps.edge_src(), rs.edge_src());
        assert_eq!(ps.edge_feats(), rs.edge_feats());

        // Batch streams through the unified handle are byte-identical.
        let streamed = |h: &dyn ReadHandle| {
            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate("val").unwrap();
            h.serve(&pool, BatchBy::Events(100), &mut mp, StreamConfig::default())
                .unwrap()
                .collect_all()
                .unwrap()
        };
        identical(&streamed(primary.as_ref()), &streamed(replica.as_ref()));

        // Point queries agree through the trait as well.
        let q = PointQuery::NeighborsBefore { node: 0, t: ps.end_time() + 1, k: 4 };
        let via_primary = ReadHandle::query(primary.as_ref(), &pool, q).unwrap();
        let via_replica = ReadHandle::query(replica.as_ref(), &pool, q).unwrap();
        assert_eq!(via_primary, via_replica);

        // Freshest pick: tied generations go to the primary...
        let picked = router.read_handle(&id).unwrap();
        assert_eq!(picked.published_generation(), Some(primary_gen));
        assert_eq!(router.read_handles(&id).len(), 2);

        // ...but a replica that tailed unpublished WAL appends past the
        // primary's published generation wins the pick.
        primary
            .ingest(vec![Event::Edge(crate::graph::EdgeEvent {
                t: ps.end_time() + 60,
                src: 0,
                dst: 1,
                features: vec![0.0; ps.edge_feat_dim()],
            })])
            .unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        while replica.published_generation() == Some(primary_gen) {
            assert!(std::time::Instant::now() < deadline, "replica never saw the append");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let picked = router.read_handle(&id).unwrap();
        assert!(picked.published_generation() > Some(primary_gen));

        // Publishing restores the tie (and the primary's precedence).
        let newer = primary.publish().unwrap();
        assert_eq!(newer.generation(), replica.published_generation().unwrap());

        assert!(replica.bootstrap_report().shipped_bytes > 0);
        assert_eq!(replica.resyncs(), 0, "incremental path only");
        assert!(replica.stop_tailer().is_some());
        assert!(replica.stop_tailer().is_none(), "second stop is a no-op");
    }
}
