//! Shared utilities: deterministic RNG, host tensors, time-granularity
//! algebra, and numeric helpers.

pub mod rng;
pub mod stats;
pub mod tensor;
pub mod time;

pub use rng::{mix64, Rng};
pub use tensor::{DType, Tensor, TensorData};
pub use time::{
    granularity_for_min_gap, infer_native_granularity, min_positive_gap, TimeGranularity,
    Timestamp,
};
