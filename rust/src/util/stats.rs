//! Small numeric helpers shared by evaluators and the bench harness.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (by copy+sort; fine for bench-sized inputs).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Numerically stable sigmoid.
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Area under the ROC curve for binary labels, with tie handling
/// (average rank of tied scores). Returns 0.5 when a class is absent.
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let pos = labels.iter().filter(|&&l| l).count();
    let neg = labels.len() - pos;
    if pos == 0 || neg == 0 {
        return 0.5;
    }
    // Rank-sum (Mann-Whitney U) formulation with average ranks for ties.
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut ranks = vec![0.0f64; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg_rank;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 =
        labels.iter().zip(&ranks).filter(|(l, _)| **l).map(|(_, r)| *r).sum();
    let u = rank_sum_pos - (pos * (pos + 1)) as f64 / 2.0;
    u / (pos as f64 * neg as f64)
}

/// Reciprocal rank of the positive score among negatives (one-vs-many,
/// TGB protocol). `optimistic=false` uses the pessimistic tie rule that
/// TGB applies: ties rank below the positive.
pub fn reciprocal_rank(pos_score: f64, neg_scores: &[f64]) -> f64 {
    let higher = neg_scores.iter().filter(|&&s| s > pos_score).count();
    let ties = neg_scores.iter().filter(|&&s| s == pos_score).count();
    // TGB-style: rank = 1 + #better + #ties/2 (expected rank under random
    // tie-breaking).
    let rank = 1.0 + higher as f64 + ties as f64 * 0.5;
    1.0 / rank
}

/// NDCG@k for a predicted score vector against non-negative relevance
/// targets (dynamic node property prediction protocol, Trade/Genre).
pub fn ndcg_at_k(pred: &[f64], target: &[f64], k: usize) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    let k = k.min(pred.len());
    let mut by_pred: Vec<usize> = (0..pred.len()).collect();
    by_pred.sort_by(|&a, &b| pred[b].partial_cmp(&pred[a]).unwrap());
    let dcg: f64 = by_pred[..k]
        .iter()
        .enumerate()
        .map(|(i, &j)| target[j] / ((i + 2) as f64).log2())
        .sum();
    let mut by_target: Vec<usize> = (0..target.len()).collect();
    by_target.sort_by(|&a, &b| target[b].partial_cmp(&target[a]).unwrap());
    let idcg: f64 = by_target[..k]
        .iter()
        .enumerate()
        .map(|(i, &j)| target[j] / ((i + 2) as f64).log2())
        .sum();
    if idcg == 0.0 {
        0.0
    } else {
        dcg / idcg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn sigmoid_bounds_and_symmetry() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(50.0) > 0.999999);
        assert!(sigmoid(-50.0) < 1e-6);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_random() {
        // Perfect separation.
        let s = [0.9, 0.8, 0.2, 0.1];
        let l = [true, true, false, false];
        assert!((auc(&s, &l) - 1.0).abs() < 1e-12);
        // Inverted.
        let l2 = [false, false, true, true];
        assert!((auc(&s, &l2) - 0.0).abs() < 1e-12);
        // All ties -> 0.5.
        let s3 = [0.5, 0.5, 0.5, 0.5];
        assert!((auc(&s3, &l) - 0.5).abs() < 1e-12);
        // Degenerate single class -> 0.5.
        assert_eq!(auc(&[0.1, 0.2], &[true, true]), 0.5);
    }

    #[test]
    fn mrr_ranks() {
        // Positive beats all 9 negatives -> rank 1.
        assert!((reciprocal_rank(1.0, &[0.0; 9]) - 1.0).abs() < 1e-12);
        // Positive below 3 negatives -> rank 4.
        assert!((reciprocal_rank(0.5, &[0.9, 0.8, 0.7, 0.1]) - 0.25).abs() < 1e-12);
        // Full tie with one negative -> expected rank 1.5.
        assert!((reciprocal_rank(0.5, &[0.5]) - (1.0 / 1.5)).abs() < 1e-12);
    }

    #[test]
    fn ndcg_perfect_is_one() {
        let t = [3.0, 2.0, 1.0, 0.0];
        assert!((ndcg_at_k(&t, &t, 4) - 1.0).abs() < 1e-12);
        // Reversed prediction is worse.
        let p = [0.0, 1.0, 2.0, 3.0];
        assert!(ndcg_at_k(&p, &t, 4) < 1.0);
        // Zero relevance -> 0.
        assert_eq!(ndcg_at_k(&p, &[0.0; 4], 4), 0.0);
    }
}
