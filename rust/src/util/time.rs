//! Time-granularity algebra (paper §3).
//!
//! TGM treats time as a first-class signal. Every temporal graph has a
//! *native* granularity τ — the coarsest unit that still discriminates all
//! event timestamps — and supports iteration/discretization at any coarser
//! granularity τ̂ ≥ τ. When wall-clock time is unavailable the special
//! *event-ordered* granularity preserves only relative order and is
//! excluded from real-time operations (Definition 3.3).

use crate::error::{Result, TgmError};

/// Raw timestamp unit: seconds since an arbitrary epoch.
pub type Timestamp = i64;

/// Time granularity: event-ordered or a wall-clock unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TimeGranularity {
    /// Only relative order is meaningful (Definition 3.3, τ_event).
    Event,
    Second,
    Minute,
    Hour,
    Day,
    Week,
    /// 365-day year (matches the Trade dataset's yearly steps).
    Year,
}

impl TimeGranularity {
    /// Length in seconds; `None` for the event-ordered granularity.
    pub fn seconds(&self) -> Option<i64> {
        match self {
            TimeGranularity::Event => None,
            TimeGranularity::Second => Some(1),
            TimeGranularity::Minute => Some(60),
            TimeGranularity::Hour => Some(3_600),
            TimeGranularity::Day => Some(86_400),
            TimeGranularity::Week => Some(604_800),
            TimeGranularity::Year => Some(31_536_000),
        }
    }

    /// True when `self` is at least as coarse as `other`.
    ///
    /// The event-ordered granularity is incomparable with wall-clock units
    /// (it carries no duration), so any mixed comparison returns `false`.
    pub fn is_coarser_or_equal(&self, other: &TimeGranularity) -> bool {
        match (self.seconds(), other.seconds()) {
            (Some(a), Some(b)) => a >= b,
            _ => self == other,
        }
    }

    /// Parse a CLI/config string.
    pub fn parse(s: &str) -> Result<TimeGranularity> {
        match s.to_ascii_lowercase().as_str() {
            "event" | "e" => Ok(TimeGranularity::Event),
            "second" | "s" | "sec" => Ok(TimeGranularity::Second),
            "minute" | "m" | "min" => Ok(TimeGranularity::Minute),
            "hour" | "h" => Ok(TimeGranularity::Hour),
            "day" | "d" => Ok(TimeGranularity::Day),
            "week" | "w" => Ok(TimeGranularity::Week),
            "year" | "y" => Ok(TimeGranularity::Year),
            other => Err(TgmError::Time(format!("unknown granularity `{other}`"))),
        }
    }

    /// Human-readable name.
    pub fn as_str(&self) -> &'static str {
        match self {
            TimeGranularity::Event => "event",
            TimeGranularity::Second => "second",
            TimeGranularity::Minute => "minute",
            TimeGranularity::Hour => "hour",
            TimeGranularity::Day => "day",
            TimeGranularity::Week => "week",
            TimeGranularity::Year => "year",
        }
    }

    /// Bucket index of `t` relative to origin `t0` at this granularity.
    ///
    /// Errors for the event-ordered granularity, which carries no duration.
    pub fn bucket_of(&self, t: Timestamp, t0: Timestamp) -> Result<i64> {
        let secs = self.seconds().ok_or_else(|| {
            TgmError::Time("event-ordered granularity has no wall-clock buckets".into())
        })?;
        Ok((t - t0).div_euclid(secs))
    }

    /// Inclusive start timestamp of bucket `b` relative to origin `t0`.
    pub fn bucket_start(&self, b: i64, t0: Timestamp) -> Result<Timestamp> {
        let secs = self.seconds().ok_or_else(|| {
            TgmError::Time("event-ordered granularity has no wall-clock buckets".into())
        })?;
        Ok(t0 + b * secs)
    }
}

/// Minimum positive gap between adjacent entries of a sorted timestamp
/// stream (`None` when all timestamps tie). This is the statistic native
/// granularity is derived from, exposed so streaming storage can fold it
/// incrementally per sealed segment instead of re-scanning history.
pub fn min_positive_gap(sorted_ts: &[Timestamp]) -> Option<i64> {
    let mut min_gap: Option<i64> = None;
    for w in sorted_ts.windows(2) {
        let gap = w[1] - w[0];
        if gap > 0 {
            min_gap = Some(min_gap.map_or(gap, |m: i64| m.min(gap)));
        }
    }
    min_gap
}

/// Map a stream's minimum positive adjacent gap to its native granularity
/// (`None` = only ties = event-ordered).
pub fn granularity_for_min_gap(min_gap: Option<i64>) -> TimeGranularity {
    use TimeGranularity::*;
    let Some(gap) = min_gap else { return Event };
    for g in [Year, Week, Day, Hour, Minute, Second] {
        if gap >= g.seconds().unwrap() {
            return g;
        }
    }
    Second
}

/// Infer the native granularity of a sorted timestamp stream: the coarsest
/// wall-clock unit that still discriminates between all *distinct*
/// timestamps (paper §3, "native time granularity").
pub fn infer_native_granularity(sorted_ts: &[Timestamp]) -> TimeGranularity {
    granularity_for_min_gap(min_positive_gap(sorted_ts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coarseness_ordering() {
        use TimeGranularity::*;
        assert!(Day.is_coarser_or_equal(&Hour));
        assert!(Hour.is_coarser_or_equal(&Hour));
        assert!(!Hour.is_coarser_or_equal(&Day));
        assert!(Year.is_coarser_or_equal(&Second));
    }

    #[test]
    fn event_granularity_incomparable() {
        use TimeGranularity::*;
        assert!(!Event.is_coarser_or_equal(&Second));
        assert!(!Second.is_coarser_or_equal(&Event));
        assert!(Event.is_coarser_or_equal(&Event));
    }

    #[test]
    fn bucketing_with_negative_offsets() {
        let g = TimeGranularity::Hour;
        assert_eq!(g.bucket_of(0, 0).unwrap(), 0);
        assert_eq!(g.bucket_of(3599, 0).unwrap(), 0);
        assert_eq!(g.bucket_of(3600, 0).unwrap(), 1);
        // div_euclid keeps buckets monotone across the origin.
        assert_eq!(g.bucket_of(-1, 0).unwrap(), -1);
        assert_eq!(g.bucket_start(1, 100).unwrap(), 3700);
    }

    #[test]
    fn event_buckets_are_errors() {
        assert!(TimeGranularity::Event.bucket_of(5, 0).is_err());
        assert!(TimeGranularity::Event.bucket_start(5, 0).is_err());
    }

    #[test]
    fn bucket_start_with_negative_epoch_origin() {
        // Streams whose first event predates the Unix epoch get a
        // negative origin; bucket arithmetic must stay exact there.
        let g = TimeGranularity::Day;
        let t0 = -1_000_000i64;
        assert_eq!(g.bucket_start(0, t0).unwrap(), t0);
        assert_eq!(g.bucket_start(1, t0).unwrap(), t0 + 86_400);
        assert_eq!(g.bucket_start(-1, t0).unwrap(), t0 - 86_400);
        // Timestamps before the origin land in negative buckets whose
        // starts still bracket them: start(b) <= t < start(b + 1).
        for t in [t0 - 86_400, t0 - 1, t0, t0 + 1, t0 + 86_399, t0 + 86_400] {
            let b = g.bucket_of(t, t0).unwrap();
            assert!(g.bucket_start(b, t0).unwrap() <= t);
            assert!(t < g.bucket_start(b + 1, t0).unwrap());
        }
    }

    #[test]
    fn bucket_zero_starts_at_the_origin() {
        for g in [
            TimeGranularity::Second,
            TimeGranularity::Minute,
            TimeGranularity::Hour,
            TimeGranularity::Day,
            TimeGranularity::Week,
            TimeGranularity::Year,
        ] {
            for t0 in [-7i64, 0, 12_345] {
                assert_eq!(g.bucket_start(0, t0).unwrap(), t0, "{g:?} t0={t0}");
                assert_eq!(g.bucket_of(t0, t0).unwrap(), 0, "{g:?} t0={t0}");
            }
        }
    }

    #[test]
    fn coarse_bucket_starts_are_idempotent_under_rebucketing() {
        // A timestamp already snapped to a bucket start re-buckets to the
        // same bucket, and snapping again is the identity — discretizing
        // an already-coarse stream at the same granularity changes nothing.
        let t0 = -3_601i64;
        for g in [TimeGranularity::Hour, TimeGranularity::Week] {
            for b in [-3i64, 0, 1, 7] {
                let start = g.bucket_start(b, t0).unwrap();
                assert_eq!(g.bucket_of(start, t0).unwrap(), b);
                assert_eq!(g.bucket_start(g.bucket_of(start, t0).unwrap(), t0).unwrap(), start);
            }
        }
    }

    #[test]
    fn parse_round_trips() {
        for g in [
            TimeGranularity::Event,
            TimeGranularity::Second,
            TimeGranularity::Minute,
            TimeGranularity::Hour,
            TimeGranularity::Day,
            TimeGranularity::Week,
            TimeGranularity::Year,
        ] {
            assert_eq!(TimeGranularity::parse(g.as_str()).unwrap(), g);
        }
        assert!(TimeGranularity::parse("fortnight").is_err());
    }

    #[test]
    fn native_granularity_inference() {
        // Gaps of exactly one hour -> Hour.
        let ts: Vec<i64> = (0..10).map(|i| i * 3600).collect();
        assert_eq!(infer_native_granularity(&ts), TimeGranularity::Hour);
        // Mixed gaps, min 1s -> Second.
        assert_eq!(infer_native_granularity(&[0, 1, 3600]), TimeGranularity::Second);
        // All identical timestamps -> Event (no discriminating unit).
        assert_eq!(infer_native_granularity(&[5, 5, 5]), TimeGranularity::Event);
        // Empty -> Event.
        assert_eq!(infer_native_granularity(&[]), TimeGranularity::Event);
    }
}
