//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set does not ship `rand`, so TGM uses a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256** for the
//! stream. All experiments in the paper use fixed seeds; every TGM
//! component that needs randomness takes an explicit [`Rng`] so runs are
//! reproducible bit-for-bit.

/// xoshiro256** seeded via SplitMix64. Deterministic and fast.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One SplitMix64 step: a stateless 64-bit mixer. Used to derive
/// independent per-batch RNG seeds from a batch ordinal so that batches
/// materialized out of order (prefetch workers) still draw the exact
/// stream the serial loader would have (see `hooks::HookContext`).
pub fn mix64(x: u64) -> u64 {
    let mut state = x;
    splitmix64(&mut state)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift with rejection for unbiased sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-like draw over [0, n): index i has weight (i+1)^(-alpha).
    /// Uses inverse-CDF on a cached-free approximation (rejection-free,
    /// O(log n) via binary search would need a table; for generator use we
    /// accept the power-transform approximation which matches Zipf for
    /// alpha in (0, 2)).
    pub fn zipf(&mut self, n: u64, alpha: f64) -> u64 {
        assert!(n > 0);
        if alpha <= 0.0 {
            return self.below(n);
        }
        // Inverse-transform of the continuous Pareto-ish density on [1, n+1).
        let u = self.f64();
        let exp = 1.0 - alpha;
        let x = if (exp.abs()) < 1e-9 {
            ((n + 1) as f64).powf(u)
        } else {
            let hi = ((n + 1) as f64).powf(exp);
            (1.0 + u * (hi - 1.0)).powf(1.0 / exp)
        };
        ((x - 1.0) as u64).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Exponential inter-arrival time with rate lambda (>0).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(0), mix64(0));
        assert_ne!(mix64(0), mix64(1));
        assert_ne!(mix64(1), mix64(2));
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 100];
        for _ in 0..20000 {
            counts[r.zipf(100, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[50].max(1) * 4, "head should dominate");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
