//! Minimal host-side tensor used throughout the data path.
//!
//! TGM batches are bags of named tensors (see [`crate::hooks::batch`]); the
//! runtime converts them to `xla::Literal`s at the device boundary. We only
//! need two dtypes on the host path: `f32` (features, times-as-float,
//! scores) and `i32` (indices, masks).

use crate::error::{Result, TgmError};

/// Data payload of a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dtype tag, matching the artifact manifest's dtype strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Parse a manifest dtype string.
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(TgmError::Manifest(format!("unknown dtype `{other}`"))),
        }
    }

    /// Manifest dtype string.
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        4
    }
}

/// A dense host tensor: shape + row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// f32 tensor from data and shape. Errors if element count mismatches.
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(TgmError::Batch(format!(
                "f32 tensor: {} elements for shape {:?} (need {n})",
                data.len(),
                shape
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    /// i32 tensor from data and shape.
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(TgmError::Batch(format!(
                "i32 tensor: {} elements for shape {:?} (need {n})",
                data.len(),
                shape
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    /// Zero-filled f32 tensor.
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    /// Zero-filled i32 tensor.
    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: TensorData::I32(vec![0; n]) }
    }

    /// Constant-filled f32 tensor.
    pub fn full_f32(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![v; n]) }
    }

    /// Scalar f32.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    /// Scalar i32.
    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    /// Shape (row-major).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True if the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dtype tag.
    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    /// Byte size of the payload (for memory accounting, Table 10).
    pub fn byte_size(&self) -> usize {
        self.len() * self.dtype().size()
    }

    /// Borrow as f32 slice; errors on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(TgmError::Batch("expected f32 tensor, got i32".into())),
        }
    }

    /// Borrow as i32 slice; errors on dtype mismatch.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(TgmError::Batch("expected i32 tensor, got f32".into())),
        }
    }

    /// Mutable f32 view.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(TgmError::Batch("expected f32 tensor, got i32".into())),
        }
    }

    /// Mutable i32 view.
    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match &mut self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(TgmError::Batch("expected i32 tensor, got f32".into())),
        }
    }

    /// Consume into the f32 payload.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(TgmError::Batch("expected f32 tensor, got i32".into())),
        }
    }

    /// Consume into the i32 payload.
    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(TgmError::Batch("expected i32 tensor, got f32".into())),
        }
    }

    /// Reshape in place (element count must match).
    pub fn reshape(&mut self, shape: &[usize]) -> Result<()> {
        let n: usize = shape.iter().product();
        if n != self.len() {
            return Err(TgmError::Batch(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape, shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(())
    }

    /// Row `i` of a rank-2 f32 tensor.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        if self.shape.len() != 2 {
            return Err(TgmError::Batch(format!("row_f32 on rank-{} tensor", self.shape.len())));
        }
        let cols = self.shape[1];
        let data = self.as_f32()?;
        data.get(i * cols..(i + 1) * cols)
            .ok_or_else(|| TgmError::Batch(format!("row {i} out of bounds")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.as_f32().unwrap()[3], 4.0);
        assert_eq!(t.byte_size(), 16);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32(vec![1.0; 3], &[2, 2]).is_err());
        assert!(Tensor::i32(vec![1; 5], &[2, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::zeros_i32(&[2]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn reshape_checks_count() {
        let mut t = Tensor::zeros_f32(&[4]);
        assert!(t.reshape(&[2, 2]).is_ok());
        assert_eq!(t.shape(), &[2, 2]);
        assert!(t.reshape(&[3]).is_err());
    }

    #[test]
    fn rows() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[3, 2]).unwrap();
        assert_eq!(t.row_f32(1).unwrap(), &[3.0, 4.0]);
        assert!(t.row_f32(3).is_err());
    }

    #[test]
    fn scalars_have_empty_shape() {
        let s = Tensor::scalar_f32(7.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.len(), 1);
    }
}
