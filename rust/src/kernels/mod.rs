//! Explicit-SIMD primitives for the serving hot path.
//!
//! After PR 5 moved sealed segments onto zero-copy mmap, batch
//! materialization became memory-bound: the cycles go into four loops —
//! timestamp `partition_point`-style filtered counts
//! ([`count_lt`]), masked gathers of neighbor ids and feature rows into
//! batch arenas ([`gather_rows_masked_f32`], [`gather_u32`],
//! [`gather_i64`]), time-cut filtering of merged adjacency parts
//! (again [`count_lt`], per part), and the negatives-dedup membership
//! scan ([`position_u32`]). Discretization adds two more: the bucket-key
//! pass over sorted timestamp columns ([`bucket_keys`]) and the grouped
//! feature-row folds ([`add_assign_f32`], [`max_assign_f32`]). This
//! module gives each of those loops an AVX2 implementation plus an
//! auto-vectorization-friendly scalar reference, and pins the two
//! byte-identical with property tests.
//!
//! Dispatch is layered:
//!
//! - **cargo feature** — the `simd` feature (on by default) compiles
//!   the `std::arch` AVX2 paths at all. `--no-default-features` builds
//!   are scalar-only.
//! - **runtime CPU detection** — `is_x86_feature_detected!("avx2")` is
//!   consulted once and cached; non-AVX2 machines silently take the
//!   scalar path.
//! - **env override** — `TGM_KERNELS=scalar` forces the scalar path at
//!   runtime (the property tests and benches use this to diff the two
//!   backends on the same machine).
//!
//! Every public function here is safe: the `unsafe` AVX2 bodies are
//! private, only reachable after the feature check, and do their own
//! bounds handling (exact 4/8-lane chunks plus scalar tails). The
//! scalar references are public (`*_scalar`) so tests and benches can
//! pin against them explicitly.

mod bucket;
mod filter;
mod gather;
mod reduce;
mod scan;

pub use bucket::{bucket_keys, bucket_keys_scalar};
pub use filter::{count_lt, count_lt_scalar};
pub use gather::{
    add_offset_u32, add_offset_u32_scalar, gather_i64, gather_i64_scalar, gather_rows_masked_f32,
    gather_rows_masked_f32_scalar, gather_u32, gather_u32_scalar,
};
pub use reduce::{add_assign_f32, add_assign_f32_scalar, max_assign_f32, max_assign_f32_scalar};
pub use scan::{min_max_u32, min_max_u32_scalar, position_u32, position_u32_scalar};

use std::sync::OnceLock;

/// True when the AVX2 paths are compiled in, the CPU has AVX2, and the
/// `TGM_KERNELS=scalar` override is not set. Cached after first call.
#[inline]
pub fn simd_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(detect)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detect() -> bool {
    if std::env::var("TGM_KERNELS").is_ok_and(|v| v.eq_ignore_ascii_case("scalar")) {
        return false;
    }
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn detect() -> bool {
    false
}

/// Human-readable name of the active backend (for logs and benches).
pub fn backend() -> &'static str {
    if simd_enabled() {
        "avx2"
    } else {
        "scalar"
    }
}

/// A cheap monotonic cycle counter for the profiler's per-batch
/// materialization accounting: `rdtsc` on x86_64 (constant-rate on
/// every CPU this crate targets), monotonic nanoseconds elsewhere.
/// Only differences between two readings are meaningful.
#[inline]
pub fn cycles() -> u64 {
    #[cfg(target_arch = "x86_64")]
    {
        // Safety: RDTSC is unprivileged and has no memory effects.
        unsafe { core::arch::x86_64::_rdtsc() }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        static EPOCH: OnceLock<std::time::Instant> = OnceLock::new();
        let epoch = *EPOCH.get_or_init(std::time::Instant::now);
        epoch.elapsed().as_nanos() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_is_stable_and_named() {
        let b = backend();
        assert!(b == "avx2" || b == "scalar");
        assert_eq!(backend(), b);
    }

    #[test]
    fn cycles_is_monotonic_enough() {
        let a = cycles();
        let mut spin = 0u64;
        for i in 0..10_000u64 {
            spin = spin.wrapping_add(i);
        }
        let b = cycles();
        assert!(b.wrapping_sub(a) > 0 || spin > 0);
    }
}
