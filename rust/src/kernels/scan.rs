//! Membership scans and range reductions over u32 columns.
//!
//! The negatives-dedup path asks "has this node id been seen in the
//! batch already?" against a short first-occurrence list — a linear
//! membership scan that AVX2 answers eight lanes at a time — and the
//! negative sampler needs the destination-id range of a segment, a
//! min/max reduction over the whole `dst` column.

/// Index of the first occurrence of `needle` in `hay`, if any.
///
/// Equivalent to `hay.iter().position(|&x| x == needle)`.
#[inline]
pub fn position_u32(hay: &[u32], needle: u32) -> Option<usize> {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`.
        return unsafe { avx2::position_u32(hay, needle) };
    }
    position_u32_scalar(hay, needle)
}

/// Scalar reference for [`position_u32`].
#[inline]
pub fn position_u32_scalar(hay: &[u32], needle: u32) -> Option<usize> {
    hay.iter().position(|&x| x == needle)
}

/// `(min, max)` over `xs`, or `None` when empty.
#[inline]
pub fn min_max_u32(xs: &[u32]) -> Option<(u32, u32)> {
    if xs.is_empty() {
        return None;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if xs.len() >= 8 && super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`; length
        // >= 8 was checked above.
        return Some(unsafe { avx2::min_max_u32(xs) });
    }
    min_max_u32_scalar(xs)
}

/// Scalar reference for [`min_max_u32`].
#[inline]
pub fn min_max_u32_scalar(xs: &[u32]) -> Option<(u32, u32)> {
    xs.iter().fold(None, |acc, &x| match acc {
        None => Some((x, x)),
        Some((lo, hi)) => Some((lo.min(x), hi.max(x))),
    })
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// First-match membership scan, eight u32 lanes per step. Chunks
    /// are visited in order and the first set lane wins, so the result
    /// is the same first occurrence the scalar scan finds.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn position_u32(hay: &[u32], needle: u32) -> Option<usize> {
        let nv = _mm256_set1_epi32(needle as i32);
        let chunks = hay.chunks_exact(8);
        let tail_start = hay.len() - chunks.remainder().len();
        for (c, chunk) in chunks.enumerate() {
            let x = _mm256_loadu_si256(chunk.as_ptr() as *const __m256i);
            let eq = _mm256_cmpeq_epi32(x, nv);
            let mask = _mm256_movemask_epi8(eq) as u32;
            if mask != 0 {
                return Some(c * 8 + (mask.trailing_zeros() / 4) as usize);
            }
        }
        hay[tail_start..].iter().position(|&x| x == needle).map(|p| tail_start + p)
    }

    /// # Safety
    /// Caller must have verified AVX2 support; `xs.len() >= 8`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn min_max_u32(xs: &[u32]) -> (u32, u32) {
        let mut lo = _mm256_loadu_si256(xs.as_ptr() as *const __m256i);
        let mut hi = lo;
        let chunks = xs.chunks_exact(8);
        let tail = chunks.remainder();
        for chunk in chunks {
            let x = _mm256_loadu_si256(chunk.as_ptr() as *const __m256i);
            lo = _mm256_min_epu32(lo, x);
            hi = _mm256_max_epu32(hi, x);
        }
        let mut lo_arr = [0u32; 8];
        let mut hi_arr = [0u32; 8];
        _mm256_storeu_si256(lo_arr.as_mut_ptr() as *mut __m256i, lo);
        _mm256_storeu_si256(hi_arr.as_mut_ptr() as *mut __m256i, hi);
        let mut lo_s = lo_arr[0];
        let mut hi_s = hi_arr[0];
        for (&l, &h) in lo_arr[1..].iter().zip(hi_arr[1..].iter()) {
            lo_s = lo_s.min(l);
            hi_s = hi_s.max(h);
        }
        for &x in tail {
            lo_s = lo_s.min(x);
            hi_s = hi_s.max(x);
        }
        (lo_s, hi_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn position_matches_scalar() {
        let mut rng = 0xdead_beef_cafe_f00du64;
        for n in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 100, 255] {
            let hay: Vec<u32> = (0..n).map(|_| (xorshift(&mut rng) % 50) as u32).collect();
            for needle in 0..60u32 {
                assert_eq!(
                    position_u32(&hay, needle),
                    position_u32_scalar(&hay, needle),
                    "n={n} needle={needle}"
                );
            }
        }
        // Duplicate-heavy input: first occurrence must win.
        let hay = vec![7u32, 3, 7, 7, 1, 7, 7, 7, 7, 3];
        assert_eq!(position_u32(&hay, 7), Some(0));
        assert_eq!(position_u32(&hay, 3), Some(1));
        assert_eq!(position_u32(&hay, 9), None);
    }

    #[test]
    fn min_max_matches_scalar() {
        let mut rng = 0x0123_4567_89ab_cdefu64;
        assert_eq!(min_max_u32(&[]), None);
        for n in [1usize, 2, 3, 7, 8, 9, 15, 16, 17, 100, 1000] {
            let xs: Vec<u32> = (0..n).map(|_| xorshift(&mut rng) as u32).collect();
            assert_eq!(min_max_u32(&xs), min_max_u32_scalar(&xs), "n={n}");
        }
        assert_eq!(min_max_u32(&[5]), Some((5, 5)));
        assert_eq!(min_max_u32(&[u32::MAX, 0, 1, 2, 3, 4, 5, 6, 7]), Some((0, u32::MAX)));
    }
}
