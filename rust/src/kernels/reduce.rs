//! Element-wise lane ops over f32 feature rows.
//!
//! The grouped reduction scan of discretization folds every event's
//! feature row into a per-class accumulator — `acc[j] += row[j]` for
//! Sum/Mean and `acc[j] = max(acc[j], row[j])` for Max. Each feature
//! dimension is an independent lane, so an 8-wide AVX2 loop computes
//! **bit-identical** results to the scalar loop (the per-dimension
//! accumulation order never changes), unlike a horizontal reduction.

/// `acc[j] += src[j]` element-wise. Panics on length mismatch.
#[inline]
pub fn add_assign_f32(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len(), "acc/src length mismatch");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if acc.len() >= 8 && super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`.
        unsafe { avx2::add_assign_f32(acc, src) };
        return;
    }
    add_assign_f32_scalar(acc, src);
}

/// Scalar reference for [`add_assign_f32`].
#[inline]
pub fn add_assign_f32_scalar(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len(), "acc/src length mismatch");
    for (a, &x) in acc.iter_mut().zip(src) {
        *a += x;
    }
}

/// `acc[j] = max(acc[j], src[j])` element-wise, with `f32::max`
/// NaN-ignoring semantics on both backends (a NaN accumulator is
/// replaced, a NaN source is ignored). Panics on length mismatch.
#[inline]
pub fn max_assign_f32(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len(), "acc/src length mismatch");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if acc.len() >= 8 && super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`.
        unsafe { avx2::max_assign_f32(acc, src) };
        return;
    }
    max_assign_f32_scalar(acc, src);
}

/// Scalar reference for [`max_assign_f32`].
#[inline]
pub fn max_assign_f32_scalar(acc: &mut [f32], src: &[f32]) {
    assert_eq!(acc.len(), src.len(), "acc/src length mismatch");
    for (a, &x) in acc.iter_mut().zip(src) {
        *a = a.max(x);
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// 8-lane `acc += src`.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime; slices must
    /// have equal length (asserted by the safe wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_f32(acc: &mut [f32], src: &[f32]) {
        let n = acc.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            let b = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, b));
            i += 8;
        }
        while i < n {
            *acc.get_unchecked_mut(i) += *src.get_unchecked(i);
            i += 1;
        }
    }

    /// 8-lane `acc = max(acc, src)` with `f32::max` NaN semantics:
    /// `vmaxps` alone returns its *second* operand whenever either lane
    /// is NaN, so a NaN source would poison the accumulator. Blending
    /// the plain `max` with `acc` wherever `src` is NaN restores the
    /// scalar `f32::max` behavior bit-for-bit (for the NaN-accumulator
    /// case, `vmaxps(acc, src)` already returns `src`).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime; slices must
    /// have equal length (asserted by the safe wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn max_assign_f32(acc: &mut [f32], src: &[f32]) {
        let n = acc.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            let b = _mm256_loadu_ps(src.as_ptr().add(i));
            let m = _mm256_max_ps(a, b);
            // src-is-NaN lanes keep the accumulator.
            let b_nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(b, b);
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_blendv_ps(m, a, b_nan));
            i += 8;
        }
        while i < n {
            let a = acc.get_unchecked_mut(i);
            *a = a.max(*src.get_unchecked(i));
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    fn random_row(state: &mut u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let r = xorshift(state);
                // Mix signs, magnitudes, and the occasional special.
                match r % 37 {
                    0 => f32::NEG_INFINITY,
                    1 => f32::INFINITY,
                    2 => f32::NAN,
                    _ => ((r % 20_000) as f32 - 10_000.0) / 97.0,
                }
            })
            .collect()
    }

    #[test]
    fn add_matches_scalar_bitwise() {
        let mut state = 0x2545f4914f6cdd1du64;
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 1000] {
            let src = random_row(&mut state, n);
            let base = random_row(&mut state, n);
            let (mut a, mut b) = (base.clone(), base);
            add_assign_f32(&mut a, &src);
            add_assign_f32_scalar(&mut b, &src);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "n={n}");
        }
    }

    #[test]
    fn max_matches_scalar_bitwise() {
        let mut state = 0x853c49e6748fea9bu64;
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 1000] {
            let src = random_row(&mut state, n);
            let base = random_row(&mut state, n);
            let (mut a, mut b) = (base.clone(), base);
            max_assign_f32(&mut a, &src);
            max_assign_f32_scalar(&mut b, &src);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "n={n}");
        }
    }

    #[test]
    fn max_from_neg_infinity_accumulator() {
        let mut acc = vec![f32::NEG_INFINITY; 9];
        let src: Vec<f32> = (0..9).map(|i| i as f32 - 4.0).collect();
        max_assign_f32(&mut acc, &src);
        assert_eq!(acc, src);
    }
}
