//! Masked gathers of neighbor ids and feature rows into batch arenas.
//!
//! Batch materialization spends most of its memory traffic here: pull a
//! feature row per sampled edge out of the (mmap-backed, format-v2
//! aligned) segment columns into the dense arena the model consumes,
//! and resolve random uniform-sampler draws against contiguous
//! adjacency columns. The masked row gather fuses the "slot filled?"
//! check with the copy; the u32/i64 index gathers use the AVX2
//! hardware gather instructions.

/// Gather `dim`-wide f32 rows `eidx[o]` of `feats` into `out[o*dim..]`
/// for every slot with `mask[o] > 0.0`; masked-off slots are left
/// untouched (the arena is pre-zeroed by the caller).
///
/// Panics if `eidx.len() != mask.len()`, if `out` is shorter than
/// `mask.len() * dim`, or if an active row index is out of bounds —
/// identically on both backends.
#[inline]
pub fn gather_rows_masked_f32(
    feats: &[f32],
    dim: usize,
    eidx: &[u32],
    mask: &[f32],
    out: &mut [f32],
) {
    assert_eq!(eidx.len(), mask.len(), "eidx/mask length mismatch");
    assert!(out.len() >= mask.len() * dim, "output arena too small");
    if dim == 0 {
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if dim >= 8 && super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`.
        unsafe { avx2::gather_rows_masked_f32(feats, dim, eidx, mask, out) };
        return;
    }
    gather_rows_masked_f32_scalar(feats, dim, eidx, mask, out);
}

/// Scalar reference for [`gather_rows_masked_f32`].
#[inline]
pub fn gather_rows_masked_f32_scalar(
    feats: &[f32],
    dim: usize,
    eidx: &[u32],
    mask: &[f32],
    out: &mut [f32],
) {
    assert_eq!(eidx.len(), mask.len(), "eidx/mask length mismatch");
    assert!(out.len() >= mask.len() * dim, "output arena too small");
    if dim == 0 {
        return;
    }
    for (o, (&m, &e)) in mask.iter().zip(eidx.iter()).enumerate() {
        if m > 0.0 {
            let start = e as usize * dim;
            out[o * dim..(o + 1) * dim].copy_from_slice(&feats[start..start + dim]);
        }
    }
}

/// Gather `out[i] = src[idx[i]]` for u32 columns (neighbor ids, edge
/// indices). All indices are bounds-checked up front, so both backends
/// panic before writing anything on a bad index.
#[inline]
pub fn gather_u32(src: &[u32], idx: &[u32], out: &mut [u32]) {
    assert_eq!(idx.len(), out.len(), "idx/out length mismatch");
    assert!(idx.iter().all(|&i| (i as usize) < src.len()), "gather index out of bounds");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_enabled() {
        // Safety: AVX2 checked by `simd_enabled`; indices validated above.
        unsafe { avx2::gather_u32(src, idx, out) };
        return;
    }
    gather_u32_scalar(src, idx, out);
}

/// Scalar reference for [`gather_u32`].
#[inline]
pub fn gather_u32_scalar(src: &[u32], idx: &[u32], out: &mut [u32]) {
    assert_eq!(idx.len(), out.len(), "idx/out length mismatch");
    for (o, &i) in out.iter_mut().zip(idx.iter()) {
        *o = src[i as usize];
    }
}

/// Gather `out[i] = src[idx[i]]` for i64 columns (timestamps). Same
/// up-front bounds validation as [`gather_u32`].
#[inline]
pub fn gather_i64(src: &[i64], idx: &[u32], out: &mut [i64]) {
    assert_eq!(idx.len(), out.len(), "idx/out length mismatch");
    assert!(idx.iter().all(|&i| (i as usize) < src.len()), "gather index out of bounds");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_enabled() {
        // Safety: AVX2 checked by `simd_enabled`; indices validated above.
        unsafe { avx2::gather_i64(src, idx, out) };
        return;
    }
    gather_i64_scalar(src, idx, out);
}

/// Scalar reference for [`gather_i64`].
#[inline]
pub fn gather_i64_scalar(src: &[i64], idx: &[u32], out: &mut [i64]) {
    assert_eq!(idx.len(), out.len(), "idx/out length mismatch");
    for (o, &i) in out.iter_mut().zip(idx.iter()) {
        *o = src[i as usize];
    }
}

/// Append `src[i].wrapping_add(base)` to `out` — rebasing a segment's
/// local edge indices onto the snapshot's logical edge space when
/// collecting merged adjacency parts.
#[inline]
pub fn add_offset_u32(src: &[u32], base: u32, out: &mut Vec<u32>) {
    if base == 0 {
        out.extend_from_slice(src);
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_enabled() {
        let start = out.len();
        out.resize(start + src.len(), 0);
        // Safety: AVX2 checked by `simd_enabled`; the destination slice
        // was just sized to match `src`.
        unsafe { avx2::add_offset_u32(src, base, &mut out[start..]) };
        return;
    }
    add_offset_u32_scalar(src, base, out);
}

/// Scalar reference for [`add_offset_u32`].
#[inline]
pub fn add_offset_u32_scalar(src: &[u32], base: u32, out: &mut Vec<u32>) {
    out.extend(src.iter().map(|&x| x.wrapping_add(base)));
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support; `eidx.len() == mask.len()`
    /// and `out.len() >= mask.len() * dim` must hold (row indices are
    /// re-checked here via safe slicing before any raw copy).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_rows_masked_f32(
        feats: &[f32],
        dim: usize,
        eidx: &[u32],
        mask: &[f32],
        out: &mut [f32],
    ) {
        for (o, (&m, &e)) in mask.iter().zip(eidx.iter()).enumerate() {
            if m > 0.0 {
                let start = e as usize * dim;
                // Safe slicing keeps the panic behavior of the scalar
                // path for out-of-bounds rows.
                let src = &feats[start..start + dim];
                let dst = &mut out[o * dim..(o + 1) * dim];
                let mut i = 0usize;
                while i + 8 <= dim {
                    let v = _mm256_loadu_ps(src.as_ptr().add(i));
                    _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
                    i += 8;
                }
                if i < dim {
                    dst[i..].copy_from_slice(&src[i..]);
                }
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support, `idx.len() == out.len()`,
    /// and that every index is in bounds for `src` (the hardware gather
    /// reads without bounds checks).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_u32(src: &[u32], idx: &[u32], out: &mut [u32]) {
        let n = idx.len();
        let base = src.as_ptr() as *const i32;
        let mut i = 0usize;
        while i + 8 <= n {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let g = _mm256_i32gather_epi32::<4>(base, iv);
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, g);
            i += 8;
        }
        while i < n {
            out[i] = src[idx[i] as usize];
            i += 1;
        }
    }

    /// # Safety
    /// Same contract as [`gather_u32`], for i64 elements.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_i64(src: &[i64], idx: &[u32], out: &mut [i64]) {
        let n = idx.len();
        let base = src.as_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            let iv = _mm_loadu_si128(idx.as_ptr().add(i) as *const __m128i);
            let g = _mm256_i32gather_epi64::<8>(base, iv);
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, g);
            i += 4;
        }
        while i < n {
            out[i] = src[idx[i] as usize];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support and `dst.len() == src.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_offset_u32(src: &[u32], base: u32, dst: &mut [u32]) {
        let n = src.len();
        let bv = _mm256_set1_epi32(base as i32);
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let s = _mm256_add_epi32(v, bv);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, s);
            i += 8;
        }
        while i < n {
            dst[i] = src[i].wrapping_add(base);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Deterministic pseudo-random stream (no external crates).
    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn masked_row_gather_matches_scalar() {
        let mut rng = 0x1234_5678_9abc_def0u64;
        for dim in [1usize, 2, 3, 4, 7, 8, 9, 15, 16, 17, 32] {
            for n in [0usize, 1, 2, 5, 8, 13, 64] {
                let rows = 64usize;
                let feats: Vec<f32> = (0..rows * dim).map(|i| i as f32 * 0.5).collect();
                let eidx: Vec<u32> =
                    (0..n).map(|_| (xorshift(&mut rng) % rows as u64) as u32).collect();
                let mask: Vec<f32> =
                    (0..n).map(|_| if xorshift(&mut rng) % 2 == 0 { 1.0 } else { 0.0 }).collect();
                let mut got = vec![-7.0f32; n * dim];
                let mut want = vec![-7.0f32; n * dim];
                gather_rows_masked_f32(&feats, dim, &eidx, &mask, &mut got);
                gather_rows_masked_f32_scalar(&feats, dim, &eidx, &mask, &mut want);
                assert_eq!(got, want, "dim={dim} n={n}");
            }
        }
    }

    #[test]
    fn masked_row_gather_skips_empty_and_zero_dim() {
        let mut out: Vec<f32> = vec![];
        gather_rows_masked_f32(&[], 4, &[], &[], &mut out);
        let mut out = vec![1.0f32; 4];
        gather_rows_masked_f32(&[], 0, &[0, 1, 2, 3], &[1.0; 4], &mut out);
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn index_gathers_match_scalar() {
        let mut rng = 0x0fed_cba9_8765_4321u64;
        let src32: Vec<u32> = (0..1000u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
        let src64: Vec<i64> = (0..1000i64).map(|i| i * -97 + 3).collect();
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 31, 100, 257] {
            let idx: Vec<u32> = (0..n).map(|_| (xorshift(&mut rng) % 1000) as u32).collect();
            let mut got32 = vec![0u32; n];
            let mut want32 = vec![0u32; n];
            gather_u32(&src32, &idx, &mut got32);
            gather_u32_scalar(&src32, &idx, &mut want32);
            assert_eq!(got32, want32, "u32 n={n}");
            let mut got64 = vec![0i64; n];
            let mut want64 = vec![0i64; n];
            gather_i64(&src64, &idx, &mut got64);
            gather_i64_scalar(&src64, &idx, &mut want64);
            assert_eq!(got64, want64, "i64 n={n}");
        }
    }

    #[test]
    fn add_offset_matches_scalar() {
        for n in [0usize, 1, 3, 7, 8, 9, 17, 100] {
            let src: Vec<u32> = (0..n as u32).map(|i| i * 3 + 1).collect();
            for base in [0u32, 1, 1000, u32::MAX - 2] {
                let mut got = vec![42u32; 2];
                let mut want = vec![42u32; 2];
                add_offset_u32(&src, base, &mut got);
                add_offset_u32_scalar(&src, base, &mut want);
                assert_eq!(got, want, "n={n} base={base}");
            }
        }
    }
}
