//! Bucket-key computation over sorted timestamp columns.
//!
//! Discretization pass 1 maps every event timestamp to its granularity
//! bucket `(t - t0).div_euclid(secs)`. A naive loop pays a 64-bit
//! integer division per event — the single most expensive scalar op in
//! the pass. Because the column is time-sorted, buckets form
//! non-decreasing runs: one division finds the current bucket, and the
//! run's extent is a "count strictly below the bucket's end timestamp"
//! query, which is exactly [`super::count_lt`] (branchless SIMD for
//! short runs, `partition_point` for long ones). The division count
//! drops from `O(events)` to `O(distinct buckets)` and the per-run fill
//! is a vectorizable `memset`-shaped extend.

use super::count_lt;

/// Append the bucket index `(t - t0).div_euclid(secs)` of every element
/// of the **non-decreasing** slice `ts` to `out`.
///
/// `secs` must be positive. Sortedness is the caller's contract (all
/// storage timestamp columns are sorted by construction); it is
/// debug-asserted here and the run-based fast path is only correct
/// under it.
#[inline]
pub fn bucket_keys(ts: &[i64], t0: i64, secs: i64, out: &mut Vec<i64>) {
    assert!(secs > 0, "bucket width must be positive");
    debug_assert!(ts.windows(2).all(|w| w[0] <= w[1]), "bucket_keys input must be sorted");
    out.reserve(ts.len());
    let mut i = 0usize;
    while i < ts.len() {
        let b = (ts[i] - t0).div_euclid(secs);
        // First timestamp of the next bucket, saturating so timestamps
        // near i64::MAX terminate the run at the slice end instead of
        // wrapping.
        let lim = t0 as i128 + (b as i128 + 1) * secs as i128;
        let run = if lim > i64::MAX as i128 {
            // The bucket's end is unrepresentable, so every remaining
            // timestamp fits in it (a limit that *equals* i64::MAX is
            // still a real boundary: ts == i64::MAX starts a new run).
            ts.len() - i
        } else {
            // `ts[i] < lim` by construction, so the run is non-empty
            // and the loop always advances.
            count_lt(&ts[i..], lim as i64)
        };
        out.resize(out.len() + run, b);
        i += run;
    }
}

/// Scalar reference for [`bucket_keys`]: one `div_euclid` per element,
/// no sortedness requirement (the property tests pin the run-based path
/// byte-identical to this on sorted inputs).
#[inline]
pub fn bucket_keys_scalar(ts: &[i64], t0: i64, secs: i64, out: &mut Vec<i64>) {
    assert!(secs > 0, "bucket width must be positive");
    out.extend(ts.iter().map(|&t| (t - t0).div_euclid(secs)));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn matches_scalar_reference() {
        let mut state = 0x9e3779b97f4a7c15u64;
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 255, 257, 2048] {
            for &(t0, secs) in &[(0i64, 1i64), (0, 3600), (-7200, 3600), (1_000_000, 60), (5, 7)] {
                let mut ts: Vec<i64> = (0..len)
                    .map(|_| t0 - 10_000 + (xorshift(&mut state) % 1_000_000) as i64)
                    .collect();
                ts.sort_unstable();
                let (mut fast, mut slow) = (Vec::new(), Vec::new());
                bucket_keys(&ts, t0, secs, &mut fast);
                bucket_keys_scalar(&ts, t0, secs, &mut slow);
                assert_eq!(fast, slow, "len={len} t0={t0} secs={secs}");
            }
        }
    }

    #[test]
    fn negative_and_tied_timestamps() {
        // Ties, negative buckets, and values straddling the origin.
        let ts = vec![-7200, -3600, -3600, -1, 0, 0, 1, 3599, 3600, 3600];
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        bucket_keys(&ts, 0, 3600, &mut fast);
        bucket_keys_scalar(&ts, 0, 3600, &mut slow);
        assert_eq!(fast, slow);
        assert_eq!(fast, vec![-2, -1, -1, -1, 0, 0, 0, 0, 1, 1]);
    }

    #[test]
    fn extreme_timestamps_do_not_wrap() {
        let ts = vec![i64::MIN, -1, 0, i64::MAX - 1, i64::MAX];
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        bucket_keys(&ts, 0, 1, &mut fast);
        bucket_keys_scalar(&ts, 0, 1, &mut slow);
        assert_eq!(fast, slow);
    }

    #[test]
    fn appends_after_existing_contents() {
        let mut out = vec![42];
        bucket_keys(&[0, 10], 0, 5, &mut out);
        assert_eq!(out, vec![42, 0, 2]);
    }
}
