//! Timestamp filtered counts over sorted columns.
//!
//! `neighbors_before` and the per-part time cut of `MergedNeighbors`
//! both reduce to "how many timestamps in this sorted run are strictly
//! below `t`". Per-node runs are short (tens of entries), where a
//! branchless linear SIMD count beats binary search's unpredictable
//! branches; long runs fall back to `partition_point`, which is optimal
//! at scale. Both answers are identical because the input is sorted.

/// Runs at or below this length take the linear (SIMD or branchless
/// scalar) count; longer runs binary-search.
const LINEAR_MAX: usize = 256;

/// Number of elements of sorted `ts` strictly less than `t`.
///
/// Equivalent to `ts.partition_point(|&u| u < t)`; the caller must pass
/// a non-decreasing slice (adjacency timestamp runs are sorted by
/// construction).
#[inline]
pub fn count_lt(ts: &[i64], t: i64) -> usize {
    if ts.len() > LINEAR_MAX {
        return ts.partition_point(|&u| u < t);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_enabled() {
        // Safety: AVX2 presence was checked by `simd_enabled`.
        return unsafe { avx2::count_lt(ts, t) };
    }
    count_lt_linear(ts, t)
}

/// Scalar reference for [`count_lt`] (the property tests pin the SIMD
/// path byte-identical to this).
#[inline]
pub fn count_lt_scalar(ts: &[i64], t: i64) -> usize {
    ts.partition_point(|&u| u < t)
}

/// Branchless linear count; auto-vectorization friendly.
#[inline]
fn count_lt_linear(ts: &[i64], t: i64) -> usize {
    ts.iter().map(|&u| usize::from(u < t)).sum()
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// Linear SIMD count of elements `< t` in a sorted slice.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn count_lt(ts: &[i64], t: i64) -> usize {
        let tv = _mm256_set1_epi64x(t);
        let mut count = 0usize;
        let chunks = ts.chunks_exact(4);
        let tail = chunks.remainder();
        for chunk in chunks {
            // `x < t` as a signed 64-bit compare: t > x.
            let x = _mm256_loadu_si256(chunk.as_ptr() as *const __m256i);
            let lt = _mm256_cmpgt_epi64(tv, x);
            let mask = _mm256_movemask_pd(_mm256_castsi256_pd(lt));
            count += mask.count_ones() as usize;
        }
        count += tail.iter().map(|&u| usize::from(u < t)).sum::<usize>();
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cases() -> Vec<(Vec<i64>, i64)> {
        let mut cases = vec![
            (vec![], 0),
            (vec![5], 5),
            (vec![5], 6),
            (vec![5], 4),
            (vec![i64::MIN, -1, 0, 1, i64::MAX], 0),
            (vec![i64::MIN, -1, 0, 1, i64::MAX], i64::MAX),
            (vec![0; 33], 0),
            (vec![0; 33], 1),
        ];
        // Odd lengths and unaligned tails around the 4-lane width, plus
        // a run longer than the linear cutoff.
        for len in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 31, 63, 255, 257, 1024] {
            let ts: Vec<i64> = (0..len as i64).map(|i| i * 3).collect();
            for t in [-1, 0, 1, 3, (len as i64 * 3) / 2, len as i64 * 3 + 1] {
                cases.push((ts.clone(), t));
            }
        }
        cases
    }

    #[test]
    fn matches_scalar_reference() {
        for (ts, t) in cases() {
            assert_eq!(count_lt(&ts, t), count_lt_scalar(&ts, t), "ts.len()={} t={t}", ts.len());
            assert_eq!(count_lt_linear(&ts, t), count_lt_scalar(&ts, t));
        }
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn avx2_matches_scalar_reference() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        for (ts, t) in cases() {
            // Safety: AVX2 detected above.
            let got = unsafe { avx2::count_lt(&ts, t) };
            assert_eq!(got, count_lt_scalar(&ts, t), "ts.len()={} t={t}", ts.len());
        }
    }
}
