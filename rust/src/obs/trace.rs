//! Bounded structured trace ring: the "what just happened" companion to
//! the metrics registry's "how much".
//!
//! A [`TraceRing`] is a fixed-capacity ring of [`TraceEvent`]s — one
//! per interesting operation (seal, compaction round, recovery, WAL
//! group sync, DTDG refresh, point query, error set/cleared). Writers
//! never block: the write cursor is one atomic `fetch_add`, each slot
//! is guarded by a `try_lock` (a contended slot counts a drop instead
//! of waiting), so tracing is safe from the hottest paths. Readers take
//! ordered copies via [`TraceRing::snapshot`] (non-destructive) or
//! [`TraceRing::drain`] (consuming), oldest first.
//!
//! [`span`] returns a guard that records its wall-clock duration on
//! drop. With `TGM_TRACE` set, spans at or above `TGM_TRACE_SLOW_US`
//! microseconds (default 10 ms) are also logged to stderr immediately —
//! a built-in slow-op log with zero setup.

use super::registry::Label;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, TryLockError};
use std::time::Instant;

/// Capacity of the process-global ring (events; ~a few hundred KB).
pub const DEFAULT_CAPACITY: usize = 4096;

/// Default `TGM_TRACE_SLOW_US` when `TGM_TRACE` is set: 10 ms.
const DEFAULT_SLOW_US: u64 = 10_000;

/// One structured trace event.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Microseconds since process start (monotonic).
    pub ts_us: u64,
    /// Owning subsystem (`persist`, `serving`, `dtdg`, `graph`, …).
    pub subsystem: &'static str,
    /// Operation kind (`seal`, `compaction`, `wal_sync`, …).
    pub kind: &'static str,
    /// Tenant / store the operation ran for, when attributable.
    pub tenant: Option<Label>,
    /// Operation duration (0 for instantaneous events).
    pub dur_us: u64,
    /// Free-form context (byte counts, error text, …).
    pub detail: String,
}

struct Slot {
    seq: u64,
    event: TraceEvent,
}

/// Fixed-capacity, never-blocking ring of [`TraceEvent`]s.
pub struct TraceRing {
    slots: Box<[Mutex<Option<Slot>>]>,
    cursor: AtomicU64,
    dropped: AtomicU64,
}

impl TraceRing {
    /// Ring holding the latest `capacity` events.
    pub fn with_capacity(capacity: usize) -> TraceRing {
        let cap = capacity.max(1);
        TraceRing {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record one event. Never blocks: a slot currently held by a
    /// reader (or another writer that wrapped a full lap) drops the
    /// event and counts it in [`TraceRing::dropped`].
    pub fn record(&self, event: TraceEvent) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        match slot.try_lock() {
            Ok(mut g) => *g = Some(Slot { seq, event }),
            Err(TryLockError::Poisoned(p)) => *p.into_inner() = Some(Slot { seq, event }),
            Err(TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Events dropped because their slot was contended at record time.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy of the retained events, oldest first (non-destructive).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.collect(false)
    }

    /// Remove and return the retained events, oldest first.
    pub fn drain(&self) -> Vec<TraceEvent> {
        self.collect(true)
    }

    fn collect(&self, take: bool) -> Vec<TraceEvent> {
        let mut out: Vec<Slot> = Vec::new();
        for slot in self.slots.iter() {
            let mut g = slot.lock().unwrap_or_else(|e| e.into_inner());
            if take {
                if let Some(s) = g.take() {
                    out.push(s);
                }
            } else if let Some(s) = g.as_ref() {
                out.push(Slot { seq: s.seq, event: s.event.clone() });
            }
        }
        out.sort_by_key(|s| s.seq);
        out.into_iter().map(|s| s.event).collect()
    }
}

/// The process-global ring all [`span`]s and [`event`]s feed.
pub fn trace_ring() -> &'static TraceRing {
    static RING: OnceLock<TraceRing> = OnceLock::new();
    RING.get_or_init(|| TraceRing::with_capacity(DEFAULT_CAPACITY))
}

fn process_start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Microseconds since process start (monotonic, saturating).
pub fn now_us() -> u64 {
    process_start().elapsed().as_micros().min(u64::MAX as u128) as u64
}

/// Slow-op logging threshold: `Some(us)` when `TGM_TRACE` is set
/// (non-empty, not `0`), with `TGM_TRACE_SLOW_US` overriding the
/// default 10 ms.
fn slow_threshold_us() -> Option<u64> {
    static T: OnceLock<Option<u64>> = OnceLock::new();
    *T.get_or_init(|| {
        match std::env::var("TGM_TRACE") {
            Ok(v) if !v.trim().is_empty() && v.trim() != "0" => {}
            _ => return None,
        }
        Some(
            std::env::var("TGM_TRACE_SLOW_US")
                .ok()
                .and_then(|v| v.trim().parse::<u64>().ok())
                .unwrap_or(DEFAULT_SLOW_US),
        )
    })
}

/// Start a span guard: duration is measured from this call to drop,
/// then recorded into the global ring (and stderr when slow logging is
/// on and the span is at or above the threshold).
pub fn span(subsystem: &'static str, kind: &'static str) -> Span {
    Span { subsystem, kind, tenant: None, detail: String::new(), start: Instant::now() }
}

/// Record one instantaneous event (no duration) into the global ring.
pub fn event(
    subsystem: &'static str,
    kind: &'static str,
    tenant: Option<Label>,
    detail: impl Into<String>,
) {
    trace_ring().record(TraceEvent {
        ts_us: now_us(),
        subsystem,
        kind,
        tenant,
        dur_us: 0,
        detail: detail.into(),
    });
}

/// Duration-measuring guard; see [`span`].
pub struct Span {
    subsystem: &'static str,
    kind: &'static str,
    tenant: Option<Label>,
    detail: String,
    start: Instant,
}

impl Span {
    /// Attribute the span to a tenant / store.
    pub fn with_tenant(mut self, tenant: impl Into<Label>) -> Span {
        self.tenant = Some(tenant.into());
        self
    }

    /// Attach free-form context (kept on the recorded event).
    pub fn with_detail(mut self, detail: impl Into<String>) -> Span {
        self.detail = detail.into();
        self
    }

    /// Replace the context after the span started (e.g. byte counts
    /// known only once the operation finished).
    pub fn set_detail(&mut self, detail: impl Into<String>) {
        self.detail = detail.into();
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_us = self.start.elapsed().as_micros().min(u64::MAX as u128) as u64;
        if let Some(threshold) = slow_threshold_us() {
            if dur_us >= threshold {
                eprintln!(
                    "[tgm-trace] slow {}.{} {}us tenant={} {}",
                    self.subsystem,
                    self.kind,
                    dur_us,
                    self.tenant.as_ref().map(|t| t.as_str()).unwrap_or("-"),
                    self.detail,
                );
            }
        }
        trace_ring().record(TraceEvent {
            ts_us: now_us(),
            subsystem: self.subsystem,
            kind: self.kind,
            tenant: self.tenant.take(),
            dur_us,
            detail: std::mem::take(&mut self.detail),
        });
    }
}
