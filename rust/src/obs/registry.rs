//! Process-global metrics registry: sharded atomic counters, gauges,
//! and log₂ latency histograms with cheap label support.
//!
//! Design goals, in order:
//!
//! * **lock-free hot path** — a [`Counter`]/[`Gauge`]/[`Histogram`]
//!   handle is a clone of `Arc`'d atomic cells; recording is one or two
//!   relaxed atomic RMWs, never a lock. Counters are sharded across
//!   cache-line-padded cells so concurrent writers on different cores
//!   do not bounce one line;
//! * **~zero cost when disabled** — every handle checks one shared
//!   `AtomicBool` and early-returns; [`MetricsRegistry::set_enabled`]
//!   flips the whole registry at once (the `obs.overhead` ablation
//!   section measures exactly this delta);
//! * **registration is rare** — creating a handle takes a mutex over
//!   the name→cells map, so instrument setup once (at pool/tenant/store
//!   construction) and keep the handle, not per event.
//!
//! Histograms share [`LatencyHistogram`]'s exact bucket layout
//! (`floor(log2(us + 1))`, 40 buckets), so a [`Histogram::snapshot`]
//! merges losslessly with profiler state and renders as a Prometheus
//! histogram with stable `le` bounds (see [`super::export`]).

use crate::loader::sched::LatencyHistogram;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A small owned label value (`tenant`, `class`, `segment_level`, …).
///
/// Backed by `Arc<str>`: cloning is a refcount bump, so dynamic
/// (per-tenant) labels work without leaking strings — the reason
/// [`crate::coordinator::Profiler::add_request_latency`] keys on this
/// instead of `&'static str`.
#[derive(Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Label(Arc<str>);

impl Label {
    /// Label from anything string-like (copies once).
    pub fn new(s: impl AsRef<str>) -> Label {
        Label(Arc::from(s.as_ref()))
    }

    /// The label text.
    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl From<&str> for Label {
    fn from(s: &str) -> Label {
        Label::new(s)
    }
}

impl From<String> for Label {
    fn from(s: String) -> Label {
        Label(Arc::from(s.as_str()))
    }
}

impl From<Arc<str>> for Label {
    fn from(s: Arc<str>) -> Label {
        Label(s)
    }
}

impl From<&Arc<str>> for Label {
    fn from(s: &Arc<str>) -> Label {
        Label(Arc::clone(s))
    }
}

impl std::ops::Deref for Label {
    type Target = str;
    fn deref(&self) -> &str {
        &self.0
    }
}

impl AsRef<str> for Label {
    fn as_ref(&self) -> &str {
        &self.0
    }
}

impl std::borrow::Borrow<str> for Label {
    fn borrow(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", &*self.0)
    }
}

/// Counter shard count: enough to spread a few writer threads across
/// cache lines without bloating every counter (8 × 64 B = 512 B each).
const SHARDS: usize = 8;

/// One cache-line-padded counter cell (no false sharing between
/// neighboring shards).
#[repr(align(64))]
#[derive(Default)]
struct PaddedU64(AtomicU64);

/// Stable per-thread shard index: threads are striped over shards in
/// registration order, so a fixed set of workers lands on distinct
/// cells.
fn shard_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    IDX.with(|i| *i)
}

#[derive(Default)]
struct CounterCells {
    shards: [PaddedU64; SHARDS],
}

impl CounterCells {
    fn add(&self, v: u64) {
        self.shards[shard_index()].0.fetch_add(v, Ordering::Relaxed);
    }

    fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Monotone counter handle (cheap to clone; all clones share cells).
#[derive(Clone)]
pub struct Counter {
    cells: Arc<CounterCells>,
    enabled: Arc<AtomicBool>,
}

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `v`.
    pub fn add(&self, v: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cells.add(v);
        }
    }

    /// Current total (sums the shards).
    pub fn get(&self) -> u64 {
        self.cells.get()
    }
}

/// Last-write-wins gauge handle.
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicI64>,
    enabled: Arc<AtomicBool>,
}

impl Gauge {
    /// Set the current value.
    pub fn set(&self, v: i64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.store(v, Ordering::Relaxed);
        }
    }

    /// Adjust by `delta` (negative to decrement).
    pub fn add(&self, delta: i64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// Atomic mirror of [`LatencyHistogram`]: same 40-bucket
/// `floor(log2(us + 1))` layout, recordable from any thread without a
/// lock.
struct HistogramCells {
    counts: [AtomicU64; 40],
    total: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for HistogramCells {
    fn default() -> HistogramCells {
        HistogramCells {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

/// Histogram handle (cheap to clone; all clones share cells).
#[derive(Clone)]
pub struct Histogram {
    cells: Arc<HistogramCells>,
    enabled: Arc<AtomicBool>,
}

impl Histogram {
    /// Record one sample in microseconds (or any u64 magnitude — the
    /// WAL uses the same log₂ buckets for group-commit window bytes).
    pub fn record_us(&self, us: u64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let c = &self.cells;
        // Exactly LatencyHistogram::record_us's bucket, so snapshots
        // merge losslessly with profiler histograms.
        let bucket = (64 - us.saturating_add(1).leading_zeros() as usize - 1).min(39);
        c.counts[bucket].fetch_add(1, Ordering::Relaxed);
        c.total.fetch_add(1, Ordering::Relaxed);
        c.sum_us.fetch_add(us, Ordering::Relaxed);
        c.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.cells.total.load(Ordering::Relaxed)
    }

    /// Materialize into a [`LatencyHistogram`]. Field loads are
    /// individually atomic, not mutually — a snapshot racing recorders
    /// may be off by in-flight samples, which is fine for monitoring.
    pub fn snapshot(&self) -> LatencyHistogram {
        let c = &self.cells;
        LatencyHistogram::from_parts(
            std::array::from_fn(|i| c.counts[i].load(Ordering::Relaxed)),
            c.total.load(Ordering::Relaxed),
            c.sum_us.load(Ordering::Relaxed),
            c.max_us.load(Ordering::Relaxed),
        )
    }
}

enum Cells {
    Counter(Arc<CounterCells>),
    Gauge(Arc<AtomicI64>),
    Histogram(Arc<HistogramCells>),
}

/// Identity of one series: metric name + sorted label pairs.
#[derive(PartialEq, Eq, Hash, Clone)]
struct MetricId {
    name: &'static str,
    labels: Vec<(&'static str, Label)>,
}

fn metric_id(name: &'static str, labels: &[(&'static str, Label)]) -> MetricId {
    let mut labels = labels.to_vec();
    labels.sort_by(|a, b| a.0.cmp(b.0).then_with(|| a.1.cmp(&b.1)));
    MetricId { name, labels }
}

/// A registry of named, labeled metric series.
///
/// One process-global instance lives behind [`registry()`]; local
/// instances (`MetricsRegistry::new`) exist for tests and for exactly
/// scoped accounting.
pub struct MetricsRegistry {
    enabled: Arc<AtomicBool>,
    inner: Mutex<HashMap<MetricId, Cells>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    /// Empty, enabled registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            enabled: Arc::new(AtomicBool::new(true)),
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// Enable or disable every handle of this registry at once.
    /// Disabled handles early-return on record (reads still work).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Whether handles currently record.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Counter handle for `name` + `labels` (created on first use;
    /// subsequent calls return handles onto the same cells).
    pub fn counter(&self, name: &'static str, labels: &[(&'static str, Label)]) -> Counter {
        let id = metric_id(name, labels);
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let cells = match g.entry(id).or_insert_with(|| Cells::Counter(Arc::default())) {
            Cells::Counter(c) => Arc::clone(c),
            other => {
                // Kind mismatch is a programming error; recover by
                // replacing rather than panicking a serving process.
                let c: Arc<CounterCells> = Arc::default();
                *other = Cells::Counter(Arc::clone(&c));
                c
            }
        };
        Counter { cells, enabled: Arc::clone(&self.enabled) }
    }

    /// Gauge handle for `name` + `labels`.
    pub fn gauge(&self, name: &'static str, labels: &[(&'static str, Label)]) -> Gauge {
        let id = metric_id(name, labels);
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let cell = match g.entry(id).or_insert_with(|| Cells::Gauge(Arc::default())) {
            Cells::Gauge(c) => Arc::clone(c),
            other => {
                let c: Arc<AtomicI64> = Arc::default();
                *other = Cells::Gauge(Arc::clone(&c));
                c
            }
        };
        Gauge { cell, enabled: Arc::clone(&self.enabled) }
    }

    /// Histogram handle for `name` + `labels`.
    pub fn histogram(&self, name: &'static str, labels: &[(&'static str, Label)]) -> Histogram {
        let id = metric_id(name, labels);
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let cells = match g.entry(id).or_insert_with(|| Cells::Histogram(Arc::default())) {
            Cells::Histogram(c) => Arc::clone(c),
            other => {
                let c: Arc<HistogramCells> = Arc::default();
                *other = Cells::Histogram(Arc::clone(&c));
                c
            }
        };
        Histogram { cells, enabled: Arc::clone(&self.enabled) }
    }

    /// Point-in-time copy of every series, sorted by name then labels
    /// (the order the exporters render in).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut metrics: Vec<MetricSnapshot> = g
            .iter()
            .map(|(id, cells)| MetricSnapshot {
                name: id.name.to_string(),
                labels: id
                    .labels
                    .iter()
                    .map(|(k, v)| (k.to_string(), v.as_str().to_string()))
                    .collect(),
                value: match cells {
                    Cells::Counter(c) => MetricValue::Counter(c.get()),
                    Cells::Gauge(c) => MetricValue::Gauge(c.load(Ordering::Relaxed)),
                    Cells::Histogram(c) => MetricValue::Histogram(LatencyHistogram::from_parts(
                        std::array::from_fn(|i| c.counts[i].load(Ordering::Relaxed)),
                        c.total.load(Ordering::Relaxed),
                        c.sum_us.load(Ordering::Relaxed),
                        c.max_us.load(Ordering::Relaxed),
                    )),
                },
            })
            .collect();
        metrics.sort_by(|a, b| a.name.cmp(&b.name).then_with(|| a.labels.cmp(&b.labels)));
        RegistrySnapshot { metrics }
    }
}

/// One series in a [`RegistrySnapshot`].
#[derive(Debug, Clone)]
pub struct MetricSnapshot {
    /// Metric name (e.g. `tgm_ingest_events_total`).
    pub name: String,
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// The value at snapshot time.
    pub value: MetricValue,
}

impl MetricSnapshot {
    /// The value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// A snapshot value, by metric kind.
#[derive(Debug, Clone)]
pub enum MetricValue {
    /// Monotone counter total.
    Counter(u64),
    /// Gauge value.
    Gauge(i64),
    /// Full histogram state.
    Histogram(LatencyHistogram),
}

/// Sorted, point-in-time copy of a registry (see
/// [`MetricsRegistry::snapshot`]).
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    /// Every series, sorted by name then labels.
    pub metrics: Vec<MetricSnapshot>,
}

impl RegistrySnapshot {
    /// Series with `name` (any labels).
    pub fn by_name<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a MetricSnapshot> {
        self.metrics.iter().filter(move |m| m.name == name)
    }
}

/// The process-global registry every subsystem instruments against.
pub fn registry() -> &'static MetricsRegistry {
    static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();
    REGISTRY.get_or_init(MetricsRegistry::new)
}
