//! Exporters for the metrics registry and trace ring: Prometheus text
//! exposition, JSON rendering, and a dependency-free scrape endpoint.
//!
//! [`ObsServer`] is a single `std::net::TcpListener` accept loop (the
//! same no-external-deps discipline as the persist layer's raw
//! mmap/flock FFI) answering:
//!
//! * `GET /metrics` — Prometheus text format (version 0.0.4) of the
//!   global registry;
//! * `GET /metrics.json` — the same snapshot as JSON;
//! * `GET /trace` — the trace ring's retained events as JSON.
//!
//! Opt in by setting `TGM_METRICS_ADDR` (e.g. `127.0.0.1:9184`, or port
//! `0` to let the OS pick) and calling [`ObsServer::from_env`]; the
//! bound address is available via [`ObsServer::local_addr`] so smoke
//! tests can scrape ephemeral ports. [`parse_prometheus`] parses the
//! text format back (the round-trip property test pins that rendering
//! loses nothing).

use super::registry::{registry, MetricValue, RegistrySnapshot};
use super::trace::{trace_ring, TraceEvent};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Upper `le` bound of log₂ bucket `i`: bucket `i` holds samples in
/// `[2^i - 1, 2^(i+1) - 2]` (see `LatencyHistogram`), and the last
/// bucket is open-ended.
fn bucket_le(i: usize) -> String {
    if i >= 39 {
        "+Inf".to_string()
    } else {
        ((1u128 << (i + 1)) - 2).to_string()
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut pairs: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

/// Render a registry snapshot in Prometheus text exposition format.
pub fn render_prometheus(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    let mut last_name: &str = "";
    for m in &snap.metrics {
        if m.name != last_name {
            let kind = match &m.value {
                MetricValue::Counter(_) => "counter",
                MetricValue::Gauge(_) => "gauge",
                MetricValue::Histogram(_) => "histogram",
            };
            let _ = writeln!(out, "# TYPE {} {kind}", m.name);
            last_name = &m.name;
        }
        match &m.value {
            MetricValue::Counter(v) => {
                let _ = writeln!(out, "{}{} {v}", m.name, label_block(&m.labels, None));
            }
            MetricValue::Gauge(v) => {
                let _ = writeln!(out, "{}{} {v}", m.name, label_block(&m.labels, None));
            }
            MetricValue::Histogram(h) => {
                let mut cumulative = 0u64;
                for (i, &c) in h.bucket_counts().iter().enumerate() {
                    cumulative += c;
                    let le = bucket_le(i);
                    let _ = writeln!(
                        out,
                        "{}_bucket{} {cumulative}",
                        m.name,
                        label_block(&m.labels, Some(("le", &le))),
                    );
                }
                let _ = writeln!(
                    out,
                    "{}_sum{} {}",
                    m.name,
                    label_block(&m.labels, None),
                    h.sum_us()
                );
                let _ = writeln!(
                    out,
                    "{}_count{} {}",
                    m.name,
                    label_block(&m.labels, None),
                    h.count()
                );
            }
        }
    }
    out
}

fn escape_json(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn json_labels(labels: &[(String, String)]) -> String {
    let pairs: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("\"{}\":\"{}\"", escape_json(k), escape_json(v)))
        .collect();
    format!("{{{}}}", pairs.join(","))
}

/// Render a registry snapshot as JSON.
pub fn render_json(snap: &RegistrySnapshot) -> String {
    let mut rows = Vec::with_capacity(snap.metrics.len());
    for m in &snap.metrics {
        let head = format!(
            "{{\"name\":\"{}\",\"labels\":{},",
            escape_json(&m.name),
            json_labels(&m.labels)
        );
        let row = match &m.value {
            MetricValue::Counter(v) => format!("{head}\"type\":\"counter\",\"value\":{v}}}"),
            MetricValue::Gauge(v) => format!("{head}\"type\":\"gauge\",\"value\":{v}}}"),
            MetricValue::Histogram(h) => {
                let buckets: Vec<String> =
                    h.bucket_counts().iter().map(|c| c.to_string()).collect();
                format!(
                    "{head}\"type\":\"histogram\",\"count\":{},\"sum\":{},\"max\":{},\
                     \"buckets\":[{}]}}",
                    h.count(),
                    h.sum_us(),
                    h.max_us(),
                    buckets.join(","),
                )
            }
        };
        rows.push(row);
    }
    format!("{{\"metrics\":[{}]}}", rows.join(","))
}

/// Render trace events as JSON (oldest first).
pub fn render_trace_json(events: &[TraceEvent]) -> String {
    let rows: Vec<String> = events
        .iter()
        .map(|e| {
            format!(
                "{{\"ts_us\":{},\"subsystem\":\"{}\",\"kind\":\"{}\",\"tenant\":{},\
                 \"dur_us\":{},\"detail\":\"{}\"}}",
                e.ts_us,
                escape_json(e.subsystem),
                escape_json(e.kind),
                match &e.tenant {
                    Some(t) => format!("\"{}\"", escape_json(t.as_str())),
                    None => "null".to_string(),
                },
                e.dur_us,
                escape_json(&e.detail),
            )
        })
        .collect();
    format!("[{}]", rows.join(","))
}

/// One parsed Prometheus text-format sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedSample {
    /// Series name as written (histograms appear as their `_bucket` /
    /// `_sum` / `_count` series).
    pub name: String,
    /// Label pairs, sorted by key then value.
    pub labels: Vec<(String, String)>,
    /// Sample value.
    pub value: f64,
}

/// Parse Prometheus text exposition back into sample lines (comments
/// skipped). Inverse of [`render_prometheus`] for the value ranges the
/// registry produces; the round-trip property test pins it.
pub fn parse_prometheus(text: &str) -> Vec<ParsedSample> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = match line.rsplit_once(' ') {
            Some((s, v)) => (s.trim(), v.trim()),
            None => continue,
        };
        let value: f64 = match value {
            "+Inf" => f64::INFINITY,
            v => match v.parse() {
                Ok(x) => x,
                Err(_) => continue,
            },
        };
        let (name, labels) = match series.split_once('{') {
            None => (series.to_string(), Vec::new()),
            Some((name, rest)) => {
                let body = rest.strip_suffix('}').unwrap_or(rest);
                let mut labels = Vec::new();
                // Split on `","` boundaries outside quotes.
                let mut pair = String::new();
                let mut in_quotes = false;
                let mut escaped = false;
                let mut pairs: Vec<String> = Vec::new();
                for c in body.chars() {
                    if escaped {
                        pair.push(c);
                        escaped = false;
                        continue;
                    }
                    match c {
                        '\\' if in_quotes => {
                            pair.push(c);
                            escaped = true;
                        }
                        '"' => {
                            pair.push(c);
                            in_quotes = !in_quotes;
                        }
                        ',' if !in_quotes => {
                            pairs.push(std::mem::take(&mut pair));
                        }
                        c => pair.push(c),
                    }
                }
                if !pair.is_empty() {
                    pairs.push(pair);
                }
                for p in pairs {
                    let Some((k, v)) = p.split_once('=') else { continue };
                    let v = v.trim().trim_matches('"');
                    let mut un = String::with_capacity(v.len());
                    let mut esc = false;
                    for c in v.chars() {
                        if esc {
                            match c {
                                'n' => un.push('\n'),
                                c => un.push(c),
                            }
                            esc = false;
                        } else if c == '\\' {
                            esc = true;
                        } else {
                            un.push(c);
                        }
                    }
                    labels.push((k.trim().to_string(), un));
                }
                labels.sort();
                (name.to_string(), labels)
            }
        };
        out.push(ParsedSample { name, labels, value });
    }
    out
}

/// Dependency-free scrape endpoint over the global registry + ring.
///
/// Binds on construction, serves from one background thread, and shuts
/// down (unblocking its own accept) on drop.
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (host:port; port 0 picks a free port) and start
    /// serving `/metrics`, `/metrics.json`, and `/trace`.
    pub fn serve(addr: &str) -> std::io::Result<ObsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = thread::Builder::new().name("tgm-obs".into()).spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(mut stream) = conn {
                    let _ = handle_conn(&mut stream);
                }
            }
        })?;
        Ok(ObsServer { addr: local, stop, handle: Some(handle) })
    }

    /// Start a server when `TGM_METRICS_ADDR` is set; `None` when unset
    /// or empty. Bind failures are reported to stderr, not fatal — a
    /// serving process must not die because its metrics port is taken.
    pub fn from_env() -> Option<ObsServer> {
        let addr = std::env::var("TGM_METRICS_ADDR").ok()?;
        let addr = addr.trim();
        if addr.is_empty() {
            return None;
        }
        match ObsServer::serve(addr) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("[tgm-obs] failed to bind TGM_METRICS_ADDR={addr}: {e}");
                None
            }
        }
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Self-connect to unblock the accept loop, then join it.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Requests are tiny GETs; read until the request line is complete
    // (or a small cap, dropping anything larger).
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !buf.windows(2).any(|w| w == b"\r\n") && buf.len() < 4096 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let request = String::from_utf8_lossy(&buf);
    let path = request
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/")
        .to_string();
    let path = path.split('?').next().unwrap_or("/");
    let (status, content_type, body) = match path {
        "/metrics" => {
            ("200 OK", "text/plain; version=0.0.4", render_prometheus(&registry().snapshot()))
        }
        "/metrics.json" => ("200 OK", "application/json", render_json(&registry().snapshot())),
        "/trace" => ("200 OK", "application/json", render_trace_json(&trace_ring().snapshot())),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())
}
