//! Unified observability layer: metrics registry, structured trace
//! ring, and a scrapeable endpoint.
//!
//! Three pieces, each usable alone:
//!
//! * [`registry`](mod@registry) — a process-global
//!   [`MetricsRegistry`] of sharded-atomic counters, gauges, and log₂
//!   histograms with cheap labels (`tenant`, `class`, `pool`, …).
//!   Ingest, serving, persist, and DTDG all record here; the
//!   coordinator's `Profiler` folds registry snapshots into its report
//!   instead of owning private state.
//! * [`trace`] — a bounded lock-free ring of structured
//!   [`TraceEvent`]s with [`span`] guards around
//!   seal/compaction/recovery/WAL-sync/dtdg-refresh/point-query, plus
//!   a slow-op stderr log (`TGM_TRACE`, `TGM_TRACE_SLOW_US`).
//! * [`export`] — Prometheus text + JSON rendering and the
//!   dependency-free [`ObsServer`] scrape endpoint
//!   (`TGM_METRICS_ADDR`, paths `/metrics`, `/metrics.json`,
//!   `/trace`).
//!
//! Run `examples/observability.rs` for the whole loop: multi-tenant
//! ingest + point queries with a live scrape endpoint, ending in a
//! registry snapshot and the slowest trace spans.

pub mod export;
pub mod registry;
pub mod trace;

pub use export::{
    parse_prometheus, render_json, render_prometheus, render_trace_json, ObsServer, ParsedSample,
};
pub use registry::{
    registry, Counter, Gauge, Histogram, Label, MetricSnapshot, MetricValue, MetricsRegistry,
    RegistrySnapshot,
};
pub use trace::{event, span, trace_ring, Span, TraceEvent, TraceRing};

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// ISSUE 9 satellite: ≥4 threads hammering one counter and one
    /// histogram concurrently lose no updates — totals are exact.
    #[test]
    fn concurrent_hammering_keeps_exact_totals() {
        // A private registry so totals cannot be perturbed by other
        // tests instrumenting the global one in parallel.
        let reg = MetricsRegistry::new();
        let counter = reg.counter("obs_test_hammer_total", &[("class", Label::from("hammer"))]);
        let hist = reg.histogram("obs_test_hammer_us", &[]);
        let gauge = reg.gauge("obs_test_hammer_depth", &[]);
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 50_000;
        thread::scope(|scope| {
            for t in 0..THREADS {
                let counter = counter.clone();
                let hist = hist.clone();
                let gauge = gauge.clone();
                scope.spawn(move || {
                    for i in 0..PER_THREAD {
                        counter.inc();
                        hist.record_us((t as u64 * PER_THREAD + i) % 1000);
                        gauge.add(1);
                    }
                });
            }
        });
        let want = THREADS as u64 * PER_THREAD;
        assert_eq!(counter.get(), want, "lost counter updates");
        assert_eq!(hist.count(), want, "lost histogram samples");
        assert_eq!(gauge.get(), want as i64, "lost gauge increments");
        let snap = hist.snapshot();
        assert_eq!(snap.count(), want);
        let bucket_total: u64 = snap.bucket_counts().iter().sum();
        assert_eq!(bucket_total, want, "bucket counts must sum to the total");
    }

    /// Registry histograms use LatencyHistogram's exact bucket layout,
    /// so snapshots merge losslessly with profiler state.
    #[test]
    fn histogram_snapshot_matches_latency_histogram() {
        use crate::loader::sched::LatencyHistogram;
        let reg = MetricsRegistry::new();
        let hist = reg.histogram("obs_test_layout_us", &[]);
        let mut reference = LatencyHistogram::new();
        for us in [0u64, 1, 2, 3, 10, 100, 1_000, 65_535, 1 << 30, u64::MAX] {
            hist.record_us(us);
            reference.record_us(us);
        }
        let snap = hist.snapshot();
        assert_eq!(snap.bucket_counts(), reference.bucket_counts());
        assert_eq!(snap.count(), reference.count());
        assert_eq!(snap.max_us(), reference.max_us());
        assert_eq!(snap.percentile_us(50.0), reference.percentile_us(50.0));
        assert_eq!(snap.percentile_us(99.0), reference.percentile_us(99.0));
    }

    #[test]
    fn disabled_registry_records_nothing_and_reenables() {
        let reg = MetricsRegistry::new();
        let counter = reg.counter("obs_test_disabled_total", &[]);
        let hist = reg.histogram("obs_test_disabled_us", &[]);
        let gauge = reg.gauge("obs_test_disabled_gauge", &[]);
        reg.set_enabled(false);
        assert!(!reg.is_enabled());
        counter.add(5);
        hist.record_us(123);
        gauge.set(9);
        assert_eq!(counter.get(), 0);
        assert_eq!(hist.count(), 0);
        assert_eq!(gauge.get(), 0);
        reg.set_enabled(true);
        counter.add(5);
        hist.record_us(123);
        gauge.set(9);
        assert_eq!(counter.get(), 5);
        assert_eq!(hist.count(), 1);
        assert_eq!(gauge.get(), 9);
    }

    #[test]
    fn handles_share_cells_by_name_and_labels() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("obs_test_shared_total", &[("tenant", Label::from("t0"))]);
        // Label order must not matter for identity.
        let b = reg.counter("obs_test_shared_total", &[("tenant", Label::from("t0"))]);
        let other = reg.counter("obs_test_shared_total", &[("tenant", Label::from("t1"))]);
        a.inc();
        b.inc();
        other.inc();
        assert_eq!(a.get(), 2);
        assert_eq!(other.get(), 1);
        let snap = reg.snapshot();
        let series: Vec<_> = snap.by_name("obs_test_shared_total").collect();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].label("tenant"), Some("t0"));
    }

    /// ISSUE 9 satellite: Prometheus text output parses back to the
    /// same names, labels, and (cumulative) bucket counts across a
    /// randomized registry population.
    #[test]
    fn prometheus_round_trip_preserves_values() {
        // Deterministic xorshift so the property covers varied shapes
        // without flaky seeds.
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for round in 0..20 {
            let reg = MetricsRegistry::new();
            let tenants = ["alpha", "beta", "g\"amma", "del\\ta", "ep\nsilon"];
            let n_counters = (next() % 5) as usize + 1;
            let n_hists = (next() % 3) as usize + 1;
            for c in 0..n_counters {
                let name: &'static str = match c {
                    0 => "rt_a_total",
                    1 => "rt_b_total",
                    2 => "rt_c_total",
                    3 => "rt_d_total",
                    _ => "rt_e_total",
                };
                let tenant = tenants[(next() % tenants.len() as u64) as usize];
                let counter = reg.counter(name, &[("tenant", Label::from(tenant))]);
                counter.add(next() % 100_000);
            }
            for h in 0..n_hists {
                let name: &'static str = match h {
                    0 => "rt_lat_us",
                    1 => "rt_dur_us",
                    _ => "rt_len_us",
                };
                let hist = reg.histogram(name, &[("class", Label::from("point"))]);
                for _ in 0..(next() % 200) {
                    hist.record_us(next() % (1 << 22));
                }
            }
            let gauge = reg.gauge("rt_depth", &[]);
            gauge.set((next() % 1000) as i64 - 500);

            let snap = reg.snapshot();
            let text = render_prometheus(&snap);
            let parsed = parse_prometheus(&text);

            for m in &snap.metrics {
                let find = |suffix: &str, extra: Option<(&str, &str)>| -> Option<f64> {
                    let want_name = format!("{}{suffix}", m.name);
                    let mut want_labels: Vec<(String, String)> = m.labels.clone();
                    if let Some((k, v)) = extra {
                        want_labels.push((k.to_string(), v.to_string()));
                    }
                    want_labels.sort();
                    parsed
                        .iter()
                        .find(|p| p.name == want_name && p.labels == want_labels)
                        .map(|p| p.value)
                };
                match &m.value {
                    MetricValue::Counter(v) => {
                        assert_eq!(
                            find("", None),
                            Some(*v as f64),
                            "round {round}: counter {} lost",
                            m.name
                        );
                    }
                    MetricValue::Gauge(v) => {
                        assert_eq!(find("", None), Some(*v as f64), "gauge {} lost", m.name);
                    }
                    MetricValue::Histogram(hist) => {
                        assert_eq!(find("_count", None), Some(hist.count() as f64));
                        assert_eq!(find("_sum", None), Some(hist.sum_us() as f64));
                        let mut cumulative = 0u64;
                        for (i, &c) in hist.bucket_counts().iter().enumerate() {
                            cumulative += c;
                            let le = if i >= 39 {
                                "+Inf".to_string()
                            } else {
                                ((1u128 << (i + 1)) - 2).to_string()
                            };
                            assert_eq!(
                                find("_bucket", Some(("le", &le))),
                                Some(cumulative as f64),
                                "round {round}: {} bucket {i} (le {le}) lost",
                                m.name
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn trace_ring_bounds_retains_latest_and_orders_events() {
        let ring = TraceRing::with_capacity(8);
        for i in 0..20u64 {
            ring.record(TraceEvent {
                ts_us: i,
                subsystem: "test",
                kind: "tick",
                tenant: None,
                dur_us: i,
                detail: format!("e{i}"),
            });
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 8, "ring must retain exactly its capacity");
        let durs: Vec<u64> = snap.iter().map(|e| e.dur_us).collect();
        assert_eq!(durs, (12..20).collect::<Vec<_>>(), "oldest-first, latest events retained");
        // Drain empties; a fresh snapshot after drain sees nothing.
        let drained = ring.drain();
        assert_eq!(drained.len(), 8);
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn span_guard_records_duration_and_tenant() {
        {
            let _s = span("obs-test", "span_probe").with_tenant("tenant-7").with_detail("d=1");
        }
        let events = trace_ring().snapshot();
        let e = events
            .iter()
            .rev()
            .find(|e| e.subsystem == "obs-test" && e.kind == "span_probe")
            .expect("span must land in the global ring");
        assert_eq!(e.tenant.as_ref().map(|t| t.as_str()), Some("tenant-7"));
        assert_eq!(e.detail, "d=1");
    }

    #[test]
    fn scrape_endpoint_serves_metrics_and_trace() {
        use std::io::{Read as _, Write as _};
        use std::net::TcpStream;

        // Populate the global registry so the scrape has content.
        let counter =
            registry().counter("obs_test_scrape_total", &[("tenant", Label::from("scrape"))]);
        counter.add(3);
        event("obs-test", "scrape_probe", Some(Label::from("scrape")), "hello");

        let server = ObsServer::serve("127.0.0.1:0").expect("bind an ephemeral port");
        let addr = server.local_addr();
        let fetch = |path: &str| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let metrics = fetch("/metrics");
        assert!(metrics.starts_with("HTTP/1.1 200 OK"), "{metrics}");
        assert!(metrics.contains("obs_test_scrape_total{tenant=\"scrape\"} 3"), "{metrics}");
        let json = fetch("/metrics.json");
        assert!(json.contains("\"obs_test_scrape_total\""), "{json}");
        let trace = fetch("/trace");
        assert!(trace.contains("scrape_probe"), "{trace}");
        let missing = fetch("/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        drop(server);
        // The port is released after drop: a fresh bind to it succeeds.
        let again = ObsServer::serve(&addr.to_string()).expect("rebind after drop");
        drop(again);
    }
}
