//! Execution layer: materialized batches, the typed hook formalism, the
//! hook manager with recipe validation, and the built-in hook library
//! (samplers, negatives, dedup, analytics) — paper §3-4.

pub mod analytics;
pub mod batch;
pub mod dedup;
pub mod eval_sampler;
pub mod hook;
pub mod manager;
pub mod negatives;
pub mod neighbor;
pub mod neighbor_naive;
pub mod recipes;

pub use batch::{attr, MaterializedBatch};
pub use hook::{Hook, HookContext, BASE_ATTRS};
pub use manager::{resolve_recipe_order, HookManager};
pub use negatives::DstRange;
pub use neighbor::{RecencySampler, SamplerConfig, UniformSampler};
pub use neighbor_naive::NaiveSampler;
pub use recipes::{
    RecipeConfig, RecipeRegistry, SamplerKind, RECIPE_ANALYTICS_DOS, RECIPE_SNAPSHOT,
    RECIPE_TGB_LINK, RECIPE_TGB_NODE,
};
