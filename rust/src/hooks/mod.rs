//! Execution layer: materialized batches, the phased hook formalism
//! (stateless worker hooks vs stateful consumer hooks), the hook manager
//! with recipe validation and phase partitioning, and the built-in hook
//! library (samplers, negatives, dedup, analytics) — paper §3-4.

pub mod analytics;
pub mod batch;
pub mod dedup;
pub mod eval_sampler;
pub mod hook;
pub mod manager;
pub mod negatives;
pub mod neighbor;
pub mod neighbor_naive;
pub mod recipes;

pub use batch::{attr, MaterializedBatch};
pub use hook::{Hook, HookContext, StatelessHook, BASE_ATTRS};
pub use manager::{resolve_recipe_order, HookEntry, HookManager, PhasedOrder, StatelessPipeline};
pub use negatives::DstRange;
pub use neighbor::{RecencySampler, SamplerConfig, UniformSampler};
pub use neighbor_naive::NaiveSampler;
pub use recipes::{
    sampler_entry, RecipeConfig, RecipeRegistry, SamplerKind, RECIPE_ANALYTICS_DOS,
    RECIPE_SNAPSHOT, RECIPE_TGB_LINK, RECIPE_TGB_NODE,
};
