//! Batch-level seed deduplication (Appendix A.1).
//!
//! The TGB one-vs-many protocol scores each positive edge against `Q`
//! negative candidates. DyGLib re-samples neighborhoods for *every*
//! (positive, candidate) pair — `B × (Q + 2)` sampler invocations per
//! batch. TGM instead deduplicates the seed set first and samples once
//! per unique node, which the paper credits for up to 246× faster
//! validation. This hook produces the unique-node list plus the inverse
//! mapping every seed slot uses to find its row.

use crate::error::Result;
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{HookContext, StatelessHook};
use crate::kernels;
use crate::util::Tensor;
use std::collections::HashMap;

/// While the unique set is at most this large, membership is resolved
/// by a [`kernels::position_u32`] linear scan (eight lanes per step, no
/// hashing, no allocation); beyond it the hook migrates to a `HashMap`.
/// Typical TGB batches (200 positives + negatives over power-law node
/// reuse) stay under this bound.
const SCAN_MAX: usize = 128;

/// Deduplicate `src ++ dst [++ negatives] [++ eval_negatives]` seeds.
/// Stateless: a pure function of the batch, safe on any prefetch worker.
pub struct DedupHook {
    include_negatives: bool,
    include_eval_negatives: bool,
}

impl DedupHook {
    /// Dedup over sources, destinations, and optionally the negative sets.
    pub fn new(include_negatives: bool, include_eval_negatives: bool) -> DedupHook {
        DedupHook { include_negatives, include_eval_negatives }
    }
}

impl StatelessHook for DedupHook {
    fn name(&self) -> &'static str {
        "dedup"
    }

    fn requires(&self) -> Vec<&'static str> {
        let mut r = vec![];
        if self.include_negatives {
            r.push(attr::NEGATIVES);
        }
        if self.include_eval_negatives {
            r.push(attr::EVAL_NEGATIVES);
        }
        r
    }

    fn produces(&self) -> Vec<&'static str> {
        vec![attr::UNIQUE_NODES, attr::UNIQUE_INVERSE]
    }

    fn apply(&self, batch: &mut MaterializedBatch, _ctx: &HookContext<'_>) -> Result<()> {
        let mut seeds: Vec<i32> = Vec::new();
        seeds.extend(batch.src.iter().map(|&n| n as i32));
        seeds.extend(batch.dst.iter().map(|&n| n as i32));
        if self.include_negatives {
            seeds.extend_from_slice(batch.get(attr::NEGATIVES)?.as_i32()?);
        }
        if self.include_eval_negatives {
            seeds.extend_from_slice(batch.get(attr::EVAL_NEGATIVES)?.as_i32()?);
        }

        // Hybrid membership: SIMD linear scan over the (bit-cast u32)
        // unique list while it is short, HashMap once it is not. The
        // first-occurrence order — and therefore the output — is
        // identical on every path.
        let mut unique: Vec<i32> = Vec::new();
        let mut probe: Vec<u32> = Vec::new();
        let mut first_row: Option<HashMap<i32, i32>> = None;
        let mut inverse: Vec<i32> = Vec::with_capacity(seeds.len());
        for &s in &seeds {
            let row = if let Some(map) = first_row.as_mut() {
                *map.entry(s).or_insert_with(|| {
                    unique.push(s);
                    (unique.len() - 1) as i32
                })
            } else if let Some(pos) = kernels::position_u32(&probe, s as u32) {
                pos as i32
            } else {
                unique.push(s);
                probe.push(s as u32);
                if unique.len() > SCAN_MAX {
                    let mut map: HashMap<i32, i32> = HashMap::with_capacity(seeds.len());
                    for (i, &u) in unique.iter().enumerate() {
                        map.insert(u, i as i32);
                    }
                    first_row = Some(map);
                }
                (unique.len() - 1) as i32
            };
            inverse.push(row);
        }
        let u = unique.len();
        let s = inverse.len();
        batch.set(attr::UNIQUE_NODES, Tensor::i32(unique, &[u])?);
        batch.set(attr::UNIQUE_INVERSE, Tensor::i32(inverse, &[s])?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};

    fn storage() -> crate::graph::StorageSnapshot {
        GraphStorage::from_events(
            vec![EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }],
            vec![],
            8,
            None,
            None,
        )
        .unwrap()
        .into_snapshot()
    }

    #[test]
    fn dedup_round_trips_every_seed() {
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let mut b = MaterializedBatch::new(0, 1);
        b.src = vec![0, 1, 0];
        b.dst = vec![2, 2, 3];
        b.ts = vec![0, 0, 0];
        b.edge_indices = vec![0, 0, 0];
        b.set(attr::NEGATIVES, Tensor::i32(vec![3, 0, 5], &[3]).unwrap());
        let h = DedupHook::new(true, false);
        h.apply(&mut b, &ctx).unwrap();

        let unique = b.get(attr::UNIQUE_NODES).unwrap().as_i32().unwrap().to_vec();
        let inverse = b.get(attr::UNIQUE_INVERSE).unwrap().as_i32().unwrap().to_vec();
        // Seeds: [0,1,0, 2,2,3, 3,0,5] -> unique {0,1,2,3,5}.
        assert_eq!(unique, vec![0, 1, 2, 3, 5]);
        assert_eq!(inverse.len(), 9);
        let seeds = [0, 1, 0, 2, 2, 3, 3, 0, 5];
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(unique[inverse[i] as usize], s, "slot {i}");
        }
    }

    #[test]
    fn dedup_survives_scan_to_hashmap_migration() {
        // More uniques than SCAN_MAX, with repeats both before and after
        // the migration point: inverse must keep first-occurrence rows.
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let n = SCAN_MAX * 2 + 7;
        let mut b = MaterializedBatch::new(0, 1);
        b.src = (0..n as u32).collect();
        b.dst = (0..n as u32).map(|i| i / 2).collect();
        b.ts = vec![0; n];
        b.edge_indices = vec![0; n];
        let h = DedupHook::new(false, false);
        h.apply(&mut b, &ctx).unwrap();
        let unique = b.get(attr::UNIQUE_NODES).unwrap().as_i32().unwrap().to_vec();
        let inverse = b.get(attr::UNIQUE_INVERSE).unwrap().as_i32().unwrap().to_vec();
        assert_eq!(unique.len(), n);
        assert_eq!(inverse.len(), 2 * n);
        let seeds: Vec<i32> = b
            .src
            .iter()
            .map(|&x| x as i32)
            .chain(b.dst.iter().map(|&x| x as i32))
            .collect();
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(unique[inverse[i] as usize], s, "slot {i}");
        }
        // First occurrences appear in seed order.
        let mut seen = std::collections::HashSet::new();
        let want: Vec<i32> = seeds.iter().copied().filter(|&s| seen.insert(s)).collect();
        assert_eq!(unique, want);
    }

    #[test]
    fn dedup_shrinks_eval_fanout() {
        // 4 positives x 8 candidates drawn from a pool of 3 -> huge shrink.
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let mut b = MaterializedBatch::new(0, 1);
        b.src = vec![0; 4];
        b.dst = vec![1; 4];
        b.ts = vec![0; 4];
        b.edge_indices = vec![0; 4];
        let cands: Vec<i32> = (0..32).map(|i| 5 + (i % 3)).collect();
        b.set(attr::EVAL_NEGATIVES, Tensor::i32(cands, &[4, 8]).unwrap());
        let h = DedupHook::new(false, true);
        h.apply(&mut b, &ctx).unwrap();
        let unique = b.get(attr::UNIQUE_NODES).unwrap();
        assert_eq!(unique.len(), 5); // {0, 1, 5, 6, 7}
        assert_eq!(b.get(attr::UNIQUE_INVERSE).unwrap().len(), 4 + 4 + 32);
    }
}
