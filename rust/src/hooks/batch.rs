//! Materialized batches (paper Definition 3.6).
//!
//! A [`MaterializedBatch`] is the data slice `B|_{T,A}`: the edge/node
//! events of a temporal sub-graph window plus a set of named *attributes*
//! `A` — tensors produced by hooks (sampled neighborhoods, negatives,
//! analytics) that enrich the slice for the model. The seed columns
//! (`src`, `dst`, `time`, edge features) are materialized by the loader;
//! everything else arrives through hook `produces` contracts.

use crate::error::{Result, TgmError};
use crate::util::{Tensor, Timestamp};
use std::collections::HashMap;

/// Canonical attribute keys (Table 2). Hooks may also define custom keys.
pub mod attr {
    /// Seed source node ids, shape `[B]` i32.
    pub const SRC: &str = "src";
    /// Seed destination node ids, shape `[B]` i32.
    pub const DST: &str = "dst";
    /// Seed event times, shape `[B]` f32.
    pub const TIME: &str = "time";
    /// Seed edge features, shape `[B, D_edge]` f32.
    pub const EDGE_FEATS: &str = "edge_feats";
    /// Training negatives, shape `[B]` i32.
    pub const NEGATIVES: &str = "negatives";
    /// One-vs-many evaluation negatives, shape `[B, Q]` i32.
    pub const EVAL_NEGATIVES: &str = "eval_negatives";
    /// Sampled neighbor ids, shape `[S, K]` i32 (S = seeds per batch).
    pub const NEIGHBORS: &str = "neighbors";
    /// Sampled neighbor interaction times, shape `[S, K]` f32.
    pub const NEIGHBOR_TIMES: &str = "neighbor_times";
    /// Neighbor validity mask, shape `[S, K]` f32 (1 = valid).
    pub const NEIGHBOR_MASK: &str = "neighbor_mask";
    /// Neighbor edge features, shape `[S, K, D_edge]` f32.
    pub const NEIGHBOR_FEATS: &str = "neighbor_feats";
    /// Two-hop neighbor ids, shape `[S, K, K2]` i32.
    pub const NEIGHBORS_2: &str = "neighbors2";
    /// Two-hop neighbor times, shape `[S, K, K2]` f32.
    pub const NEIGHBOR_TIMES_2: &str = "neighbor_times2";
    /// Two-hop mask, shape `[S, K, K2]` f32.
    pub const NEIGHBOR_MASK_2: &str = "neighbor_mask2";
    /// Two-hop neighbor edge features, shape `[S, K, K2, D_edge]` f32.
    pub const NEIGHBOR_FEATS_2: &str = "neighbor_feats2";
    /// Deduplicated seed node list, shape `[U]` i32.
    pub const UNIQUE_NODES: &str = "unique_nodes";
    /// Map from each seed slot to its unique-node row, shape `[S]` i32.
    pub const UNIQUE_INVERSE: &str = "unique_inverse";
    /// Density-of-states spectral moment estimates, shape `[M]` f32.
    pub const DOS: &str = "dos";
    /// Dense normalized snapshot adjacency, shape `[N, N]` f32.
    pub const SNAPSHOT_ADJ: &str = "snapshot_adj";
}

/// The materialized batch `B|_{T,A}`.
#[derive(Debug, Clone)]
pub struct MaterializedBatch {
    /// Inclusive window start.
    pub start: Timestamp,
    /// Exclusive window end.
    pub end: Timestamp,
    /// Source node of each seed edge event.
    pub src: Vec<u32>,
    /// Destination node of each seed edge event.
    pub dst: Vec<u32>,
    /// Timestamp of each seed edge event.
    pub ts: Vec<Timestamp>,
    /// Storage index of each seed edge event.
    pub edge_indices: Vec<u32>,
    /// Node events in the window: (time, node, feature row offset).
    pub node_events: Vec<(Timestamp, u32)>,
    attrs: HashMap<&'static str, Tensor>,
    /// Custom string-keyed attributes (user hooks).
    custom: HashMap<String, Tensor>,
}

impl MaterializedBatch {
    /// Empty batch over a window.
    pub fn new(start: Timestamp, end: Timestamp) -> MaterializedBatch {
        MaterializedBatch {
            start,
            end,
            src: Vec::new(),
            dst: Vec::new(),
            ts: Vec::new(),
            edge_indices: Vec::new(),
            node_events: Vec::new(),
            attrs: HashMap::new(),
            custom: HashMap::new(),
        }
    }

    /// Number of seed edge events.
    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// Attribute names currently present (the set `A`).
    pub fn attr_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.attrs.keys().copied().collect();
        v.extend(self.custom.keys().map(|s| s.as_str()));
        v.sort_unstable();
        v
    }

    /// True when attribute `key` is present.
    pub fn has(&self, key: &str) -> bool {
        self.attrs.contains_key(key) || self.custom.contains_key(key)
    }

    /// Insert / overwrite an attribute tensor under a canonical key.
    pub fn set(&mut self, key: &'static str, value: Tensor) {
        self.attrs.insert(key, value);
    }

    /// Insert under a custom (string) key.
    pub fn set_custom(&mut self, key: impl Into<String>, value: Tensor) {
        self.custom.insert(key.into(), value);
    }

    /// Fetch an attribute; errors with the missing key name.
    pub fn get(&self, key: &str) -> Result<&Tensor> {
        self.attrs
            .get(key)
            .or_else(|| self.custom.get(key))
            .ok_or_else(|| TgmError::Batch(format!("missing batch attribute `{key}`")))
    }

    /// Remove and return an attribute.
    pub fn take(&mut self, key: &str) -> Result<Tensor> {
        self.attrs
            .remove(key)
            .or_else(|| self.custom.remove(key))
            .ok_or_else(|| TgmError::Batch(format!("missing batch attribute `{key}`")))
    }

    /// Total bytes across seed columns and attributes (memory accounting).
    pub fn byte_size(&self) -> usize {
        let seeds = self.src.len() * 4
            + self.dst.len() * 4
            + self.ts.len() * 8
            + self.edge_indices.len() * 4
            + self.node_events.len() * 12;
        let attrs: usize = self.attrs.values().map(|t| t.byte_size()).sum();
        let custom: usize = self.custom.values().map(|t| t.byte_size()).sum();
        seeds + attrs + custom
    }
}

/// Test-only full structural equality between two batch streams: seed
/// columns, windows, node events, and every attribute tensor
/// byte-for-byte. One shared copy so loader/serving determinism tests
/// cannot drift apart field-by-field.
#[cfg(test)]
pub(crate) fn assert_batches_identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
    assert_eq!(a.len(), b.len(), "batch counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.start, y.start, "batch {i} window start");
        assert_eq!(x.end, y.end, "batch {i} window end");
        assert_eq!(x.src, y.src, "batch {i} src");
        assert_eq!(x.dst, y.dst, "batch {i} dst");
        assert_eq!(x.ts, y.ts, "batch {i} ts");
        assert_eq!(x.edge_indices, y.edge_indices, "batch {i} edge indices");
        assert_eq!(x.node_events, y.node_events, "batch {i} node events");
        assert_eq!(x.attr_names(), y.attr_names(), "batch {i} attribute sets");
        for name in x.attr_names() {
            assert_eq!(
                x.get(name).unwrap(),
                y.get(name).unwrap(),
                "batch {i} attribute `{name}` differs"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_take() {
        let mut b = MaterializedBatch::new(0, 10);
        assert!(!b.has(attr::NEGATIVES));
        b.set(attr::NEGATIVES, Tensor::zeros_i32(&[4]));
        assert!(b.has(attr::NEGATIVES));
        assert_eq!(b.get(attr::NEGATIVES).unwrap().shape(), &[4]);
        let t = b.take(attr::NEGATIVES).unwrap();
        assert_eq!(t.len(), 4);
        assert!(b.get(attr::NEGATIVES).is_err());
    }

    #[test]
    fn custom_attrs_coexist() {
        let mut b = MaterializedBatch::new(0, 10);
        b.set(attr::DOS, Tensor::zeros_f32(&[8]));
        b.set_custom("my_hook_output", Tensor::zeros_f32(&[2]));
        assert!(b.has("my_hook_output"));
        assert_eq!(b.attr_names(), vec!["dos", "my_hook_output"]);
    }

    #[test]
    fn missing_attr_error_names_key() {
        let b = MaterializedBatch::new(0, 1);
        let err = b.get("neighbors").unwrap_err().to_string();
        assert!(err.contains("neighbors"), "{err}");
    }
}
