//! The hook traits (paper Definition 3.7), split by execution phase.
//!
//! A hook `φ_{R,P}` is a transformation on a materialized batch declaring
//! a typed contract: the attributes it *requires* on input (`R`) and the
//! attributes it *produces* (`P`). Recipes (ordered hook sets) are valid
//! exactly when the contracts compose — validated by
//! [`super::manager::HookManager`] via topological sort (Definition 3.8).
//!
//! TGM materializes batches on a pool of prefetch workers (see
//! [`crate::loader::PrefetchLoader`]), which splits the hook formalism
//! into two phases:
//!
//! * [`StatelessHook`] — transformations with no cross-batch state
//!   (negative sampling, uniform/naive neighbor sampling, dedup,
//!   analytics). They take `&self`, are `Send + Sync`, and may run on any
//!   worker thread in any batch order. Randomized stateless hooks draw
//!   from a per-batch RNG seeded by [`HookContext::batch_seed`] so the
//!   stream depends only on the batch's position, never on which worker
//!   (or in which order) the batch was materialized.
//! * [`Hook`] — stateful transformations (the recency sampler's circular
//!   buffers) that must observe batches in order. They take `&mut self`
//!   and always run on the consumer side, after the worker phase.
//!
//! [`super::manager::HookManager::activate`] validates the combined
//! recipe, then partitions the topological order into the two phases.

use crate::error::Result;
use crate::graph::StorageSnapshot;
use crate::hooks::batch::MaterializedBatch;

/// Execution context passed to hooks: the shared immutable storage
/// snapshot, the split tag (hooks like negative samplers behave
/// differently between train and eval), and the batch's position in the
/// iteration plus the RNG seed derived from it.
pub struct HookContext<'a> {
    /// The versioned snapshot backing the view being iterated.
    pub storage: &'a StorageSnapshot,
    /// Active manager key (e.g. "train", "val") — see
    /// [`super::manager::HookManager::activate`].
    pub key: &'a str,
    /// Ordinal of this batch within the current iteration plan (0-based).
    pub batch_index: usize,
    /// Deterministic per-batch seed (`mix64(batch_index)`). Stateless
    /// hooks that need randomness must fold this into their own seed so
    /// out-of-order materialization reproduces the serial stream.
    pub batch_seed: u64,
}

impl<'a> HookContext<'a> {
    /// Context for the first batch of an iteration.
    pub fn new(storage: &'a StorageSnapshot, key: &'a str) -> HookContext<'a> {
        HookContext::for_batch(storage, key, 0)
    }

    /// Context for the batch at `batch_index` in the iteration plan.
    pub fn for_batch(
        storage: &'a StorageSnapshot,
        key: &'a str,
        batch_index: usize,
    ) -> HookContext<'a> {
        HookContext {
            storage,
            key,
            batch_index,
            batch_seed: crate::util::mix64(batch_index as u64),
        }
    }
}

/// A stateful, batch-order-dependent transformation (consumer phase).
///
/// Implementations carry state across batches (e.g. the recency sampler's
/// circular buffer); `reset` clears it between epochs/splits.
pub trait Hook: Send {
    /// Stable name for diagnostics and profiling.
    fn name(&self) -> &'static str;

    /// Attributes the hook requires on the input batch (`R`).
    fn requires(&self) -> Vec<&'static str>;

    /// Attributes the hook produces (`P`).
    fn produces(&self) -> Vec<&'static str>;

    /// Apply the transformation: `B|_{T,A} -> B|_{T, A ∪ P}`.
    fn apply(&mut self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()>;

    /// Clear accumulated state (between epochs / splits).
    fn reset(&mut self) {}
}

/// A stateless transformation safe to run on any prefetch worker.
///
/// No cross-batch state is allowed: the output must be a pure function of
/// `(batch, storage, ctx)`. Internal memoization of per-storage derived
/// structures (e.g. a CSR adjacency via
/// [`crate::graph::AdjacencyCache`]) is fine — it changes cost, never
/// output.
pub trait StatelessHook: Send + Sync {
    /// Stable name for diagnostics and profiling.
    fn name(&self) -> &'static str;

    /// Attributes the hook requires on the input batch (`R`).
    fn requires(&self) -> Vec<&'static str>;

    /// Attributes the hook produces (`P`).
    fn produces(&self) -> Vec<&'static str>;

    /// Apply the transformation: `B|_{T,A} -> B|_{T, A ∪ P}`.
    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()>;
}

/// Attributes the loader always materializes before hooks run (the base
/// set `A₀` that recipe validation seeds from).
pub const BASE_ATTRS: &[&str] = &[
    crate::hooks::batch::attr::SRC,
    crate::hooks::batch::attr::DST,
    crate::hooks::batch::attr::TIME,
    crate::hooks::batch::attr::EDGE_FEATS,
];
