//! The hook trait (paper Definition 3.7).
//!
//! A hook `φ_{R,P}` is a transformation on a materialized batch declaring
//! a typed contract: the attributes it *requires* on input (`R`) and the
//! attributes it *produces* (`P`). Recipes (ordered hook sets) are valid
//! exactly when the contracts compose — validated by
//! [`super::manager::HookManager`] via topological sort (Definition 3.8).

use crate::error::Result;
use crate::graph::GraphStorage;
use crate::hooks::batch::MaterializedBatch;

/// Execution context passed to hooks: shared immutable storage plus the
/// split tag (hooks like negative samplers behave differently between
/// train and eval).
pub struct HookContext<'a> {
    /// The storage backing the view being iterated.
    pub storage: &'a GraphStorage,
    /// Active manager key (e.g. "train", "val") — see
    /// [`super::manager::HookManager::activate`].
    pub key: &'a str,
}

/// A typed transformation on a materialized batch.
///
/// Implementations may carry state across batches (e.g. the recency
/// sampler's circular buffer); `reset` clears it between epochs/splits.
pub trait Hook: Send {
    /// Stable name for diagnostics and profiling.
    fn name(&self) -> &'static str;

    /// Attributes the hook requires on the input batch (`R`).
    fn requires(&self) -> Vec<&'static str>;

    /// Attributes the hook produces (`P`).
    fn produces(&self) -> Vec<&'static str>;

    /// Apply the transformation: `B|_{T,A} -> B|_{T, A ∪ P}`.
    fn apply(&mut self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()>;

    /// Clear accumulated state (no-op for stateless hooks).
    fn reset(&mut self) {}
}

/// Attributes the loader always materializes before hooks run (the base
/// set `A₀` that recipe validation seeds from).
pub const BASE_ATTRS: &[&str] = &[
    crate::hooks::batch::attr::SRC,
    crate::hooks::batch::attr::DST,
    crate::hooks::batch::attr::TIME,
    crate::hooks::batch::attr::EDGE_FEATS,
];
