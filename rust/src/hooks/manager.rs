//! Hook registry and recipe validation (paper §4, Definition 3.8).
//!
//! The [`HookManager`] owns hooks under string keys ("train", "val",
//! "analytics", ...). Activating a key validates that the hook set forms a
//! *recipe*: the dependency relation `φ_i → φ_j ⟺ P_i ∩ R_j ≠ ∅` must be
//! acyclic and every requirement must be met by the base attributes or an
//! earlier hook's products. Valid recipes are re-ordered topologically and
//! executed transparently during data loading; per-hook wall-clock is
//! recorded for the profiler (Table 11).

use crate::error::{Result, TgmError};
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::hook::{Hook, HookContext, BASE_ATTRS};
use std::collections::HashMap;
use std::time::Duration;

/// Keyed hook registry with recipe validation and execution.
#[derive(Default)]
pub struct HookManager {
    groups: HashMap<String, Vec<Box<dyn Hook>>>,
    /// Execution order per key, computed at activation.
    orders: HashMap<String, Vec<usize>>,
    active: Option<String>,
    /// Cumulative wall-clock per hook name (for profiling).
    timings: HashMap<&'static str, Duration>,
}

impl HookManager {
    /// Empty manager.
    pub fn new() -> HookManager {
        HookManager::default()
    }

    /// Register a hook under `key`. Invalidates any cached order for the
    /// key (re-validated on next activation).
    pub fn register(&mut self, key: impl Into<String>, hook: Box<dyn Hook>) {
        let key = key.into();
        self.orders.remove(&key);
        self.groups.entry(key).or_default().push(hook);
    }

    /// Names of hooks registered under `key`, in registration order.
    pub fn hook_names(&self, key: &str) -> Vec<&'static str> {
        self.groups.get(key).map(|hs| hs.iter().map(|h| h.name()).collect()).unwrap_or_default()
    }

    /// Activate a key: validates the recipe (Definition 3.8) and caches
    /// its topological execution order.
    pub fn activate(&mut self, key: &str) -> Result<()> {
        let hooks = self
            .groups
            .get(key)
            .ok_or_else(|| TgmError::Hook(format!("no hooks registered under key `{key}`")))?;
        if !self.orders.contains_key(key) {
            let order = resolve_recipe_order(hooks, BASE_ATTRS)?;
            self.orders.insert(key.to_string(), order);
        }
        self.active = Some(key.to_string());
        Ok(())
    }

    /// Currently active key.
    pub fn active_key(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// Run the active recipe over a batch.
    pub fn run(&mut self, batch: &mut MaterializedBatch, storage: &crate::graph::GraphStorage) -> Result<()> {
        let key = self
            .active
            .clone()
            .ok_or_else(|| TgmError::Hook("no active hook key; call activate() first".into()))?;
        let order = self.orders.get(&key).cloned().unwrap_or_default();
        let hooks = self.groups.get_mut(&key).unwrap();
        let ctx = HookContext { storage, key: &key };
        for &i in &order {
            let hook = &mut hooks[i];
            let t0 = std::time::Instant::now();
            hook.apply(batch, &ctx).map_err(|e| {
                TgmError::Hook(format!("hook `{}` failed: {e}", hook.name()))
            })?;
            // Post-condition: everything the hook promised must exist.
            for p in hook.produces() {
                if !batch.has(p) {
                    return Err(TgmError::Hook(format!(
                        "hook `{}` declared `{p}` in produces() but did not set it",
                        hook.name()
                    )));
                }
            }
            *self.timings.entry(hook.name()).or_default() += t0.elapsed();
        }
        Ok(())
    }

    /// Single API to clear the state of all hooks under all keys
    /// (between epochs / splits — paper §4, "reset method").
    pub fn reset_state(&mut self) {
        for hooks in self.groups.values_mut() {
            for h in hooks.iter_mut() {
                h.reset();
            }
        }
    }

    /// Cumulative per-hook wall-clock (profiling, Table 11).
    pub fn timings(&self) -> &HashMap<&'static str, Duration> {
        &self.timings
    }

    /// Clear profiling counters.
    pub fn reset_timings(&mut self) {
        self.timings.clear();
    }
}

/// Compute a valid execution order for a hook set (Kahn's algorithm over
/// attribute availability), or explain why the set is not a recipe.
pub fn resolve_recipe_order(hooks: &[Box<dyn Hook>], base: &[&str]) -> Result<Vec<usize>> {
    let n = hooks.len();
    let mut available: Vec<&str> = base.to_vec();
    let mut placed = vec![false; n];
    let mut order = Vec::with_capacity(n);

    for _round in 0..n {
        let mut progressed = false;
        for (i, h) in hooks.iter().enumerate() {
            if placed[i] {
                continue;
            }
            let reqs = h.requires();
            if reqs.iter().all(|r| available.contains(r)) {
                placed[i] = true;
                order.push(i);
                for p in h.produces() {
                    if !available.contains(&p) {
                        available.push(p);
                    }
                }
                progressed = true;
            }
        }
        if order.len() == n {
            return Ok(order);
        }
        if !progressed {
            break;
        }
    }

    // Diagnose: name the stuck hooks and their missing requirements.
    let mut missing = Vec::new();
    for (i, h) in hooks.iter().enumerate() {
        if !placed[i] {
            let unmet: Vec<&str> =
                h.requires().into_iter().filter(|r| !available.contains(r)).collect();
            missing.push(format!("`{}` missing {{{}}}", h.name(), unmet.join(", ")));
        }
    }
    Err(TgmError::Recipe(format!(
        "hook set is not a valid recipe (cycle or unmet requirement): {}",
        missing.join("; ")
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::batch::MaterializedBatch;
    use crate::util::Tensor;

    /// Test hook producing `out` from `reqs`.
    struct Fake {
        name: &'static str,
        reqs: Vec<&'static str>,
        outs: Vec<&'static str>,
        applied: usize,
        honest: bool,
    }

    impl Fake {
        fn boxed(name: &'static str, reqs: &[&'static str], outs: &[&'static str]) -> Box<dyn Hook> {
            Box::new(Fake { name, reqs: reqs.to_vec(), outs: outs.to_vec(), applied: 0, honest: true })
        }
    }

    impl Hook for Fake {
        fn name(&self) -> &'static str {
            self.name
        }
        fn requires(&self) -> Vec<&'static str> {
            self.reqs.clone()
        }
        fn produces(&self) -> Vec<&'static str> {
            self.outs.clone()
        }
        fn apply(&mut self, batch: &mut MaterializedBatch, _ctx: &HookContext<'_>) -> Result<()> {
            self.applied += 1;
            if self.honest {
                for o in &self.outs {
                    batch.set_custom(*o, Tensor::scalar_f32(1.0));
                }
            }
            Ok(())
        }
        fn reset(&mut self) {
            self.applied = 0;
        }
    }

    fn storage() -> crate::graph::GraphStorage {
        crate::graph::GraphStorage::from_events(
            vec![crate::graph::EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }],
            vec![],
            2,
            None,
            None,
        )
        .unwrap()
    }

    #[test]
    fn topo_order_respects_dependencies() {
        // c needs b's output, b needs a's; registered in reverse order.
        let hooks: Vec<Box<dyn Hook>> = vec![
            Fake::boxed("c", &["B"], &["C"]),
            Fake::boxed("b", &["A"], &["B"]),
            Fake::boxed("a", &[], &["A"]),
        ];
        let order = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn base_attrs_satisfy_requirements() {
        let hooks: Vec<Box<dyn Hook>> = vec![Fake::boxed("n", &["src", "time"], &["X"])];
        assert!(resolve_recipe_order(&hooks, BASE_ATTRS).is_ok());
    }

    #[test]
    fn cycle_is_rejected_with_names() {
        let hooks: Vec<Box<dyn Hook>> = vec![
            Fake::boxed("x", &["Y"], &["X"]),
            Fake::boxed("y", &["X"], &["Y"]),
        ];
        let err = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap_err().to_string();
        assert!(err.contains('x') && err.contains('y'), "{err}");
    }

    #[test]
    fn unmet_requirement_rejected() {
        let hooks: Vec<Box<dyn Hook>> = vec![Fake::boxed("z", &["nonexistent"], &["Z"])];
        let err = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap_err().to_string();
        assert!(err.contains("nonexistent"), "{err}");
    }

    #[test]
    fn manager_runs_in_order_and_times() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("second", &["A"], &["B"]));
        m.register("train", Fake::boxed("first", &[], &["A"]));
        m.activate("train").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("A") && b.has("B"));
        assert!(m.timings().contains_key("first"));
        assert!(m.timings().contains_key("second"));
    }

    #[test]
    fn dishonest_hook_caught() {
        let mut m = HookManager::new();
        m.register(
            "train",
            Box::new(Fake { name: "liar", reqs: vec![], outs: vec!["L"], applied: 0, honest: false }),
        );
        m.activate("train").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        let err = m.run(&mut b, &st).unwrap_err().to_string();
        assert!(err.contains("liar") && err.contains('L'), "{err}");
    }

    #[test]
    fn activation_of_unknown_key_fails() {
        let mut m = HookManager::new();
        assert!(m.activate("nope").is_err());
        assert!(m.active_key().is_none());
    }

    #[test]
    fn keys_are_isolated() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("t", &[], &["T"]));
        m.register("analytics", Fake::boxed("a", &[], &["A"]));
        m.activate("analytics").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("A") && !b.has("T"));
    }

    #[test]
    fn run_without_activation_errors() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("t", &[], &["T"]));
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        assert!(m.run(&mut b, &st).is_err());
    }
}
