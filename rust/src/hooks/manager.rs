//! Hook registry, recipe validation, and phase partitioning (paper §4,
//! Definition 3.8).
//!
//! The [`HookManager`] owns hooks under string keys ("train", "val",
//! "analytics", ...). Activating a key validates that the hook set forms a
//! *recipe*: the dependency relation `φ_i → φ_j ⟺ P_i ∩ R_j ≠ ∅` must be
//! acyclic and every requirement must be met by the base attributes or an
//! earlier hook's products. Valid recipes are re-ordered topologically and
//! then *partitioned into two phases*:
//!
//! * a **worker phase** of [`StatelessHook`]s whose requirements are
//!   satisfiable without any stateful product — safe to run on prefetch
//!   worker threads in any batch order (see
//!   [`crate::loader::PrefetchLoader`]);
//! * a **consumer phase** of stateful [`Hook`]s (plus any stateless hook
//!   that depends on a stateful product, which is demoted to preserve
//!   correctness) — always executed in batch order on the consumer side.
//!
//! Running both phases back-to-back on one thread (the serial loader) and
//! running the worker phase remotely followed by the consumer phase
//! locally (the prefetch loader) produce identical batches, because the
//! combined `worker ++ consumer` sequence is itself a valid topological
//! order and per-batch RNG seeds depend only on the batch index.
//!
//! Per-hook wall-clock is recorded for the profiler (Table 11) behind a
//! shared mutex so worker threads contribute to the same totals.

use crate::error::{Result, TgmError};
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::hook::{Hook, HookContext, StatelessHook, BASE_ATTRS};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A registered hook: stateful (consumer-only) or stateless (worker-safe).
pub enum HookEntry {
    /// Batch-order-dependent hook; runs on the consumer side.
    Stateful(Box<dyn Hook>),
    /// Order-independent hook; may run on any prefetch worker.
    Stateless(Arc<dyn StatelessHook>),
}

impl HookEntry {
    /// Stable hook name.
    pub fn name(&self) -> &'static str {
        match self {
            HookEntry::Stateful(h) => h.name(),
            HookEntry::Stateless(h) => h.name(),
        }
    }

    /// Required attributes (`R`).
    pub fn requires(&self) -> Vec<&'static str> {
        match self {
            HookEntry::Stateful(h) => h.requires(),
            HookEntry::Stateless(h) => h.requires(),
        }
    }

    /// Produced attributes (`P`).
    pub fn produces(&self) -> Vec<&'static str> {
        match self {
            HookEntry::Stateful(h) => h.produces(),
            HookEntry::Stateless(h) => h.produces(),
        }
    }

    /// True for worker-safe hooks.
    pub fn is_stateless(&self) -> bool {
        matches!(self, HookEntry::Stateless(_))
    }
}

/// A validated recipe order split into the two execution phases. Indices
/// point into the registration list; concatenating `worker ++ consumer`
/// yields a valid topological order of the full recipe.
#[derive(Debug, Clone, Default)]
pub struct PhasedOrder {
    /// Stateless hooks whose inputs never depend on a stateful product.
    pub worker: Vec<usize>,
    /// Everything else, in topological order.
    pub consumer: Vec<usize>,
}

type Timings = Arc<Mutex<HashMap<&'static str, Duration>>>;

/// Keyed hook registry with recipe validation and phased execution.
#[derive(Default)]
pub struct HookManager {
    groups: HashMap<String, Vec<HookEntry>>,
    /// Phased execution order per key, resolved lazily and invalidated by
    /// registration.
    orders: HashMap<String, PhasedOrder>,
    active: Option<String>,
    /// Cumulative wall-clock per hook name (shared with worker threads).
    timings: Timings,
    /// Ordinal handed to the next `run` call (serial iteration).
    next_index: usize,
    /// Bumped on every registration; lets long-lived snapshots (e.g. a
    /// prefetch loader's worker pipeline) detect that the recipe changed
    /// under them.
    epoch: u64,
}

impl HookManager {
    /// Empty manager.
    pub fn new() -> HookManager {
        HookManager::default()
    }

    /// Register a stateful hook under `key`. Invalidates any cached order
    /// for the key (re-validated lazily on the next activation or run).
    pub fn register(&mut self, key: impl Into<String>, hook: Box<dyn Hook>) {
        self.register_entry(key, HookEntry::Stateful(hook));
    }

    /// Register a stateless (worker-safe) hook under `key`.
    pub fn register_stateless(&mut self, key: impl Into<String>, hook: Arc<dyn StatelessHook>) {
        self.register_entry(key, HookEntry::Stateless(hook));
    }

    /// Register a pre-wrapped entry under `key`.
    pub fn register_entry(&mut self, key: impl Into<String>, entry: HookEntry) {
        let key = key.into();
        self.orders.remove(&key);
        self.groups.entry(key).or_default().push(entry);
        self.epoch += 1;
    }

    /// Monotonic counter of registrations. A snapshot taken at epoch `e`
    /// (see [`HookManager::stateless_pipeline`]) is stale once this
    /// differs from `e`; [`crate::loader::PrefetchLoader`] uses it to
    /// fail loudly instead of silently skipping late-registered hooks.
    pub fn registration_epoch(&self) -> u64 {
        self.epoch
    }

    /// Names of hooks registered under `key`, in registration order.
    pub fn hook_names(&self, key: &str) -> Vec<&'static str> {
        self.groups.get(key).map(|hs| hs.iter().map(|h| h.name()).collect()).unwrap_or_default()
    }

    /// Resolve and cache the phased order for `key` if missing.
    fn ensure_order(&mut self, key: &str) -> Result<()> {
        if !self.orders.contains_key(key) {
            let entries = self
                .groups
                .get(key)
                .ok_or_else(|| TgmError::Hook(format!("no hooks registered under key `{key}`")))?;
            let order = resolve_entry_order(entries, BASE_ATTRS)?;
            let phased = partition_phases(entries, &order, BASE_ATTRS);
            self.orders.insert(key.to_string(), phased);
        }
        Ok(())
    }

    /// Activate a key: validates the recipe (Definition 3.8), caches its
    /// phased execution order, and restarts batch numbering.
    pub fn activate(&mut self, key: &str) -> Result<()> {
        self.ensure_order(key)?;
        self.active = Some(key.to_string());
        self.next_index = 0;
        Ok(())
    }

    /// Currently active key.
    pub fn active_key(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// Run the active recipe over a batch, assigning it the next serial
    /// batch ordinal.
    pub fn run(
        &mut self,
        batch: &mut MaterializedBatch,
        storage: &crate::graph::StorageSnapshot,
    ) -> Result<()> {
        let index = self.next_index;
        self.next_index += 1;
        self.run_indexed(batch, storage, index)
    }

    /// Run both phases of the active recipe over the batch at `index` in
    /// the iteration plan.
    pub fn run_indexed(
        &mut self,
        batch: &mut MaterializedBatch,
        storage: &crate::graph::StorageSnapshot,
        index: usize,
    ) -> Result<()> {
        self.run_phases(batch, storage, index, true)
    }

    /// Run only the consumer (stateful) phase — the worker phase has
    /// already been applied by a prefetch worker.
    pub fn run_stateful_indexed(
        &mut self,
        batch: &mut MaterializedBatch,
        storage: &crate::graph::StorageSnapshot,
        index: usize,
    ) -> Result<()> {
        self.run_phases(batch, storage, index, false)
    }

    /// Execute the active recipe's phases over one batch. Re-resolves
    /// the order lazily when a registration invalidated the cache (a
    /// `register` under the active key no longer silently runs zero
    /// hooks).
    fn run_phases(
        &mut self,
        batch: &mut MaterializedBatch,
        storage: &crate::graph::StorageSnapshot,
        index: usize,
        include_worker_phase: bool,
    ) -> Result<()> {
        let key = self
            .active
            .clone()
            .ok_or_else(|| TgmError::Hook("no active hook key; call activate() first".into()))?;
        self.ensure_order(&key)?;
        // The order is cloned (two small Vec<usize>) because `entries`
        // below needs a disjoint `&mut` borrow of the groups map.
        let phased = self.orders.get(&key).cloned().unwrap_or_default();
        let ctx = HookContext::for_batch(storage, &key, index);
        let entries = self.groups.get_mut(&key).ok_or_else(|| {
            TgmError::Hook(format!("no hooks registered under key `{key}`"))
        })?;
        // Collect timings locally and fold under one lock per batch, so
        // the shared mutex never serializes per-hook work.
        let mut local: Vec<(&'static str, Duration)> =
            Vec::with_capacity(phased.worker.len() + phased.consumer.len());
        let phases: [&[usize]; 2] = if include_worker_phase {
            [&phased.worker, &phased.consumer]
        } else {
            [&[], &phased.consumer]
        };
        for phase in phases {
            for &i in phase {
                let entry = &mut entries[i];
                let name = entry.name();
                let t0 = std::time::Instant::now();
                let applied = match entry {
                    HookEntry::Stateful(h) => h.apply(batch, &ctx),
                    HookEntry::Stateless(h) => h.apply(batch, &ctx),
                };
                applied.map_err(|e| TgmError::Hook(format!("hook `{name}` failed: {e}")))?;
                check_produces(batch, name, &entry.produces())?;
                local.push((name, t0.elapsed()));
            }
        }
        fold_timings(&self.timings, &local);
        Ok(())
    }

    /// Snapshot of the active key's worker phase for prefetch workers:
    /// cheap to clone, `Send + Sync`, and records into the same timing
    /// totals as the manager.
    pub fn stateless_pipeline(&mut self) -> Result<StatelessPipeline> {
        let key = self
            .active
            .clone()
            .ok_or_else(|| TgmError::Hook("no active hook key; call activate() first".into()))?;
        self.ensure_order(&key)?;
        let phased = self.orders.get(&key).cloned().unwrap_or_default();
        // `ensure_order` guarantees the group exists; keep the error
        // typed anyway — this sits on the serving hot path, where a
        // panic would take the whole worker down.
        let entries = self
            .groups
            .get(&key)
            .ok_or_else(|| TgmError::Hook(format!("no hooks registered under key `{key}`")))?;
        let hooks = phased
            .worker
            .iter()
            .map(|&i| match &entries[i] {
                HookEntry::Stateless(h) => Arc::clone(h),
                HookEntry::Stateful(_) => {
                    unreachable!("worker phase contains only stateless hooks")
                }
            })
            .collect();
        Ok(StatelessPipeline {
            hooks,
            key: Arc::from(key.as_str()),
            timings: Arc::clone(&self.timings),
        })
    }

    /// Single API to clear the state of all stateful hooks under all keys
    /// (between epochs / splits — paper §4, "reset method"). Stateless
    /// hooks carry no cross-batch state by contract. Batch numbering
    /// restarts too, so per-batch RNG streams repeat each epoch.
    pub fn reset_state(&mut self) {
        for hooks in self.groups.values_mut() {
            for h in hooks.iter_mut() {
                if let HookEntry::Stateful(h) = h {
                    h.reset();
                }
            }
        }
        self.next_index = 0;
    }

    /// Cumulative per-hook wall-clock (profiling, Table 11), including
    /// time spent by prefetch workers.
    pub fn timings(&self) -> HashMap<&'static str, Duration> {
        self.timings.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Clear profiling counters.
    pub fn reset_timings(&mut self) {
        self.timings.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

/// The worker-phase slice of an activated recipe: applies the stateless
/// hooks to one batch, independent of every other batch.
#[derive(Clone)]
pub struct StatelessPipeline {
    hooks: Vec<Arc<dyn StatelessHook>>,
    key: Arc<str>,
    timings: Timings,
}

impl StatelessPipeline {
    /// Apply all worker-phase hooks to `batch` at plan position
    /// `batch_index`.
    pub fn run(
        &self,
        batch: &mut MaterializedBatch,
        storage: &crate::graph::StorageSnapshot,
        batch_index: usize,
    ) -> Result<()> {
        let ctx = HookContext::for_batch(storage, &self.key, batch_index);
        let mut local: Vec<(&'static str, Duration)> = Vec::with_capacity(self.hooks.len());
        for h in &self.hooks {
            let t0 = std::time::Instant::now();
            h.apply(batch, &ctx)
                .map_err(|e| TgmError::Hook(format!("hook `{}` failed: {e}", h.name())))?;
            check_produces(batch, h.name(), &h.produces())?;
            local.push((h.name(), t0.elapsed()));
        }
        // One lock per batch keeps worker threads off each other's necks.
        fold_timings(&self.timings, &local);
        Ok(())
    }

    /// Number of worker-phase hooks.
    pub fn len(&self) -> usize {
        self.hooks.len()
    }

    /// True when no hook can be offloaded to workers.
    pub fn is_empty(&self) -> bool {
        self.hooks.is_empty()
    }
}

/// Fold locally accumulated per-hook durations into the shared totals
/// under a single lock acquisition.
fn fold_timings(timings: &Timings, local: &[(&'static str, Duration)]) {
    if local.is_empty() {
        return;
    }
    let mut totals = timings.lock().unwrap_or_else(|e| e.into_inner());
    for &(name, d) in local {
        *totals.entry(name).or_default() += d;
    }
}

/// Post-condition: everything the hook promised must exist on the batch.
fn check_produces(
    batch: &MaterializedBatch,
    name: &'static str,
    produces: &[&'static str],
) -> Result<()> {
    for p in produces {
        if !batch.has(p) {
            return Err(TgmError::Hook(format!(
                "hook `{name}` declared `{p}` in produces() but did not set it"
            )));
        }
    }
    Ok(())
}

/// One hook's contract, extracted for order resolution.
struct Contract {
    name: &'static str,
    requires: Vec<&'static str>,
    produces: Vec<&'static str>,
}

/// Kahn's algorithm over attribute availability: compute a valid
/// execution order, or explain why the set is not a recipe.
fn resolve_contract_order(contracts: &[Contract], base: &[&str]) -> Result<Vec<usize>> {
    let n = contracts.len();
    let mut available: Vec<&str> = base.to_vec();
    let mut placed = vec![false; n];
    let mut order = Vec::with_capacity(n);

    for _round in 0..n {
        let mut progressed = false;
        for (i, c) in contracts.iter().enumerate() {
            if placed[i] {
                continue;
            }
            if c.requires.iter().all(|r| available.contains(r)) {
                placed[i] = true;
                order.push(i);
                for &p in &c.produces {
                    if !available.contains(&p) {
                        available.push(p);
                    }
                }
                progressed = true;
            }
        }
        if order.len() == n {
            return Ok(order);
        }
        if !progressed {
            break;
        }
    }

    // Diagnose: name the stuck hooks and their missing requirements.
    let mut missing = Vec::new();
    for (i, c) in contracts.iter().enumerate() {
        if !placed[i] {
            let unmet: Vec<&str> =
                c.requires.iter().copied().filter(|r| !available.contains(r)).collect();
            missing.push(format!("`{}` missing {{{}}}", c.name, unmet.join(", ")));
        }
    }
    Err(TgmError::Recipe(format!(
        "hook set is not a valid recipe (cycle or unmet requirement): {}",
        missing.join("; ")
    )))
}

/// Compute a valid execution order for a stateful hook set (kept for
/// callers predating the phase split).
pub fn resolve_recipe_order(hooks: &[Box<dyn Hook>], base: &[&str]) -> Result<Vec<usize>> {
    let contracts: Vec<Contract> = hooks
        .iter()
        .map(|h| Contract { name: h.name(), requires: h.requires(), produces: h.produces() })
        .collect();
    resolve_contract_order(&contracts, base)
}

/// Compute a valid execution order for a mixed (stateful + stateless)
/// hook set.
pub fn resolve_entry_order(entries: &[HookEntry], base: &[&str]) -> Result<Vec<usize>> {
    let contracts: Vec<Contract> = entries
        .iter()
        .map(|e| Contract { name: e.name(), requires: e.requires(), produces: e.produces() })
        .collect();
    resolve_contract_order(&contracts, base)
}

/// Split a topological order into worker/consumer phases. A stateless
/// hook joins the worker phase only while its requirements are covered by
/// the base attributes plus earlier worker products; once a stateful hook
/// intervenes in its dependency chain it is demoted to the consumer phase
/// (correctness over parallelism). Relative order inside each phase
/// follows the input order, so `worker ++ consumer` stays topological.
pub fn partition_phases(entries: &[HookEntry], order: &[usize], base: &[&str]) -> PhasedOrder {
    let mut available: Vec<&str> = base.to_vec();
    let mut phased = PhasedOrder::default();
    for &i in order {
        let e = &entries[i];
        if e.is_stateless() && e.requires().iter().all(|r| available.contains(r)) {
            for p in e.produces() {
                if !available.contains(&p) {
                    available.push(p);
                }
            }
            phased.worker.push(i);
        } else {
            phased.consumer.push(i);
        }
    }
    phased
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::batch::MaterializedBatch;
    use crate::util::Tensor;

    /// Stateful test hook producing `outs` from `reqs`.
    struct Fake {
        name: &'static str,
        reqs: Vec<&'static str>,
        outs: Vec<&'static str>,
        applied: usize,
        honest: bool,
    }

    impl Fake {
        fn boxed(name: &'static str, reqs: &[&'static str], outs: &[&'static str]) -> Box<dyn Hook> {
            Box::new(Fake { name, reqs: reqs.to_vec(), outs: outs.to_vec(), applied: 0, honest: true })
        }
    }

    impl Hook for Fake {
        fn name(&self) -> &'static str {
            self.name
        }
        fn requires(&self) -> Vec<&'static str> {
            self.reqs.clone()
        }
        fn produces(&self) -> Vec<&'static str> {
            self.outs.clone()
        }
        fn apply(&mut self, batch: &mut MaterializedBatch, _ctx: &HookContext<'_>) -> Result<()> {
            self.applied += 1;
            if self.honest {
                for o in &self.outs {
                    batch.set_custom(*o, Tensor::scalar_f32(1.0));
                }
            }
            Ok(())
        }
        fn reset(&mut self) {
            self.applied = 0;
        }
    }

    /// Stateless test hook stamping the batch seed into its output.
    struct FakeStateless {
        name: &'static str,
        reqs: Vec<&'static str>,
        outs: Vec<&'static str>,
    }

    impl FakeStateless {
        fn shared(
            name: &'static str,
            reqs: &[&'static str],
            outs: &[&'static str],
        ) -> Arc<dyn StatelessHook> {
            Arc::new(FakeStateless { name, reqs: reqs.to_vec(), outs: outs.to_vec() })
        }
    }

    impl StatelessHook for FakeStateless {
        fn name(&self) -> &'static str {
            self.name
        }
        fn requires(&self) -> Vec<&'static str> {
            self.reqs.clone()
        }
        fn produces(&self) -> Vec<&'static str> {
            self.outs.clone()
        }
        fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
            for o in &self.outs {
                batch.set_custom(*o, Tensor::scalar_f32(ctx.batch_seed as f32));
            }
            Ok(())
        }
    }

    fn storage() -> crate::graph::StorageSnapshot {
        crate::graph::GraphStorage::from_events(
            vec![crate::graph::EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }],
            vec![],
            2,
            None,
            None,
        )
        .unwrap()
        .into_snapshot()
    }

    #[test]
    fn topo_order_respects_dependencies() {
        // c needs b's output, b needs a's; registered in reverse order.
        let hooks: Vec<Box<dyn Hook>> = vec![
            Fake::boxed("c", &["B"], &["C"]),
            Fake::boxed("b", &["A"], &["B"]),
            Fake::boxed("a", &[], &["A"]),
        ];
        let order = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn base_attrs_satisfy_requirements() {
        let hooks: Vec<Box<dyn Hook>> = vec![Fake::boxed("n", &["src", "time"], &["X"])];
        assert!(resolve_recipe_order(&hooks, BASE_ATTRS).is_ok());
    }

    #[test]
    fn cycle_is_rejected_with_names() {
        let hooks: Vec<Box<dyn Hook>> = vec![
            Fake::boxed("x", &["Y"], &["X"]),
            Fake::boxed("y", &["X"], &["Y"]),
        ];
        let err = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap_err().to_string();
        assert!(err.contains('x') && err.contains('y'), "{err}");
    }

    #[test]
    fn unmet_requirement_rejected() {
        let hooks: Vec<Box<dyn Hook>> = vec![Fake::boxed("z", &["nonexistent"], &["Z"])];
        let err = resolve_recipe_order(&hooks, BASE_ATTRS).unwrap_err().to_string();
        assert!(err.contains("nonexistent"), "{err}");
    }

    #[test]
    fn manager_runs_in_order_and_times() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("second", &["A"], &["B"]));
        m.register("train", Fake::boxed("first", &[], &["A"]));
        m.activate("train").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("A") && b.has("B"));
        assert!(m.timings().contains_key("first"));
        assert!(m.timings().contains_key("second"));
    }

    #[test]
    fn dishonest_hook_caught() {
        let mut m = HookManager::new();
        m.register(
            "train",
            Box::new(Fake { name: "liar", reqs: vec![], outs: vec!["L"], applied: 0, honest: false }),
        );
        m.activate("train").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        let err = m.run(&mut b, &st).unwrap_err().to_string();
        assert!(err.contains("liar") && err.contains('L'), "{err}");
    }

    #[test]
    fn activation_of_unknown_key_fails() {
        let mut m = HookManager::new();
        assert!(m.activate("nope").is_err());
        assert!(m.active_key().is_none());
    }

    #[test]
    fn keys_are_isolated() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("t", &[], &["T"]));
        m.register("analytics", Fake::boxed("a", &[], &["A"]));
        m.activate("analytics").unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("A") && !b.has("T"));
    }

    #[test]
    fn run_without_activation_errors() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("t", &[], &["T"]));
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        assert!(m.run(&mut b, &st).is_err());
    }

    #[test]
    fn register_under_active_key_re_resolves_lazily() {
        // Regression: registering under the currently active key used to
        // drop the cached order while leaving the key active, so the next
        // run silently executed zero hooks.
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("a", &[], &["A"]));
        m.activate("train").unwrap();
        m.register("train", Fake::boxed("b", &["A"], &["B"]));
        let st = storage();
        let mut batch = MaterializedBatch::new(0, 1);
        m.run(&mut batch, &st).unwrap();
        assert!(batch.has("A"), "pre-existing hook must still run");
        assert!(batch.has("B"), "late-registered hook must run too");
    }

    #[test]
    fn register_under_active_key_surfaces_invalid_recipes() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("a", &[], &["A"]));
        m.activate("train").unwrap();
        m.register("train", Fake::boxed("broken", &["missing_attr"], &["B"]));
        let st = storage();
        let mut batch = MaterializedBatch::new(0, 1);
        let err = m.run(&mut batch, &st).unwrap_err().to_string();
        assert!(err.contains("missing_attr"), "{err}");
    }

    /// Audit regression: the serving hot path must never panic — an
    /// unactivated or unknown-key manager surfaces typed errors.
    #[test]
    fn stateless_pipeline_errors_are_typed_not_panics() {
        let mut m = HookManager::new();
        let err = m.stateless_pipeline().unwrap_err();
        assert!(matches!(err, crate::error::TgmError::Hook(_)), "{err}");
        assert!(err.to_string().contains("activate"), "{err}");
        let err = m.activate("ghost").unwrap_err();
        assert!(matches!(err, crate::error::TgmError::Hook(_)), "{err}");
    }

    #[test]
    fn stateless_hooks_partition_to_worker_phase() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("stateful", &["S"], &["T"]));
        m.register_stateless("train", FakeStateless::shared("sless", &[], &["S"]));
        m.activate("train").unwrap();
        let p = m.stateless_pipeline().unwrap();
        assert_eq!(p.len(), 1, "only the stateless hook may run on workers");
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("S") && b.has("T"));
    }

    #[test]
    fn stateless_depending_on_stateful_is_demoted() {
        let mut m = HookManager::new();
        m.register("train", Fake::boxed("stateful", &[], &["T"]));
        m.register_stateless("train", FakeStateless::shared("sless", &["T"], &["U"]));
        m.activate("train").unwrap();
        let p = m.stateless_pipeline().unwrap();
        assert!(p.is_empty(), "a stateless hook behind a stateful one must not prefetch");
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        m.run(&mut b, &st).unwrap();
        assert!(b.has("T") && b.has("U"));
    }

    #[test]
    fn split_execution_matches_combined_run() {
        // Running the worker phase via the pipeline then the stateful
        // phase via the manager must equal one combined run.
        let build = || {
            let mut m = HookManager::new();
            m.register_stateless("train", FakeStateless::shared("w", &[], &["W"]));
            m.register("train", Fake::boxed("c", &["W"], &["C"]));
            m.activate("train").unwrap();
            m
        };
        let st = storage();

        let mut combined = build();
        let mut b1 = MaterializedBatch::new(0, 1);
        combined.run_indexed(&mut b1, &st, 3).unwrap();

        let mut split = build();
        let pipeline = split.stateless_pipeline().unwrap();
        let mut b2 = MaterializedBatch::new(0, 1);
        pipeline.run(&mut b2, &st, 3).unwrap();
        split.run_stateful_indexed(&mut b2, &st, 3).unwrap();

        assert_eq!(
            b1.get("W").unwrap(),
            b2.get("W").unwrap(),
            "worker output must not depend on where it ran"
        );
        assert!(b2.has("C"));
    }

    #[test]
    fn stateless_pipeline_is_send_sync_and_threadable() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<StatelessPipeline>();

        let mut m = HookManager::new();
        m.register_stateless("train", FakeStateless::shared("w", &[], &["W"]));
        m.activate("train").unwrap();
        let pipeline = m.stateless_pipeline().unwrap();
        let st = std::sync::Arc::new(storage());

        let handles: Vec<_> = (0..4)
            .map(|i| {
                let p = pipeline.clone();
                let st = std::sync::Arc::clone(&st);
                std::thread::spawn(move || {
                    let mut b = MaterializedBatch::new(0, 1);
                    p.run(&mut b, &st, i).unwrap();
                    b.get("W").unwrap().as_f32().unwrap()[0]
                })
            })
            .collect();
        let outs: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Each thread saw its own batch's seed.
        for (i, &o) in outs.iter().enumerate() {
            assert_eq!(o, crate::util::mix64(i as u64) as f32, "batch {i}");
        }
    }

    #[test]
    fn timings_aggregate_across_pipeline_and_manager() {
        let mut m = HookManager::new();
        m.register_stateless("train", FakeStateless::shared("w", &[], &["W"]));
        m.activate("train").unwrap();
        let pipeline = m.stateless_pipeline().unwrap();
        let st = storage();
        let mut b = MaterializedBatch::new(0, 1);
        pipeline.run(&mut b, &st, 0).unwrap();
        assert!(m.timings().contains_key("w"), "worker-side time lands in the manager totals");
        m.reset_timings();
        assert!(m.timings().is_empty());
    }
}
