//! Negative edge construction hooks (paper §1: "negative edge
//! construction [is] implemented inconsistently" — TGM standardizes it).
//!
//! * [`NegativeSampler`] — one random negative destination per positive
//!   edge (training). Supports restricting draws to the destination
//!   id range (bipartite graphs) and *historical* negatives (destinations
//!   the source interacted with before, but not at this timestamp —
//!   Poursafaei et al. 2022).
//! * [`EvalNegativeSampler`] — `Q` negatives per positive for the TGB
//!   one-vs-many evaluation protocol (Table 9), deterministic per edge so
//!   every model ranks against the same candidates.
//!
//! Both are [`StatelessHook`]s: the training sampler draws from a
//! per-batch RNG seeded by `seed ^ ctx.batch_seed`, so a batch
//! materialized on any prefetch worker receives exactly the negatives the
//! serial loader would have produced for that batch position.

use crate::error::Result;
use crate::graph::{SnapshotId, StorageSnapshot};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{HookContext, StatelessHook};
use crate::util::{Rng, Tensor};
use std::sync::Mutex;

/// Destination-id range negatives are drawn from.
#[derive(Debug, Clone, Copy)]
pub enum DstRange {
    /// All node ids `0..num_nodes`.
    AllNodes,
    /// Explicit `[lo, hi)` id range (bipartite item side).
    Range(u32, u32),
    /// Infer `[min(dst), max(dst)+1]` from storage (cached per storage).
    InferFromData,
}

/// `[min(dst), max(dst)+1)` of one segment's destination column — a
/// [`crate::kernels::min_max_u32`] SIMD reduction over the whole column.
/// Empty segments keep the `(u32::MAX, 0)` fold identity.
fn segment_dst_range(seg: &crate::graph::GraphStorage) -> (u32, u32) {
    match crate::kernels::min_max_u32(seg.edge_dst()) {
        Some((lo, hi)) => (lo, hi + 1),
        None => (u32::MAX, 0),
    }
}

/// Interior-mutable per-snapshot cache of the resolved id range, so
/// `InferFromData` scans each destination column once instead of once per
/// batch. Keyed by the snapshot's explicit [`SnapshotId`] (store id +
/// generation) — globally unique and never reused, so no allocator
/// recycling can alias two graphs the way the old pointer-address key
/// could. Like the adjacency cache, per-segment ranges are cached by
/// never-reused segment ids and folded across generations, so a growing
/// streamed graph only ever scans each sealed segment once (not the whole
/// history per generation).
#[derive(Debug, Default)]
struct RangeCache {
    inner: Mutex<RangeInner>,
}

#[derive(Debug, Default)]
struct RangeInner {
    snapshot: Option<(SnapshotId, (u32, u32))>,
    per_segment: std::collections::HashMap<u64, (u32, u32)>,
}

impl RangeCache {
    fn get(&self, range: DstRange, storage: &StorageSnapshot) -> (u32, u32) {
        match range {
            DstRange::AllNodes => return (0, storage.num_nodes() as u32),
            DstRange::Range(lo, hi) => return (lo, hi),
            DstRange::InferFromData => {}
        }
        let key = storage.id();
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((k, r)) = inner.snapshot {
            if k == key {
                return r;
            }
        }
        let (mut lo, mut hi) = (u32::MAX, 0u32);
        let mut fresh = std::collections::HashMap::with_capacity(storage.num_segments());
        for (s, seg) in storage.segments().iter().enumerate() {
            let sid = storage.segment_ids()[s];
            let (slo, shi) =
                inner.per_segment.get(&sid).copied().unwrap_or_else(|| segment_dst_range(seg));
            fresh.insert(sid, (slo, shi));
            lo = lo.min(slo);
            hi = hi.max(shi);
        }
        inner.per_segment = fresh;
        let r = if hi == 0 { (0, 1) } else { (lo, hi) };
        inner.snapshot = Some((key, r));
        r
    }
}

/// Training negative sampler: one negative per seed edge.
pub struct NegativeSampler {
    range: DstRange,
    /// Probability of drawing a *historical* negative (a past destination
    /// of some edge) instead of a uniform one.
    historical_prob: f64,
    seed: u64,
    cache: RangeCache,
}

impl NegativeSampler {
    /// Uniform negatives over `range`.
    pub fn new(range: DstRange, seed: u64) -> NegativeSampler {
        NegativeSampler { range, historical_prob: 0.0, seed, cache: RangeCache::default() }
    }

    /// Mix in historical negatives with probability `p`.
    pub fn with_historical(mut self, p: f64) -> NegativeSampler {
        self.historical_prob = p;
        self
    }
}

impl StatelessHook for NegativeSampler {
    fn name(&self) -> &'static str {
        "negative_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![]
    }

    fn produces(&self) -> Vec<&'static str> {
        vec![attr::NEGATIVES]
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let (lo, hi) = self.cache.get(self.range, ctx.storage);
        let mut rng = Rng::new(self.seed ^ ctx.batch_seed);
        let b = batch.num_edges();
        let mut negs = Vec::with_capacity(b);
        for i in 0..b {
            let neg = if self.historical_prob > 0.0 && rng.bool(self.historical_prob) {
                // Historical: destination of a uniformly random past edge.
                let past = ctx.storage.edge_range(ctx.storage.start_time(), batch.ts[i]);
                if past.is_empty() {
                    rng.range(lo as i64, hi as i64) as i32
                } else {
                    let j = past.start + rng.below(past.len() as u64) as usize;
                    ctx.storage.edge_dst_at(j) as i32
                }
            } else {
                rng.range(lo as i64, hi as i64) as i32
            };
            negs.push(neg);
        }
        batch.set(attr::NEGATIVES, Tensor::i32(negs, &[b])?);
        Ok(())
    }
}

/// One-vs-many evaluation negatives: `Q` candidates per positive,
/// deterministic per (src, dst, t) triple so rankings are reproducible
/// and identical across models (the TGB protocol).
pub struct EvalNegativeSampler {
    range: DstRange,
    num_negatives: usize,
    seed: u64,
    cache: RangeCache,
}

impl EvalNegativeSampler {
    /// `Q` negatives per positive edge over `range`.
    pub fn new(range: DstRange, num_negatives: usize, seed: u64) -> EvalNegativeSampler {
        EvalNegativeSampler { range, num_negatives, seed, cache: RangeCache::default() }
    }
}

impl StatelessHook for EvalNegativeSampler {
    fn name(&self) -> &'static str {
        "eval_negative_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![]
    }

    fn produces(&self) -> Vec<&'static str> {
        vec![attr::EVAL_NEGATIVES]
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let (lo, hi) = self.cache.get(self.range, ctx.storage);
        let b = batch.num_edges();
        let q = self.num_negatives;
        let mut negs = Vec::with_capacity(b * q);
        for i in 0..b {
            // Deterministic per-edge stream: seed from the edge identity.
            let tag = (batch.src[i] as u64) << 40
                ^ (batch.dst[i] as u64) << 20
                ^ batch.ts[i] as u64;
            let mut rng = Rng::new(self.seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15));
            for _ in 0..q {
                // Avoid sampling the true destination.
                let mut cand = rng.range(lo as i64, hi as i64) as u32;
                if cand == batch.dst[i] {
                    cand = if cand + 1 < hi { cand + 1 } else { lo };
                }
                negs.push(cand as i32);
            }
        }
        batch.set(attr::EVAL_NEGATIVES, Tensor::i32(negs, &[b, q])?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeEvent;

    fn storage() -> StorageSnapshot {
        let edges = (0..50)
            .map(|i| EdgeEvent { t: i as i64, src: (i % 3) as u32, dst: 5 + (i % 4) as u32, features: vec![] })
            .collect();
        crate::graph::GraphStorage::from_events(edges, vec![], 9, None, None)
            .unwrap()
            .into_snapshot()
    }

    fn batch(st: &StorageSnapshot) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(10, 20);
        for i in 10..20 {
            b.src.push(st.edge_src_at(i));
            b.dst.push(st.edge_dst_at(i));
            b.ts.push(st.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b
    }

    #[test]
    fn uniform_negatives_in_range() {
        let st = storage();
        let ctx = HookContext::new(&st, "train");
        let h = NegativeSampler::new(DstRange::Range(5, 9), 1);
        let mut b = batch(&st);
        h.apply(&mut b, &ctx).unwrap();
        let negs = b.get(attr::NEGATIVES).unwrap().as_i32().unwrap();
        assert_eq!(negs.len(), 10);
        assert!(negs.iter().all(|&n| (5..9).contains(&n)));
    }

    #[test]
    fn inferred_range_matches_data() {
        let st = storage();
        let ctx = HookContext::new(&st, "train");
        let h = NegativeSampler::new(DstRange::InferFromData, 1);
        let mut b = batch(&st);
        h.apply(&mut b, &ctx).unwrap();
        let negs = b.get(attr::NEGATIVES).unwrap().as_i32().unwrap();
        assert!(negs.iter().all(|&n| (5..9).contains(&n)));
        // A second apply hits the cached range and stays in bounds.
        let mut b2 = batch(&st);
        h.apply(&mut b2, &ctx).unwrap();
        assert!(b2.get(attr::NEGATIVES).unwrap().as_i32().unwrap().iter().all(|&n| (5..9).contains(&n)));
    }

    #[test]
    fn inferred_range_not_aliased_across_same_shape_storages() {
        // Two storages with identical (num_edges, num_nodes) but
        // different destination populations must not share a cached
        // range (snapshot ids are globally unique, never shape-derived).
        let mk = |base: u32| {
            let edges = (0..50)
                .map(|i| EdgeEvent {
                    t: i as i64,
                    src: (i % 3) as u32,
                    dst: base + (i % 4) as u32,
                    features: vec![],
                })
                .collect();
            crate::graph::GraphStorage::from_events(edges, vec![], 9, None, None)
                .unwrap()
                .into_snapshot()
        };
        let st_hi = mk(5); // destinations 5..=8
        let st_lo = mk(1); // destinations 1..=4
        let h = NegativeSampler::new(DstRange::InferFromData, 3);

        let ctx_hi = HookContext::new(&st_hi, "train");
        let mut b_hi = batch(&st_hi);
        h.apply(&mut b_hi, &ctx_hi).unwrap();
        assert!(b_hi
            .get(attr::NEGATIVES)
            .unwrap()
            .as_i32()
            .unwrap()
            .iter()
            .all(|&n| (5..9).contains(&n)));

        let ctx_lo = HookContext::new(&st_lo, "train");
        let mut b_lo = batch(&st_lo);
        h.apply(&mut b_lo, &ctx_lo).unwrap();
        assert!(b_lo
            .get(attr::NEGATIVES)
            .unwrap()
            .as_i32()
            .unwrap()
            .iter()
            .all(|&n| (1..5).contains(&n)));
    }

    #[test]
    fn historical_negatives_are_past_destinations() {
        let st = storage();
        let ctx = HookContext::new(&st, "train");
        let h = NegativeSampler::new(DstRange::AllNodes, 1).with_historical(1.0);
        let mut b = batch(&st);
        h.apply(&mut b, &ctx).unwrap();
        let negs = b.get(attr::NEGATIVES).unwrap().as_i32().unwrap();
        // All destinations in this storage are >= 5.
        assert!(negs.iter().all(|&n| n >= 5));
    }

    #[test]
    fn negatives_depend_only_on_batch_index() {
        // The stream is a pure function of (hook seed, batch index): two
        // applies at the same index agree, regardless of history.
        let st = storage();
        let h = NegativeSampler::new(DstRange::AllNodes, 7);
        let ctx3 = HookContext::for_batch(&st, "train", 3);
        let mut b1 = batch(&st);
        h.apply(&mut b1, &ctx3).unwrap();
        // Interleave an unrelated batch at another index.
        let ctx9 = HookContext::for_batch(&st, "train", 9);
        let mut other = batch(&st);
        h.apply(&mut other, &ctx9).unwrap();
        let mut b2 = batch(&st);
        h.apply(&mut b2, &ctx3).unwrap();
        assert_eq!(
            b1.get(attr::NEGATIVES).unwrap().as_i32().unwrap(),
            b2.get(attr::NEGATIVES).unwrap().as_i32().unwrap()
        );
    }

    #[test]
    fn eval_negatives_deterministic_and_exclude_positive() {
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let h = EvalNegativeSampler::new(DstRange::Range(5, 9), 20, 3);
        let mut b1 = batch(&st);
        h.apply(&mut b1, &ctx).unwrap();
        let t1 = b1.get(attr::EVAL_NEGATIVES).unwrap();
        assert_eq!(t1.shape(), &[10, 20]);
        let n1 = t1.as_i32().unwrap();
        // No candidate equals its row's positive destination.
        for (row, &d) in b1.dst.iter().enumerate() {
            assert!(n1[row * 20..(row + 1) * 20].iter().all(|&c| c != d as i32));
        }
        // Re-running yields identical candidates (protocol determinism),
        // even at a different batch index: the stream is per-edge.
        let h2 = EvalNegativeSampler::new(DstRange::Range(5, 9), 20, 3);
        let ctx5 = HookContext::for_batch(&st, "val", 5);
        let mut b2 = batch(&st);
        h2.apply(&mut b2, &ctx5).unwrap();
        assert_eq!(n1, b2.get(attr::EVAL_NEGATIVES).unwrap().as_i32().unwrap());
    }
}
