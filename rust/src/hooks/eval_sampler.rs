//! Evaluation-time neighbor lookup with batch-level dedup (Table 9).
//!
//! The one-vs-many protocol needs neighborhoods for every candidate
//! destination. DyGLib re-samples per (positive, candidate) pair —
//! `B x (Q+2)` lookups; TGM samples **once per unique node** in the
//! batch (src ∪ dst ∪ candidates) and lets the packer fan the unique
//! rows out to slots with cheap memcpys. The paper credits this for up
//! to 246x faster validation.
//!
//! Produces `unique_nbr_ids/ts/mask/feats` rows aligned with
//! [`attr::UNIQUE_NODES`]; times are *absolute* so the packer can form
//! per-slot deltas against each slot's own prediction time.

use crate::error::Result;
use crate::graph::{AdjacencyCache, NeighborCols};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{HookContext, StatelessHook};
use crate::util::Tensor;

/// Unique-node attribute keys (consumed by the batch packer).
pub const UNIQUE_NBR_IDS: &str = "unique_nbr_ids";
pub const UNIQUE_NBR_TS: &str = "unique_nbr_ts";
pub const UNIQUE_NBR_MASK: &str = "unique_nbr_mask";
pub const UNIQUE_NBR_FEATS: &str = "unique_nbr_feats";
/// Two-hop variants, rows aligned with `[U*K, K2]`.
pub const UNIQUE_NBR2_IDS: &str = "unique_nbr2_ids";
pub const UNIQUE_NBR2_TS: &str = "unique_nbr2_ts";
pub const UNIQUE_NBR2_MASK: &str = "unique_nbr2_mask";
pub const UNIQUE_NBR2_FEATS: &str = "unique_nbr2_feats";

/// Most-recent-K lookup for each unique batch node, cut at batch start.
/// Stateless: the cut depends only on the batch window, and the CSR index
/// is a shared per-storage cache — safe on any prefetch worker.
pub struct UniqueRecencyLookup {
    num_neighbors: usize,
    two_hop: Option<usize>,
    adj: AdjacencyCache,
}

impl UniqueRecencyLookup {
    /// Look up the K most recent interactions per unique node.
    pub fn new(num_neighbors: usize) -> UniqueRecencyLookup {
        UniqueRecencyLookup { num_neighbors, two_hop: None, adj: AdjacencyCache::new() }
    }

    /// Also look up K2 hop-2 interactions per hop-1 slot (TGAT eval).
    pub fn with_two_hop(mut self, k2: usize) -> UniqueRecencyLookup {
        self.two_hop = Some(k2);
        self
    }
}

impl StatelessHook for UniqueRecencyLookup {
    fn name(&self) -> &'static str {
        "unique_recency_lookup"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![attr::UNIQUE_NODES]
    }

    fn produces(&self) -> Vec<&'static str> {
        let mut p = vec![UNIQUE_NBR_IDS, UNIQUE_NBR_TS, UNIQUE_NBR_MASK, UNIQUE_NBR_FEATS];
        if self.two_hop.is_some() {
            p.extend([UNIQUE_NBR2_IDS, UNIQUE_NBR2_TS, UNIQUE_NBR2_MASK, UNIQUE_NBR2_FEATS]);
        }
        p
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let adj = self.adj.get(ctx.storage);

        let unique = batch.get(attr::UNIQUE_NODES)?.as_i32()?.to_vec();
        let u = unique.len();
        let k = self.num_neighbors;
        let d = ctx.storage.edge_feat_dim();
        let cut = batch.start; // batch-level semantics: strictly before the window

        // Per unique node: resolve the view's columns once (zero-copy
        // for single-segment snapshots, one scratch copy otherwise)
        // instead of part-walking `view.get` per slot, record the edge
        // index per filled slot, and batch-gather all feature rows in
        // one SIMD pass at the end.
        let mut ids = vec![0i32; u * k];
        let mut ts = vec![0.0f32; u * k];
        let mut mask = vec![0.0f32; u * k];
        let mut eidx = vec![0u32; u * k];
        let mut cols = NeighborCols::new();
        for (row, &node) in unique.iter().enumerate() {
            let view = adj.neighbors_before(node as u32, cut);
            let avail = view.len();
            let take = k.min(avail);
            if take == 0 {
                continue;
            }
            let (ns, tss, es, base) = match view.single_part() {
                Some(p) => p,
                None => {
                    view.collect_into(&mut cols);
                    (&cols.nbr[..], &cols.ts[..], &cols.eidx[..], 0u32)
                }
            };
            for slot in 0..take {
                let j = avail - 1 - slot; // newest first
                let o = row * k + slot;
                ids[o] = ns[j] as i32;
                ts[o] = tss[j] as f32;
                mask[o] = 1.0;
                eidx[o] = es[j] + base;
            }
        }
        let mut feats = vec![0.0f32; u * k * d];
        ctx.storage.gather_edge_feat_rows(&eidx, &mask, &mut feats);
        if let Some(k2) = self.two_hop {
            let rows = u * k;
            let mut ids2 = vec![0i32; rows * k2];
            let mut ts2 = vec![0.0f32; rows * k2];
            let mut mask2 = vec![0.0f32; rows * k2];
            let mut eidx2 = vec![0u32; rows * k2];
            for o in 0..rows {
                if mask[o] > 0.0 {
                    let view = adj.neighbors_before(ids[o] as u32, ts[o] as i64);
                    let avail = view.len();
                    let take = k2.min(avail);
                    if take == 0 {
                        continue;
                    }
                    let (ns, tss, es, base) = match view.single_part() {
                        Some(p) => p,
                        None => {
                            view.collect_into(&mut cols);
                            (&cols.nbr[..], &cols.ts[..], &cols.eidx[..], 0u32)
                        }
                    };
                    for slot in 0..take {
                        let j = avail - 1 - slot;
                        let q = o * k2 + slot;
                        ids2[q] = ns[j] as i32;
                        ts2[q] = tss[j] as f32;
                        mask2[q] = 1.0;
                        eidx2[q] = es[j] + base;
                    }
                }
            }
            let mut feats2 = vec![0.0f32; rows * k2 * d];
            ctx.storage.gather_edge_feat_rows(&eidx2, &mask2, &mut feats2);
            batch.set(UNIQUE_NBR2_IDS, Tensor::i32(ids2, &[rows, k2])?);
            batch.set(UNIQUE_NBR2_TS, Tensor::f32(ts2, &[rows, k2])?);
            batch.set(UNIQUE_NBR2_MASK, Tensor::f32(mask2, &[rows, k2])?);
            batch.set(UNIQUE_NBR2_FEATS, Tensor::f32(feats2, &[rows, k2, d])?);
        }
        batch.set(UNIQUE_NBR_IDS, Tensor::i32(ids, &[u, k])?);
        batch.set(UNIQUE_NBR_TS, Tensor::f32(ts, &[u, k])?);
        batch.set(UNIQUE_NBR_MASK, Tensor::f32(mask, &[u, k])?);
        batch.set(UNIQUE_NBR_FEATS, Tensor::f32(feats, &[u, k, d])?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};

    fn storage() -> crate::graph::StorageSnapshot {
        let edges = (0..30)
            .map(|i| EdgeEvent {
                t: i as i64,
                src: (i % 3) as u32,
                dst: 3 + (i % 2) as u32,
                features: vec![i as f32],
            })
            .collect();
        GraphStorage::from_events(edges, vec![], 6, None, None).unwrap().into_snapshot()
    }

    #[test]
    fn lookup_is_recent_and_strictly_past() {
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let mut b = MaterializedBatch::new(20, 25);
        b.src = vec![0];
        b.dst = vec![3];
        b.ts = vec![20];
        b.edge_indices = vec![20];
        b.set(attr::UNIQUE_NODES, Tensor::i32(vec![0, 3, 5], &[3]).unwrap());
        let h = UniqueRecencyLookup::new(4);
        h.apply(&mut b, &ctx).unwrap();
        let ts = b.get(UNIQUE_NBR_TS).unwrap().as_f32().unwrap();
        let mask = b.get(UNIQUE_NBR_MASK).unwrap().as_f32().unwrap();
        // All sampled interactions precede the batch window.
        for (i, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                assert!(ts[i] < 20.0);
            }
        }
        // Row 0 = node 0: most recent interaction before t=20 is t=18
        // (edges with src 0 at t = 0,3,6,...,18).
        assert_eq!(ts[0], 18.0);
        assert_eq!(mask[0], 1.0);
        // Node 5 never appears -> fully masked.
        let row2 = &mask[2 * 4..3 * 4];
        assert!(row2.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn feats_follow_edges() {
        let st = storage();
        let ctx = HookContext::new(&st, "val");
        let mut b = MaterializedBatch::new(10, 12);
        b.src = vec![1];
        b.dst = vec![4];
        b.ts = vec![10];
        b.edge_indices = vec![10];
        b.set(attr::UNIQUE_NODES, Tensor::i32(vec![1], &[1]).unwrap());
        let h = UniqueRecencyLookup::new(2);
        h.apply(&mut b, &ctx).unwrap();
        // Node 1's latest pre-10 interactions: t=7 and t=4; features == t.
        let f = b.get(UNIQUE_NBR_FEATS).unwrap().as_f32().unwrap();
        assert_eq!(f[0], 7.0);
        assert_eq!(f[1], 4.0);
    }
}
