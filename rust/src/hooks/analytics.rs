//! Analytics hooks (paper Fig. 3: "Density of States Analysis").
//!
//! TGM treats temporal-graph *analytics* as first-class recipes sharing
//! the hook ecosystem with ML workflows. Implemented here:
//!
//! * [`DosEstimateHook`] — spectral density-of-states moment estimates of
//!   the batch-window adjacency via Hutchinson stochastic trace probes
//!   (`tr(Â^k)/n` for `k = 1..M`), the standard moment-method DOS
//!   estimator.
//! * [`SnapshotAdjHook`] — dense symmetric-normalized snapshot adjacency
//!   `Â = D^{-1/2}(A + I)D^{-1/2}` for DTDG models (GCN/GCLSTM/T-GCN).
//! * [`DegreeStatsHook`] — per-batch degree summary (mean/max), a cheap
//!   example of a custom analytics hook.

use crate::error::Result;
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{HookContext, StatelessHook};
use crate::util::{Rng, Tensor};

/// Multiply the symmetric-normalized batch adjacency against `x`:
/// `y = Â x` using the batch's edge list (sparse matvec).
fn normalized_matvec(
    src: &[u32],
    dst: &[u32],
    deg_inv_sqrt: &[f32],
    x: &[f32],
    y: &mut [f32],
) {
    y.iter_mut().for_each(|v| *v = 0.0);
    // Self-loops contribute deg_inv_sqrt[i]^2 * x[i].
    for i in 0..x.len() {
        y[i] += deg_inv_sqrt[i] * deg_inv_sqrt[i] * x[i];
    }
    for (&s, &d) in src.iter().zip(dst) {
        let (s, d) = (s as usize, d as usize);
        let w = deg_inv_sqrt[s] * deg_inv_sqrt[d];
        y[d] += w * x[s];
        y[s] += w * x[d];
    }
}

/// Degrees (with self-loop) of the batch-window graph.
fn batch_degrees(batch: &MaterializedBatch, n: usize) -> Vec<f32> {
    let mut deg = vec![1.0f32; n]; // self-loop
    for (&s, &d) in batch.src.iter().zip(&batch.dst) {
        deg[s as usize] += 1.0;
        deg[d as usize] += 1.0;
    }
    deg
}

/// DOS spectral-moment estimator (Hutchinson probes). Stateless: probes
/// are drawn from a per-batch RNG (`seed ^ ctx.batch_seed`), so estimates
/// are reproducible under out-of-order prefetch materialization.
pub struct DosEstimateHook {
    num_moments: usize,
    num_probes: usize,
    seed: u64,
}

impl DosEstimateHook {
    /// Estimate `num_moments` moments with `num_probes` Rademacher probes.
    pub fn new(num_moments: usize, num_probes: usize, seed: u64) -> DosEstimateHook {
        DosEstimateHook { num_moments, num_probes, seed }
    }
}

impl StatelessHook for DosEstimateHook {
    fn name(&self) -> &'static str {
        "dos_estimate"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![]
    }

    fn produces(&self) -> Vec<&'static str> {
        vec![attr::DOS]
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let n = ctx.storage.num_nodes();
        let deg = batch_degrees(batch, n);
        let dis: Vec<f32> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();

        let mut rng = Rng::new(self.seed ^ ctx.batch_seed);
        let mut moments = vec![0.0f64; self.num_moments];
        let mut x = vec![0.0f32; n];
        let mut y = vec![0.0f32; n];
        for _ in 0..self.num_probes {
            // Rademacher probe z.
            let z: Vec<f32> =
                (0..n).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
            x.copy_from_slice(&z);
            for m in 0..self.num_moments {
                normalized_matvec(&batch.src, &batch.dst, &dis, &x, &mut y);
                std::mem::swap(&mut x, &mut y);
                // moment_k ~ E[z^T Â^k z] / n
                let dot: f64 =
                    z.iter().zip(&x).map(|(&a, &b)| a as f64 * b as f64).sum();
                moments[m] += dot / n as f64;
            }
        }
        let probes = self.num_probes.max(1) as f64;
        let out: Vec<f32> = moments.iter().map(|&m| (m / probes) as f32).collect();
        batch.set(attr::DOS, Tensor::f32(out, &[self.num_moments])?);
        Ok(())
    }
}

/// Dense symmetric-normalized snapshot adjacency for DTDG models.
/// Stateless and deterministic.
pub struct SnapshotAdjHook;

impl StatelessHook for SnapshotAdjHook {
    fn name(&self) -> &'static str {
        "snapshot_adj"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![]
    }

    fn produces(&self) -> Vec<&'static str> {
        vec![attr::SNAPSHOT_ADJ]
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let n = ctx.storage.num_nodes();
        let deg = batch_degrees(batch, n);
        let dis: Vec<f32> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
        let mut adj = vec![0.0f32; n * n];
        for i in 0..n {
            adj[i * n + i] = dis[i] * dis[i];
        }
        for (&s, &d) in batch.src.iter().zip(&batch.dst) {
            let (s, d) = (s as usize, d as usize);
            let w = dis[s] * dis[d];
            // Accumulate duplicate edges (weighted multigraph collapse).
            adj[s * n + d] += w;
            adj[d * n + s] += w;
        }
        batch.set(attr::SNAPSHOT_ADJ, Tensor::f32(adj, &[n, n])?);
        Ok(())
    }
}

/// Cheap per-batch degree statistics (example custom analytics hook).
/// Stateless and deterministic.
pub struct DegreeStatsHook;

impl StatelessHook for DegreeStatsHook {
    fn name(&self) -> &'static str {
        "degree_stats"
    }

    fn requires(&self) -> Vec<&'static str> {
        vec![]
    }

    fn produces(&self) -> Vec<&'static str> {
        vec!["degree_stats"]
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let n = ctx.storage.num_nodes();
        let mut deg = vec![0.0f32; n];
        for (&s, &d) in batch.src.iter().zip(&batch.dst) {
            deg[s as usize] += 1.0;
            deg[d as usize] += 1.0;
        }
        let active = deg.iter().filter(|&&d| d > 0.0).count().max(1);
        let mean = deg.iter().sum::<f32>() / active as f32;
        let max = deg.iter().fold(0.0f32, |a, &b| a.max(b));
        batch.set_custom("degree_stats", Tensor::f32(vec![mean, max], &[2])?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};

    fn storage(n: usize) -> crate::graph::StorageSnapshot {
        GraphStorage::from_events(
            vec![EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }],
            vec![],
            n,
            None,
            None,
        )
        .unwrap()
        .into_snapshot()
    }

    fn batch(edges: &[(u32, u32)]) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(0, 10);
        for &(s, d) in edges {
            b.src.push(s);
            b.dst.push(d);
            b.ts.push(0);
            b.edge_indices.push(0);
        }
        b
    }

    #[test]
    fn snapshot_adjacency_is_symmetric_normalized() {
        let st = storage(3);
        let ctx = HookContext::new(&st, "analytics");
        let mut b = batch(&[(0, 1)]);
        let h = SnapshotAdjHook;
        h.apply(&mut b, &ctx).unwrap();
        let a = b.get(attr::SNAPSHOT_ADJ).unwrap();
        assert_eq!(a.shape(), &[3, 3]);
        let m = a.as_f32().unwrap();
        // Symmetry.
        for i in 0..3 {
            for j in 0..3 {
                assert!((m[i * 3 + j] - m[j * 3 + i]).abs() < 1e-6);
            }
        }
        // deg(0)=deg(1)=2 (edge + self-loop), deg(2)=1.
        assert!((m[0 * 3 + 1] - 0.5).abs() < 1e-6);
        assert!((m[0 * 3 + 0] - 0.5).abs() < 1e-6);
        assert!((m[2 * 3 + 2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dos_first_moment_matches_normalized_trace() {
        // For Â = D^{-1/2}(A+I)D^{-1/2}, tr(Â) = sum_i 1/deg_i; moment_1
        // = tr(Â)/n. Use enough probes for a tight estimate.
        let st = storage(4);
        let ctx = HookContext::new(&st, "analytics");
        let mut b = batch(&[(0, 1), (1, 2)]);
        let h = DosEstimateHook::new(3, 600, 9);
        h.apply(&mut b, &ctx).unwrap();
        let dos = b.get(attr::DOS).unwrap().as_f32().unwrap().to_vec();
        assert_eq!(dos.len(), 3);
        // deg = [2, 3, 2, 1]; tr = 1/2 + 1/3 + 1/2 + 1 = 2.3333; /4 = 0.5833
        assert!((dos[0] - 0.5833).abs() < 0.08, "moment1={}", dos[0]);
        // Moments of a normalized adjacency stay within [-1, 1].
        assert!(dos.iter().all(|&m| m.abs() <= 1.1));
    }

    #[test]
    fn dos_is_deterministic_per_batch_index() {
        // Stateless contract: the estimate is a pure function of
        // (batch, batch_index), with no reset needed in between.
        let st = storage(4);
        let ctx = HookContext::for_batch(&st, "analytics", 5);
        let h = DosEstimateHook::new(4, 8, 3);
        let mut b1 = batch(&[(0, 1), (2, 3)]);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch(&[(0, 1), (2, 3)]);
        h.apply(&mut b2, &ctx).unwrap();
        assert_eq!(
            b1.get(attr::DOS).unwrap().as_f32().unwrap(),
            b2.get(attr::DOS).unwrap().as_f32().unwrap()
        );
    }

    #[test]
    fn degree_stats() {
        let st = storage(4);
        let ctx = HookContext::new(&st, "analytics");
        let mut b = batch(&[(0, 1), (0, 2), (0, 3)]);
        let h = DegreeStatsHook;
        h.apply(&mut b, &ctx).unwrap();
        let s = b.get("degree_stats").unwrap().as_f32().unwrap().to_vec();
        assert_eq!(s[1], 3.0); // max degree (node 0)
        assert!((s[0] - 6.0 / 4.0).abs() < 1e-6); // mean over active nodes
    }
}
