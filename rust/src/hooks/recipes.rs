//! Pre-defined hook recipes (paper §4, "Hook Registry and Management").
//!
//! Recipes package the hook combinations common TGL workflows need, so
//! new practitioners avoid pitfalls like mismanaging sampler state across
//! splits or using the wrong negatives. Each builder returns a
//! [`HookManager`] with `train` and `val` groups registered; custom hooks
//! can still be added before activation.

use crate::error::Result;
use crate::hooks::analytics::{DosEstimateHook, SnapshotAdjHook};
use crate::hooks::dedup::DedupHook;
use crate::hooks::eval_sampler::UniqueRecencyLookup;
use crate::hooks::manager::{HookEntry, HookManager};
use crate::hooks::negatives::{DstRange, EvalNegativeSampler, NegativeSampler};
use crate::hooks::neighbor::{RecencySampler, SamplerConfig, UniformSampler};
use crate::hooks::neighbor_naive::NaiveSampler;
use std::sync::Arc;

/// Recipe identifiers (mirrors `tgm.constants` in the paper's Fig. 5).
pub const RECIPE_TGB_LINK: &str = "tgb_link";
pub const RECIPE_TGB_NODE: &str = "tgb_node";
pub const RECIPE_SNAPSHOT: &str = "snapshot";
pub const RECIPE_ANALYTICS_DOS: &str = "analytics_dos";

/// Which neighbor sampler a recipe wires in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// TGM's circular-buffer recency sampler (default).
    Recency,
    /// Uniform draws over the temporal neighborhood.
    Uniform,
    /// DyGLib-style per-seed history copies (baseline for benches).
    Naive,
}

/// Options shared by the recipe builders.
#[derive(Debug, Clone)]
pub struct RecipeConfig {
    pub sampler: SamplerKind,
    pub num_neighbors: usize,
    pub two_hop: Option<usize>,
    pub include_features: bool,
    /// Negative-candidate id range (bipartite item side for TGB links).
    pub dst_range: DstRange,
    /// One-vs-many candidates per positive at evaluation.
    pub eval_negatives: usize,
    pub seed: u64,
}

impl Default for RecipeConfig {
    fn default() -> Self {
        RecipeConfig {
            sampler: SamplerKind::Recency,
            num_neighbors: 10,
            two_hop: None,
            include_features: true,
            dst_range: DstRange::InferFromData,
            eval_negatives: 20,
            seed: 0,
        }
    }
}

/// Wire up the configured neighbor sampler as a phased hook entry: the
/// recency sampler is stateful (circular buffers must see batches in
/// order), while the uniform and naive samplers are stateless and safe to
/// run on prefetch workers.
pub fn sampler_entry(cfg: &RecipeConfig, seed_negatives: bool) -> HookEntry {
    let sc = SamplerConfig {
        num_neighbors: cfg.num_neighbors,
        two_hop: cfg.two_hop,
        include_features: cfg.include_features,
        seed_negatives,
    };
    match cfg.sampler {
        SamplerKind::Recency => HookEntry::Stateful(Box::new(RecencySampler::new(sc))),
        SamplerKind::Uniform => {
            HookEntry::Stateless(Arc::new(UniformSampler::new(sc, cfg.seed ^ 0xA5A5)))
        }
        SamplerKind::Naive => HookEntry::Stateless(Arc::new(NaiveSampler::new(sc))),
    }
}

/// Registry of named recipes (paper Fig. 5: `RecipeRegistry.build(...)`).
pub struct RecipeRegistry;

impl RecipeRegistry {
    /// Build a manager for a named recipe with default options.
    pub fn build(name: &str) -> Result<HookManager> {
        Self::build_with(name, &RecipeConfig::default())
    }

    /// Build a manager for a named recipe.
    pub fn build_with(name: &str, cfg: &RecipeConfig) -> Result<HookManager> {
        let mut m = HookManager::new();
        match name {
            RECIPE_TGB_LINK => {
                // train: negatives (worker phase) -> sampler(seeds incl.
                // negatives); the default recency sampler runs in the
                // stateful consumer phase.
                m.register_stateless(
                    "train",
                    Arc::new(NegativeSampler::new(cfg.dst_range, cfg.seed)),
                );
                m.register_entry("train", sampler_entry(cfg, true));
                // val: deterministic one-vs-many negatives -> dedup ->
                // one recency lookup per unique node (the Table-9
                // optimization; the packer fans unique rows out to
                // slots). All three are stateless, so the whole val
                // recipe prefetches on workers.
                m.register_stateless(
                    "val",
                    Arc::new(EvalNegativeSampler::new(cfg.dst_range, cfg.eval_negatives, cfg.seed)),
                );
                m.register_stateless("val", Arc::new(DedupHook::new(false, true)));
                let mut lookup = UniqueRecencyLookup::new(cfg.num_neighbors);
                if let Some(k2) = cfg.two_hop {
                    lookup = lookup.with_two_hop(k2);
                }
                m.register_stateless("val", Arc::new(lookup));
            }
            RECIPE_TGB_NODE => {
                // Node tasks: no negatives; sample src/dst neighborhoods.
                m.register_entry("train", sampler_entry(cfg, false));
                m.register_entry("val", sampler_entry(cfg, false));
            }
            RECIPE_SNAPSHOT => {
                // DTDG: dense normalized snapshot adjacency per batch.
                m.register_stateless("train", Arc::new(SnapshotAdjHook));
                m.register_stateless("val", Arc::new(SnapshotAdjHook));
            }
            RECIPE_ANALYTICS_DOS => {
                m.register_stateless("analytics", Arc::new(DosEstimateHook::new(8, 16, cfg.seed)));
            }
            other => {
                return Err(crate::error::TgmError::Recipe(format!("unknown recipe `{other}`")))
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};
    use crate::hooks::batch::{attr, MaterializedBatch};

    fn storage() -> crate::graph::StorageSnapshot {
        let edges = (0..30)
            .map(|i| EdgeEvent {
                t: i as i64,
                src: (i % 3) as u32,
                dst: 3 + (i % 2) as u32,
                features: vec![1.0],
            })
            .collect();
        GraphStorage::from_events(edges, vec![], 5, None, None).unwrap().into_snapshot()
    }

    fn batch(st: &crate::graph::StorageSnapshot, r: std::ops::Range<usize>) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(st.edge_ts_at(r.start), st.edge_ts_at(r.end - 1) + 1);
        for i in r {
            b.src.push(st.edge_src_at(i));
            b.dst.push(st.edge_dst_at(i));
            b.ts.push(st.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b
    }

    #[test]
    fn tgb_link_train_recipe_composes() {
        let st = storage();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut b = batch(&st, 10..15);
        m.run(&mut b, &st).unwrap();
        assert!(b.has(attr::NEGATIVES));
        assert!(b.has(attr::NEIGHBORS));
        // Sampler covered src+dst+neg seeds.
        assert_eq!(b.get(attr::NEIGHBORS).unwrap().shape()[0], 15);
    }

    #[test]
    fn tgb_link_val_recipe_composes() {
        let st = storage();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut b = batch(&st, 10..15);
        m.run(&mut b, &st).unwrap();
        assert!(b.has(attr::EVAL_NEGATIVES));
        assert!(b.has(attr::UNIQUE_NODES));
        assert!(b.has(crate::hooks::eval_sampler::UNIQUE_NBR_IDS));
        // One lookup row per unique node.
        let u = b.get(attr::UNIQUE_NODES).unwrap().len();
        assert_eq!(b.get(crate::hooks::eval_sampler::UNIQUE_NBR_IDS).unwrap().shape()[0], u);
    }

    #[test]
    fn snapshot_recipe_produces_adjacency() {
        let st = storage();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut b = batch(&st, 0..10);
        m.run(&mut b, &st).unwrap();
        assert_eq!(b.get(attr::SNAPSHOT_ADJ).unwrap().shape(), &[5, 5]);
    }

    #[test]
    fn analytics_recipe() {
        let st = storage();
        let mut m = RecipeRegistry::build(RECIPE_ANALYTICS_DOS).unwrap();
        m.activate("analytics").unwrap();
        let mut b = batch(&st, 0..10);
        m.run(&mut b, &st).unwrap();
        assert!(b.has(attr::DOS));
    }

    #[test]
    fn unknown_recipe_rejected() {
        assert!(RecipeRegistry::build("nonsense").is_err());
    }

    #[test]
    fn tgb_link_phases_split_as_designed() {
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        // Train: the negative sampler prefetches on workers; the default
        // recency sampler must stay in the serial consumer phase.
        m.activate("train").unwrap();
        assert_eq!(m.stateless_pipeline().unwrap().len(), 1);
        // Val: negatives -> dedup -> unique lookup are all stateless, so
        // the entire materialization overlaps with model execution.
        m.activate("val").unwrap();
        assert_eq!(m.stateless_pipeline().unwrap().len(), 3);
    }

    #[test]
    fn all_sampler_kinds_wire_up() {
        let st = storage();
        for kind in [SamplerKind::Recency, SamplerKind::Uniform, SamplerKind::Naive] {
            let cfg = RecipeConfig { sampler: kind, ..Default::default() };
            let mut m = RecipeRegistry::build_with(RECIPE_TGB_LINK, &cfg).unwrap();
            m.activate("train").unwrap();
            let mut b = batch(&st, 5..10);
            m.run(&mut b, &st).unwrap();
            assert!(b.has(attr::NEIGHBORS), "{kind:?}");
        }
    }
}
