//! Temporal neighbor sampling hooks (paper §5.1, Table 2).
//!
//! Two production samplers live here:
//!
//! * [`RecencySampler`] — TGM's fully vectorized recency sampler, backed by
//!   a per-node **circular buffer** laid out as structure-of-arrays for
//!   cache-friendly access. Sampling a seed costs `O(K)` regardless of node
//!   degree; the buffer is updated with the batch's edges *after* sampling,
//!   so neighborhoods never leak the events being predicted. This is the
//!   component the paper credits for its end-to-end speedups.
//! * [`UniformSampler`] — uniform draws from the full temporal
//!   neighborhood `N_t(s)` via the CSR [`TemporalAdjacency`] index.
//!
//! The DyGLib-style baseline with per-seed history copies is in
//! [`super::neighbor_naive`].
//!
//! ### Produced attributes
//!
//! For `S` seeds (`src` rows, then `dst` rows, then — when
//! `seed_negatives` — `negatives` rows):
//!
//! * `neighbors` `[S, K]` i32 — neighbor ids (0-padded),
//! * `neighbor_times` `[S, K]` f32 — **delta** times `t_seed − t_nbr ≥ 0`,
//! * `neighbor_mask` `[S, K]` f32 — 1 for valid entries,
//! * `neighbor_feats` `[S, K, D]` f32 — edge features (when enabled),
//! * the `*2` two-hop variants `[S, K, K2]` when `two_hop` is set.

use crate::error::{Result, TgmError};
use crate::graph::{AdjacencyCache, StorageSnapshot};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{Hook, HookContext, StatelessHook};
use crate::util::{Rng, Tensor, Timestamp};

/// Shared sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Neighbors per seed (K).
    pub num_neighbors: usize,
    /// Two-hop fan-out (K2); `None` disables the second hop.
    pub two_hop: Option<usize>,
    /// Also gather neighbor edge features.
    pub include_features: bool,
    /// Sample neighborhoods for the batch's negatives too (adds the
    /// `negatives` attribute to the hook's requirements).
    pub seed_negatives: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { num_neighbors: 10, two_hop: None, include_features: true, seed_negatives: true }
    }
}

/// Collect the seed (node, time) pairs of a batch in the canonical layout
/// `src rows ++ dst rows ++ negative rows`.
fn collect_seeds(
    batch: &MaterializedBatch,
    seed_negatives: bool,
) -> Result<(Vec<u32>, Vec<Timestamp>)> {
    let b = batch.num_edges();
    let mut nodes = Vec::with_capacity(b * 3);
    let mut times = Vec::with_capacity(b * 3);
    nodes.extend_from_slice(&batch.src);
    times.extend_from_slice(&batch.ts);
    nodes.extend_from_slice(&batch.dst);
    times.extend_from_slice(&batch.ts);
    if seed_negatives {
        let negs = batch.get(attr::NEGATIVES)?.as_i32()?;
        if negs.len() != b {
            return Err(TgmError::Hook(format!(
                "negatives length {} != batch size {b}",
                negs.len()
            )));
        }
        nodes.extend(negs.iter().map(|&n| n as u32));
        times.extend_from_slice(&batch.ts);
    }
    Ok((nodes, times))
}

/// Common output buffers for one sampling pass.
struct SampleOut {
    k: usize,
    ids: Vec<i32>,
    dts: Vec<f32>,
    mask: Vec<f32>,
    feats: Option<(usize, Vec<f32>)>,
    /// Absolute interaction times (needed to seed the second hop).
    abs_ts: Vec<Timestamp>,
    eidx: Vec<u32>,
}

impl SampleOut {
    fn new(s: usize, k: usize, feat_dim: Option<usize>) -> SampleOut {
        SampleOut {
            k,
            ids: vec![0; s * k],
            dts: vec![0.0; s * k],
            mask: vec![0.0; s * k],
            feats: feat_dim.map(|d| (d, vec![0.0; s * k * d])),
            abs_ts: vec![0; s * k],
            eidx: vec![0; s * k],
        }
    }

    #[inline]
    fn write(&mut self, row: usize, slot: usize, nbr: u32, nbr_t: Timestamp, seed_t: Timestamp, eidx: u32) {
        let o = row * self.k + slot;
        self.ids[o] = nbr as i32;
        self.dts[o] = (seed_t - nbr_t) as f32;
        self.mask[o] = 1.0;
        self.abs_ts[o] = nbr_t;
        self.eidx[o] = eidx;
    }

    fn gather_features(&mut self, storage: &StorageSnapshot) {
        if let Some((d, feats)) = &mut self.feats {
            let d = *d;
            for (o, (&m, &e)) in self.mask.iter().zip(&self.eidx).enumerate() {
                if m > 0.0 {
                    feats[o * d..(o + 1) * d].copy_from_slice(storage.edge_feat_row(e as usize));
                }
            }
        }
    }
}

fn produces_list(cfg: &SamplerConfig) -> Vec<&'static str> {
    let mut p = vec![attr::NEIGHBORS, attr::NEIGHBOR_TIMES, attr::NEIGHBOR_MASK];
    if cfg.include_features {
        p.push(attr::NEIGHBOR_FEATS);
    }
    if cfg.two_hop.is_some() {
        p.extend([attr::NEIGHBORS_2, attr::NEIGHBOR_TIMES_2, attr::NEIGHBOR_MASK_2]);
        if cfg.include_features {
            p.push(attr::NEIGHBOR_FEATS_2);
        }
    }
    p
}

fn requires_list(cfg: &SamplerConfig) -> Vec<&'static str> {
    if cfg.seed_negatives {
        vec![attr::NEGATIVES]
    } else {
        vec![]
    }
}

fn store_outputs(
    batch: &mut MaterializedBatch,
    s: usize,
    hop1: SampleOut,
    hop2: Option<SampleOut>,
) -> Result<()> {
    let k = hop1.k;
    batch.set(attr::NEIGHBORS, Tensor::i32(hop1.ids, &[s, k])?);
    batch.set(attr::NEIGHBOR_TIMES, Tensor::f32(hop1.dts, &[s, k])?);
    batch.set(attr::NEIGHBOR_MASK, Tensor::f32(hop1.mask, &[s, k])?);
    if let Some((d, f)) = hop1.feats {
        batch.set(attr::NEIGHBOR_FEATS, Tensor::f32(f, &[s, k, d])?);
    }
    if let Some(h2) = hop2 {
        let k2 = h2.k;
        batch.set(attr::NEIGHBORS_2, Tensor::i32(h2.ids, &[s, k, k2])?);
        batch.set(attr::NEIGHBOR_TIMES_2, Tensor::f32(h2.dts, &[s, k, k2])?);
        batch.set(attr::NEIGHBOR_MASK_2, Tensor::f32(h2.mask, &[s, k, k2])?);
        if let Some((d, f)) = h2.feats {
            batch.set(attr::NEIGHBOR_FEATS_2, Tensor::f32(f, &[s, k, k2, d])?);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Recency sampler (circular buffer)
// ---------------------------------------------------------------------

/// Per-node circular buffers in structure-of-arrays layout.
#[derive(Debug, Default)]
struct CircularBuffers {
    cap: usize,
    nbr: Vec<u32>,
    ts: Vec<Timestamp>,
    eidx: Vec<u32>,
    head: Vec<u32>,
    count: Vec<u32>,
}

impl CircularBuffers {
    fn ensure(&mut self, num_nodes: usize, cap: usize) {
        if self.nbr.len() != num_nodes * cap || self.cap != cap {
            self.cap = cap;
            self.nbr = vec![0; num_nodes * cap];
            self.ts = vec![0; num_nodes * cap];
            self.eidx = vec![0; num_nodes * cap];
            self.head = vec![0; num_nodes];
            self.count = vec![0; num_nodes];
        }
    }

    #[inline]
    fn push(&mut self, node: u32, nbr: u32, t: Timestamp, eidx: u32) {
        let n = node as usize;
        let pos = n * self.cap + self.head[n] as usize;
        self.nbr[pos] = nbr;
        self.ts[pos] = t;
        self.eidx[pos] = eidx;
        self.head[n] = (self.head[n] + 1) % self.cap as u32;
        self.count[n] = (self.count[n] + 1).min(self.cap as u32);
    }

    /// Visit up to `k` most-recent entries with `ts < t`, newest first.
    #[inline]
    fn sample_into(&self, node: u32, t: Timestamp, k: usize, mut f: impl FnMut(usize, u32, Timestamp, u32)) {
        let n = node as usize;
        let cnt = self.count[n] as usize;
        let base = n * self.cap;
        let mut slot = 0;
        for j in 0..cnt {
            if slot >= k {
                break;
            }
            let pos = base + (self.head[n] as usize + self.cap - 1 - j) % self.cap;
            if self.ts[pos] < t {
                f(slot, self.nbr[pos], self.ts[pos], self.eidx[pos]);
                slot += 1;
            }
        }
    }

    fn clear(&mut self) {
        self.head.iter_mut().for_each(|h| *h = 0);
        self.count.iter_mut().for_each(|c| *c = 0);
    }
}

/// TGM's vectorized recency sampler (circular buffer, `O(K)` per seed).
pub struct RecencySampler {
    cfg: SamplerConfig,
    buffers: CircularBuffers,
    /// Buffer capacity: keeps a margin above K so two-hop time filtering
    /// still finds enough strictly-earlier entries.
    cap: usize,
}

impl RecencySampler {
    /// Create with the given config.
    pub fn new(cfg: SamplerConfig) -> RecencySampler {
        let cap = (cfg.num_neighbors.max(cfg.two_hop.unwrap_or(0)) * 2).max(4);
        RecencySampler { cfg, buffers: CircularBuffers::default(), cap }
    }

    fn sample_all(
        &self,
        storage: &StorageSnapshot,
        nodes: &[u32],
        times: &[Timestamp],
    ) -> (SampleOut, Option<SampleOut>) {
        let s = nodes.len();
        let k = self.cfg.num_neighbors;
        let fd = self.cfg.include_features.then(|| storage.edge_feat_dim());
        let mut hop1 = SampleOut::new(s, k, fd);
        for (row, (&node, &t)) in nodes.iter().zip(times).enumerate() {
            self.buffers.sample_into(node, t, k, |slot, nbr, nbr_t, eidx| {
                hop1.write(row, slot, nbr, nbr_t, t, eidx);
            });
        }
        hop1.gather_features(storage);

        let hop2 = self.cfg.two_hop.map(|k2| {
            let mut h2 = SampleOut::new(s * k, k2, fd);
            for row in 0..s {
                for slot in 0..k {
                    let o = row * k + slot;
                    if hop1.mask[o] > 0.0 {
                        let (n1, t1) = (hop1.ids[o] as u32, hop1.abs_ts[o]);
                        self.buffers.sample_into(n1, t1, k2, |s2, nbr, nbr_t, eidx| {
                            h2.write(o, s2, nbr, nbr_t, t1, eidx);
                        });
                    }
                }
            }
            h2.gather_features(storage);
            h2
        });
        (hop1, hop2)
    }

    fn update(&mut self, batch: &MaterializedBatch) {
        for i in 0..batch.num_edges() {
            let (s, d, t, e) = (batch.src[i], batch.dst[i], batch.ts[i], batch.edge_indices[i]);
            self.buffers.push(s, d, t, e);
            self.buffers.push(d, s, t, e);
        }
    }
}

impl Hook for RecencySampler {
    fn name(&self) -> &'static str {
        "recency_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        requires_list(&self.cfg)
    }

    fn produces(&self) -> Vec<&'static str> {
        produces_list(&self.cfg)
    }

    fn apply(&mut self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        self.buffers.ensure(ctx.storage.num_nodes(), self.cap);
        let (nodes, times) = collect_seeds(batch, self.cfg.seed_negatives)?;
        // Sample from *past* state first, then absorb this batch's edges.
        let (hop1, hop2) = self.sample_all(ctx.storage, &nodes, &times);
        store_outputs(batch, nodes.len(), hop1, hop2)?;
        self.update(batch);
        Ok(())
    }

    fn reset(&mut self) {
        self.buffers.clear();
    }
}

// ---------------------------------------------------------------------
// Uniform sampler (CSR index)
// ---------------------------------------------------------------------

/// Uniform temporal-neighborhood sampler over the CSR adjacency index.
///
/// Stateless: the merged CSR index is a shared generation-keyed cache and
/// every batch draws from a fresh RNG seeded by `seed ^ ctx.batch_seed`,
/// so prefetch workers reproduce the serial stream regardless of
/// materialization order — and the draw sequence is identical whether the
/// snapshot holds one segment or many (the merged view preserves global
/// time order).
pub struct UniformSampler {
    cfg: SamplerConfig,
    adj: AdjacencyCache,
    seed: u64,
}

impl UniformSampler {
    /// Create with the given config and RNG seed.
    pub fn new(cfg: SamplerConfig, seed: u64) -> UniformSampler {
        UniformSampler { cfg, adj: AdjacencyCache::new(), seed }
    }
}

impl StatelessHook for UniformSampler {
    fn name(&self) -> &'static str {
        "uniform_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        requires_list(&self.cfg)
    }

    fn produces(&self) -> Vec<&'static str> {
        produces_list(&self.cfg)
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let adj = self.adj.get(ctx.storage);
        let mut rng = Rng::new(self.seed ^ ctx.batch_seed);
        let (nodes, times) = collect_seeds(batch, self.cfg.seed_negatives)?;
        let s = nodes.len();
        let k = self.cfg.num_neighbors;
        let fd = self.cfg.include_features.then(|| ctx.storage.edge_feat_dim());

        let mut hop1 = SampleOut::new(s, k, fd);
        for (row, (&node, &t)) in nodes.iter().zip(&times).enumerate() {
            let view = adj.neighbors_before(node, t);
            let avail = view.len();
            for slot in 0..k.min(avail) {
                let j = rng.below(avail as u64) as usize;
                let (nbr, nbr_t, eidx) = view.get(j);
                hop1.write(row, slot, nbr, nbr_t, t, eidx);
            }
        }
        hop1.gather_features(ctx.storage);

        let hop2 = self.cfg.two_hop.map(|k2| {
            let mut h2 = SampleOut::new(s * k, k2, fd);
            for o in 0..s * k {
                if hop1.mask[o] > 0.0 {
                    let (n1, t1) = (hop1.ids[o] as u32, hop1.abs_ts[o]);
                    let view = adj.neighbors_before(n1, t1);
                    let avail = view.len();
                    for slot in 0..k2.min(avail) {
                        let j = rng.below(avail as u64) as usize;
                        let (nbr, nbr_t, eidx) = view.get(j);
                        h2.write(o, slot, nbr, nbr_t, t1, eidx);
                    }
                }
            }
            h2.gather_features(ctx.storage);
            h2
        });
        store_outputs(batch, s, hop1, hop2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeEvent;
    use crate::hooks::batch::MaterializedBatch;

    fn storage() -> StorageSnapshot {
        let edges = (0..20)
            .map(|i| EdgeEvent {
                t: i as i64 * 10,
                src: (i % 4) as u32,
                dst: 4 + (i % 3) as u32,
                features: vec![i as f32, 1.0],
            })
            .collect();
        crate::graph::GraphStorage::from_events(edges, vec![], 7, None, None)
            .unwrap()
            .into_snapshot()
    }

    fn batch_from(storage: &StorageSnapshot, range: std::ops::Range<usize>) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(
            storage.edge_ts_at(range.start),
            storage.edge_ts_at(range.end - 1) + 1,
        );
        for i in range {
            b.src.push(storage.edge_src_at(i));
            b.dst.push(storage.edge_dst_at(i));
            b.ts.push(storage.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b
    }

    fn cfg() -> SamplerConfig {
        SamplerConfig { num_neighbors: 3, two_hop: None, include_features: true, seed_negatives: false }
    }

    #[test]
    fn recency_first_batch_has_no_neighbors() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 0..5);
        h.apply(&mut b, &ctx).unwrap();
        let mask = b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0), "no history before first batch");
    }

    #[test]
    fn recency_returns_most_recent_first() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        // Seed row 0 is src of edge 10 => node (10 % 4) = 2. Node 2's most
        // recent interaction before t=100 is edge 6 (t=60, dst 4+6%3=4).
        let ids = b2.get(attr::NEIGHBORS).unwrap().as_i32().unwrap();
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert_eq!(mask[0], 1.0);
        assert_eq!(ids[0], 4);
        // Delta times are non-negative and increasing along slots.
        let dts = b2.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap();
        assert!(dts[0] >= 0.0);
        let row0: Vec<f32> = dts[0..3].to_vec();
        let valid: Vec<f32> =
            row0.iter().zip(&mask[0..3]).filter(|(_, &m)| m > 0.0).map(|(d, _)| *d).collect();
        assert!(valid.windows(2).all(|w| w[0] <= w[1]), "newest-first deltas: {valid:?}");
    }

    #[test]
    fn recency_never_leaks_current_batch() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 0..20);
        h.apply(&mut b, &ctx).unwrap();
        // Single batch covering everything: all samples must be empty.
        let mask = b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn recency_reset_clears_history() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        h.reset();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0), "reset must clear buffers");
    }

    #[test]
    fn two_hop_shapes_and_masks() {
        let st = storage();
        let mut h = RecencySampler::new(SamplerConfig { two_hop: Some(2), ..cfg() });
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        let s = 10; // 5 src + 5 dst
        assert_eq!(b2.get(attr::NEIGHBORS_2).unwrap().shape(), &[s, 3, 2]);
        let m1 = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap().to_vec();
        let m2 = b2.get(attr::NEIGHBOR_MASK_2).unwrap().as_f32().unwrap().to_vec();
        // Hop-2 entries only exist under valid hop-1 entries.
        for (o, &m) in m1.iter().enumerate() {
            if m == 0.0 {
                assert!(m2[o * 2..(o + 1) * 2].iter().all(|&x| x == 0.0));
            }
        }
        // Hop-2 deltas are relative to the hop-1 interaction time (>= 0).
        let d2 = b2.get(attr::NEIGHBOR_TIMES_2).unwrap().as_f32().unwrap();
        assert!(d2.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn uniform_sampler_respects_time_and_determinism() {
        let st = storage();
        let ctx = HookContext::new(&st, "train");
        let run = |seed| {
            let h = UniformSampler::new(cfg(), seed);
            let mut b = batch_from(&st, 10..15);
            h.apply(&mut b, &ctx).unwrap();
            (
                b.get(attr::NEIGHBORS).unwrap().as_i32().unwrap().to_vec(),
                b.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap().to_vec(),
                b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap().to_vec(),
            )
        };
        let (ids_a, dts_a, mask_a) = run(5);
        let (ids_b, _, _) = run(5);
        assert_eq!(ids_a, ids_b, "same seed, same samples");
        // All sampled interactions are strictly in the past.
        for (i, &m) in mask_a.iter().enumerate() {
            if m > 0.0 {
                assert!(dts_a[i] > 0.0);
            }
        }
        // Uniform sampler sees full history (unlike first-batch recency).
        assert!(mask_a.iter().any(|&m| m > 0.0));
    }

    #[test]
    fn seed_negatives_layout() {
        let st = storage();
        let mut h = RecencySampler::new(SamplerConfig { seed_negatives: true, ..cfg() });
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 10..15);
        b.set(attr::NEGATIVES, Tensor::i32(vec![6; 5], &[5]).unwrap());
        // Warm the buffers first.
        let mut warm = batch_from(&st, 0..10);
        let mut h2 = RecencySampler::new(SamplerConfig { seed_negatives: true, ..cfg() });
        warm.set(attr::NEGATIVES, Tensor::i32(vec![6; 10], &[10]).unwrap());
        h2.apply(&mut warm, &ctx).unwrap();
        h2.apply(&mut b, &ctx).unwrap();
        assert_eq!(b.get(attr::NEIGHBORS).unwrap().shape(), &[15, 3]);
        drop(h);
    }

    #[test]
    fn feature_gather_matches_storage() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..12);
        h.apply(&mut b2, &ctx).unwrap();
        let feats = b2.get(attr::NEIGHBOR_FEATS).unwrap();
        assert_eq!(feats.shape(), &[4, 3, 2]);
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        let f = feats.as_f32().unwrap();
        // Valid entries carry real feature rows (feature[1] == 1.0 by
        // construction); padded entries are zero.
        for (o, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                assert_eq!(f[o * 2 + 1], 1.0);
            } else {
                assert_eq!(f[o * 2], 0.0);
            }
        }
    }
}
