//! Temporal neighbor sampling hooks (paper §5.1, Table 2).
//!
//! Two production samplers live here:
//!
//! * [`RecencySampler`] — TGM's fully vectorized recency sampler, backed by
//!   a per-node **circular buffer** laid out as structure-of-arrays for
//!   cache-friendly access. Sampling a seed costs `O(K)` regardless of node
//!   degree; the buffer is updated with the batch's edges *after* sampling,
//!   so neighborhoods never leak the events being predicted. This is the
//!   component the paper credits for its end-to-end speedups.
//! * [`UniformSampler`] — uniform draws from the full temporal
//!   neighborhood `N_t(s)` via the CSR [`TemporalAdjacency`] index.
//!
//! The DyGLib-style baseline with per-seed history copies is in
//! [`super::neighbor_naive`].
//!
//! ### Produced attributes
//!
//! For `S` seeds (`src` rows, then `dst` rows, then — when
//! `seed_negatives` — `negatives` rows):
//!
//! * `neighbors` `[S, K]` i32 — neighbor ids (0-padded),
//! * `neighbor_times` `[S, K]` f32 — **delta** times `t_seed − t_nbr ≥ 0`,
//! * `neighbor_mask` `[S, K]` f32 — 1 for valid entries,
//! * `neighbor_feats` `[S, K, D]` f32 — edge features (when enabled),
//! * the `*2` two-hop variants `[S, K, K2]` when `two_hop` is set.

use crate::error::{Result, TgmError};
use crate::graph::{AdjacencyCache, NeighborCols, StorageSnapshot};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{Hook, HookContext, StatelessHook};
use crate::kernels;
use crate::util::{Rng, Tensor, Timestamp};

/// Shared sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Neighbors per seed (K).
    pub num_neighbors: usize,
    /// Two-hop fan-out (K2); `None` disables the second hop.
    pub two_hop: Option<usize>,
    /// Also gather neighbor edge features.
    pub include_features: bool,
    /// Sample neighborhoods for the batch's negatives too (adds the
    /// `negatives` attribute to the hook's requirements).
    pub seed_negatives: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { num_neighbors: 10, two_hop: None, include_features: true, seed_negatives: true }
    }
}

/// Collect the seed (node, time) pairs of a batch in the canonical layout
/// `src rows ++ dst rows ++ negative rows`.
fn collect_seeds(
    batch: &MaterializedBatch,
    seed_negatives: bool,
) -> Result<(Vec<u32>, Vec<Timestamp>)> {
    let mut nodes = Vec::new();
    let mut times = Vec::new();
    collect_seeds_into(batch, seed_negatives, &mut nodes, &mut times)?;
    Ok((nodes, times))
}

/// [`collect_seeds`] into caller-owned scratch (cleared first, capacity
/// retained across batches — the stateful sampler reuses one pair for
/// its whole stream).
fn collect_seeds_into(
    batch: &MaterializedBatch,
    seed_negatives: bool,
    nodes: &mut Vec<u32>,
    times: &mut Vec<Timestamp>,
) -> Result<()> {
    let b = batch.num_edges();
    nodes.clear();
    times.clear();
    nodes.reserve(b * 3);
    times.reserve(b * 3);
    nodes.extend_from_slice(&batch.src);
    times.extend_from_slice(&batch.ts);
    nodes.extend_from_slice(&batch.dst);
    times.extend_from_slice(&batch.ts);
    if seed_negatives {
        let negs = batch.get(attr::NEGATIVES)?.as_i32()?;
        if negs.len() != b {
            return Err(TgmError::Hook(format!(
                "negatives length {} != batch size {b}",
                negs.len()
            )));
        }
        nodes.extend(negs.iter().map(|&n| n as u32));
        times.extend_from_slice(&batch.ts);
    }
    Ok(())
}

/// Common output buffers for one sampling pass.
struct SampleOut {
    k: usize,
    ids: Vec<i32>,
    dts: Vec<f32>,
    mask: Vec<f32>,
    feats: Option<(usize, Vec<f32>)>,
    /// Absolute interaction times (needed to seed the second hop).
    abs_ts: Vec<Timestamp>,
    eidx: Vec<u32>,
}

impl SampleOut {
    fn new(s: usize, k: usize, feat_dim: Option<usize>) -> SampleOut {
        SampleOut {
            k,
            ids: vec![0; s * k],
            dts: vec![0.0; s * k],
            mask: vec![0.0; s * k],
            feats: feat_dim.map(|d| (d, vec![0.0; s * k * d])),
            abs_ts: vec![0; s * k],
            eidx: vec![0; s * k],
        }
    }

    #[inline]
    fn write(&mut self, row: usize, slot: usize, nbr: u32, nbr_t: Timestamp, seed_t: Timestamp, eidx: u32) {
        let o = row * self.k + slot;
        self.ids[o] = nbr as i32;
        self.dts[o] = (seed_t - nbr_t) as f32;
        self.mask[o] = 1.0;
        self.abs_ts[o] = nbr_t;
        self.eidx[o] = eidx;
    }

    fn gather_features(&mut self, storage: &StorageSnapshot) {
        if let Some((_, feats)) = &mut self.feats {
            // One batched masked SIMD gather over the whole arena
            // (single kernel call on single-segment snapshots).
            storage.gather_edge_feat_rows(&self.eidx, &self.mask, feats);
        }
    }
}

fn produces_list(cfg: &SamplerConfig) -> Vec<&'static str> {
    let mut p = vec![attr::NEIGHBORS, attr::NEIGHBOR_TIMES, attr::NEIGHBOR_MASK];
    if cfg.include_features {
        p.push(attr::NEIGHBOR_FEATS);
    }
    if cfg.two_hop.is_some() {
        p.extend([attr::NEIGHBORS_2, attr::NEIGHBOR_TIMES_2, attr::NEIGHBOR_MASK_2]);
        if cfg.include_features {
            p.push(attr::NEIGHBOR_FEATS_2);
        }
    }
    p
}

fn requires_list(cfg: &SamplerConfig) -> Vec<&'static str> {
    if cfg.seed_negatives {
        vec![attr::NEGATIVES]
    } else {
        vec![]
    }
}

fn store_outputs(
    batch: &mut MaterializedBatch,
    s: usize,
    hop1: SampleOut,
    hop2: Option<SampleOut>,
) -> Result<()> {
    let k = hop1.k;
    batch.set(attr::NEIGHBORS, Tensor::i32(hop1.ids, &[s, k])?);
    batch.set(attr::NEIGHBOR_TIMES, Tensor::f32(hop1.dts, &[s, k])?);
    batch.set(attr::NEIGHBOR_MASK, Tensor::f32(hop1.mask, &[s, k])?);
    if let Some((d, f)) = hop1.feats {
        batch.set(attr::NEIGHBOR_FEATS, Tensor::f32(f, &[s, k, d])?);
    }
    if let Some(h2) = hop2 {
        let k2 = h2.k;
        batch.set(attr::NEIGHBORS_2, Tensor::i32(h2.ids, &[s, k, k2])?);
        batch.set(attr::NEIGHBOR_TIMES_2, Tensor::f32(h2.dts, &[s, k, k2])?);
        batch.set(attr::NEIGHBOR_MASK_2, Tensor::f32(h2.mask, &[s, k, k2])?);
        if let Some((d, f)) = h2.feats {
            batch.set(attr::NEIGHBOR_FEATS_2, Tensor::f32(f, &[s, k, k2, d])?);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Recency sampler (circular buffer)
// ---------------------------------------------------------------------

/// Per-node circular buffers in structure-of-arrays layout. Capacity is
/// always a power of two so every ring step is an AND mask instead of
/// an integer division — the division sat on both the push and the
/// sample inner loops.
#[derive(Debug, Default)]
struct CircularBuffers {
    cap: usize,
    nbr: Vec<u32>,
    ts: Vec<Timestamp>,
    eidx: Vec<u32>,
    head: Vec<u32>,
    count: Vec<u32>,
}

impl CircularBuffers {
    /// `cap` must be a power of two (callers round up via
    /// [`usize::next_power_of_two`]).
    fn ensure(&mut self, num_nodes: usize, cap: usize) {
        debug_assert!(cap.is_power_of_two());
        if self.nbr.len() != num_nodes * cap || self.cap != cap {
            self.cap = cap;
            self.nbr = vec![0; num_nodes * cap];
            self.ts = vec![0; num_nodes * cap];
            self.eidx = vec![0; num_nodes * cap];
            self.head = vec![0; num_nodes];
            self.count = vec![0; num_nodes];
        }
    }

    #[inline]
    fn push(&mut self, node: u32, nbr: u32, t: Timestamp, eidx: u32) {
        let n = node as usize;
        let pos = n * self.cap + self.head[n] as usize;
        self.nbr[pos] = nbr;
        self.ts[pos] = t;
        self.eidx[pos] = eidx;
        self.head[n] = (self.head[n] + 1) & (self.cap as u32 - 1);
        self.count[n] = (self.count[n] + 1).min(self.cap as u32);
    }

    /// Visit up to `k` most-recent entries with `ts < t`, newest first.
    #[inline]
    fn sample_into(
        &self,
        node: u32,
        t: Timestamp,
        k: usize,
        mut f: impl FnMut(usize, u32, Timestamp, u32),
    ) {
        let n = node as usize;
        let cnt = self.count[n] as usize;
        let base = n * self.cap;
        let mask = self.cap - 1;
        let newest = self.head[n] as usize + self.cap - 1;
        let mut slot = 0;
        for j in 0..cnt {
            if slot >= k {
                break;
            }
            let pos = base + ((newest - j) & mask);
            if self.ts[pos] < t {
                f(slot, self.nbr[pos], self.ts[pos], self.eidx[pos]);
                slot += 1;
            }
        }
    }

    fn clear(&mut self) {
        self.head.iter_mut().for_each(|h| *h = 0);
        self.count.iter_mut().for_each(|c| *c = 0);
    }
}

/// [`CircularBuffers`] sharded by `node_id % S`: shard `s` owns every
/// node with `node % S == s`, stored under local index `node / S`.
///
/// Sharding exists so the stateful consumer-phase `update` can absorb a
/// batch's edges with one thread per shard: a node's ring lives in
/// exactly one shard, every shard scans the batch in edge order
/// (src-endpoint before dst-endpoint within an edge), so each ring sees
/// exactly the push sequence the serial walk would apply — the final
/// state is byte-identical to serial, regardless of shard count or
/// whether the parallel path engaged (pinned by the determinism tests).
#[derive(Debug, Default)]
struct ShardedBuffers {
    shards: Vec<CircularBuffers>,
}

impl ShardedBuffers {
    fn ensure(&mut self, num_nodes: usize, cap: usize, num_shards: usize) {
        let cap = cap.next_power_of_two();
        if self.shards.len() != num_shards {
            self.shards = (0..num_shards).map(|_| CircularBuffers::default()).collect();
        }
        let per_shard = num_nodes.div_ceil(num_shards);
        for shard in &mut self.shards {
            shard.ensure(per_shard, cap);
        }
    }

    #[inline]
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn sample_into(
        &self,
        node: u32,
        t: Timestamp,
        k: usize,
        f: impl FnMut(usize, u32, Timestamp, u32),
    ) {
        let s = self.shards.len();
        if s == 1 {
            self.shards[0].sample_into(node, t, k, f);
        } else {
            self.shards[node as usize % s].sample_into(node / s as u32, t, k, f);
        }
    }

    #[inline]
    fn push(&mut self, node: u32, nbr: u32, t: Timestamp, eidx: u32) {
        let s = self.shards.len();
        if s == 1 {
            self.shards[0].push(node, nbr, t, eidx);
        } else {
            self.shards[node as usize % s].push(node / s as u32, nbr, t, eidx);
        }
    }

    fn clear(&mut self) {
        for shard in &mut self.shards {
            shard.clear();
        }
    }
}

/// Default threshold (in work items: seeds for sampling, endpoint
/// pushes for updates) below which the sampler stays serial — scoped
/// thread spawns cost more than they save on small batches. The output
/// is byte-identical either way; the threshold only moves the cutover.
const PARALLEL_THRESHOLD: usize = 4096;

fn default_shards() -> usize {
    if let Ok(v) = std::env::var("TGM_SAMPLER_SHARDS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// TGM's vectorized recency sampler (circular buffer, `O(K)` per seed).
///
/// The per-node rings are sharded by `node_id % S` ([`ShardedBuffers`]);
/// large batches run both the read phase (seed sampling, disjoint
/// row-chunks of the output arenas) and the stateful consumer update
/// (one thread per shard) in parallel, byte-identical to the serial
/// walk. `S` defaults to the machine's available parallelism (capped at
/// 8) and can be forced with `TGM_SAMPLER_SHARDS` or
/// [`RecencySampler::with_shards`]; `TGM_SAMPLER_SHARDS=1` restores the
/// fully serial sampler.
pub struct RecencySampler {
    cfg: SamplerConfig,
    buffers: ShardedBuffers,
    /// Buffer capacity: keeps a margin above K so two-hop time filtering
    /// still finds enough strictly-earlier entries. Rounded up to a
    /// power of two by the ring allocator.
    cap: usize,
    shards: usize,
    parallel_threshold: usize,
    /// Reused seed scratch (cleared per batch, capacity retained).
    seed_nodes: Vec<u32>,
    seed_times: Vec<Timestamp>,
}

impl RecencySampler {
    /// Create with the given config.
    pub fn new(cfg: SamplerConfig) -> RecencySampler {
        let cap = (cfg.num_neighbors.max(cfg.two_hop.unwrap_or(0)) * 2).max(4);
        RecencySampler {
            cfg,
            buffers: ShardedBuffers::default(),
            cap,
            shards: default_shards(),
            parallel_threshold: PARALLEL_THRESHOLD,
            seed_nodes: Vec::new(),
            seed_times: Vec::new(),
        }
    }

    /// Override the shard count (1 = fully serial). Must be called
    /// before the first batch (the rings are laid out per shard).
    pub fn with_shards(mut self, shards: usize) -> RecencySampler {
        self.shards = shards.max(1);
        self.buffers = ShardedBuffers::default();
        self
    }

    /// Override the work-item threshold below which batches are
    /// processed serially (0 forces the parallel path; outputs are
    /// byte-identical at any setting).
    pub fn with_parallel_threshold(mut self, threshold: usize) -> RecencySampler {
        self.parallel_threshold = threshold;
        self
    }

    /// Sample one row-chunk of seeds into row-aligned output slices.
    #[allow(clippy::too_many_arguments)]
    fn sample_chunk(
        buffers: &ShardedBuffers,
        k: usize,
        nodes: &[u32],
        times: &[Timestamp],
        ids: &mut [i32],
        dts: &mut [f32],
        mask: &mut [f32],
        abs_ts: &mut [Timestamp],
        eidx: &mut [u32],
    ) {
        for (row, (&node, &t)) in nodes.iter().zip(times).enumerate() {
            buffers.sample_into(node, t, k, |slot, nbr, nbr_t, ei| {
                let o = row * k + slot;
                ids[o] = nbr as i32;
                dts[o] = (t - nbr_t) as f32;
                mask[o] = 1.0;
                abs_ts[o] = nbr_t;
                eidx[o] = ei;
            });
        }
    }

    /// Sample every seed into `out`, splitting the rows across scoped
    /// threads when the batch is large enough. Each thread owns a
    /// disjoint row range of every output arena, so the bytes written
    /// are identical to the serial single-chunk walk.
    fn sample_rows(&self, nodes: &[u32], times: &[Timestamp], k: usize, out: &mut SampleOut) {
        let s = nodes.len();
        if k == 0 {
            return;
        }
        let workers = if self.shards <= 1 || s < self.parallel_threshold.max(1) {
            1
        } else {
            self.shards.min(s)
        };
        if workers <= 1 {
            Self::sample_chunk(
                &self.buffers,
                k,
                nodes,
                times,
                &mut out.ids,
                &mut out.dts,
                &mut out.mask,
                &mut out.abs_ts,
                &mut out.eidx,
            );
            return;
        }
        let rows_per = s.div_ceil(workers);
        let elems = rows_per * k;
        let buffers = &self.buffers;
        std::thread::scope(|scope| {
            let chunks = nodes
                .chunks(rows_per)
                .zip(times.chunks(rows_per))
                .zip(out.ids.chunks_mut(elems))
                .zip(out.dts.chunks_mut(elems))
                .zip(out.mask.chunks_mut(elems))
                .zip(out.abs_ts.chunks_mut(elems))
                .zip(out.eidx.chunks_mut(elems));
            for ((((((ns, ts), ids), dts), mask), abs_ts), eidx) in chunks {
                scope.spawn(move || {
                    Self::sample_chunk(buffers, k, ns, ts, ids, dts, mask, abs_ts, eidx);
                });
            }
        });
    }

    fn sample_all(
        &self,
        storage: &StorageSnapshot,
        nodes: &[u32],
        times: &[Timestamp],
    ) -> (SampleOut, Option<SampleOut>) {
        let s = nodes.len();
        let k = self.cfg.num_neighbors;
        let fd = self.cfg.include_features.then(|| storage.edge_feat_dim());
        let mut hop1 = SampleOut::new(s, k, fd);
        self.sample_rows(nodes, times, k, &mut hop1);
        hop1.gather_features(storage);

        let hop2 = self.cfg.two_hop.map(|k2| {
            // Hop 2 is hop 1 re-run on synthesized seeds: every hop-1
            // slot becomes a row seeded at its interaction time; empty
            // slots get `i64::MIN`, which matches nothing (strict `<`),
            // so they stay fully masked exactly like the old skip.
            let nodes2: Vec<u32> = hop1.ids.iter().map(|&i| i as u32).collect();
            let times2: Vec<Timestamp> = hop1
                .mask
                .iter()
                .zip(&hop1.abs_ts)
                .map(|(&m, &t)| if m > 0.0 { t } else { Timestamp::MIN })
                .collect();
            let mut h2 = SampleOut::new(s * k, k2, fd);
            self.sample_rows(&nodes2, &times2, k2, &mut h2);
            h2.gather_features(storage);
            h2
        });
        (hop1, hop2)
    }

    /// Absorb the batch's edges into the rings (stateful consumer
    /// phase). One thread per shard when the batch is large enough:
    /// every shard scans the edges in order and keeps only its own
    /// endpoints, so each ring receives exactly the serial push
    /// sequence.
    fn update(&mut self, batch: &MaterializedBatch) {
        let e = batch.num_edges();
        let num = self.buffers.num_shards();
        if num <= 1 || e * 2 < self.parallel_threshold.max(1) {
            for i in 0..e {
                let (s, d, t, ei) =
                    (batch.src[i], batch.dst[i], batch.ts[i], batch.edge_indices[i]);
                self.buffers.push(s, d, t, ei);
                self.buffers.push(d, s, t, ei);
            }
            return;
        }
        let (src, dst, ts, eidx) = (&batch.src, &batch.dst, &batch.ts, &batch.edge_indices);
        std::thread::scope(|scope| {
            for (sid, shard) in self.buffers.shards.iter_mut().enumerate() {
                scope.spawn(move || {
                    for i in 0..e {
                        if src[i] as usize % num == sid {
                            shard.push(src[i] / num as u32, dst[i], ts[i], eidx[i]);
                        }
                        if dst[i] as usize % num == sid {
                            shard.push(dst[i] / num as u32, src[i], ts[i], eidx[i]);
                        }
                    }
                });
            }
        });
    }
}

impl Hook for RecencySampler {
    fn name(&self) -> &'static str {
        "recency_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        requires_list(&self.cfg)
    }

    fn produces(&self) -> Vec<&'static str> {
        produces_list(&self.cfg)
    }

    fn apply(&mut self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        self.buffers.ensure(ctx.storage.num_nodes(), self.cap, self.shards);
        let mut nodes = std::mem::take(&mut self.seed_nodes);
        let mut times = std::mem::take(&mut self.seed_times);
        collect_seeds_into(batch, self.cfg.seed_negatives, &mut nodes, &mut times)?;
        // Sample from *past* state first, then absorb this batch's edges.
        let (hop1, hop2) = self.sample_all(ctx.storage, &nodes, &times);
        store_outputs(batch, nodes.len(), hop1, hop2)?;
        self.update(batch);
        self.seed_nodes = nodes;
        self.seed_times = times;
        Ok(())
    }

    fn reset(&mut self) {
        self.buffers.clear();
    }
}

// ---------------------------------------------------------------------
// Uniform sampler (CSR index)
// ---------------------------------------------------------------------

/// Uniform temporal-neighborhood sampler over the CSR adjacency index.
///
/// Stateless: the merged CSR index is a shared generation-keyed cache and
/// every batch draws from a fresh RNG seeded by `seed ^ ctx.batch_seed`,
/// so prefetch workers reproduce the serial stream regardless of
/// materialization order — and the draw sequence is identical whether the
/// snapshot holds one segment or many (the merged view preserves global
/// time order).
pub struct UniformSampler {
    cfg: SamplerConfig,
    adj: AdjacencyCache,
    seed: u64,
}

impl UniformSampler {
    /// Create with the given config and RNG seed.
    pub fn new(cfg: SamplerConfig, seed: u64) -> UniformSampler {
        UniformSampler { cfg, adj: AdjacencyCache::new(), seed }
    }
}

impl StatelessHook for UniformSampler {
    fn name(&self) -> &'static str {
        "uniform_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        requires_list(&self.cfg)
    }

    fn produces(&self) -> Vec<&'static str> {
        produces_list(&self.cfg)
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        let adj = self.adj.get(ctx.storage);
        let mut rng = Rng::new(self.seed ^ ctx.batch_seed);
        let (nodes, times) = collect_seeds(batch, self.cfg.seed_negatives)?;
        let s = nodes.len();
        let k = self.cfg.num_neighbors;
        let k2max = self.cfg.two_hop.unwrap_or(0);
        let fd = self.cfg.include_features.then(|| ctx.storage.edge_feat_dim());

        // Per-seed scratch: random draw indices plus the gathered
        // columns, reused across seeds (and a NeighborCols scratch for
        // multi-part views). Draw order matches the old per-slot
        // `view.get` walk exactly, so the RNG stream — and therefore
        // the output — is unchanged.
        let kmax = k.max(k2max);
        let mut js: Vec<u32> = Vec::with_capacity(kmax);
        let mut g_nbr = vec![0u32; kmax];
        let mut g_ts = vec![0i64; kmax];
        let mut g_eidx = vec![0u32; kmax];
        let mut cols = NeighborCols::new();

        let mut hop1 = SampleOut::new(s, k, fd);
        for (row, (&node, &t)) in nodes.iter().zip(&times).enumerate() {
            let view = adj.neighbors_before(node, t);
            let avail = view.len();
            let take = k.min(avail);
            if take == 0 {
                continue;
            }
            js.clear();
            for _ in 0..take {
                js.push(rng.below(avail as u64) as u32);
            }
            let (ns, tss, es, base) = match view.single_part() {
                Some(p) => p,
                None => {
                    view.collect_into(&mut cols);
                    (&cols.nbr[..], &cols.ts[..], &cols.eidx[..], 0u32)
                }
            };
            kernels::gather_u32(ns, &js, &mut g_nbr[..take]);
            kernels::gather_i64(tss, &js, &mut g_ts[..take]);
            kernels::gather_u32(es, &js, &mut g_eidx[..take]);
            for slot in 0..take {
                hop1.write(row, slot, g_nbr[slot], g_ts[slot], t, g_eidx[slot] + base);
            }
        }
        hop1.gather_features(ctx.storage);

        let hop2 = self.cfg.two_hop.map(|k2| {
            let mut h2 = SampleOut::new(s * k, k2, fd);
            for o in 0..s * k {
                if hop1.mask[o] > 0.0 {
                    let (n1, t1) = (hop1.ids[o] as u32, hop1.abs_ts[o]);
                    let view = adj.neighbors_before(n1, t1);
                    let avail = view.len();
                    let take = k2.min(avail);
                    if take == 0 {
                        continue;
                    }
                    js.clear();
                    for _ in 0..take {
                        js.push(rng.below(avail as u64) as u32);
                    }
                    let (ns, tss, es, base) = match view.single_part() {
                        Some(p) => p,
                        None => {
                            view.collect_into(&mut cols);
                            (&cols.nbr[..], &cols.ts[..], &cols.eidx[..], 0u32)
                        }
                    };
                    kernels::gather_u32(ns, &js, &mut g_nbr[..take]);
                    kernels::gather_i64(tss, &js, &mut g_ts[..take]);
                    kernels::gather_u32(es, &js, &mut g_eidx[..take]);
                    for slot in 0..take {
                        h2.write(o, slot, g_nbr[slot], g_ts[slot], t1, g_eidx[slot] + base);
                    }
                }
            }
            h2.gather_features(ctx.storage);
            h2
        });
        store_outputs(batch, s, hop1, hop2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeEvent;
    use crate::hooks::batch::MaterializedBatch;

    fn storage() -> StorageSnapshot {
        let edges = (0..20)
            .map(|i| EdgeEvent {
                t: i as i64 * 10,
                src: (i % 4) as u32,
                dst: 4 + (i % 3) as u32,
                features: vec![i as f32, 1.0],
            })
            .collect();
        crate::graph::GraphStorage::from_events(edges, vec![], 7, None, None)
            .unwrap()
            .into_snapshot()
    }

    fn batch_from(storage: &StorageSnapshot, range: std::ops::Range<usize>) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(
            storage.edge_ts_at(range.start),
            storage.edge_ts_at(range.end - 1) + 1,
        );
        for i in range {
            b.src.push(storage.edge_src_at(i));
            b.dst.push(storage.edge_dst_at(i));
            b.ts.push(storage.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b
    }

    fn cfg() -> SamplerConfig {
        SamplerConfig { num_neighbors: 3, two_hop: None, include_features: true, seed_negatives: false }
    }

    #[test]
    fn recency_first_batch_has_no_neighbors() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 0..5);
        h.apply(&mut b, &ctx).unwrap();
        let mask = b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0), "no history before first batch");
    }

    #[test]
    fn recency_returns_most_recent_first() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        // Seed row 0 is src of edge 10 => node (10 % 4) = 2. Node 2's most
        // recent interaction before t=100 is edge 6 (t=60, dst 4+6%3=4).
        let ids = b2.get(attr::NEIGHBORS).unwrap().as_i32().unwrap();
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert_eq!(mask[0], 1.0);
        assert_eq!(ids[0], 4);
        // Delta times are non-negative and increasing along slots.
        let dts = b2.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap();
        assert!(dts[0] >= 0.0);
        let row0: Vec<f32> = dts[0..3].to_vec();
        let valid: Vec<f32> =
            row0.iter().zip(&mask[0..3]).filter(|(_, &m)| m > 0.0).map(|(d, _)| *d).collect();
        assert!(valid.windows(2).all(|w| w[0] <= w[1]), "newest-first deltas: {valid:?}");
    }

    #[test]
    fn recency_never_leaks_current_batch() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 0..20);
        h.apply(&mut b, &ctx).unwrap();
        // Single batch covering everything: all samples must be empty.
        let mask = b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn recency_reset_clears_history() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        h.reset();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().all(|&m| m == 0.0), "reset must clear buffers");
    }

    #[test]
    fn two_hop_shapes_and_masks() {
        let st = storage();
        let mut h = RecencySampler::new(SamplerConfig { two_hop: Some(2), ..cfg() });
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..15);
        h.apply(&mut b2, &ctx).unwrap();
        let s = 10; // 5 src + 5 dst
        assert_eq!(b2.get(attr::NEIGHBORS_2).unwrap().shape(), &[s, 3, 2]);
        let m1 = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap().to_vec();
        let m2 = b2.get(attr::NEIGHBOR_MASK_2).unwrap().as_f32().unwrap().to_vec();
        // Hop-2 entries only exist under valid hop-1 entries.
        for (o, &m) in m1.iter().enumerate() {
            if m == 0.0 {
                assert!(m2[o * 2..(o + 1) * 2].iter().all(|&x| x == 0.0));
            }
        }
        // Hop-2 deltas are relative to the hop-1 interaction time (>= 0).
        let d2 = b2.get(attr::NEIGHBOR_TIMES_2).unwrap().as_f32().unwrap();
        assert!(d2.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn uniform_sampler_respects_time_and_determinism() {
        let st = storage();
        let ctx = HookContext::new(&st, "train");
        let run = |seed| {
            let h = UniformSampler::new(cfg(), seed);
            let mut b = batch_from(&st, 10..15);
            h.apply(&mut b, &ctx).unwrap();
            (
                b.get(attr::NEIGHBORS).unwrap().as_i32().unwrap().to_vec(),
                b.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap().to_vec(),
                b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap().to_vec(),
            )
        };
        let (ids_a, dts_a, mask_a) = run(5);
        let (ids_b, _, _) = run(5);
        assert_eq!(ids_a, ids_b, "same seed, same samples");
        // All sampled interactions are strictly in the past.
        for (i, &m) in mask_a.iter().enumerate() {
            if m > 0.0 {
                assert!(dts_a[i] > 0.0);
            }
        }
        // Uniform sampler sees full history (unlike first-batch recency).
        assert!(mask_a.iter().any(|&m| m > 0.0));
    }

    #[test]
    fn seed_negatives_layout() {
        let st = storage();
        let mut h = RecencySampler::new(SamplerConfig { seed_negatives: true, ..cfg() });
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 10..15);
        b.set(attr::NEGATIVES, Tensor::i32(vec![6; 5], &[5]).unwrap());
        // Warm the buffers first.
        let mut warm = batch_from(&st, 0..10);
        let mut h2 = RecencySampler::new(SamplerConfig { seed_negatives: true, ..cfg() });
        warm.set(attr::NEGATIVES, Tensor::i32(vec![6; 10], &[10]).unwrap());
        h2.apply(&mut warm, &ctx).unwrap();
        h2.apply(&mut b, &ctx).unwrap();
        assert_eq!(b.get(attr::NEIGHBORS).unwrap().shape(), &[15, 3]);
        drop(h);
    }

    /// Run a full batch stream through a sampler and collect every
    /// produced tensor of every batch, flattened for byte comparison.
    fn stream_outputs(
        st: &StorageSnapshot,
        mut h: RecencySampler,
        keys: &[&str],
    ) -> Vec<(Vec<i32>, Vec<u32>)> {
        let ctx = HookContext::new(st, "train");
        let mut out = Vec::new();
        for (lo, hi) in [(0usize, 6), (6, 11), (11, 16), (16, 20)] {
            let mut b = batch_from(st, lo..hi);
            h.apply(&mut b, &ctx).unwrap();
            for &key in keys {
                let t = b.get(key).unwrap();
                let ints = t.as_i32().map(|v| v.to_vec()).unwrap_or_else(|_| {
                    t.as_f32().unwrap().iter().map(|&f| f.to_bits() as i32).collect()
                });
                out.push((ints, t.shape().iter().map(|&d| d as u32).collect()));
            }
        }
        out
    }

    /// The tentpole determinism pin: sharded rings (1/2/4 shards) and
    /// the forced-parallel update/sample paths must produce outputs
    /// byte-identical to the serial single-shard baseline.
    #[test]
    fn sharded_sampler_is_byte_identical_to_serial() {
        let st = storage();
        let cfg = SamplerConfig { two_hop: Some(2), ..cfg() };
        let keys = [
            attr::NEIGHBORS,
            attr::NEIGHBOR_TIMES,
            attr::NEIGHBOR_MASK,
            attr::NEIGHBOR_FEATS,
            attr::NEIGHBORS_2,
            attr::NEIGHBOR_TIMES_2,
            attr::NEIGHBOR_MASK_2,
            attr::NEIGHBOR_FEATS_2,
        ];
        let serial = stream_outputs(&st, RecencySampler::new(cfg.clone()).with_shards(1), &keys);
        for shards in [1usize, 2, 4] {
            // Threshold 0 forces the scoped-thread paths even on these
            // tiny batches; usize::MAX forces the serial paths.
            for threshold in [0usize, usize::MAX] {
                let h = RecencySampler::new(cfg.clone())
                    .with_shards(shards)
                    .with_parallel_threshold(threshold);
                let got = stream_outputs(&st, h, &keys);
                assert_eq!(
                    got, serial,
                    "shards={shards} threshold={threshold} diverged from serial"
                );
            }
        }
    }

    #[test]
    fn env_shard_default_is_sane() {
        // Whatever the machine, the default must be at least one shard.
        let h = RecencySampler::new(cfg());
        assert!(h.shards >= 1);
        assert!(h.buffers.num_shards() == 0, "rings are laid out lazily");
    }

    #[test]
    fn feature_gather_matches_storage() {
        let st = storage();
        let mut h = RecencySampler::new(cfg());
        let ctx = HookContext::new(&st, "train");
        let mut b1 = batch_from(&st, 0..10);
        h.apply(&mut b1, &ctx).unwrap();
        let mut b2 = batch_from(&st, 10..12);
        h.apply(&mut b2, &ctx).unwrap();
        let feats = b2.get(attr::NEIGHBOR_FEATS).unwrap();
        assert_eq!(feats.shape(), &[4, 3, 2]);
        let mask = b2.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        let f = feats.as_f32().unwrap();
        // Valid entries carry real feature rows (feature[1] == 1.0 by
        // construction); padded entries are zero.
        for (o, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                assert_eq!(f[o * 2 + 1], 1.0);
            } else {
                assert_eq!(f[o * 2], 0.0);
            }
        }
    }
}
