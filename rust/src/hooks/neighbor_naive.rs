//! DyGLib-style baseline sampler (comparator for Tables 3/9).
//!
//! Mirrors the access pattern of DyGLib's `NeighborSampler.
//! get_historical_neighbors`: for every seed it *copies* the node's full
//! interaction history into freshly allocated arrays, then slices the most
//! recent K entries. The copies are what NumPy fancy-indexing does in the
//! original; the per-seed allocation and `O(deg)` traffic — versus the
//! recency buffer's `O(K)` — are exactly the costs TGM's vectorized
//! sampler removes, so this baseline is kept as a first-class comparator.
//!
//! Contract (requires/produces) is identical to
//! [`super::neighbor::RecencySampler`].

use crate::error::Result;
use crate::graph::{AdjacencyCache, MergedAdjacency};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::hook::{HookContext, StatelessHook};
use crate::hooks::neighbor::SamplerConfig;
use crate::util::{Tensor, Timestamp};

/// Per-seed history-copy sampler (the DyGLib pattern). Stateless: the
/// retrieval is a pure function of the batch and the shared CSR index,
/// so it runs on any prefetch worker.
pub struct NaiveSampler {
    cfg: SamplerConfig,
    adj: AdjacencyCache,
}

impl NaiveSampler {
    /// Create with the given config.
    pub fn new(cfg: SamplerConfig) -> NaiveSampler {
        NaiveSampler { cfg, adj: AdjacencyCache::new() }
    }

    /// DyGLib-style retrieval: copy the full pre-`t` history, then take
    /// the last K entries (newest first).
    fn recent_copy(
        adj: &MergedAdjacency,
        node: u32,
        t: Timestamp,
        k: usize,
    ) -> (Vec<u32>, Vec<Timestamp>, Vec<u32>) {
        // Deliberate full-history copies (the NumPy slicing cost).
        let (nbrs, ts, eidx) = adj.neighbors_before(node, t).to_vecs();
        let n = nbrs.len();
        let take = k.min(n);
        let mut out_n = Vec::with_capacity(take);
        let mut out_t = Vec::with_capacity(take);
        let mut out_e = Vec::with_capacity(take);
        for j in 0..take {
            let i = n - 1 - j;
            out_n.push(nbrs[i]);
            out_t.push(ts[i]);
            out_e.push(eidx[i]);
        }
        (out_n, out_t, out_e)
    }
}

impl StatelessHook for NaiveSampler {
    fn name(&self) -> &'static str {
        "naive_sampler"
    }

    fn requires(&self) -> Vec<&'static str> {
        if self.cfg.seed_negatives {
            vec![attr::NEGATIVES]
        } else {
            vec![]
        }
    }

    fn produces(&self) -> Vec<&'static str> {
        let mut p = vec![attr::NEIGHBORS, attr::NEIGHBOR_TIMES, attr::NEIGHBOR_MASK];
        if self.cfg.include_features {
            p.push(attr::NEIGHBOR_FEATS);
        }
        if self.cfg.two_hop.is_some() {
            p.extend([attr::NEIGHBORS_2, attr::NEIGHBOR_TIMES_2, attr::NEIGHBOR_MASK_2]);
            if self.cfg.include_features {
                p.push(attr::NEIGHBOR_FEATS_2);
            }
        }
        p
    }

    fn apply(&self, batch: &mut MaterializedBatch, ctx: &HookContext<'_>) -> Result<()> {
        // DyGLib builds its adjacency once over the *full* dataset; the
        // shared cache mirrors that while staying worker-safe.
        let adj = self.adj.get(ctx.storage);
        let adj = &*adj;

        let b = batch.num_edges();
        let mut nodes: Vec<u32> = Vec::with_capacity(b * 3);
        let mut times: Vec<Timestamp> = Vec::with_capacity(b * 3);
        nodes.extend_from_slice(&batch.src);
        times.extend_from_slice(&batch.ts);
        nodes.extend_from_slice(&batch.dst);
        times.extend_from_slice(&batch.ts);
        if self.cfg.seed_negatives {
            let negs = batch.get(attr::NEGATIVES)?.as_i32()?;
            nodes.extend(negs.iter().map(|&n| n as u32));
            times.extend_from_slice(&batch.ts);
        }

        let s = nodes.len();
        let k = self.cfg.num_neighbors;
        let d = ctx.storage.edge_feat_dim();
        let mut ids = vec![0i32; s * k];
        let mut dts = vec![0.0f32; s * k];
        let mut mask = vec![0.0f32; s * k];
        let mut abs = vec![0i64; s * k];
        let mut feats = vec![0.0f32; if self.cfg.include_features { s * k * d } else { 0 }];

        for (row, (&node, &t)) in nodes.iter().zip(&times).enumerate() {
            let (n1, t1, e1) = Self::recent_copy(adj, node, t, k);
            for (slot, ((&nb, &nt), &ei)) in n1.iter().zip(&t1).zip(&e1).enumerate() {
                let o = row * k + slot;
                ids[o] = nb as i32;
                dts[o] = (t - nt) as f32;
                mask[o] = 1.0;
                abs[o] = nt;
                if self.cfg.include_features {
                    feats[o * d..(o + 1) * d]
                        .copy_from_slice(ctx.storage.edge_feat_row(ei as usize));
                }
            }
        }
        batch.set(attr::NEIGHBORS, Tensor::i32(ids.clone(), &[s, k])?);
        batch.set(attr::NEIGHBOR_TIMES, Tensor::f32(dts, &[s, k])?);
        batch.set(attr::NEIGHBOR_MASK, Tensor::f32(mask.clone(), &[s, k])?);
        if self.cfg.include_features {
            batch.set(attr::NEIGHBOR_FEATS, Tensor::f32(feats, &[s, k, d])?);
        }

        if let Some(k2) = self.cfg.two_hop {
            let sk = s * k;
            let mut ids2 = vec![0i32; sk * k2];
            let mut dts2 = vec![0.0f32; sk * k2];
            let mut mask2 = vec![0.0f32; sk * k2];
            let mut feats2 = vec![0.0f32; if self.cfg.include_features { sk * k2 * d } else { 0 }];
            for o in 0..sk {
                if mask[o] > 0.0 {
                    let (n2, t2, e2) = Self::recent_copy(adj, ids[o] as u32, abs[o], k2);
                    for (slot, ((&nb, &nt), &ei)) in n2.iter().zip(&t2).zip(&e2).enumerate() {
                        let q = o * k2 + slot;
                        ids2[q] = nb as i32;
                        dts2[q] = (abs[o] - nt) as f32;
                        mask2[q] = 1.0;
                        if self.cfg.include_features {
                            feats2[q * d..(q + 1) * d]
                                .copy_from_slice(ctx.storage.edge_feat_row(ei as usize));
                        }
                    }
                }
            }
            batch.set(attr::NEIGHBORS_2, Tensor::i32(ids2, &[s, k, k2])?);
            batch.set(attr::NEIGHBOR_TIMES_2, Tensor::f32(dts2, &[s, k, k2])?);
            batch.set(attr::NEIGHBOR_MASK_2, Tensor::f32(mask2, &[s, k, k2])?);
            if self.cfg.include_features {
                batch.set(attr::NEIGHBOR_FEATS_2, Tensor::f32(feats2, &[s, k, k2, d])?);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};
    use crate::hooks::hook::Hook;
    use crate::hooks::neighbor::RecencySampler;

    fn storage() -> crate::graph::StorageSnapshot {
        // Events arrive three-at-a-time with a shared timestamp, so
        // batch-level (recency buffer) and event-level (naive/DyGLib)
        // sampling semantics coincide: same-time events are excluded by
        // the strict `ts < t` rule in both.
        let mut rng = crate::util::Rng::new(31);
        let edges: Vec<EdgeEvent> = (0..200)
            .map(|i| EdgeEvent {
                t: (i / 3) as i64,
                src: rng.below(6) as u32,
                dst: 6 + rng.below(4) as u32,
                features: vec![i as f32],
            })
            .collect();
        GraphStorage::from_events(edges, vec![], 10, None, None).unwrap().into_snapshot()
    }

    fn batch_from(
        st: &crate::graph::StorageSnapshot,
        r: std::ops::Range<usize>,
    ) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(st.edge_ts_at(r.start), st.edge_ts_at(r.end - 1) + 1);
        for i in r {
            b.src.push(st.edge_src_at(i));
            b.dst.push(st.edge_dst_at(i));
            b.ts.push(st.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b
    }

    /// The naive sampler and the recency sampler implement the same
    /// semantics (most recent K before t); outputs must agree whenever the
    /// recency buffer has not evicted (batch histories shorter than cap).
    #[test]
    fn naive_matches_recency_semantics() {
        let st = storage();
        let cfg = SamplerConfig {
            num_neighbors: 5,
            two_hop: None,
            include_features: true,
            seed_negatives: false,
        };
        let naive = NaiveSampler::new(cfg.clone());
        let mut recency = RecencySampler::new(cfg);
        let ctx = HookContext::new(&st, "train");

        // Stream a few small batches; compare outputs on the last one.
        for (lo, hi) in [(0, 3), (3, 6), (6, 9)] {
            let mut bn = batch_from(&st, lo..hi);
            let mut br = batch_from(&st, lo..hi);
            naive.apply(&mut bn, &ctx).unwrap();
            recency.apply(&mut br, &ctx).unwrap();
            if lo == 6 {
                assert_eq!(
                    bn.get(attr::NEIGHBORS).unwrap().as_i32().unwrap(),
                    br.get(attr::NEIGHBORS).unwrap().as_i32().unwrap(),
                );
                assert_eq!(
                    bn.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap(),
                    br.get(attr::NEIGHBOR_TIMES).unwrap().as_f32().unwrap(),
                );
                assert_eq!(
                    bn.get(attr::NEIGHBOR_FEATS).unwrap().as_f32().unwrap(),
                    br.get(attr::NEIGHBOR_FEATS).unwrap().as_f32().unwrap(),
                );
            }
        }
    }

    /// Unlike the buffer (warm-up limited), the naive sampler sees the
    /// full pre-t history immediately because it reads the global index.
    #[test]
    fn naive_sees_full_history() {
        let st = storage();
        let cfg = SamplerConfig {
            num_neighbors: 4,
            two_hop: Some(2),
            include_features: false,
            seed_negatives: false,
        };
        let naive = NaiveSampler::new(cfg);
        let ctx = HookContext::new(&st, "train");
        let mut b = batch_from(&st, 150..155);
        naive.apply(&mut b, &ctx).unwrap();
        let mask = b.get(attr::NEIGHBOR_MASK).unwrap().as_f32().unwrap();
        assert!(mask.iter().sum::<f32>() > 0.0);
        assert_eq!(b.get(attr::NEIGHBORS_2).unwrap().shape(), &[10, 4, 2]);
    }
}
