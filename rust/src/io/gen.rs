//! Synthetic temporal-graph generators (TGB surrogates).
//!
//! The paper evaluates on TGB datasets (Wikipedia, Reddit, LastFM, Trade,
//! Genre — Table 13). Those require downloads that are unavailable in this
//! environment, so we generate surrogates that match the *statistical
//! shape* that drives both efficiency and learning behaviour:
//!
//! * bipartite user-item structure (wiki/reddit/lastfm/genre),
//! * Zipf-skewed item popularity and user activity,
//! * recency-biased repeat interactions (controls the "surprise" index —
//!   the fraction of test edges unseen in training),
//! * exponential inter-arrival times over a fixed duration,
//! * optional per-edge features (LIWC-like: smooth per-pair signature plus
//!   noise) and periodic node events,
//! * a dense small-N yearly network for the Trade surrogate.
//!
//! Sizes are scaled down (configurable via [`GenConfig::scale`]) so CPU
//! benches complete in seconds; the benches report events/second so the
//! comparison shape is scale-invariant. See DESIGN.md "Environment
//! deviations".

use crate::error::Result;
use crate::graph::{DGData, EdgeEvent, GraphStorage, NodeEvent, Task};
use crate::util::{Rng, TimeGranularity};

/// Configuration for the bipartite interaction generator.
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub name: String,
    pub num_users: usize,
    pub num_items: usize,
    pub num_edges: usize,
    /// Total wall-clock span in seconds.
    pub duration: i64,
    /// Edge feature dimensionality (0 = unattributed).
    pub edge_feat_dim: usize,
    /// Static node feature dimensionality.
    pub static_feat_dim: usize,
    /// Probability that a user repeats a previously-visited item
    /// (higher -> lower surprise).
    pub repeat_prob: f64,
    /// Zipf exponent for item popularity.
    pub popularity_alpha: f64,
    /// Zipf exponent for user activity.
    pub activity_alpha: f64,
    /// Number of node events to interleave (dynamic node features).
    pub num_node_events: usize,
    /// Dynamic node feature dimensionality.
    pub node_feat_dim: usize,
    pub seed: u64,
    pub task: Task,
}

impl GenConfig {
    /// Scale node/edge counts by `f` (benches use small scales).
    pub fn scale(mut self, f: f64) -> GenConfig {
        self.num_users = ((self.num_users as f64 * f) as usize).max(4);
        self.num_items = ((self.num_items as f64 * f) as usize).max(4);
        self.num_edges = ((self.num_edges as f64 * f) as usize).max(64);
        self.num_node_events = (self.num_node_events as f64 * f) as usize;
        self
    }

    /// Override the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> GenConfig {
        self.seed = seed;
        self
    }
}

/// Wikipedia surrogate: bipartite page-editor network, 1 month, second
/// resolution, 172-d LIWC-like edge features in the paper — we default to
/// a narrower feature dim for CPU budgets (overridable).
pub fn wiki_config() -> GenConfig {
    GenConfig {
        name: "wiki".into(),
        num_users: 700,
        num_items: 220,
        num_edges: 16_000,
        duration: 30 * 86_400,
        edge_feat_dim: 16,
        static_feat_dim: 8,
        repeat_prob: 0.88, // paper surprise 0.108
        popularity_alpha: 1.1,
        activity_alpha: 1.0,
        num_node_events: 0,
        node_feat_dim: 0,
        seed: 7,
        task: Task::LinkPrediction,
    }
}

/// Reddit surrogate: user-subreddit posts, 1 month, low surprise (0.069).
pub fn reddit_config() -> GenConfig {
    GenConfig {
        name: "reddit".into(),
        num_users: 900,
        num_items: 100,
        num_edges: 64_000,
        duration: 30 * 86_400,
        edge_feat_dim: 16,
        static_feat_dim: 8,
        repeat_prob: 0.93,
        popularity_alpha: 1.2,
        activity_alpha: 1.1,
        num_node_events: 0,
        node_feat_dim: 0,
        seed: 11,
        task: Task::LinkPrediction,
    }
}

/// LastFM surrogate: user-song listens, unattributed, higher surprise (0.35).
pub fn lastfm_config() -> GenConfig {
    GenConfig {
        name: "lastfm".into(),
        num_users: 250,
        num_items: 750,
        num_edges: 120_000,
        duration: 30 * 86_400,
        edge_feat_dim: 0,
        static_feat_dim: 8,
        repeat_prob: 0.62,
        popularity_alpha: 0.9,
        activity_alpha: 1.0,
        num_node_events: 0,
        node_feat_dim: 0,
        seed: 13,
        task: Task::LinkPrediction,
    }
}

/// Genre surrogate: weekly user-genre proportions, node property task.
pub fn genre_config() -> GenConfig {
    GenConfig {
        name: "genre".into(),
        num_users: 400,
        num_items: 64,
        num_edges: 90_000,
        duration: 30 * 86_400,
        edge_feat_dim: 1, // interaction weight
        static_feat_dim: 8,
        repeat_prob: 0.95,
        popularity_alpha: 1.3,
        activity_alpha: 1.1,
        num_node_events: 800,
        node_feat_dim: 4,
        seed: 17,
        task: Task::NodeProperty,
    }
}

/// Generate a bipartite interaction dataset. Users are ids
/// `0..num_users`, items are `num_users..num_users+num_items`.
pub fn bipartite(cfg: &GenConfig) -> Result<DGData> {
    let mut rng = Rng::new(cfg.seed);
    let n_nodes = cfg.num_users + cfg.num_items;

    // Per-user interaction history for repeat behaviour.
    let mut history: Vec<Vec<u32>> = vec![Vec::new(); cfg.num_users];
    // Per-pair feature signature cache is implicit: signature is a hash of
    // (u, i) expanded deterministically, so repeats share a signature.
    let pair_sig = |u: u32, i: u32, k: usize| -> f32 {
        let mut h = (u as u64) << 32 | i as u64;
        h ^= (k as u64).wrapping_mul(0x9E3779B97F4A7C15);
        h = h.wrapping_mul(0xBF58476D1CE4E5B9);
        ((h >> 40) as f32 / (1u32 << 24) as f32) * 2.0 - 1.0
    };

    // Exponential inter-arrival times normalised to the total duration.
    let mut raw_times: Vec<f64> = Vec::with_capacity(cfg.num_edges);
    let mut acc = 0.0;
    for _ in 0..cfg.num_edges {
        acc += rng.exponential(1.0);
        raw_times.push(acc);
    }
    let scale = cfg.duration as f64 / acc;

    let mut edges: Vec<EdgeEvent> = Vec::with_capacity(cfg.num_edges);
    for raw_t in &raw_times {
        let t = (raw_t * scale) as i64;
        let u = rng.zipf(cfg.num_users as u64, cfg.activity_alpha) as u32;
        let item = if !history[u as usize].is_empty() && rng.bool(cfg.repeat_prob) {
            // Recency-biased repeat: favour the most recent items.
            let h = &history[u as usize];
            let k = h.len().min(8);
            h[h.len() - 1 - rng.below(k as u64) as usize]
        } else {
            (cfg.num_users as u64 + rng.zipf(cfg.num_items as u64, cfg.popularity_alpha)) as u32
        };
        history[u as usize].push(item);
        let features: Vec<f32> = (0..cfg.edge_feat_dim)
            .map(|k| pair_sig(u, item, k) + 0.1 * rng.normal_f32(0.0, 1.0))
            .collect();
        edges.push(EdgeEvent { t, src: u, dst: item, features });
    }

    // Periodic node events with drifting dynamic features.
    let mut node_events: Vec<NodeEvent> = Vec::with_capacity(cfg.num_node_events);
    for k in 0..cfg.num_node_events {
        let t = (cfg.duration * k as i64) / cfg.num_node_events.max(1) as i64;
        let node = rng.below(n_nodes as u64) as u32;
        let features = (0..cfg.node_feat_dim)
            .map(|j| (t as f32 / cfg.duration as f32) + pair_sig(node, j as u32, 3))
            .collect();
        node_events.push(NodeEvent { t, node, features });
    }

    // Static features: smooth per-node signature.
    let static_feats: Vec<f32> = (0..n_nodes)
        .flat_map(|n| (0..cfg.static_feat_dim).map(move |k| pair_sig(n as u32, 0, k + 101)))
        .collect();

    let storage = GraphStorage::from_events(
        edges,
        node_events,
        n_nodes,
        Some((cfg.static_feat_dim, static_feats)),
        Some(TimeGranularity::Second),
    )?;
    Ok(DGData::new(storage, cfg.name.clone(), cfg.task))
}

/// Trade surrogate: dense country-to-country network with yearly steps
/// (Table 13: 255 nodes, 32 unique steps, 30-year duration). Edge feature
/// is the (normalised) trade value; the node-property task predicts next
/// year's trade proportions.
pub fn trade(num_countries: usize, num_years: usize, seed: u64) -> Result<DGData> {
    let mut rng = Rng::new(seed);
    // Fallible lookup: only wall-clock granularities have a fixed
    // length, and threading a non-fixed one through here must surface
    // as an error, never a panic.
    let year_secs = TimeGranularity::Year.seconds().ok_or_else(|| {
        crate::error::TgmError::Time(
            "generator stepping requires a fixed-length granularity (got event-ordered)".into(),
        )
    })?;
    // Latent country "sizes" drive a gravity-model trade volume.
    let sizes: Vec<f64> = (0..num_countries).map(|_| rng.exponential(1.0) + 0.1).collect();
    let mut edges = Vec::new();
    for year in 0..num_years {
        let t = year as i64 * year_secs;
        let drift = 1.0 + 0.05 * (year as f64).sin();
        for s in 0..num_countries {
            for d in 0..num_countries {
                if s == d {
                    continue;
                }
                let vol = sizes[s] * sizes[d] * drift;
                // Sparsify small flows to keep edge counts realistic.
                if vol < 0.25 {
                    continue;
                }
                let noisy = (vol * (1.0 + 0.1 * rng.normal())).max(0.0) as f32;
                edges.push(EdgeEvent { t, src: s as u32, dst: d as u32, features: vec![noisy] });
            }
        }
    }
    let static_feats: Vec<f32> =
        (0..num_countries).flat_map(|i| vec![sizes[i] as f32, (i % 7) as f32 / 7.0]).collect();
    let storage = GraphStorage::from_events(
        edges,
        vec![],
        num_countries,
        Some((2, static_feats)),
        Some(TimeGranularity::Year),
    )?;
    Ok(DGData::new(storage, "trade", Task::NodeProperty))
}

/// Build a surrogate dataset by name at a given scale factor.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Result<DGData> {
    match name {
        "wiki" => bipartite(&wiki_config().scale(scale).with_seed(seed)),
        "reddit" => bipartite(&reddit_config().scale(scale).with_seed(seed)),
        "lastfm" => bipartite(&lastfm_config().scale(scale).with_seed(seed)),
        "genre" => bipartite(&genre_config().scale(scale).with_seed(seed)),
        "trade" => trade(
            ((64.0 * scale) as usize).clamp(8, 255),
            ((32.0 * scale.max(0.5)) as usize).clamp(4, 32),
            seed,
        ),
        other => Err(crate::error::TgmError::Config(format!("unknown dataset `{other}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wiki_surrogate_shape() {
        let d = bipartite(&wiki_config().scale(0.1)).unwrap();
        let s = d.stats();
        assert_eq!(s.num_edges, 1600);
        assert!(s.num_unique_edges < s.num_edges, "repeats must exist");
        assert!(s.surprise < 0.5, "wiki surrogate should be low-surprise: {}", s.surprise);
        assert_eq!(d.task(), Task::LinkPrediction);
        // Bipartite: sources are users, destinations are items.
        let st = d.storage();
        let nu = wiki_config().scale(0.1).num_users as u32;
        assert!(st.edge_src().iter().all(|&u| u < nu));
        assert!(st.edge_dst().iter().all(|&i| i >= nu));
    }

    #[test]
    fn repeat_prob_controls_edge_reuse() {
        // Higher repeat probability -> fewer unique (src, dst) pairs for
        // the same edge budget (the mechanism behind the surprise index).
        let low_repeat =
            bipartite(&GenConfig { repeat_prob: 0.05, ..lastfm_config().scale(0.05) }).unwrap();
        let high_repeat =
            bipartite(&GenConfig { repeat_prob: 0.97, ..lastfm_config().scale(0.05) }).unwrap();
        let lo = low_repeat.stats();
        let hi = high_repeat.stats();
        assert!(
            lo.num_unique_edges > hi.num_unique_edges,
            "{} vs {}",
            lo.num_unique_edges,
            hi.num_unique_edges
        );
        assert!((0.0..=1.0).contains(&lo.surprise) && (0.0..=1.0).contains(&hi.surprise));
    }

    #[test]
    fn generation_is_deterministic() {
        let a = bipartite(&wiki_config().scale(0.05)).unwrap();
        let b = bipartite(&wiki_config().scale(0.05)).unwrap();
        assert_eq!(a.storage().edge_ts(), b.storage().edge_ts());
        assert_eq!(a.storage().edge_src(), b.storage().edge_src());
        assert_eq!(a.storage().edge_feats(), b.storage().edge_feats());
        let c = bipartite(&wiki_config().scale(0.05).with_seed(999)).unwrap();
        assert_ne!(a.storage().edge_src(), c.storage().edge_src());
    }

    #[test]
    fn year_stepping_is_fallible_not_panicking() {
        // Regression for the old `Year.seconds().unwrap()` at the top of
        // `trade`: non-fixed granularities must be unrepresentable as
        // panics on the generator path (the lookup is threaded through
        // the fallible result instead).
        assert!(TimeGranularity::Event.seconds().is_none());
        assert!(trade(8, 4, 1).is_ok());
    }

    #[test]
    fn trade_surrogate_is_yearly_and_dense() {
        let d = trade(16, 8, 3).unwrap();
        let s = d.stats();
        assert_eq!(d.storage().granularity(), TimeGranularity::Year);
        assert_eq!(s.num_unique_steps, 8);
        assert!(s.num_edges > 16 * 4, "dense-ish: {}", s.num_edges);
        assert_eq!(d.task(), Task::NodeProperty);
    }

    #[test]
    fn by_name_covers_all_presets() {
        for name in ["wiki", "reddit", "lastfm", "genre", "trade"] {
            let d = by_name(name, 0.05, 1).unwrap();
            assert!(d.storage().num_edges() > 0, "{name}");
        }
        assert!(by_name("nope", 1.0, 1).is_err());
    }

    #[test]
    fn genre_has_node_events() {
        let d = by_name("genre", 0.1, 1).unwrap();
        assert!(d.storage().num_node_events() > 0);
        assert_eq!(d.storage().node_feat_dim(), 4);
    }
}
