//! CSV adaptor (paper §4, "Custom adapters ... via CSV").
//!
//! Format: one edge event per line, `src,dst,t[,f0,f1,...]`, with an
//! optional header row (detected when the first field is non-numeric).
//! Node ids are compacted to `0..num_nodes` in first-appearance order; the
//! mapping is returned so callers can translate predictions back.

use crate::error::{Result, TgmError};
use crate::graph::{DGData, EdgeEvent, GraphStorage, Task};
use std::collections::HashMap;
use std::io::BufRead;
use std::path::Path;

/// Result of a CSV load: the dataset plus the raw-id -> compact-id map.
pub struct CsvLoad {
    pub data: DGData,
    pub id_map: HashMap<String, u32>,
}

/// Parse edge events from any reader (used directly by tests).
pub fn parse_events<R: BufRead>(reader: R) -> Result<(Vec<EdgeEvent>, HashMap<String, u32>)> {
    let mut id_map: HashMap<String, u32> = HashMap::new();
    let mut edges = Vec::new();
    let mut intern = |raw: &str, map: &mut HashMap<String, u32>| -> u32 {
        if let Some(&id) = map.get(raw) {
            id
        } else {
            let id = map.len() as u32;
            map.insert(raw.to_string(), id);
            id
        }
    };

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() < 3 {
            return Err(TgmError::Io(format!(
                "line {}: need at least src,dst,t (got {} fields)",
                lineno + 1,
                fields.len()
            )));
        }
        // Header detection: timestamp field non-numeric on the first row.
        if lineno == 0 && fields[2].parse::<f64>().is_err() {
            continue;
        }
        let t = fields[2].parse::<f64>().map_err(|_| {
            TgmError::Io(format!("line {}: bad timestamp `{}`", lineno + 1, fields[2]))
        })? as i64;
        let src = intern(fields[0], &mut id_map);
        let dst = intern(fields[1], &mut id_map);
        let features = fields[3..]
            .iter()
            .map(|f| {
                f.parse::<f32>()
                    .map_err(|_| TgmError::Io(format!("line {}: bad feature `{f}`", lineno + 1)))
            })
            .collect::<Result<Vec<f32>>>()?;
        edges.push(EdgeEvent { t, src, dst, features });
    }
    Ok((edges, id_map))
}

/// Load a dataset from a CSV file.
pub fn from_csv(path: impl AsRef<Path>, name: &str, task: Task) -> Result<CsvLoad> {
    let file = std::fs::File::open(path.as_ref())?;
    let (edges, id_map) = parse_events(std::io::BufReader::new(file))?;
    if edges.is_empty() {
        return Err(TgmError::Io("CSV contained no edge events".into()));
    }
    let num_nodes = id_map.len();
    let storage = GraphStorage::from_events(edges, vec![], num_nodes, None, None)?;
    Ok(CsvLoad { data: DGData::new(storage, name, task), id_map })
}

/// Write a dataset's edges back to CSV (round-trip support / export).
pub fn to_csv(data: &DGData, path: impl AsRef<Path>) -> Result<()> {
    use std::io::Write;
    let st = data.storage();
    let mut out = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    writeln!(out, "src,dst,t{}", {
        let mut s = String::new();
        for k in 0..st.edge_feat_dim() {
            s.push_str(&format!(",f{k}"));
        }
        s
    })?;
    for i in 0..st.num_edges() {
        let mut line =
            format!("{},{},{}", st.edge_src_at(i), st.edge_dst_at(i), st.edge_ts_at(i));
        for v in st.edge_feat_row(i) {
            line.push_str(&format!(",{v}"));
        }
        writeln!(out, "{line}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_with_header_and_features() {
        let csv = "src,dst,t,f0\nalice,bob,10,0.5\nbob,carol,20,1.5\nalice,bob,30,2.5\n";
        let (edges, map) = parse_events(Cursor::new(csv)).unwrap();
        assert_eq!(edges.len(), 3);
        assert_eq!(map.len(), 3);
        assert_eq!(edges[0].src, map["alice"]);
        assert_eq!(edges[0].features, vec![0.5]);
        assert_eq!(edges[2].t, 30);
    }

    #[test]
    fn parses_headerless_numeric_ids() {
        let csv = "0,1,100\n1,2,200\n";
        let (edges, map) = parse_events(Cursor::new(csv)).unwrap();
        assert_eq!(edges.len(), 2);
        assert_eq!(map.len(), 3);
        assert!(edges[0].features.is_empty());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let csv = "# a comment\n\n0,1,5\n";
        let (edges, _) = parse_events(Cursor::new(csv)).unwrap();
        assert_eq!(edges.len(), 1);
    }

    #[test]
    fn rejects_malformed_rows() {
        assert!(parse_events(Cursor::new("0,1\n")).is_err());
        assert!(parse_events(Cursor::new("0,1,5\n0,1,bad\n")).is_err());
        assert!(parse_events(Cursor::new("0,1,5,notafloat\n")).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("tgm_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.csv");
        std::fs::write(&path, "u,v,t\n0,1,1\n1,2,2\n2,0,3\n").unwrap();
        let loaded = from_csv(&path, "toy", Task::LinkPrediction).unwrap();
        assert_eq!(loaded.data.storage().num_edges(), 3);
        assert_eq!(loaded.data.storage().num_nodes(), 3);

        let out = dir.join("roundtrip.csv");
        to_csv(&loaded.data, &out).unwrap();
        let re = from_csv(&out, "toy2", Task::LinkPrediction).unwrap();
        assert_eq!(re.data.storage().edge_ts(), loaded.data.storage().edge_ts());
    }
}
