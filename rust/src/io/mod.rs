//! IO adaptors: CSV loading/export and synthetic TGB-surrogate generators
//! (paper §4, "IO Adaptors and Data Preprocessing").

pub mod csv;
pub mod gen;

pub use csv::{from_csv, to_csv, CsvLoad};
pub use gen::{bipartite, by_name, trade, GenConfig};
