//! IO adaptors: CSV loading/export, synthetic TGB-surrogate generators
//! (paper §4, "IO Adaptors and Data Preprocessing"), and streaming event
//! sources for online ingestion.

pub mod csv;
pub mod gen;
pub mod stream;

pub use csv::{from_csv, to_csv, CsvLoad};
pub use gen::{bipartite, by_name, trade, GenConfig};
pub use stream::{EventSource, ReplaySource};
