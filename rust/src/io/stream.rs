//! Streaming event sources for online ingestion.
//!
//! An [`EventSource`] hands the coordinator chunks of events in arrival
//! order; [`crate::coordinator::StreamingTrainer`] appends them into a
//! [`crate::graph::SegmentedStorage`] and trains over successive
//! snapshots. [`ReplaySource`] is the reference implementation: it
//! replays an existing dataset's event log (edge and node events merged
//! in time order, edges first at ties — the [`Event`] total order), which
//! is both the simulation harness for online-learning experiments and
//! the oracle for the streamed-equals-one-shot determinism tests.

use crate::graph::{DGData, EdgeEvent, Event, NodeEvent};

/// A pull-based source of timestamped events.
pub trait EventSource {
    /// Next chunk of up to `max` events in arrival order. An empty vec
    /// means the source is (currently) drained.
    fn next_chunk(&mut self, max: usize) -> Vec<Event>;

    /// Events still buffered, if known (`None` for unbounded sources).
    fn remaining(&self) -> Option<usize> {
        None
    }
}

/// Replays a fixed event log in order.
pub struct ReplaySource {
    events: Vec<Event>,
    pos: usize,
}

impl ReplaySource {
    /// Replay an explicit event list (assumed already in arrival order).
    pub fn new(events: Vec<Event>) -> ReplaySource {
        ReplaySource { events, pos: 0 }
    }

    /// Replay a dataset's full event log: edge and node events merged by
    /// timestamp, edge events first at ties (the `Event` total order).
    pub fn from_data(data: &DGData) -> ReplaySource {
        let storage = data.storage();
        let ne = storage.num_edges();
        let nn = storage.num_node_events();
        let mut events = Vec::with_capacity(ne + nn);
        let (mut i, mut j) = (0usize, 0usize);
        while i < ne || j < nn {
            let take_edge = if i >= ne {
                false
            } else if j >= nn {
                true
            } else {
                // Edges before node events at equal timestamps.
                storage.edge_ts_at(i) <= storage.node_event_at(j).0
            };
            if take_edge {
                events.push(Event::Edge(EdgeEvent {
                    t: storage.edge_ts_at(i),
                    src: storage.edge_src_at(i),
                    dst: storage.edge_dst_at(i),
                    features: storage.edge_feat_row(i).to_vec(),
                }));
                i += 1;
            } else {
                let (t, node) = storage.node_event_at(j);
                events.push(Event::Node(NodeEvent {
                    t,
                    node,
                    features: storage.node_event_feat_row(j).to_vec(),
                }));
                j += 1;
            }
        }
        ReplaySource::new(events)
    }

    /// Total events in the log (delivered + pending).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the log is empty.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl EventSource for ReplaySource {
    fn next_chunk(&mut self, max: usize) -> Vec<Event> {
        let hi = self.pos.saturating_add(max.max(1)).min(self.events.len());
        let chunk = self.events[self.pos..hi].to_vec();
        self.pos = hi;
        chunk
    }

    fn remaining(&self) -> Option<usize> {
        Some(self.events.len() - self.pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::gen;

    #[test]
    fn replay_covers_every_event_in_time_order() {
        let data = gen::by_name("genre", 0.05, 3).unwrap();
        let total = data.storage().num_edges() + data.storage().num_node_events();
        let mut src = ReplaySource::from_data(&data);
        assert_eq!(src.len(), total);
        assert_eq!(src.remaining(), Some(total));
        let mut seen = 0;
        let mut last_t = i64::MIN;
        loop {
            let chunk = src.next_chunk(97);
            if chunk.is_empty() {
                break;
            }
            for ev in &chunk {
                assert!(ev.t() >= last_t, "events must replay in time order");
                last_t = ev.t();
            }
            seen += chunk.len();
        }
        assert_eq!(seen, total);
        assert_eq!(src.remaining(), Some(0));
    }

    #[test]
    fn replay_edge_columns_round_trip() {
        let data = gen::by_name("wiki", 0.05, 9).unwrap();
        let mut src = ReplaySource::from_data(&data);
        let events = src.next_chunk(usize::MAX);
        let edges: Vec<&EdgeEvent> = events
            .iter()
            .filter_map(|e| match e {
                Event::Edge(e) => Some(e),
                Event::Node(_) => None,
            })
            .collect();
        let st = data.storage();
        assert_eq!(edges.len(), st.num_edges());
        for (i, e) in edges.iter().enumerate() {
            assert_eq!((e.t, e.src, e.dst), (st.edge_ts_at(i), st.edge_src_at(i), st.edge_dst_at(i)));
            assert_eq!(e.features.as_slice(), st.edge_feat_row(i));
        }
    }
}
