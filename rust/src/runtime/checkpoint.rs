//! Model checkpointing: persist/restore a [`ModelRuntime`]'s live state
//! (params + Adam slots + memory/recurrent state) between runs.
//!
//! Format mirrors the AOT `.state.bin` blobs (f32 LE in canonical
//! tree-flatten order) with a small header binding the checkpoint to its
//! model and state layout, so loading a checkpoint into the wrong model
//! or an artifact rebuilt with different shapes fails loudly.

use crate::error::{Result, TgmError};
use crate::runtime::engine::ModelRuntime;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"TGMCKPT1";

/// Save the runtime's current state to `path`.
pub fn save(runtime: &ModelRuntime<'_>, path: impl AsRef<Path>) -> Result<()> {
    let state = runtime.state_to_host()?;
    let name = runtime.name().as_bytes();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(MAGIC)?;
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name)?;
    f.write_all(&(state.len() as u64).to_le_bytes())?;
    for v in &state {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Restore a checkpoint into the runtime (model name and state size must
/// match the manifest the runtime was loaded from).
pub fn load(runtime: &mut ModelRuntime<'_>, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(TgmError::Runtime("not a TGM checkpoint (bad magic)".into()));
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let name_len = u32::from_le_bytes(len4) as usize;
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name)
        .map_err(|_| TgmError::Runtime("corrupt checkpoint name".into()))?;
    if name != runtime.name() {
        return Err(TgmError::Runtime(format!(
            "checkpoint is for model `{name}`, runtime is `{}`",
            runtime.name()
        )));
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    if n != runtime.spec.state_elements() {
        return Err(TgmError::Runtime(format!(
            "checkpoint has {n} state elements, manifest expects {} — artifacts rebuilt?",
            runtime.spec.state_elements()
        )));
    }
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let state: Vec<f32> =
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    runtime.load_host_state(&state)
}

#[cfg(test)]
mod tests {
    // Round-trip behaviour is exercised in rust/tests/integration.rs
    // (needs compiled artifacts); here we only check header rejection.
    use super::*;

    #[test]
    fn rejects_garbage_files() {
        let dir = std::env::temp_dir().join("tgm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let mut f = std::io::BufReader::new(std::fs::File::open(&path).unwrap());
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).unwrap();
        assert_ne!(&magic, MAGIC);
    }
}
