//! Host `Tensor` <-> `xla::Literal` conversion at the device boundary.

use crate::error::{Result, TgmError};
use crate::util::{DType, Tensor};

fn rt(e: xla::Error) -> TgmError {
    TgmError::Runtime(e.to_string())
}

/// Reinterpret a 4-byte-element slice as raw bytes (zero-copy).
///
/// Safe on this target: x86-64 is little-endian and `f32`/`i32` have
/// alignment >= 1, so the byte view matches the wire format the XLA
/// literal constructor expects. This replaced a per-element
/// `to_le_bytes` collect that dominated the device boundary on multi-MB
/// predict batches (see EXPERIMENTS.md §Perf).
fn as_bytes<T>(data: &[T]) -> &[u8] {
    // SAFETY: plain-old-data elements; length scaled by size_of::<T>().
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// Convert a host tensor into an XLA literal (one bulk copy).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match t.dtype() {
        DType::F32 => (xla::ElementType::F32, as_bytes(t.as_f32()?)),
        DType::I32 => (xla::ElementType::S32, as_bytes(t.as_i32()?)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), bytes).map_err(rt)
}

/// Convert an f32 slice (with shape) into a literal.
pub fn f32_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, as_bytes(data))
        .map_err(rt)
}

/// Read a literal back into a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let ty = lit.ty().map_err(rt)?;
    match ty {
        xla::ElementType::F32 => {
            let v: Vec<f32> = lit.to_vec().map_err(rt)?;
            Tensor::f32(v, shape)
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = lit.to_vec().map_err(rt)?;
            Tensor::i32(v, shape)
        }
        other => Err(TgmError::Runtime(format!("unsupported literal type {other:?}"))),
    }
}

/// Read a scalar f32 out of a literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(rt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let t = Tensor::f32(vec![1.0, -2.5, 3.25, 0.0, 7.0, 8.0], &[2, 3]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(lit.element_count(), 6);
        let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_round_trip() {
        let t = Tensor::i32(vec![5, -7, 0, 123], &[4]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[4]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literal() {
        let t = Tensor::scalar_f32(42.5);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_scalar_f32(&lit).unwrap(), 42.5);
    }
}
