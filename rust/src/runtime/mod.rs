//! Runtime layer: PJRT client wrapper that loads `artifacts/*.hlo.txt`
//! (AOT-lowered by `python/compile/aot.py`), compiles them once, and
//! executes them from the coordinator hot path with automatic state
//! threading. See /opt/xla-example/load_hlo for the pattern this adapts.

pub mod checkpoint;
pub mod engine;
pub mod literal;
pub mod manifest;

pub use engine::{ModelRuntime, RunOutput, XlaEngine};
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelSpec, OutSpec, Profile};
