//! Artifact manifest parsing.
//!
//! `python -m compile.aot` writes `artifacts/manifest.txt` in a simple
//! line format (one token stream per line) describing, for every model:
//! the size *profile* it was compiled against, the canonical state-tensor
//! list (shapes in tree-flatten order) with the `.state.bin` initializer
//! blob, and per-artifact (`train`/`predict`/`update`) input and output
//! specs. This module is the Rust half of that contract.

use crate::error::{Result, TgmError};
use crate::util::DType;
use std::collections::HashMap;
use std::path::Path;

/// Static-shape envelope (mirrors `python/compile/config.py::Profile`).
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: String,
    pub n: usize,
    pub b: usize,
    pub k: usize,
    pub k2: usize,
    pub seq: usize,
    pub c: usize,
    pub d_edge: usize,
    pub d_static: usize,
    pub p: usize,
}

/// One named tensor input.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// Artifact output description.
#[derive(Debug, Clone)]
pub enum OutSpec {
    /// The full state list, in canonical order.
    State,
    /// A named tensor (loss scalar, score matrix...).
    Tensor(IoSpec),
}

/// One compiled function of a model.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub kind: String,
    pub hlo_file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<OutSpec>,
}

/// One model: state layout + artifacts.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub profile: String,
    pub state_file: String,
    pub state_shapes: Vec<Vec<usize>>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl ModelSpec {
    /// Total f32 element count of the state.
    pub fn state_elements(&self) -> usize {
        self.state_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// Bytes the state occupies (f32).
    pub fn state_bytes(&self) -> usize {
        self.state_elements() * 4
    }
}

/// Parsed manifest: profiles + models.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub profiles: HashMap<String, Profile>,
    pub models: HashMap<String, ModelSpec>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "-" {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| TgmError::Manifest(format!("bad shape dim `{d}`")))
        })
        .collect()
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur_model: Option<ModelSpec> = None;
        let mut cur_artifact: Option<ArtifactSpec> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = |msg: &str| TgmError::Manifest(format!("line {}: {msg}", lineno + 1));
            match toks[0] {
                "profile" => {
                    // profile <name> n <v> b <v> ...
                    if toks.len() < 2 || toks.len() % 2 != 0 {
                        return Err(err("malformed profile line"));
                    }
                    let mut kv = HashMap::new();
                    for pair in toks[2..].chunks(2) {
                        let v = pair[1]
                            .parse::<usize>()
                            .map_err(|_| err(&format!("bad profile value `{}`", pair[1])))?;
                        kv.insert(pair[0].to_string(), v);
                    }
                    let get = |k: &str| {
                        kv.get(k).copied().ok_or_else(|| err(&format!("profile missing `{k}`")))
                    };
                    m.profiles.insert(
                        toks[1].to_string(),
                        Profile {
                            name: toks[1].to_string(),
                            n: get("n")?,
                            b: get("b")?,
                            k: get("k")?,
                            k2: get("k2")?,
                            seq: get("seq")?,
                            c: get("c")?,
                            d_edge: get("d_edge")?,
                            d_static: get("d_static")?,
                            p: get("p")?,
                        },
                    );
                }
                "model" => {
                    if toks.len() != 4 || toks[2] != "profile" {
                        return Err(err("expected `model <name> profile <profile>`"));
                    }
                    cur_model = Some(ModelSpec {
                        name: toks[1].to_string(),
                        profile: toks[3].to_string(),
                        state_file: String::new(),
                        state_shapes: Vec::new(),
                        artifacts: HashMap::new(),
                    });
                }
                "state_file" => {
                    cur_model.as_mut().ok_or_else(|| err("state_file outside model"))?.state_file =
                        toks[1].to_string();
                }
                "state" => {
                    let model = cur_model.as_mut().ok_or_else(|| err("state outside model"))?;
                    if toks.len() != 3 || toks[1] != "f32" {
                        return Err(err("state lines must be `state f32 <shape>`"));
                    }
                    model.state_shapes.push(parse_shape(toks[2])?);
                }
                "artifact" => {
                    if toks.len() != 3 {
                        return Err(err("expected `artifact <kind> <file>`"));
                    }
                    cur_artifact = Some(ArtifactSpec {
                        kind: toks[1].to_string(),
                        hlo_file: toks[2].to_string(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                "in" => {
                    let a = cur_artifact.as_mut().ok_or_else(|| err("in outside artifact"))?;
                    if toks.len() != 4 {
                        return Err(err("expected `in <name> <dtype> <shape>`"));
                    }
                    a.inputs.push(IoSpec {
                        name: toks[1].to_string(),
                        dtype: DType::parse(toks[2])?,
                        shape: parse_shape(toks[3])?,
                    });
                }
                "out" => {
                    let a = cur_artifact.as_mut().ok_or_else(|| err("out outside artifact"))?;
                    if toks.len() == 2 && toks[1] == "state" {
                        a.outputs.push(OutSpec::State);
                    } else if toks.len() == 4 {
                        a.outputs.push(OutSpec::Tensor(IoSpec {
                            name: toks[1].to_string(),
                            dtype: DType::parse(toks[2])?,
                            shape: parse_shape(toks[3])?,
                        }));
                    } else {
                        return Err(err("malformed out line"));
                    }
                }
                "end" => {
                    let a = cur_artifact.take().ok_or_else(|| err("end outside artifact"))?;
                    cur_model
                        .as_mut()
                        .ok_or_else(|| err("artifact outside model"))?
                        .artifacts
                        .insert(a.kind.clone(), a);
                }
                "endmodel" => {
                    let model = cur_model.take().ok_or_else(|| err("endmodel outside model"))?;
                    if !m.profiles.contains_key(&model.profile) {
                        return Err(err(&format!("unknown profile `{}`", model.profile)));
                    }
                    m.models.insert(model.name.clone(), model);
                }
                other => return Err(err(&format!("unknown directive `{other}`"))),
            }
        }
        if cur_model.is_some() || cur_artifact.is_some() {
            return Err(TgmError::Manifest("unterminated model/artifact section".into()));
        }
        Ok(m)
    }

    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            TgmError::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    /// Model spec lookup with a helpful error.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| {
            let mut known: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
            known.sort_unstable();
            TgmError::Manifest(format!("unknown model `{name}`; built: {}", known.join(", ")))
        })
    }

    /// Profile lookup for a model.
    pub fn profile_of(&self, model: &ModelSpec) -> &Profile {
        &self.profiles[&model.profile]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# TGM artifact manifest v1
profile tiny n 32 b 8 k 4 k2 2 seq 8 c 3 d_edge 4 d_static 4 p 4

model toy_link profile tiny
state_file toy_link.state.bin
state f32 4,4
state f32 -
artifact train toy_link.train.hlo.txt
in src i32 8
in t f32 8
out state
out loss f32 -
end
artifact predict toy_link.predict.hlo.txt
in src i32 8
out scores f32 8,3
end
endmodel
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.profiles["tiny"].n, 32);
        assert_eq!(m.profiles["tiny"].c, 3);
        let spec = m.model("toy_link").unwrap();
        assert_eq!(spec.state_shapes, vec![vec![4, 4], vec![]]);
        assert_eq!(spec.state_elements(), 17);
        let train = &spec.artifacts["train"];
        assert_eq!(train.inputs.len(), 2);
        assert_eq!(train.inputs[0].dtype, DType::I32);
        assert!(matches!(train.outputs[0], OutSpec::State));
        match &train.outputs[1] {
            OutSpec::Tensor(t) => {
                assert_eq!(t.name, "loss");
                assert!(t.shape.is_empty());
            }
            _ => panic!("expected tensor out"),
        }
        let predict = &spec.artifacts["predict"];
        match &predict.outputs[0] {
            OutSpec::Tensor(t) => assert_eq!(t.shape, vec![8, 3]),
            _ => panic!(),
        }
    }

    #[test]
    fn unknown_model_error_lists_known() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.model("nope").unwrap_err().to_string();
        assert!(err.contains("toy_link"), "{err}");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("model x profile missing\nendmodel\n").is_err());
        assert!(Manifest::parse("state f32 3\n").is_err());
        assert!(Manifest::parse("bogus line\n").is_err());
        assert!(Manifest::parse("model x profile p\n").is_err()); // unterminated
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("tgat_link"));
            assert_eq!(m.models.len(), 16);
            for spec in m.models.values() {
                assert!(spec.artifacts.contains_key("train"), "{}", spec.name);
                assert!(spec.artifacts.contains_key("predict"), "{}", spec.name);
                assert!(dir.join(&spec.state_file).exists());
            }
        }
    }
}
