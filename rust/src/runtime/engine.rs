//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! from the coordinator's hot path. Python is never involved here.

use crate::error::{Result, TgmError};
use crate::runtime::literal::{literal_scalar_f32, literal_to_tensor, tensor_to_literal};
use crate::runtime::manifest::{ArtifactSpec, Manifest, ModelSpec, OutSpec, Profile};
use crate::util::Tensor;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

fn rt(e: xla::Error) -> TgmError {
    TgmError::Runtime(e.to_string())
}

/// Owns the PJRT client and the parsed manifest.
pub struct XlaEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl XlaEngine {
    /// Create a CPU engine over an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<XlaEngine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(rt)?;
        Ok(XlaEngine { client, dir: artifacts_dir.as_ref().to_path_buf(), manifest })
    }

    /// Parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, hlo_file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(hlo_file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().ok_or_else(|| {
            TgmError::Runtime(format!("non-utf8 path {}", path.display()))
        })?)
        .map_err(rt)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(rt)
    }

    /// Load a model: reads its initial state blob and compiles all of its
    /// artifacts.
    pub fn load_model(&self, name: &str) -> Result<ModelRuntime<'_>> {
        let spec = self.manifest.model(name)?.clone();
        let init = self.read_state_blob(&spec)?;
        let mut executables = HashMap::new();
        for (kind, art) in &spec.artifacts {
            executables.insert(kind.clone(), Rc::new(self.compile(&art.hlo_file)?));
        }
        let state = blob_to_literals(&init, &spec)?;
        Ok(ModelRuntime {
            engine: self,
            profile: self.manifest.profile_of(&spec).clone(),
            spec,
            executables,
            state,
            init_blob: init,
            calls: 0,
        })
    }

    fn read_state_blob(&self, spec: &ModelSpec) -> Result<Vec<f32>> {
        let path = self.dir.join(&spec.state_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| TgmError::Runtime(format!("read {}: {e}", path.display())))?;
        if bytes.len() != spec.state_bytes() {
            return Err(TgmError::Runtime(format!(
                "{}: blob has {} bytes, manifest expects {}",
                spec.state_file,
                bytes.len(),
                spec.state_bytes()
            )));
        }
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

fn blob_to_literals(blob: &[f32], spec: &ModelSpec) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(spec.state_shapes.len());
    let mut offset = 0usize;
    for shape in &spec.state_shapes {
        let n: usize = shape.iter().product();
        let lit = crate::runtime::literal::f32_to_literal(&blob[offset..offset + n], shape)?;
        out.push(lit);
        offset += n;
    }
    Ok(out)
}

/// Output of one artifact execution.
#[derive(Debug, Default)]
pub struct RunOutput {
    /// Scalar loss (train artifacts).
    pub loss: Option<f32>,
    /// Named tensor outputs (e.g. `scores`).
    pub tensors: HashMap<String, Tensor>,
}

/// A loaded model: compiled executables + live state literals.
///
/// `run` threads the state automatically: artifacts declaring `out state`
/// replace the runtime's state in place, exactly mirroring the functional
/// state threading of the JAX side.
pub struct ModelRuntime<'e> {
    engine: &'e XlaEngine,
    pub spec: ModelSpec,
    pub profile: Profile,
    executables: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
    state: Vec<xla::Literal>,
    init_blob: Vec<f32>,
    calls: u64,
}

impl ModelRuntime<'_> {
    /// Model name.
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Number of artifact executions so far.
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Reset state to the initial blob (fresh training run).
    pub fn reset_state(&mut self) -> Result<()> {
        self.state = blob_to_literals(&self.init_blob, &self.spec)?;
        Ok(())
    }

    /// Artifact input spec (for batch packers).
    pub fn artifact(&self, kind: &str) -> Result<&ArtifactSpec> {
        self.spec.artifacts.get(kind).ok_or_else(|| {
            TgmError::Runtime(format!("model `{}` has no `{kind}` artifact", self.spec.name))
        })
    }

    /// Execute one artifact. `batch` must contain every input the
    /// artifact's manifest spec declares (shape-checked here).
    pub fn run(&mut self, kind: &str, batch: &HashMap<String, Tensor>) -> Result<RunOutput> {
        let art = self.artifact(kind)?.clone();
        let exe = Rc::clone(self.executables.get(kind).unwrap());

        // Assemble inputs: state first, then batch tensors in spec order.
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.state.len() + art.inputs.len());
        inputs.extend(self.state.iter());
        let mut batch_literals = Vec::with_capacity(art.inputs.len());
        for spec in &art.inputs {
            let t = batch.get(&spec.name).ok_or_else(|| {
                TgmError::Runtime(format!(
                    "{}.{kind}: missing batch input `{}`",
                    self.spec.name, spec.name
                ))
            })?;
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype {
                return Err(TgmError::Runtime(format!(
                    "{}.{kind}: input `{}` is {:?}/{:?}, manifest expects {:?}/{:?}",
                    self.spec.name,
                    spec.name,
                    t.shape(),
                    t.dtype(),
                    spec.shape,
                    spec.dtype
                )));
            }
            batch_literals.push(tensor_to_literal(t)?);
        }
        inputs.extend(batch_literals.iter());

        let result = exe.execute::<&xla::Literal>(&inputs).map_err(rt)?;
        let tuple = result[0][0].to_literal_sync().map_err(rt)?;
        let mut outs = tuple.to_tuple().map_err(rt)?;
        self.calls += 1;

        // Distribute outputs per the manifest.
        let mut out = RunOutput::default();
        let mut cursor = 0usize;
        for ospec in &art.outputs {
            match ospec {
                OutSpec::State => {
                    let n = self.spec.state_shapes.len();
                    if cursor + n > outs.len() {
                        return Err(TgmError::Runtime(format!(
                            "{}.{kind}: output tuple too short for state",
                            self.spec.name
                        )));
                    }
                    self.state = outs.drain(..n).collect();
                    // Note: drain from the front keeps `cursor` at 0 for
                    // the remaining tensor outputs.
                }
                OutSpec::Tensor(t) => {
                    if cursor >= outs.len() {
                        return Err(TgmError::Runtime(format!(
                            "{}.{kind}: missing output `{}`",
                            self.spec.name, t.name
                        )));
                    }
                    let lit = &outs[cursor];
                    if t.name == "loss" && t.shape.is_empty() {
                        out.loss = Some(literal_scalar_f32(lit)?);
                    } else {
                        out.tensors.insert(t.name.clone(), literal_to_tensor(lit, &t.shape)?);
                    }
                    cursor += 1;
                }
            }
        }
        Ok(out)
    }

    /// Replace the live state from a host f32 vector in canonical order
    /// (checkpoint restore). Length must match the manifest layout.
    pub fn load_host_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.spec.state_elements() {
            return Err(TgmError::Runtime(format!(
                "state has {} elements, manifest expects {}",
                state.len(),
                self.spec.state_elements()
            )));
        }
        self.state = blob_to_literals(state, &self.spec)?;
        Ok(())
    }

    /// Copy the current state back to host f32 (testing / checkpointing).
    pub fn state_to_host(&self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.spec.state_elements());
        for (lit, shape) in self.state.iter().zip(&self.spec.state_shapes) {
            let t = literal_to_tensor(lit, shape)?;
            out.extend_from_slice(t.as_f32()?);
        }
        Ok(out)
    }

    /// Engine backing this runtime.
    pub fn engine(&self) -> &XlaEngine {
        self.engine
    }
}
