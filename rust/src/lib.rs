//! # TGM — Temporal Graph Modelling
//!
//! A modular and efficient library for machine learning on temporal
//! graphs, reproducing Chmura, Huang et al. (2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the data/execution layers: segmented
//!   append-only storage with immutable time-sorted segments and
//!   versioned epoch snapshots, a durable segment store (WAL +
//!   checksummed on-disk columnar segment files, crash recovery to the
//!   acknowledged prefix, background compaction), lightweight graph
//!   views, vectorized discretization, the phased hook/recipe system
//!   (stateless worker hooks + stateful consumer hooks), CTDG/DTDG data
//!   loaders with a deterministic parallel prefetch pipeline (adaptive
//!   queue depth) over a shared serving pool with weighted-DRR tenant
//!   QoS scheduling, a zero-materialization point-query path
//!   (`neighbors_before`/`edge_lookup` with per-tenant admission
//!   control and per-class latency accounting), a sharded multi-tenant
//!   tenant router with atomic snapshot pinning and per-tenant durable
//!   directories, samplers, evaluation, and the epoch + streaming
//!   training coordinators.
//! * **Layer 2 (`python/compile`)** — JAX model definitions (TGAT, TGN,
//!   GCN, GCLSTM, T-GCN, GraphMixer, DyGFormer, TPNet) AOT-lowered to HLO
//!   text artifacts with the optimizer inside the training step.
//! * **Layer 1 (`python/compile/kernels`)** — Pallas kernels for the
//!   compute hot-spots (temporal attention, time encoding, snapshot GCN
//!   aggregation, TPNet propagation), validated against pure-jnp oracles.
//!
//! Python runs only at build time (`make artifacts`); the `tgm` binary
//! executes the compiled artifacts through the PJRT C API (`xla` crate)
//! and never touches Python.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tgm::io::gen;
//!
//! let data = gen::by_name("wiki", 0.1, 42).unwrap();
//! let splits = data.split().unwrap();
//! println!("{}", data.stats());
//! println!("train edges: {}", splits.train.num_edges());
//! ```
//!
//! See `examples/quickstart.rs` for the full end-to-end training driver.

pub mod coordinator;
pub mod error;
pub mod graph;
pub mod hooks;
pub mod io;
pub mod kernels;
pub mod loader;
pub mod models;
pub mod obs;
pub mod persist;
pub mod replica;
pub mod runtime;
pub mod serving;
pub mod util;

pub use error::{Result, TgmError};
