//! Data layer: events, immutable time-sorted segment storage, the
//! append-only segmented store with epoch snapshots, lightweight views,
//! discretization, dataset containers and statistics (paper §3-4 plus the
//! streaming-ingestion extension).

pub mod adjacency;
pub mod data;
pub mod discretize;
pub mod dtdg;
pub mod events;
pub mod point;
pub mod segment;
pub mod storage;
pub mod view;

pub use adjacency::{
    AdjacencyCache, MergedAdjacency, MergedNeighbors, NeighborCols, TemporalAdjacency,
};
pub use data::{DGData, DatasetStats, Splits, Task};
pub use discretize::{discretize, discretize_utg, ReduceOp};
pub use dtdg::DtdgHandle;
pub use events::{EdgeEvent, Event, NodeEvent, NodeId};
pub use point::{EdgeHit, PointQuery, PointReader, PointResponse};
pub use segment::{SealPolicy, SegmentedStorage, SnapshotCell, SnapshotId, StorageSnapshot};
pub use storage::GraphStorage;
pub use view::DGraph;
