//! Data layer: events, immutable time-sorted COO storage, lightweight
//! views, discretization, dataset containers and statistics (paper §3-4).

pub mod adjacency;
pub mod data;
pub mod discretize;
pub mod events;
pub mod storage;
pub mod view;

pub use adjacency::{AdjacencyCache, TemporalAdjacency};
pub use data::{DGData, DatasetStats, Splits, Task};
pub use discretize::{discretize, discretize_utg, ReduceOp};
pub use events::{EdgeEvent, Event, NodeEvent, NodeId};
pub use storage::GraphStorage;
pub use view::DGraph;
