//! Zero-materialization point reads over pinned snapshots.
//!
//! Batch streams answer "iterate the epoch"; real serving traffic is
//! dominated by small reads — "neighbors of `v` before `t`", "when did
//! `src` last touch `dst`". Forcing those through the batch path means
//! allocating a [`crate::hooks::batch::MaterializedBatch`] arena and
//! running the hook pipeline per query. A [`PointReader`] answers them
//! directly from a pinned [`StorageSnapshot`] and its per-segment CSR
//! indices instead:
//!
//! * the time cut inside each per-segment [`TemporalAdjacency`] run is
//!   the same [`crate::kernels::count_lt`] filtered count the samplers
//!   use (branchless SIMD linear scan for short runs, binary search for
//!   long ones);
//! * results reference the snapshot's columns by **logical edge index**,
//!   so [`PointReader::edge_features`] serves feature rows straight from
//!   the (possibly mmap-backed) segment columns — no copy, no batch, no
//!   hooks.
//!
//! A reader pins one snapshot generation: queries against it are
//! byte-stable forever, exactly like a pooled stream. Build one per
//! published generation (cheaply, via [`PointReader::with_cache`], which
//! reuses per-segment indices across generations) and share it across
//! threads — it is `Clone` (two `Arc`s) and `Send + Sync`.

use crate::graph::adjacency::{AdjacencyCache, MergedAdjacency};
use crate::graph::segment::StorageSnapshot;
use crate::util::Timestamp;
use std::sync::Arc;

/// One point request, as submitted to the serving pool's scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PointQuery {
    /// The `k` most recent neighbors of `node` strictly before `t`.
    NeighborsBefore {
        /// Seed node.
        node: u32,
        /// Exclusive time cut (strict, no leakage).
        t: Timestamp,
        /// Maximum triples returned.
        k: usize,
    },
    /// The most recent edge event between `src` and `dst` strictly
    /// before `t`.
    EdgeLookup {
        /// One endpoint.
        src: u32,
        /// The other endpoint (interactions are undirected).
        dst: u32,
        /// Exclusive time cut.
        t: Timestamp,
    },
}

/// Answer to one [`PointQuery`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PointResponse {
    /// `(neighbor, time, logical edge index)` triples, oldest first.
    Neighbors(Vec<(u32, Timestamp, u32)>),
    /// The matching edge, or `None` when the pair never interacted
    /// before `t`.
    Edge(Option<EdgeHit>),
}

/// One located edge event: its timestamp plus the logical edge index
/// into the snapshot the reader is pinned to (usable with
/// [`StorageSnapshot::edge_feat_row`] and friends).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeHit {
    /// Event timestamp.
    pub t: Timestamp,
    /// Logical (snapshot-wide) edge index.
    pub eidx: u32,
}

/// Point-read API over one pinned snapshot generation.
#[derive(Clone)]
pub struct PointReader {
    snapshot: Arc<StorageSnapshot>,
    adjacency: Arc<MergedAdjacency>,
}

impl PointReader {
    /// Build fresh per-segment indices for `snapshot` (no cache). Prefer
    /// [`PointReader::with_cache`] on serving paths, where generations
    /// succeed each other and indices should be reused.
    pub fn new(snapshot: Arc<StorageSnapshot>) -> PointReader {
        let adjacency = Arc::new(MergedAdjacency::build(&snapshot));
        PointReader { snapshot, adjacency }
    }

    /// Build (or reuse) the merged index through `cache`: only segments
    /// not yet indexed are built, so advancing one generation costs one
    /// delta index.
    pub fn with_cache(snapshot: Arc<StorageSnapshot>, cache: &AdjacencyCache) -> PointReader {
        let adjacency = cache.get(&snapshot);
        PointReader { snapshot, adjacency }
    }

    /// The snapshot this reader is pinned to.
    pub fn snapshot(&self) -> &Arc<StorageSnapshot> {
        &self.snapshot
    }

    /// The `k` most recent `(neighbor, time, logical edge index)`
    /// triples of `node` strictly before `t`, oldest first. Allocates
    /// only the ≤`k`-element result vector — no batch, no hooks.
    pub fn neighbors_before(
        &self,
        node: u32,
        t: Timestamp,
        k: usize,
    ) -> Vec<(u32, Timestamp, u32)> {
        if node as usize >= self.snapshot.num_nodes() || k == 0 {
            return Vec::new();
        }
        let view = self.adjacency.neighbors_before(node, t);
        let take = view.len().min(k);
        let mut out = Vec::with_capacity(take);
        out.extend(view.iter_rev().take(take));
        out.reverse();
        out
    }

    /// The most recent edge event between `src` and `dst` strictly
    /// before `t`. Scans `src`'s time-cut neighbor run newest-first, so
    /// the cost is the recency rank of the pair, not the degree.
    pub fn edge_lookup(&self, src: u32, dst: u32, t: Timestamp) -> Option<EdgeHit> {
        if src as usize >= self.snapshot.num_nodes() || dst as usize >= self.snapshot.num_nodes() {
            return None;
        }
        self.adjacency
            .neighbors_before(src, t)
            .iter_rev()
            .find(|(n, _, _)| *n == dst)
            .map(|(_, ts, eidx)| EdgeHit { t: ts, eidx })
    }

    /// Feature row of a located edge, served directly from the pinned
    /// snapshot's columns.
    pub fn edge_features(&self, hit: EdgeHit) -> &[f32] {
        self.snapshot.edge_feat_row(hit.eidx as usize)
    }

    /// Execute one [`PointQuery`].
    pub fn execute(&self, query: &PointQuery) -> PointResponse {
        match *query {
            PointQuery::NeighborsBefore { node, t, k } => {
                PointResponse::Neighbors(self.neighbors_before(node, t, k))
            }
            PointQuery::EdgeLookup { src, dst, t } => {
                PointResponse::Edge(self.edge_lookup(src, dst, t))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;
    use crate::graph::segment::{SealPolicy, SegmentedStorage};
    use crate::graph::storage::GraphStorage;

    fn single_segment_reader() -> PointReader {
        let edges = vec![
            EdgeEvent { t: 10, src: 0, dst: 1, features: vec![1.0] },
            EdgeEvent { t: 20, src: 0, dst: 2, features: vec![2.0] },
            EdgeEvent { t: 30, src: 1, dst: 2, features: vec![3.0] },
            EdgeEvent { t: 40, src: 0, dst: 1, features: vec![4.0] },
        ];
        let snap = GraphStorage::from_events(edges, vec![], 4, None, None).unwrap().into_snapshot();
        PointReader::new(Arc::new(snap))
    }

    #[test]
    fn neighbors_before_takes_most_recent_k() {
        let r = single_segment_reader();
        assert_eq!(r.neighbors_before(0, 1000, 10), vec![(1, 10, 0), (2, 20, 1), (1, 40, 3)]);
        // k truncates from the old end: only the most recent survive.
        assert_eq!(r.neighbors_before(0, 1000, 2), vec![(2, 20, 1), (1, 40, 3)]);
        // The cut is strict (t = 40 excludes the t = 40 event).
        assert_eq!(r.neighbors_before(0, 40, 2), vec![(1, 10, 0), (2, 20, 1)]);
        assert!(r.neighbors_before(0, 10, 4).is_empty());
        assert!(r.neighbors_before(0, 1000, 0).is_empty());
        // Out-of-range node: empty, not a panic.
        assert!(r.neighbors_before(99, 1000, 4).is_empty());
    }

    #[test]
    fn edge_lookup_finds_most_recent_pair_event() {
        let r = single_segment_reader();
        assert_eq!(r.edge_lookup(0, 1, 1000), Some(EdgeHit { t: 40, eidx: 3 }));
        // Before the second (0,1) event only the first is visible.
        assert_eq!(r.edge_lookup(0, 1, 40), Some(EdgeHit { t: 10, eidx: 0 }));
        // Undirected: both endpoints see the event.
        assert_eq!(r.edge_lookup(1, 0, 1000), Some(EdgeHit { t: 40, eidx: 3 }));
        assert_eq!(r.edge_lookup(0, 3, 1000), None);
        assert_eq!(r.edge_lookup(0, 99, 1000), None);
        let hit = r.edge_lookup(0, 2, 1000).unwrap();
        assert_eq!(r.edge_features(hit), &[2.0]);
    }

    #[test]
    fn multi_segment_reader_rebases_edge_indices() {
        let mut st = SegmentedStorage::new(6, SealPolicy::by_events(3));
        for i in 0..12u32 {
            st.append_edge(EdgeEvent {
                t: i as i64 * 10,
                src: i % 3,
                dst: 3 + (i % 2),
                features: vec![i as f32],
            })
            .unwrap();
        }
        let snap = st.snapshot().unwrap();
        assert!(snap.num_segments() > 1);
        let reader = PointReader::with_cache(snap, &AdjacencyCache::new());
        // Node 0 interacts at i = 0, 3, 6, 9; the logical indices must
        // survive segmentation.
        let got = reader.neighbors_before(0, 10_000, 2);
        assert_eq!(got, vec![(3 + 6 % 2, 60, 6), (3 + 9 % 2, 90, 9)]);
        let hit = reader.edge_lookup(0, 4, 10_000).unwrap();
        assert_eq!(hit, EdgeHit { t: 90, eidx: 9 });
        assert_eq!(reader.edge_features(hit), &[9.0]);
        // execute() round-trips both variants.
        let q = PointQuery::NeighborsBefore { node: 0, t: 10_000, k: 2 };
        assert_eq!(reader.execute(&q), PointResponse::Neighbors(got));
        let q = PointQuery::EdgeLookup { src: 0, dst: 4, t: 10_000 };
        assert_eq!(reader.execute(&q), PointResponse::Edge(Some(hit)));
    }
}
