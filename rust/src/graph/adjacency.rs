//! Temporal adjacency index (CSR), the substrate for neighbor sampling.
//!
//! Built once per storage: for every node, the list of (neighbor,
//! timestamp, edge index) pairs sorted by time. Because the storage's edge
//! columns are already time-sorted, a counting-sort fill yields per-node
//! time-sorted lists in `O(E)` with no comparison sort. Interactions are
//! treated as undirected for neighborhood purposes (both endpoints see the
//! event), matching TGAT/TGN semantics.

use crate::graph::storage::GraphStorage;
use crate::util::Timestamp;
use std::sync::{Arc, Mutex};

/// CSR over (neighbor, time, edge-index) triples, time-sorted per node.
#[derive(Debug, Clone)]
pub struct TemporalAdjacency {
    offsets: Vec<u32>,
    nbr: Vec<u32>,
    ts: Vec<Timestamp>,
    eidx: Vec<u32>,
    /// Edge count of the storage this index was built from (staleness check).
    built_from_edges: usize,
}

impl TemporalAdjacency {
    /// Build the index from storage (undirected).
    pub fn build(storage: &GraphStorage) -> TemporalAdjacency {
        let n = storage.num_nodes();
        let e = storage.num_edges();
        let src = storage.edge_src();
        let dst = storage.edge_dst();
        let ets = storage.edge_ts();

        let mut degree = vec![0u32; n];
        for i in 0..e {
            degree[src[i] as usize] += 1;
            degree[dst[i] as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let total = offsets[n] as usize;
        let mut nbr = vec![0u32; total];
        let mut ts = vec![0i64; total];
        let mut eidx = vec![0u32; total];
        let mut cursor = offsets[..n].to_vec();
        // Edges are time-sorted, so sequential fill keeps per-node lists
        // time-sorted too.
        for i in 0..e {
            let (s, d, t) = (src[i] as usize, dst[i] as usize, ets[i]);
            let cs = cursor[s] as usize;
            nbr[cs] = d as u32;
            ts[cs] = t;
            eidx[cs] = i as u32;
            cursor[s] += 1;
            let cd = cursor[d] as usize;
            nbr[cd] = s as u32;
            ts[cd] = t;
            eidx[cd] = i as u32;
            cursor[d] += 1;
        }
        TemporalAdjacency { offsets, nbr, ts, eidx, built_from_edges: e }
    }

    /// True if this index matches `storage` (cheap staleness check).
    pub fn matches(&self, storage: &GraphStorage) -> bool {
        self.built_from_edges == storage.num_edges()
            && self.offsets.len() == storage.num_nodes() + 1
    }

    /// Full (time-sorted) neighbor list of `node`.
    pub fn neighbors(&self, node: u32) -> (&[u32], &[Timestamp], &[u32]) {
        let lo = self.offsets[node as usize] as usize;
        let hi = self.offsets[node as usize + 1] as usize;
        (&self.nbr[lo..hi], &self.ts[lo..hi], &self.eidx[lo..hi])
    }

    /// Neighbors of `node` strictly before `t` (temporal neighborhood
    /// `N_t(s)`, paper Eq. 4 with strict inequality to prevent leakage).
    pub fn neighbors_before(&self, node: u32, t: Timestamp) -> (&[u32], &[Timestamp], &[u32]) {
        let lo = self.offsets[node as usize] as usize;
        let hi = self.offsets[node as usize + 1] as usize;
        let cut = lo + self.ts[lo..hi].partition_point(|&u| u < t);
        (&self.nbr[lo..cut], &self.ts[lo..cut], &self.eidx[lo..cut])
    }

    /// Degree of `node` (all time).
    pub fn degree(&self, node: u32) -> usize {
        (self.offsets[node as usize + 1] - self.offsets[node as usize]) as usize
    }

    /// Total stored triples (2 × edges).
    pub fn len(&self) -> usize {
        self.nbr.len()
    }

    /// True when the index holds no triples.
    pub fn is_empty(&self) -> bool {
        self.nbr.is_empty()
    }

    /// Wrap in an `Arc` for sharing across worker threads.
    pub fn into_shared(self) -> Arc<TemporalAdjacency> {
        Arc::new(self)
    }
}

/// Memoized, thread-safe CSR index shared by stateless hooks.
///
/// Building the CSR costs `O(E)`; several hooks (uniform sampler, naive
/// sampler, unique-recency lookup) each used to carry their own private
/// copy. With the prefetch pipeline one hook instance is applied from
/// many worker threads concurrently, so the cache is interior-mutable:
/// the first caller builds (under the lock, so concurrent first calls
/// build once) and everyone else clones the `Arc`. Staleness is detected
/// by a fingerprint of the storage: its column address (distinguishes
/// distinct live storages with equal counts) plus event counts and time
/// span via [`TemporalAdjacency::matches`] and the window fields. A
/// false hit would need a dropped storage's allocation to be recycled by
/// another graph with identical edge count, node count, start time and
/// end time — accepted as vanishingly unlikely, since full content
/// hashing would cost more than the `O(E)` rebuild the cache avoids.
#[derive(Debug, Default)]
pub struct AdjacencyCache {
    slot: Mutex<Option<(StorageFingerprint, Arc<TemporalAdjacency>)>>,
}

/// Cheap O(1) identity for a storage: column address + time span.
type StorageFingerprint = (usize, i64, i64);

fn fingerprint(storage: &GraphStorage) -> StorageFingerprint {
    (storage.edge_ts().as_ptr() as usize, storage.start_time(), storage.end_time())
}

impl AdjacencyCache {
    /// Empty cache.
    pub fn new() -> AdjacencyCache {
        AdjacencyCache::default()
    }

    /// Shared index for `storage`, building it on first use.
    pub fn get(&self, storage: &GraphStorage) -> Arc<TemporalAdjacency> {
        let key = fingerprint(storage);
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        match slot.as_ref() {
            Some((k, adj)) if *k == key && adj.matches(storage) => Arc::clone(adj),
            _ => {
                let adj = TemporalAdjacency::build(storage).into_shared();
                *slot = Some((key, Arc::clone(&adj)));
                adj
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;

    fn storage() -> GraphStorage {
        let edges = vec![
            EdgeEvent { t: 10, src: 0, dst: 1, features: vec![] },
            EdgeEvent { t: 20, src: 0, dst: 2, features: vec![] },
            EdgeEvent { t: 30, src: 1, dst: 2, features: vec![] },
            EdgeEvent { t: 40, src: 0, dst: 1, features: vec![] },
        ];
        GraphStorage::from_events(edges, vec![], 4, None, None).unwrap()
    }

    #[test]
    fn csr_structure() {
        let adj = TemporalAdjacency::build(&storage());
        assert_eq!(adj.len(), 8);
        assert_eq!(adj.degree(0), 3);
        assert_eq!(adj.degree(3), 0);
        let (n, t, e) = adj.neighbors(0);
        assert_eq!(n, &[1, 2, 1]);
        assert_eq!(t, &[10, 20, 40]);
        assert_eq!(e, &[0, 1, 3]);
    }

    #[test]
    fn undirected_symmetry() {
        let adj = TemporalAdjacency::build(&storage());
        let (n1, _, _) = adj.neighbors(1);
        assert_eq!(n1, &[0, 2, 0]);
    }

    #[test]
    fn temporal_cut_is_strict() {
        let adj = TemporalAdjacency::build(&storage());
        let (n, t, _) = adj.neighbors_before(0, 20);
        assert_eq!(n, &[1]);
        assert_eq!(t, &[10]);
        // Exactly at an event time: that event is excluded (no leakage).
        let (n2, _, _) = adj.neighbors_before(0, 10);
        assert!(n2.is_empty());
        let (n3, _, _) = adj.neighbors_before(0, 1_000);
        assert_eq!(n3.len(), 3);
    }

    #[test]
    fn shared_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<GraphStorage>();
        assert_send_sync::<TemporalAdjacency>();
        assert_send_sync::<AdjacencyCache>();
        assert_send_sync::<Arc<TemporalAdjacency>>();
    }

    #[test]
    fn cache_builds_once_and_detects_staleness() {
        let st = storage();
        let cache = AdjacencyCache::new();
        let a = cache.get(&st);
        let b = cache.get(&st);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must reuse the build");
        // A different storage invalidates the slot.
        let other = GraphStorage::from_events(
            vec![EdgeEvent { t: 1, src: 0, dst: 1, features: vec![] }],
            vec![],
            2,
            None,
            None,
        )
        .unwrap();
        let c = cache.get(&other);
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(c.matches(&other));
    }

    #[test]
    fn per_node_lists_time_sorted_randomized() {
        let mut rng = crate::util::Rng::new(77);
        let edges: Vec<EdgeEvent> = (0..300)
            .map(|_| EdgeEvent {
                t: rng.range(0, 1000),
                src: rng.below(10) as u32,
                dst: rng.below(10) as u32,
                features: vec![],
            })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 10, None, None).unwrap();
        let adj = TemporalAdjacency::build(&st);
        for node in 0..10 {
            let (_, ts, _) = adj.neighbors(node);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "node {node} unsorted");
        }
        assert!(adj.matches(&st));
    }
}
