//! Temporal adjacency index (CSR), the substrate for neighbor sampling.
//!
//! [`TemporalAdjacency`] is built once per **segment**: for every node,
//! the list of (neighbor, timestamp, edge index) pairs sorted by time.
//! Because a segment's edge columns are already time-sorted, a
//! counting-sort fill yields per-node time-sorted lists in `O(E)` with no
//! comparison sort. Interactions are treated as undirected for
//! neighborhood purposes (both endpoints see the event), matching
//! TGAT/TGN semantics.
//!
//! With segmented storage the CSR layer is **incremental**:
//! [`MergedAdjacency`] stacks one immutable per-segment index per
//! snapshot segment and merges on read — per-node per-segment lists are
//! time-sorted and segments are time-ordered, so concatenation preserves
//! global time order. [`AdjacencyCache`] keys on the snapshot's explicit
//! [`SnapshotId`] (generation id) and reuses per-segment indices across
//! generations by their globally unique segment ids, so appending and
//! sealing a new segment only ever builds the delta index for that
//! segment. The old pointer-address `StorageFingerprint` heuristic (which
//! could false-hit when a dropped storage's allocation was recycled) is
//! gone entirely.

use crate::graph::segment::{SnapshotId, StorageSnapshot};
use crate::graph::storage::GraphStorage;
use crate::kernels;
use crate::util::Timestamp;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// CSR over (neighbor, time, edge-index) triples, time-sorted per node.
/// Edge indices are local to the segment the index was built from.
#[derive(Debug, Clone)]
pub struct TemporalAdjacency {
    offsets: Vec<u32>,
    nbr: Vec<u32>,
    ts: Vec<Timestamp>,
    eidx: Vec<u32>,
}

impl TemporalAdjacency {
    /// Build the index from one segment (undirected).
    pub fn build(storage: &GraphStorage) -> TemporalAdjacency {
        let n = storage.num_nodes();
        let e = storage.num_edges();
        let src = storage.edge_src();
        let dst = storage.edge_dst();
        let ets = storage.edge_ts();

        let mut degree = vec![0u32; n];
        for i in 0..e {
            degree[src[i] as usize] += 1;
            degree[dst[i] as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let total = offsets[n] as usize;
        let mut nbr = vec![0u32; total];
        let mut ts = vec![0i64; total];
        let mut eidx = vec![0u32; total];
        let mut cursor = offsets[..n].to_vec();
        // Edges are time-sorted, so sequential fill keeps per-node lists
        // time-sorted too.
        for i in 0..e {
            let (s, d, t) = (src[i] as usize, dst[i] as usize, ets[i]);
            let cs = cursor[s] as usize;
            nbr[cs] = d as u32;
            ts[cs] = t;
            eidx[cs] = i as u32;
            cursor[s] += 1;
            let cd = cursor[d] as usize;
            nbr[cd] = s as u32;
            ts[cd] = t;
            eidx[cd] = i as u32;
            cursor[d] += 1;
        }
        TemporalAdjacency { offsets, nbr, ts, eidx }
    }

    /// Full (time-sorted) neighbor list of `node`.
    pub fn neighbors(&self, node: u32) -> (&[u32], &[Timestamp], &[u32]) {
        let lo = self.offsets[node as usize] as usize;
        let hi = self.offsets[node as usize + 1] as usize;
        (&self.nbr[lo..hi], &self.ts[lo..hi], &self.eidx[lo..hi])
    }

    /// Neighbors of `node` strictly before `t` (temporal neighborhood
    /// `N_t(s)`, paper Eq. 4 with strict inequality to prevent leakage).
    ///
    /// The time cut is a [`kernels::count_lt`] filtered count: a
    /// branchless SIMD linear scan for the short per-node runs sampling
    /// actually sees, binary search for long ones — identical to
    /// `partition_point` either way because the run is time-sorted.
    pub fn neighbors_before(&self, node: u32, t: Timestamp) -> (&[u32], &[Timestamp], &[u32]) {
        let lo = self.offsets[node as usize] as usize;
        let hi = self.offsets[node as usize + 1] as usize;
        let cut = lo + kernels::count_lt(&self.ts[lo..hi], t);
        (&self.nbr[lo..cut], &self.ts[lo..cut], &self.eidx[lo..cut])
    }

    /// Degree of `node` (all time).
    pub fn degree(&self, node: u32) -> usize {
        (self.offsets[node as usize + 1] - self.offsets[node as usize]) as usize
    }

    /// Total stored triples (2 × edges).
    pub fn len(&self) -> usize {
        self.nbr.len()
    }

    /// True when the index holds no triples.
    pub fn is_empty(&self) -> bool {
        self.nbr.is_empty()
    }

    /// Wrap in an `Arc` for sharing across worker threads.
    pub fn into_shared(self) -> Arc<TemporalAdjacency> {
        Arc::new(self)
    }
}

/// Merge-on-read view over one immutable [`TemporalAdjacency`] per
/// snapshot segment. Edge indices returned by lookups are **logical**
/// (segment base + local index), matching `MaterializedBatch` and
/// [`StorageSnapshot::edge_feat_row`].
#[derive(Debug)]
pub struct MergedAdjacency {
    /// (per-segment index, logical edge base), oldest segment first.
    parts: Vec<(Arc<TemporalAdjacency>, u32)>,
}

impl MergedAdjacency {
    /// Build fresh indices for every segment of `snapshot` (no cache).
    pub fn build(snapshot: &StorageSnapshot) -> MergedAdjacency {
        let parts = snapshot
            .segments()
            .iter()
            .enumerate()
            .map(|(s, seg)| {
                (TemporalAdjacency::build(seg).into_shared(), snapshot.segment_edge_base(s) as u32)
            })
            .collect();
        MergedAdjacency { parts }
    }

    /// Assemble from cached per-segment indices (used by
    /// [`AdjacencyCache`]).
    fn from_parts(parts: Vec<(Arc<TemporalAdjacency>, u32)>) -> MergedAdjacency {
        MergedAdjacency { parts }
    }

    /// Number of segment indices merged on read.
    pub fn num_segments(&self) -> usize {
        self.parts.len()
    }

    /// Full (time-sorted) neighbor view of `node` across all segments.
    pub fn neighbors(&self, node: u32) -> MergedNeighbors<'_> {
        MergedNeighbors::collect(self.parts.iter().map(|(adj, base)| {
            let (n, t, e) = adj.neighbors(node);
            (n, t, e, *base)
        }))
    }

    /// Neighbors of `node` strictly before `t`, across all segments, in
    /// global time order (oldest first).
    pub fn neighbors_before(&self, node: u32, t: Timestamp) -> MergedNeighbors<'_> {
        MergedNeighbors::collect(self.parts.iter().map(|(adj, base)| {
            let (n, ts, e) = adj.neighbors_before(node, t);
            (n, ts, e, *base)
        }))
    }

    /// All-time degree of `node`.
    pub fn degree(&self, node: u32) -> usize {
        self.parts.iter().map(|(a, _)| a.degree(node)).sum()
    }

    /// Total stored triples (2 × edges).
    pub fn len(&self) -> usize {
        self.parts.iter().map(|(a, _)| a.len()).sum()
    }

    /// True when no segment holds any triple.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One per-segment slice of a node's neighbor list:
/// (neighbors, times, segment-local edge indices, logical edge base).
pub type NeighborPart<'a> = (&'a [u32], &'a [Timestamp], &'a [u32], u32);

/// A node's neighbor list assembled from per-segment slices — zero-copy,
/// globally time-sorted (oldest first, index `len()-1` is the newest).
/// The common ≤1-non-empty-part case (every single-segment snapshot, and
/// most nodes of multi-segment ones) is stored inline with no heap
/// allocation, so samplers on one-shot datasets pay nothing over the old
/// direct slice API.
pub struct MergedNeighbors<'a> {
    parts: PartStore<'a>,
    len: usize,
}

enum PartStore<'a> {
    None,
    One(NeighborPart<'a>),
    Many(Vec<NeighborPart<'a>>),
}

impl<'a> MergedNeighbors<'a> {
    fn collect(parts: impl Iterator<Item = NeighborPart<'a>>) -> MergedNeighbors<'a> {
        let mut store = PartStore::None;
        let mut len = 0;
        for p in parts {
            if p.0.is_empty() {
                continue;
            }
            len += p.0.len();
            store = match store {
                PartStore::None => PartStore::One(p),
                PartStore::One(first) => PartStore::Many(vec![first, p]),
                PartStore::Many(mut v) => {
                    v.push(p);
                    PartStore::Many(v)
                }
            };
        }
        MergedNeighbors { parts: store, len }
    }

    fn parts(&self) -> &[NeighborPart<'a>] {
        match &self.parts {
            PartStore::None => &[],
            PartStore::One(p) => std::slice::from_ref(p),
            PartStore::Many(v) => v,
        }
    }

    /// Number of (neighbor, time, edge) triples in the view.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the view is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The `i`-th triple in global time order:
    /// `(neighbor, time, logical edge index)`.
    pub fn get(&self, i: usize) -> (u32, Timestamp, u32) {
        let mut i = i;
        for (n, t, e, base) in self.parts() {
            if i < n.len() {
                return (n[i], t[i], e[i] + base);
            }
            i -= n.len();
        }
        panic!("MergedNeighbors index {i} out of bounds (len {})", self.len);
    }

    /// Iterate triples oldest-first.
    pub fn iter(&self) -> impl Iterator<Item = (u32, Timestamp, u32)> + '_ {
        self.parts().iter().flat_map(|(n, t, e, base)| {
            (0..n.len()).map(move |i| (n[i], t[i], e[i] + base))
        })
    }

    /// Iterate triples newest-first — the point-read access pattern
    /// ("last k before t" / "most recent pair event"), which touches
    /// only as many triples as it consumes instead of walking the whole
    /// history forward.
    pub fn iter_rev(&self) -> impl Iterator<Item = (u32, Timestamp, u32)> + '_ {
        self.parts().iter().rev().flat_map(|(n, t, e, base)| {
            (0..n.len()).rev().map(move |i| (n[i], t[i], e[i] + base))
        })
    }

    /// Copy the view into owned columns (the DyGLib-baseline cost model;
    /// hot paths should prefer [`MergedNeighbors::collect_into`] with a
    /// reused [`NeighborCols`] scratch instead).
    pub fn to_vecs(&self) -> (Vec<u32>, Vec<Timestamp>, Vec<u32>) {
        let mut cols = NeighborCols::new();
        self.collect_into(&mut cols);
        (cols.nbr, cols.ts, cols.eidx)
    }

    /// Copy the view into a reusable [`NeighborCols`] scratch buffer —
    /// the allocation-free replacement for [`MergedNeighbors::to_vecs`]
    /// on the sampler hot path (the scratch's capacity is retained
    /// across seeds, so steady state allocates nothing). Edge indices
    /// are rebased to logical snapshot indices via
    /// [`kernels::add_offset_u32`].
    pub fn collect_into(&self, out: &mut NeighborCols) {
        out.clear();
        out.reserve(self.len);
        for (ns, ts, es, base) in self.parts() {
            out.nbr.extend_from_slice(ns);
            out.ts.extend_from_slice(ts);
            kernels::add_offset_u32(es, *base, &mut out.eidx);
        }
    }

    /// The view's single contiguous part, if it has exactly one —
    /// lets callers skip the scratch copy entirely in the common
    /// single-segment case. Returns `(neighbors, times, local edge
    /// indices, logical edge base)`.
    pub fn single_part(&self) -> Option<NeighborPart<'a>> {
        match &self.parts {
            PartStore::One(p) => Some(*p),
            _ => None,
        }
    }
}

/// Owned, reusable neighbor columns filled by
/// [`MergedNeighbors::collect_into`]: `(nbr, ts, eidx)` with logical
/// (snapshot-wide) edge indices. Keep one per sampler and reuse it
/// across seeds to stay allocation-free in steady state.
#[derive(Debug, Default, Clone)]
pub struct NeighborCols {
    /// Neighbor node ids, oldest-first.
    pub nbr: Vec<u32>,
    /// Event timestamps, non-decreasing.
    pub ts: Vec<Timestamp>,
    /// Logical edge indices into the owning snapshot.
    pub eidx: Vec<u32>,
}

impl NeighborCols {
    /// Empty scratch.
    pub fn new() -> NeighborCols {
        NeighborCols::default()
    }

    /// Number of triples currently held.
    pub fn len(&self) -> usize {
        self.nbr.len()
    }

    /// True when no triples are held.
    pub fn is_empty(&self) -> bool {
        self.nbr.is_empty()
    }

    /// Drop contents, keep capacity.
    pub fn clear(&mut self) {
        self.nbr.clear();
        self.ts.clear();
        self.eidx.clear();
    }

    fn reserve(&mut self, n: usize) {
        self.nbr.reserve(n);
        self.ts.reserve(n);
        self.eidx.reserve(n);
    }
}

/// Memoized, thread-safe adjacency shared by stateless hooks.
///
/// Staleness is decided by the snapshot's explicit [`SnapshotId`]
/// (store id + monotonic generation) — ids are globally unique and never
/// reused, so no allocator recycling can cause a false hit. Per-segment
/// indices are cached by their globally unique segment ids and survive
/// across generations: when a writer seals a new segment, the next `get`
/// builds only that segment's **delta index** and merges it with the
/// cached ones on read. Indices for segments no longer present (after
/// compaction) are dropped.
#[derive(Debug, Default)]
pub struct AdjacencyCache {
    inner: Mutex<CacheInner>,
}

#[derive(Debug, Default)]
struct CacheInner {
    /// The merged view of the most recent snapshot seen.
    merged: Option<(SnapshotId, Arc<MergedAdjacency>)>,
    /// Immutable per-segment indices, keyed by globally unique segment id.
    per_segment: HashMap<u64, Arc<TemporalAdjacency>>,
}

impl AdjacencyCache {
    /// Empty cache.
    pub fn new() -> AdjacencyCache {
        AdjacencyCache::default()
    }

    /// Shared merged index for `snapshot`, building only what is missing.
    pub fn get(&self, snapshot: &StorageSnapshot) -> Arc<MergedAdjacency> {
        let id = snapshot.id();
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((k, merged)) = &inner.merged {
            if *k == id {
                return Arc::clone(merged);
            }
        }
        let mut fresh: HashMap<u64, Arc<TemporalAdjacency>> =
            HashMap::with_capacity(snapshot.num_segments());
        let mut parts = Vec::with_capacity(snapshot.num_segments());
        for (s, seg) in snapshot.segments().iter().enumerate() {
            let seg_id = snapshot.segment_ids()[s];
            let adj = inner
                .per_segment
                .get(&seg_id)
                .cloned()
                .unwrap_or_else(|| TemporalAdjacency::build(seg).into_shared());
            fresh.insert(seg_id, Arc::clone(&adj));
            parts.push((adj, snapshot.segment_edge_base(s) as u32));
        }
        // Retain only the current snapshot's segments (drops compacted-away
        // or superseded indices).
        inner.per_segment = fresh;
        let merged = Arc::new(MergedAdjacency::from_parts(parts));
        inner.merged = Some((id, Arc::clone(&merged)));
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;
    use crate::graph::segment::{SealPolicy, SegmentedStorage};

    fn storage() -> GraphStorage {
        let edges = vec![
            EdgeEvent { t: 10, src: 0, dst: 1, features: vec![] },
            EdgeEvent { t: 20, src: 0, dst: 2, features: vec![] },
            EdgeEvent { t: 30, src: 1, dst: 2, features: vec![] },
            EdgeEvent { t: 40, src: 0, dst: 1, features: vec![] },
        ];
        GraphStorage::from_events(edges, vec![], 4, None, None).unwrap()
    }

    #[test]
    fn csr_structure() {
        let adj = TemporalAdjacency::build(&storage());
        assert_eq!(adj.len(), 8);
        assert_eq!(adj.degree(0), 3);
        assert_eq!(adj.degree(3), 0);
        let (n, t, e) = adj.neighbors(0);
        assert_eq!(n, &[1, 2, 1]);
        assert_eq!(t, &[10, 20, 40]);
        assert_eq!(e, &[0, 1, 3]);
    }

    #[test]
    fn undirected_symmetry() {
        let adj = TemporalAdjacency::build(&storage());
        let (n1, _, _) = adj.neighbors(1);
        assert_eq!(n1, &[0, 2, 0]);
    }

    #[test]
    fn temporal_cut_is_strict() {
        let adj = TemporalAdjacency::build(&storage());
        let (n, t, _) = adj.neighbors_before(0, 20);
        assert_eq!(n, &[1]);
        assert_eq!(t, &[10]);
        // Exactly at an event time: that event is excluded (no leakage).
        let (n2, _, _) = adj.neighbors_before(0, 10);
        assert!(n2.is_empty());
        let (n3, _, _) = adj.neighbors_before(0, 1_000);
        assert_eq!(n3.len(), 3);
    }

    #[test]
    fn shared_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<GraphStorage>();
        assert_send_sync::<TemporalAdjacency>();
        assert_send_sync::<MergedAdjacency>();
        assert_send_sync::<AdjacencyCache>();
        assert_send_sync::<Arc<TemporalAdjacency>>();
    }

    #[test]
    fn cache_builds_once_and_detects_generations() {
        let snap = storage().into_snapshot();
        let cache = AdjacencyCache::new();
        let a = cache.get(&snap);
        let b = cache.get(&snap);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must reuse the build");
        // A different snapshot (fresh store id) invalidates the slot.
        let other = GraphStorage::from_events(
            vec![EdgeEvent { t: 1, src: 0, dst: 1, features: vec![] }],
            vec![],
            2,
            None,
            None,
        )
        .unwrap()
        .into_snapshot();
        let c = cache.get(&other);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn merged_view_matches_single_segment_build() {
        // Stream the same edges through a segmented store; the merged
        // adjacency must agree with the single-storage CSR, with logical
        // edge indices.
        let edges: Vec<EdgeEvent> = (0..60)
            .map(|i| EdgeEvent {
                t: (i as i64 / 2) * 5,
                src: (i % 4) as u32,
                dst: 4 + (i % 3) as u32,
                features: vec![],
            })
            .collect();
        let single = TemporalAdjacency::build(
            &GraphStorage::from_events(edges.clone(), vec![], 7, None, None).unwrap(),
        );
        let mut st = SegmentedStorage::new(7, SealPolicy::by_events(7));
        for e in &edges {
            st.append_edge(e.clone()).unwrap();
        }
        let snap = st.snapshot().unwrap();
        assert!(snap.num_segments() > 4);
        let merged = MergedAdjacency::build(&snap);
        assert_eq!(merged.len(), single.len());
        for node in 0..7u32 {
            assert_eq!(merged.degree(node), single.degree(node));
            let (sn, st_, se) = single.neighbors(node);
            let mv = merged.neighbors(node);
            assert_eq!(mv.len(), sn.len());
            for (i, got) in mv.iter().enumerate() {
                assert_eq!(got, (sn[i], st_[i], se[i]), "node {node} slot {i}");
            }
            // Time cuts agree too.
            for t in [0i64, 3, 50, 100, 1000] {
                let (cn, _, _) = single.neighbors_before(node, t);
                assert_eq!(merged.neighbors_before(node, t).len(), cn.len());
            }
        }
    }

    #[test]
    fn cache_reuses_segment_indices_across_generations() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2));
        st.append_edge(EdgeEvent { t: 1, src: 0, dst: 1, features: vec![] }).unwrap();
        st.append_edge(EdgeEvent { t: 2, src: 1, dst: 2, features: vec![] }).unwrap();
        let cache = AdjacencyCache::new();
        let snap1 = st.snapshot().unwrap();
        let m1 = cache.get(&snap1);
        assert_eq!(m1.num_segments(), 1);

        // Seal a second segment: only the delta index is new.
        st.append_edge(EdgeEvent { t: 3, src: 2, dst: 3, features: vec![] }).unwrap();
        st.append_edge(EdgeEvent { t: 4, src: 3, dst: 0, features: vec![] }).unwrap();
        let snap2 = st.snapshot().unwrap();
        let m2 = cache.get(&snap2);
        assert_eq!(m2.num_segments(), 2);
        assert!(
            Arc::ptr_eq(&m1.parts[0].0, &m2.parts[0].0),
            "first segment's index must be reused, not rebuilt"
        );
        // Old merged view still answers for the old snapshot's data.
        assert_eq!(m1.len(), 4);
        assert_eq!(m2.len(), 8);
    }

    #[test]
    fn merged_neighbors_to_vecs_and_get_agree() {
        let snap = storage().into_snapshot();
        let merged = MergedAdjacency::build(&snap);
        let view = merged.neighbors_before(0, 1000);
        let (n, t, e) = view.to_vecs();
        assert_eq!(n.len(), view.len());
        for i in 0..view.len() {
            assert_eq!(view.get(i), (n[i], t[i], e[i]));
        }
        // The newest-first iterator is exactly the forward order reversed.
        let mut rev: Vec<_> = view.iter_rev().collect();
        rev.reverse();
        assert_eq!(rev, view.iter().collect::<Vec<_>>());
    }

    #[test]
    fn collect_into_reuses_scratch_and_matches_to_vecs() {
        // Multi-segment snapshot so edge-index rebasing is exercised.
        let mut st = SegmentedStorage::new(6, SealPolicy::by_events(3));
        for i in 0..12u32 {
            st.append_edge(EdgeEvent {
                t: i as i64,
                src: i % 3,
                dst: 3 + (i % 2),
                features: vec![],
            })
            .unwrap();
        }
        let snap = st.snapshot().unwrap();
        let merged = MergedAdjacency::build(&snap);
        let mut cols = NeighborCols::new();
        for node in 0..6u32 {
            for t in [0i64, 5, 100] {
                let view = merged.neighbors_before(node, t);
                view.collect_into(&mut cols);
                let (n, ts, e) = view.to_vecs();
                assert_eq!(cols.nbr, n, "node {node} t {t}");
                assert_eq!(cols.ts, ts);
                assert_eq!(cols.eidx, e);
                assert_eq!(cols.len(), view.len());
            }
        }
        // Scratch capacity survives clears: fill big, then small.
        let big = merged.neighbors(0);
        big.collect_into(&mut cols);
        let cap = cols.nbr.capacity();
        merged.neighbors_before(0, 0).collect_into(&mut cols);
        assert!(cols.is_empty());
        assert_eq!(cols.nbr.capacity(), cap, "clear must keep capacity");
    }

    #[test]
    fn per_node_lists_time_sorted_randomized() {
        let mut rng = crate::util::Rng::new(77);
        let edges: Vec<EdgeEvent> = (0..300)
            .map(|_| EdgeEvent {
                t: rng.range(0, 1000),
                src: rng.below(10) as u32,
                dst: rng.below(10) as u32,
                features: vec![],
            })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 10, None, None).unwrap();
        let adj = TemporalAdjacency::build(&st);
        for node in 0..10 {
            let (_, ts, _) = adj.neighbors(node);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "node {node} unsorted");
        }
    }
}
