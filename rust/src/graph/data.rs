//! Dataset container and chronological splits (paper §4, "IO Adaptors").
//!
//! [`DGData`] owns one immutable [`StorageSnapshot`] plus task metadata and
//! produces train/validation/test [`DGraph`] views via chronological
//! splitting (the TGB protocol: 70/15/15 by time). One-shot datasets wrap
//! a freshly built [`GraphStorage`] into a single-segment snapshot;
//! streamed datasets pass a [`super::segment::SegmentedStorage`] snapshot
//! directly via [`DGData::from_snapshot`].

use crate::error::{Result, TgmError};
use crate::graph::segment::StorageSnapshot;
use crate::graph::storage::GraphStorage;
use crate::graph::view::DGraph;
use crate::util::Timestamp;
use std::collections::HashSet;
use std::sync::Arc;

/// Prediction task attached to a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Dynamic link property prediction (one-vs-many evaluation).
    LinkPrediction,
    /// Dynamic node property prediction (NDCG@10 evaluation).
    NodeProperty,
    /// Dynamic graph property prediction (AUC evaluation).
    GraphProperty,
}

/// Train/validation/test views sharing one snapshot.
#[derive(Debug, Clone)]
pub struct Splits {
    pub train: DGraph,
    pub val: DGraph,
    pub test: DGraph,
}

/// A loaded dataset: snapshot + name + task.
#[derive(Debug, Clone)]
pub struct DGData {
    storage: Arc<StorageSnapshot>,
    name: String,
    task: Task,
}

impl DGData {
    /// Wrap a one-shot storage with a dataset name and task.
    pub fn new(storage: GraphStorage, name: impl Into<String>, task: Task) -> DGData {
        DGData { storage: storage.into_shared_snapshot(), name: name.into(), task }
    }

    /// Wrap an existing snapshot (e.g. from a streaming store).
    pub fn from_snapshot(
        storage: Arc<StorageSnapshot>,
        name: impl Into<String>,
        task: Task,
    ) -> DGData {
        DGData { storage, name: name.into(), task }
    }

    /// Dataset name (e.g. `wiki-small`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Attached task.
    pub fn task(&self) -> Task {
        self.task
    }

    /// Shared snapshot.
    pub fn storage(&self) -> &Arc<StorageSnapshot> {
        &self.storage
    }

    /// View over the full dataset.
    pub fn full(&self) -> DGraph {
        DGraph::full(Arc::clone(&self.storage))
    }

    /// Chronological split at the given ratios (must sum to <= 1).
    ///
    /// Split boundaries are timestamps, so events sharing a timestamp are
    /// never divided across splits (TGB protocol).
    pub fn split_ratios(&self, train: f64, val: f64) -> Result<Splits> {
        if !(0.0..=1.0).contains(&train) || !(0.0..=1.0).contains(&val) || train + val > 1.0 {
            return Err(TgmError::Config(format!("bad split ratios ({train}, {val})")));
        }
        let n = self.storage.num_edges();
        let t_begin = self.storage.start_time();
        let t_end = self.storage.end_time() + 1;

        // Timestamp at the split quantiles; clamp to event boundaries.
        let train_idx = ((n as f64 * train) as usize).min(n - 1);
        let val_idx = ((n as f64 * (train + val)) as usize).min(n - 1);
        let t_train_end = self.storage.edge_ts_at(train_idx);
        let t_val_end = self.storage.edge_ts_at(val_idx).max(t_train_end);

        let train = DGraph::slice_of(Arc::clone(&self.storage), t_begin, t_train_end)?;
        let val = DGraph::slice_of(Arc::clone(&self.storage), t_train_end, t_val_end)?;
        let test = DGraph::slice_of(Arc::clone(&self.storage), t_val_end, t_end)?;
        Ok(Splits { train, val, test })
    }

    /// Default TGB split: 70% train, 15% validation, 15% test.
    pub fn split(&self) -> Result<Splits> {
        self.split_ratios(0.70, 0.15)
    }

    /// Dataset statistics (Table 13 columns).
    pub fn stats(&self) -> DatasetStats {
        DatasetStats::compute(&self.storage, &self.name)
    }
}

/// Summary statistics matching the paper's Table 13.
#[derive(Debug, Clone)]
pub struct DatasetStats {
    pub name: String,
    pub num_nodes: usize,
    pub num_edges: usize,
    pub num_unique_edges: usize,
    pub num_unique_steps: usize,
    /// Fraction of test-period edges never seen during the train period
    /// (Poursafaei et al. 2022's "surprise" index, on the default split).
    pub surprise: f64,
    pub duration: Timestamp,
    pub num_node_events: usize,
}

impl DatasetStats {
    fn compute(storage: &Arc<StorageSnapshot>, name: &str) -> DatasetStats {
        let n = storage.num_edges();
        // Surprise on the default 85/15 boundary (train+val vs test).
        let split_idx = (n as f64 * 0.85) as usize;

        let mut unique: HashSet<(u32, u32)> = HashSet::with_capacity(n);
        let mut train_edges: HashSet<(u32, u32)> = HashSet::with_capacity(split_idx);
        let mut unseen = 0usize;
        let mut i = 0usize;
        for (seg, local) in storage.edge_chunks(0..n) {
            let src = &seg.edge_src()[local.clone()];
            let dst = &seg.edge_dst()[local];
            for k in 0..src.len() {
                let pair = (src[k], dst[k]);
                unique.insert(pair);
                if i < split_idx {
                    train_edges.insert(pair);
                } else if !train_edges.contains(&pair) {
                    unseen += 1;
                }
                i += 1;
            }
        }
        let test_n = n - split_idx;
        let surprise = if test_n == 0 { 0.0 } else { unseen as f64 / test_n as f64 };

        DatasetStats {
            name: name.to_string(),
            num_nodes: storage.num_nodes(),
            num_edges: n,
            num_unique_edges: unique.len(),
            num_unique_steps: storage.num_unique_timestamps(),
            surprise,
            duration: storage.end_time() - storage.start_time(),
            num_node_events: storage.num_node_events(),
        }
    }
}

impl std::fmt::Display for DatasetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: nodes={} edges={} unique_edges={} unique_steps={} surprise={:.3} duration={}s node_events={}",
            self.name,
            self.num_nodes,
            self.num_edges,
            self.num_unique_edges,
            self.num_unique_steps,
            self.surprise,
            self.duration,
            self.num_node_events
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;
    use crate::graph::segment::{SealPolicy, SegmentedStorage};

    fn data(n_edges: usize) -> DGData {
        let edges = (0..n_edges)
            .map(|i| EdgeEvent {
                t: i as i64,
                src: (i % 4) as u32,
                dst: ((i + 1) % 4) as u32,
                features: vec![],
            })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 4, None, None).unwrap();
        DGData::new(st, "toy", Task::LinkPrediction)
    }

    #[test]
    fn split_is_chronological_and_complete() {
        let d = data(100);
        let s = d.split().unwrap();
        assert_eq!(s.train.num_edges() + s.val.num_edges() + s.test.num_edges(), 100);
        assert!(s.train.end_time() <= s.val.start_time() + 1);
        assert!(s.val.end_time() <= s.test.start_time() + 1);
        // Roughly 70/15/15.
        assert!((65..=75).contains(&s.train.num_edges()), "{}", s.train.num_edges());
        assert!((10..=20).contains(&s.val.num_edges()));
        assert!((10..=20).contains(&s.test.num_edges()));
    }

    #[test]
    fn split_never_divides_a_timestamp() {
        // All events share one timestamp: everything must land in one split.
        let edges = (0..10)
            .map(|i| EdgeEvent { t: 5, src: (i % 3) as u32, dst: ((i + 1) % 3) as u32, features: vec![] })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 3, None, None).unwrap();
        let d = DGData::new(st, "same-ts", Task::LinkPrediction);
        let s = d.split().unwrap();
        let counts =
            [s.train.num_edges(), s.val.num_edges(), s.test.num_edges()];
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert_eq!(counts.iter().filter(|&&c| c > 0).count(), 1);
    }

    #[test]
    fn bad_ratios_rejected() {
        let d = data(10);
        assert!(d.split_ratios(0.8, 0.3).is_err());
        assert!(d.split_ratios(-0.1, 0.5).is_err());
    }

    #[test]
    fn stats_fields() {
        let d = data(100);
        let st = d.stats();
        assert_eq!(st.num_edges, 100);
        assert_eq!(st.num_nodes, 4);
        assert_eq!(st.num_unique_edges, 4); // cycle of 4 pairs
        assert_eq!(st.num_unique_steps, 100);
        assert_eq!(st.duration, 99);
        // Every test edge was seen in train -> surprise 0.
        assert_eq!(st.surprise, 0.0);
    }

    #[test]
    fn streamed_dataset_matches_one_shot() {
        // Identical stats and splits whether the data was built one-shot
        // or appended through a segmented store.
        let one_shot = data(100);
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(23));
        for i in 0..100usize {
            st.append_edge(EdgeEvent {
                t: i as i64,
                src: (i % 4) as u32,
                dst: ((i + 1) % 4) as u32,
                features: vec![],
            })
            .unwrap();
        }
        let streamed = DGData::from_snapshot(st.snapshot().unwrap(), "toy", Task::LinkPrediction);
        assert!(streamed.storage().num_segments() > 1);
        let (a, b) = (one_shot.stats(), streamed.stats());
        assert_eq!(a.num_edges, b.num_edges);
        assert_eq!(a.num_unique_edges, b.num_unique_edges);
        assert_eq!(a.num_unique_steps, b.num_unique_steps);
        assert_eq!(a.surprise, b.surprise);
        let (sa, sb) = (one_shot.split().unwrap(), streamed.split().unwrap());
        assert_eq!(sa.train.num_edges(), sb.train.num_edges());
        assert_eq!(sa.val.num_edges(), sb.val.num_edges());
        assert_eq!(sa.test.num_edges(), sb.test.num_edges());
    }
}
