//! Incrementally-maintained DTDG materialized views.
//!
//! [`discretize`](crate::graph::discretize::discretize) converts a CTDG
//! snapshot to a coarser discrete-time graph in one O(n) rescan. For a
//! *growing* store that cost recurs on every refresh, so this module
//! turns coarse views into **derived segments** maintained incrementally
//! as the base [`SegmentedStorage`](crate::graph::SegmentedStorage)
//! seals:
//!
//! * A [`DtdgView`] is registered on a store with a target granularity
//!   and [`ReduceOp`]. Each seal hands the view only the newly sealed
//!   events; refresh cost is O(new events), amortized O(1) per event.
//! * Consumed events are split at the **last complete bucket boundary**.
//!   The complete prefix is discretized alone (with the same vectorized
//!   [`discretize_columns`] pass the one-shot path uses, anchored at the
//!   stream's global origin) and frozen into a bucket-aligned derived
//!   segment. Only the trailing partial bucket region stays mutable: it
//!   is held as raw pending columns and re-reduced into a fresh tail
//!   segment on every refresh (the "partial-bucket rule").
//! * A bucket is complete exactly when no future event can land in it.
//!   Stale appends are rejected by the base store, so every future edge
//!   has `t >= last sealed edge timestamp` — all buckets strictly before
//!   `bucket(last_edge_ts)` are final. Node events have their own
//!   watermark and are finalized against it independently.
//! * Each refresh publishes a fresh `Arc<StorageSnapshot>` generation
//!   (finalized segments + tail) through a
//!   [`SnapshotCell`](crate::graph::SnapshotCell), so an hourly/daily
//!   view is always one `pin()` away.
//!
//! **Compaction invariance.** Tiered compaction replaces a run of base
//! segments with one merged segment holding the *identical* logical
//! event stream (runs are addressed by never-reused segment ids, and
//! installs splice byte-identical columns). The view consumes the stream
//! by logical offset, not by segment identity, so an install changes
//! nothing it depends on — the derived run needs no rebuild, which is
//! the cheapest possible "rebuild only the affected run". The
//! integration property test pins this under randomized fanouts.
//!
//! **Bit-identity.** The view's concatenated columns are bit-identical
//! to `discretize()` over the full coalesced snapshot because (a) bucket
//! classes never straddle derived-segment boundaries (cuts are bucket
//! starts), (b) the class sort inside `discretize_columns` is a total
//! order tie-broken by stream position, so per-class f32 folds run in
//! the same order no matter how the stream is sliced, and (c) both paths
//! share one bucket origin: the stream's first sealed edge timestamp,
//! which is fixed forever after the first seal.

use crate::error::{Result, TgmError};
use crate::graph::discretize::{
    check_coarser_granularity, discretize_columns, EventColumns, ReduceOp,
};
use crate::graph::segment::{next_id, SnapshotCell, SnapshotId, StorageSnapshot};
use crate::graph::storage::GraphStorage;
use crate::obs::{self, Counter, Gauge, Histogram, Label};
use crate::util::{TimeGranularity, Timestamp};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// State shared between a [`DtdgView`] (owned by the store) and its
/// [`DtdgHandle`]s (held by trainers / serving readers).
struct ViewShared {
    cell: SnapshotCell,
    /// Exclusive end timestamp of the finalized (complete-bucket) edge
    /// region; `i64::MIN` until the first refresh finalizes anything.
    complete_until: AtomicI64,
    refreshes: AtomicU64,
    /// Most recent refresh failure (e.g. the base stream's inferred
    /// granularity is still event-ordered or finer than the target).
    /// Cleared by the next successful refresh; refreshes never fail the
    /// seal that triggered them.
    last_error: Mutex<Option<String>>,
    /// `store=<view_store_id>` label shared by this view's registry
    /// series, so concurrent views never cross-contaminate.
    store: Label,
    /// `tgm_dtdg_refresh_duration_us{store}`.
    refresh_hist: Histogram,
    /// `tgm_dtdg_refreshes_total{store}`.
    refreshes_total: Counter,
    /// `tgm_dtdg_complete_lag_seconds{store}`: how far the newest sealed
    /// edge runs ahead of the finalized-bucket watermark.
    lag_gauge: Gauge,
    /// `tgm_dtdg_error{store}`: 1 while the view is stalled on a refresh
    /// error, 0 once a later refresh succeeds.
    error_gauge: Gauge,
    /// `tgm_dtdg_errors_total{store}`.
    errors_total: Counter,
}

/// Reader handle to a registered DTDG materialized view.
///
/// Cheap to clone; outlives nothing — the view keeps refreshing as long
/// as its store lives, and pinned snapshots stay byte-stable forever.
#[derive(Clone)]
pub struct DtdgHandle {
    target: TimeGranularity,
    reduce: ReduceOp,
    shared: Arc<ViewShared>,
}

impl DtdgHandle {
    /// Pin the latest published view generation (`None` before the
    /// first successful refresh).
    pub fn pin(&self) -> Option<Arc<StorageSnapshot>> {
        self.shared.cell.pin()
    }

    /// The underlying publish cell (for wiring into serving surfaces).
    pub fn cell(&self) -> SnapshotCell {
        self.shared.cell.clone()
    }

    /// Target granularity of the view.
    pub fn target(&self) -> TimeGranularity {
        self.target
    }

    /// Reduction op of the view.
    pub fn reduce(&self) -> ReduceOp {
        self.reduce
    }

    /// Exclusive end timestamp of the finalized region: every bucket
    /// starting strictly before this is complete — no future append can
    /// add events to it. `None` until the first refresh.
    pub fn complete_until(&self) -> Option<Timestamp> {
        let v = self.shared.complete_until.load(Ordering::Acquire);
        if v == i64::MIN {
            None
        } else {
            Some(v)
        }
    }

    /// Generation of the latest published view snapshot.
    pub fn generation(&self) -> Option<u64> {
        self.shared.cell.generation()
    }

    /// Number of successful refreshes so far.
    pub fn refreshes(&self) -> u64 {
        self.shared.refreshes.load(Ordering::Relaxed)
    }

    /// The most recent refresh error, if the view is currently stalled
    /// (it retries on every seal).
    pub fn last_error(&self) -> Option<String> {
        self.shared.last_error.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// One incrementally-maintained materialized view (store-side state).
pub(crate) struct DtdgView {
    target: TimeGranularity,
    reduce: ReduceOp,
    /// Bucket origin: the stream's first sealed edge timestamp. Fixed at
    /// the first refresh that sees a sealed edge (stale appends are
    /// rejected, so it can never change afterwards) and identical to the
    /// `start_time()` a full-snapshot `discretize()` would use.
    origin: Option<Timestamp>,
    /// Finalized bucket-aligned derived segments (+ their never-reused
    /// ids). Immutable once pushed.
    derived: Vec<Arc<GraphStorage>>,
    derived_ids: Vec<u64>,
    /// Raw pending columns: consumed events not yet provably complete
    /// (the trailing partial bucket region), re-reduced every refresh.
    pend_ts: Vec<Timestamp>,
    pend_src: Vec<u32>,
    pend_dst: Vec<u32>,
    pend_feats: Vec<f32>,
    pend_node_ts: Vec<Timestamp>,
    pend_node_ids: Vec<u32>,
    pend_node_feats: Vec<f32>,
    edge_feat_dim: usize,
    node_feat_dim: usize,
    /// Logical consumption offsets into the base store's sealed stream.
    /// Compaction preserves the stream byte-for-byte, so these survive
    /// installs unchanged.
    consumed_edges: usize,
    consumed_nodes: usize,
    /// Set when a refresh failed *after* consuming events into the
    /// pending columns: the consumption counts then match the stream
    /// again, but unprocessed data is sitting in the view. The next
    /// refresh must not treat matching counts as a no-op — it reruns
    /// the reduce over the pending columns (and clears the recorded
    /// error on success) instead of staying stalled forever.
    retry: bool,
    /// Test hook: make the next refresh fail after its consumption
    /// bookkeeping (simulating a reduce failure mid-refresh).
    #[cfg(test)]
    pub(crate) fail_next: bool,
    /// Store id for the view's published snapshots (distinct from the
    /// base store's).
    view_store_id: u64,
    generation: u64,
    shared: Arc<ViewShared>,
}

impl DtdgView {
    pub(crate) fn new(target: TimeGranularity, reduce: ReduceOp) -> DtdgView {
        let view_store_id = next_id();
        let store = Label::from(view_store_id.to_string());
        let registry = obs::registry();
        DtdgView {
            target,
            reduce,
            origin: None,
            derived: Vec::new(),
            derived_ids: Vec::new(),
            pend_ts: Vec::new(),
            pend_src: Vec::new(),
            pend_dst: Vec::new(),
            pend_feats: Vec::new(),
            pend_node_ts: Vec::new(),
            pend_node_ids: Vec::new(),
            pend_node_feats: Vec::new(),
            edge_feat_dim: 0,
            node_feat_dim: 0,
            consumed_edges: 0,
            consumed_nodes: 0,
            retry: false,
            #[cfg(test)]
            fail_next: false,
            view_store_id,
            generation: 0,
            shared: Arc::new(ViewShared {
                cell: SnapshotCell::new(),
                complete_until: AtomicI64::new(i64::MIN),
                refreshes: AtomicU64::new(0),
                last_error: Mutex::new(None),
                refresh_hist: registry
                    .histogram("tgm_dtdg_refresh_duration_us", &[("store", store.clone())]),
                refreshes_total: registry
                    .counter("tgm_dtdg_refreshes_total", &[("store", store.clone())]),
                lag_gauge: registry
                    .gauge("tgm_dtdg_complete_lag_seconds", &[("store", store.clone())]),
                error_gauge: registry.gauge("tgm_dtdg_error", &[("store", store.clone())]),
                errors_total: registry
                    .counter("tgm_dtdg_errors_total", &[("store", store.clone())]),
                store,
            }),
        }
    }

    pub(crate) fn handle(&self) -> DtdgHandle {
        DtdgHandle { target: self.target, reduce: self.reduce, shared: Arc::clone(&self.shared) }
    }

    /// Refresh from the base store's sealed segments, recording (never
    /// propagating) errors — a stalled view must not fail the seal that
    /// triggered it, and it retries on the next one.
    pub(crate) fn refresh_recording(
        &mut self,
        sealed: &[Arc<GraphStorage>],
        native: TimeGranularity,
        num_nodes: usize,
        static_feat_dim: usize,
        static_feats: &Arc<Vec<f32>>,
    ) {
        let started = Instant::now();
        let span = obs::span("dtdg", "refresh").with_tenant(self.shared.store.clone());
        let res = self.refresh(sealed, native, num_nodes, static_feat_dim, static_feats);
        drop(span);
        let mut slot = self.shared.last_error.lock().unwrap_or_else(|e| e.into_inner());
        match res {
            Ok(true) => {
                self.shared
                    .refresh_hist
                    .record_us(started.elapsed().as_micros().min(u64::MAX as u128) as u64);
                self.shared.refreshes_total.inc();
                if slot.take().is_some() {
                    self.shared.error_gauge.set(0);
                    obs::event(
                        "dtdg",
                        "error_cleared",
                        Some(self.shared.store.clone()),
                        "a later refresh succeeded",
                    );
                }
                self.retry = false;
            }
            // A no-op refresh proves nothing about a previously recorded
            // stall (the failed events wait for a later seal to change
            // the stream) — keep the error visible.
            Ok(false) => {}
            Err(e) => {
                *slot = Some(e.to_string());
                self.retry = true;
                self.shared.error_gauge.set(1);
                self.shared.errors_total.inc();
                obs::event(
                    "dtdg",
                    "refresh_error",
                    Some(self.shared.store.clone()),
                    e.to_string(),
                );
            }
        }
    }

    /// Consume newly sealed events and publish a fresh view generation.
    /// Returns `true` when anything was consumed.
    pub(crate) fn refresh(
        &mut self,
        sealed: &[Arc<GraphStorage>],
        native: TimeGranularity,
        num_nodes: usize,
        static_feat_dim: usize,
        static_feats: &Arc<Vec<f32>>,
    ) -> Result<bool> {
        let edge_total: usize = sealed.iter().map(|s| s.num_edges()).sum();
        let node_total: usize = sealed.iter().map(|s| s.num_node_events()).sum();
        debug_assert!(edge_total >= self.consumed_edges && node_total >= self.consumed_nodes);
        // Matching counts are only a no-op when no earlier refresh died
        // holding consumed-but-unreduced events in the pending columns;
        // with `retry` set, fall through and rerun the reduce over them.
        if edge_total == self.consumed_edges && node_total == self.consumed_nodes && !self.retry {
            return Ok(false);
        }
        // No origin without a sealed edge: hold everything until the
        // first edge-bearing seal (base segments always carry one).
        if edge_total == 0 {
            return Ok(false);
        }
        let secs = check_coarser_granularity(native, self.target)?;
        let origin = *self.origin.get_or_insert_with(|| sealed[0].start_time());

        // Learn feature dims from the first segments that carry each kind.
        if self.edge_feat_dim == 0 {
            self.edge_feat_dim = sealed[0].edge_feat_dim();
        }
        if self.node_feat_dim == 0 {
            if let Some(s) = sealed.iter().find(|s| s.num_node_events() > 0) {
                self.node_feat_dim = s.node_feat_dim();
            }
        }
        let d = self.edge_feat_dim;
        let nd = self.node_feat_dim;

        // Append the unconsumed logical suffix of the sealed stream to
        // the pending columns. Offsets are logical, so this walk is
        // correct across compaction installs (same stream, fewer parts).
        let mut skip = self.consumed_edges;
        for seg in sealed {
            let n = seg.num_edges();
            if skip >= n {
                skip -= n;
                continue;
            }
            self.pend_ts.extend_from_slice(&seg.edge_ts()[skip..]);
            self.pend_src.extend_from_slice(&seg.edge_src()[skip..]);
            self.pend_dst.extend_from_slice(&seg.edge_dst()[skip..]);
            self.pend_feats.extend_from_slice(&seg.edge_feats()[skip * d..]);
            skip = 0;
        }
        let mut nskip = self.consumed_nodes;
        for seg in sealed {
            let n = seg.num_node_events();
            if nskip >= n {
                nskip -= n;
                continue;
            }
            self.pend_node_ts.extend_from_slice(&seg.node_event_ts()[nskip..]);
            self.pend_node_ids.extend_from_slice(&seg.node_event_ids()[nskip..]);
            self.pend_node_feats.extend_from_slice(&seg.node_event_feats()[nskip * nd..]);
            nskip = 0;
        }
        self.consumed_edges = edge_total;
        self.consumed_nodes = node_total;
        #[cfg(test)]
        if std::mem::take(&mut self.fail_next) {
            return Err(TgmError::Time("injected refresh failure after consumption".into()));
        }

        // Completeness watermarks. Future edge appends have
        // `t >= last_edge_ts`, so buckets before bucket(last_edge_ts)
        // are final; node events carry their own watermark.
        let last_edge_ts = sealed.last().expect("edge_total > 0").end_time();
        let edge_cut = origin + (last_edge_ts - origin).div_euclid(secs) * secs;
        self.shared.lag_gauge.set(last_edge_ts.saturating_sub(edge_cut));
        let ek = self.pend_ts.partition_point(|&t| t < edge_cut);
        let nk = match sealed.iter().rev().find_map(|s| s.node_event_ts().last().copied()) {
            Some(last_node_ts) => {
                let node_cut = origin + (last_node_ts - origin).div_euclid(secs) * secs;
                self.pend_node_ts.partition_point(|&t| t < node_cut)
            }
            None => 0,
        };

        // Freeze the complete prefix into a new derived segment. Node
        // events only ride along with an edge-bearing freeze so every
        // derived segment carries a time span (they stay pending
        // otherwise — the tail still serves them).
        if ek > 0 {
            let cols = EventColumns {
                ts: &self.pend_ts[..ek],
                src: &self.pend_src[..ek],
                dst: &self.pend_dst[..ek],
                feat_dim: d,
                feats: &self.pend_feats[..ek * d],
                node_ts: &self.pend_node_ts[..nk],
                node_ids: &self.pend_node_ids[..nk],
                node_feat_dim: nd,
                node_feats: &self.pend_node_feats[..nk * nd],
            };
            let out = discretize_columns(&cols, self.target, secs, origin, self.reduce)?;
            let seg = out.into_storage(num_nodes, 0, Vec::new(), self.target);
            self.derived.push(Arc::new(seg));
            self.derived_ids.push(next_id());
            self.pend_ts.drain(..ek);
            self.pend_src.drain(..ek);
            self.pend_dst.drain(..ek);
            self.pend_feats.drain(..ek * d);
            self.pend_node_ts.drain(..nk);
            self.pend_node_ids.drain(..nk);
            self.pend_node_feats.drain(..nk * nd);
            self.shared.complete_until.store(edge_cut, Ordering::Release);
        }

        // Re-reduce the trailing partial region into a fresh tail
        // segment (pending edges are never empty here: the newest sealed
        // edge is always in the incomplete bucket) and publish.
        debug_assert!(!self.pend_ts.is_empty());
        let tail_cols = EventColumns {
            ts: &self.pend_ts,
            src: &self.pend_src,
            dst: &self.pend_dst,
            feat_dim: d,
            feats: &self.pend_feats,
            node_ts: &self.pend_node_ts,
            node_ids: &self.pend_node_ids,
            node_feat_dim: nd,
            node_feats: &self.pend_node_feats,
        };
        let tail = discretize_columns(&tail_cols, self.target, secs, origin, self.reduce)?;
        let tail_seg = Arc::new(tail.into_storage(num_nodes, 0, Vec::new(), self.target));

        self.generation += 1;
        let mut segments = self.derived.clone();
        let mut ids = self.derived_ids.clone();
        segments.push(tail_seg);
        ids.push(next_id());
        let snap = StorageSnapshot::from_parts(
            segments,
            ids,
            num_nodes,
            self.target,
            static_feat_dim,
            Arc::clone(static_feats),
            SnapshotId { store: self.view_store_id, generation: self.generation },
        );
        self.shared.cell.publish(Arc::new(snap));
        self.shared.refreshes.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Number of finalized derived segments (diagnostics/tests).
    #[cfg(test)]
    pub(crate) fn num_derived(&self) -> usize {
        self.derived.len()
    }
}

/// Validate a view registration target: must be a wall-clock unit (an
/// event-ordered "view" could never bucket anything).
pub(crate) fn check_view_target(target: TimeGranularity) -> Result<()> {
    if target.seconds().is_none() {
        return Err(TgmError::Time(
            "DTDG view target must be a wall-clock granularity, not event-ordered".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::discretize::discretize;
    use crate::graph::events::EdgeEvent;
    use crate::graph::segment::{SealPolicy, SegmentedStorage};

    fn edge(t: Timestamp, src: u32, dst: u32, f: f32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![f] }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn view_tracks_full_discretize_across_seals() {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(usize::MAX))
            .with_granularity(TimeGranularity::Second);
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        assert!(h.pin().is_none());

        // Three seals with buckets straddling the seal boundaries.
        let chunks: Vec<Vec<EdgeEvent>> = vec![
            vec![edge(0, 0, 1, 1.0), edge(1800, 0, 1, 2.0)],
            vec![edge(1900, 0, 1, 4.0), edge(4000, 2, 3, 8.0)],
            vec![edge(4100, 2, 3, 16.0), edge(9000, 1, 2, 32.0)],
        ];
        for chunk in chunks {
            for e in chunk {
                st.append_edge(e).unwrap();
            }
            st.seal().unwrap();
            let view = h.pin().expect("published after seal");
            let full = discretize(&st.snapshot().unwrap(), TimeGranularity::Hour, ReduceOp::Sum)
                .unwrap();
            let got = view.coalesce();
            assert_eq!(got.edge_ts(), full.edge_ts());
            assert_eq!(got.edge_src(), full.edge_src());
            assert_eq!(got.edge_dst(), full.edge_dst());
            assert_eq!(bits(got.edge_feats()), bits(full.edge_feats()));
        }
        // Hour 0 closed once an hour-1 edge sealed; hour 1 closed at 9000.
        assert_eq!(h.complete_until(), Some(7200));
        assert_eq!(h.refreshes(), 3);
    }

    #[test]
    fn view_is_invariant_under_compaction_install() {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(2))
            .with_granularity(TimeGranularity::Second);
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        for i in 0..20i64 {
            st.append_edge(edge(i * 700, (i % 4) as u32, ((i + 1) % 4) as u32, i as f32)).unwrap();
        }
        st.seal().unwrap();
        let before = h.pin().unwrap();
        assert!(st.compact_tiered(4).unwrap().is_some());
        // Nothing new sealed: the published view generation is untouched
        // and a forced refresh is a no-op.
        st.refresh_dtdg_views();
        let after = h.pin().unwrap();
        assert_eq!(before.id(), after.id());
        // Content still matches a full rescan over the compacted base.
        let full =
            discretize(&st.snapshot().unwrap(), TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        let got = after.coalesce();
        assert_eq!(got.edge_ts(), full.edge_ts());
        assert_eq!(bits(got.edge_feats()), bits(full.edge_feats()));
    }

    #[test]
    fn registration_after_seals_catches_up() {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(3))
            .with_granularity(TimeGranularity::Second);
        for i in 0..12i64 {
            st.append_edge(edge(i * 1000, 0, 1, 1.0)).unwrap();
        }
        st.seal().unwrap();
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Count).unwrap();
        let view = h.pin().expect("catch-up publish at registration");
        let full =
            discretize(&st.snapshot().unwrap(), TimeGranularity::Hour, ReduceOp::Count).unwrap();
        assert_eq!(view.coalesce().edge_ts(), full.edge_ts());
    }

    #[test]
    fn event_target_is_rejected_and_event_native_stalls() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX));
        assert!(st.register_dtdg_view(TimeGranularity::Event, ReduceOp::Sum).is_err());

        // All-tied timestamps infer an event-ordered native granularity:
        // the view stalls with a recorded error instead of failing seal.
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        st.append_edge(edge(5, 0, 1, 1.0)).unwrap();
        st.append_edge(edge(5, 1, 2, 1.0)).unwrap();
        st.seal().unwrap();
        assert!(h.pin().is_none());
        assert!(h.last_error().unwrap().contains("event-ordered"));

        // A spaced edge refines the native granularity; the stalled view
        // catches up on the next seal.
        st.append_edge(edge(3605, 2, 3, 1.0)).unwrap();
        st.seal().unwrap();
        assert!(h.pin().is_some());
        assert!(h.last_error().is_none());
    }

    /// Regression (ISSUE 8): a refresh that fails *after* consuming
    /// events used to stall the view forever — the consumption counts
    /// matched the stream again, so every later refresh early-returned
    /// as a no-op, the recorded error stayed sticky, and the consumed
    /// events were never published. A retry must reprocess the pending
    /// columns and clear the error.
    #[test]
    fn post_consumption_refresh_failure_retries_and_clears_the_error() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX))
            .with_granularity(TimeGranularity::Second);
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        st.append_edge(edge(0, 0, 1, 1.0)).unwrap();
        st.append_edge(edge(4000, 1, 2, 2.0)).unwrap();
        st.fail_next_dtdg_refresh();
        st.seal().unwrap();
        assert!(h.pin().is_none(), "failed refresh must not publish");
        assert!(h.last_error().unwrap().contains("injected"));
        assert_eq!(h.refreshes(), 0);

        // Nothing new sealed: the stream counts match what the view
        // consumed, but the retry must still run, publish the pending
        // events, and clear the sticky error.
        st.refresh_dtdg_views();
        assert!(h.last_error().is_none(), "a later successful refresh must clear the error");
        let view = h.pin().expect("pending events published on retry");
        let full =
            discretize(&st.snapshot().unwrap(), TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        let got = view.coalesce();
        assert_eq!(got.edge_ts(), full.edge_ts());
        assert_eq!(bits(got.edge_feats()), bits(full.edge_feats()));
        assert_eq!(h.refreshes(), 1);
        assert_eq!(h.complete_until(), Some(3600));

        // Steady state afterwards: later seals refresh normally.
        st.append_edge(edge(8000, 2, 3, 4.0)).unwrap();
        st.seal().unwrap();
        assert!(h.last_error().is_none());
        assert_eq!(h.refreshes(), 2);
    }

    /// Satellite (ISSUE 9): refresh failures surface as registry
    /// metrics — an injected failure sets the per-view error gauge and
    /// bumps the monotonic error counter; a later successful refresh
    /// clears the gauge but never the counter.
    #[test]
    fn refresh_failure_sets_error_metrics_and_success_clears_the_gauge() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX))
            .with_granularity(TimeGranularity::Second);
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        assert_eq!(h.shared.error_gauge.get(), 0);
        assert_eq!(h.shared.errors_total.get(), 0, "fresh view, fresh per-store series");

        st.append_edge(edge(0, 0, 1, 1.0)).unwrap();
        st.append_edge(edge(4000, 1, 2, 2.0)).unwrap();
        st.fail_next_dtdg_refresh();
        st.seal().unwrap();
        assert_eq!(h.shared.error_gauge.get(), 1, "failure raises the gauge");
        assert_eq!(h.shared.errors_total.get(), 1, "and increments the counter");

        st.refresh_dtdg_views();
        assert!(h.last_error().is_none());
        assert_eq!(h.shared.error_gauge.get(), 0, "success clears the gauge");
        assert_eq!(h.shared.errors_total.get(), 1, "the counter stays monotonic");

        // The series is visible in a registry snapshot under this
        // view's own store label.
        let store = h.shared.store.as_str();
        let snap = crate::obs::registry().snapshot();
        assert!(
            snap.by_name("tgm_dtdg_errors_total").any(|m| m.label("store") == Some(store)),
            "per-store error counter must appear in the registry snapshot"
        );
    }

    #[test]
    fn trailing_partial_bucket_is_rereduced_not_frozen() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX))
            .with_granularity(TimeGranularity::Second);
        let h = st.register_dtdg_view(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        // Two seals inside one bucket: the class (0,1) keeps absorbing.
        st.append_edge(edge(0, 0, 1, 1.0)).unwrap();
        st.seal().unwrap();
        let v1 = h.pin().unwrap();
        assert_eq!(v1.num_edges(), 1);
        assert_eq!(v1.coalesce().edge_feats(), &[1.0]);
        st.append_edge(edge(100, 0, 1, 2.0)).unwrap();
        st.seal().unwrap();
        let v2 = h.pin().unwrap();
        assert_eq!(v2.num_edges(), 1, "same class, re-reduced");
        assert_eq!(v2.coalesce().edge_feats(), &[3.0]);
        assert_eq!(h.complete_until(), None, "nothing finalized yet");
        // The earlier pin is untouched (byte-stable generations).
        assert_eq!(v1.coalesce().edge_feats(), &[1.0]);
    }
}
