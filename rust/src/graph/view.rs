//! Lightweight, concurrency-safe temporal sub-graph views (paper §4).
//!
//! A [`DGraph`] is a time-bounded window `[start, end)` over a shared,
//! immutable [`StorageSnapshot`], plus a *read granularity* that encodes
//! how the window is iterated: the event-ordered granularity gives
//! CTDG-style fixed-size event batches, any coarser wall-clock granularity
//! gives DTDG-style snapshots (Definitions 3.3/3.4). Views are cheap to
//! clone and share the snapshot through an `Arc`. Because snapshots are
//! versioned and immutable, a view stays byte-stable even while the
//! producing [`super::segment::SegmentedStorage`] keeps ingesting new
//! events.

use crate::error::{Result, TgmError};
use crate::graph::segment::StorageSnapshot;
use crate::graph::storage::GraphStorage;
use crate::util::{TimeGranularity, Timestamp};
use std::ops::Range;
use std::sync::Arc;

/// A time-sliced view over a shared storage snapshot.
#[derive(Debug, Clone)]
pub struct DGraph {
    storage: Arc<StorageSnapshot>,
    /// Inclusive start of the window.
    start: Timestamp,
    /// Exclusive end of the window.
    end: Timestamp,
    /// Read granularity for iteration (see module docs).
    granularity: TimeGranularity,
}

impl DGraph {
    /// View covering the entire snapshot at its native granularity.
    pub fn full(storage: Arc<StorageSnapshot>) -> DGraph {
        let start = storage.start_time();
        let end = storage.end_time() + 1;
        let granularity = storage.granularity();
        DGraph { storage, start, end, granularity }
    }

    /// View over `[start, end)` at the snapshot's native granularity.
    pub fn slice_of(
        storage: Arc<StorageSnapshot>,
        start: Timestamp,
        end: Timestamp,
    ) -> Result<DGraph> {
        if end < start {
            return Err(TgmError::Time(format!("invalid window [{start}, {end})")));
        }
        let granularity = storage.granularity();
        Ok(DGraph { storage, start, end, granularity })
    }

    /// Narrow this view to `[t0, t1)` (must be inside the current window).
    pub fn slice(&self, t0: Timestamp, t1: Timestamp) -> Result<DGraph> {
        if t0 < self.start || t1 > self.end || t1 < t0 {
            return Err(TgmError::Time(format!(
                "slice [{t0}, {t1}) outside view window [{}, {})",
                self.start, self.end
            )));
        }
        Ok(DGraph {
            storage: Arc::clone(&self.storage),
            start: t0,
            end: t1,
            granularity: self.granularity,
        })
    }

    /// Change the read granularity. The new granularity must be coarser
    /// than or equal to the snapshot's native granularity, or the special
    /// event-ordered granularity (always permitted).
    pub fn with_granularity(&self, g: TimeGranularity) -> Result<DGraph> {
        if g != TimeGranularity::Event && !g.is_coarser_or_equal(&self.storage.granularity()) {
            return Err(TgmError::Time(format!(
                "granularity {} finer than native {}",
                g.as_str(),
                self.storage.granularity().as_str()
            )));
        }
        let mut v = self.clone();
        v.granularity = g;
        Ok(v)
    }

    /// Shared snapshot backing this view.
    pub fn storage(&self) -> &Arc<StorageSnapshot> {
        &self.storage
    }

    /// Inclusive window start.
    pub fn start_time(&self) -> Timestamp {
        self.start
    }

    /// Exclusive window end.
    pub fn end_time(&self) -> Timestamp {
        self.end
    }

    /// Current read granularity.
    pub fn granularity(&self) -> TimeGranularity {
        self.granularity
    }

    /// Logical edge index range of this window in the snapshot.
    pub fn edge_indices(&self) -> Range<usize> {
        self.storage.edge_range(self.start, self.end)
    }

    /// Logical node-event index range of this window.
    pub fn node_event_indices(&self) -> Range<usize> {
        self.storage.node_event_range(self.start, self.end)
    }

    /// Number of edge events in the window.
    pub fn num_edges(&self) -> usize {
        self.edge_indices().len()
    }

    /// Number of node events in the window.
    pub fn num_node_events(&self) -> usize {
        self.node_event_indices().len()
    }

    /// Number of nodes in the underlying snapshot (ids are global).
    pub fn num_nodes(&self) -> usize {
        self.storage.num_nodes()
    }

    /// Timestamps of edges in the window (copied out of the snapshot's
    /// segments; prefer chunked reads on hot paths).
    pub fn edge_ts(&self) -> Vec<Timestamp> {
        self.storage.copy_edge_column(self.edge_indices(), GraphStorage::edge_ts)
    }

    /// Sources of edges in the window.
    pub fn edge_src(&self) -> Vec<u32> {
        self.storage.copy_edge_column(self.edge_indices(), GraphStorage::edge_src)
    }

    /// Destinations of edges in the window.
    pub fn edge_dst(&self) -> Vec<u32> {
        self.storage.copy_edge_column(self.edge_indices(), GraphStorage::edge_dst)
    }

    /// Number of snapshot buckets the window spans at the read
    /// granularity. Errors for the event-ordered granularity.
    pub fn num_snapshots(&self) -> Result<usize> {
        if self.end <= self.start {
            return Ok(0);
        }
        let first = self.granularity.bucket_of(self.start, 0)?;
        let last = self.granularity.bucket_of(self.end - 1, 0)?;
        Ok((last - first + 1) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;
    use crate::graph::segment::{SealPolicy, SegmentedStorage};
    use crate::graph::storage::GraphStorage;

    fn storage() -> Arc<StorageSnapshot> {
        let edges = (0..100)
            .map(|i| EdgeEvent {
                t: i * 60, // one event per minute
                src: (i % 5) as u32,
                dst: ((i + 1) % 5) as u32,
                features: vec![],
            })
            .collect();
        GraphStorage::from_events(edges, vec![], 5, None, None).unwrap().into_shared_snapshot()
    }

    #[test]
    fn full_view_covers_everything() {
        let v = DGraph::full(storage());
        assert_eq!(v.num_edges(), 100);
        assert_eq!(v.granularity(), TimeGranularity::Minute);
        assert_eq!(v.edge_ts().len(), 100);
    }

    #[test]
    fn slicing_narrows_and_validates() {
        let v = DGraph::full(storage());
        let s = v.slice(60, 180).unwrap();
        assert_eq!(s.num_edges(), 2); // t=60, t=120
        assert_eq!(s.edge_ts(), &[60, 120]);
        // Out-of-window slice rejected.
        assert!(s.slice(0, 100).is_err());
        // Inverted rejected.
        assert!(v.slice(100, 50).is_err());
    }

    #[test]
    fn views_share_storage() {
        let st = storage();
        let a = DGraph::full(Arc::clone(&st));
        let b = a.slice(0, 600).unwrap();
        assert!(Arc::ptr_eq(a.storage(), b.storage()));
        assert_eq!(Arc::strong_count(&st), 3);
    }

    #[test]
    fn views_window_multi_segment_snapshots() {
        // The same columns streamed through a segmented store: windows
        // resolve to identical logical ranges and columns.
        let mut st = SegmentedStorage::new(5, SealPolicy::by_events(16))
            .with_granularity(TimeGranularity::Minute);
        for i in 0..100i64 {
            st.append_edge(EdgeEvent {
                t: i * 60,
                src: (i % 5) as u32,
                dst: ((i + 1) % 5) as u32,
                features: vec![],
            })
            .unwrap();
        }
        let seg_view = DGraph::full(st.snapshot().unwrap());
        let flat_view = DGraph::full(storage());
        assert!(seg_view.storage().num_segments() > 4);
        assert_eq!(seg_view.num_edges(), flat_view.num_edges());
        let s1 = seg_view.slice(60, 1800).unwrap();
        let s2 = flat_view.slice(60, 1800).unwrap();
        assert_eq!(s1.edge_indices(), s2.edge_indices());
        assert_eq!(s1.edge_ts(), s2.edge_ts());
        assert_eq!(s1.edge_src(), s2.edge_src());
        assert_eq!(s1.edge_dst(), s2.edge_dst());
    }

    #[test]
    fn granularity_rules() {
        let v = DGraph::full(storage()); // native = Minute
        assert!(v.with_granularity(TimeGranularity::Hour).is_ok());
        assert!(v.with_granularity(TimeGranularity::Minute).is_ok());
        assert!(v.with_granularity(TimeGranularity::Second).is_err());
        assert!(v.with_granularity(TimeGranularity::Event).is_ok());
    }

    #[test]
    fn snapshot_counting() {
        let v = DGraph::full(storage()); // spans [0, 99*60+1)
        let hourly = v.with_granularity(TimeGranularity::Hour).unwrap();
        // 99 minutes -> buckets 0 and 1.
        assert_eq!(hourly.num_snapshots().unwrap(), 2);
        let ev = v.with_granularity(TimeGranularity::Event).unwrap();
        assert!(ev.num_snapshots().is_err());
    }

    #[test]
    fn views_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<DGraph>();
    }
}
