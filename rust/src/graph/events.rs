//! Event types (paper Definition 3.1).
//!
//! Events are the fundamental unit of a temporal graph. TGM distinguishes
//! *edge events* — a timestamped interaction `(t, src, dst, x_edge)` — and
//! *node events* — the arrival of new features `(t, node, x_node)` at a
//! node. Both carry optional feature payloads; in columnar storage the
//! payload is a row index into a feature matrix (see
//! [`super::storage::GraphStorage`]).

use crate::util::Timestamp;

/// Node identifier. Graphs are re-indexed to a compact `0..num_nodes` range
/// at construction time.
pub type NodeId = u32;

/// A timestamped interaction between two nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeEvent {
    pub t: Timestamp,
    pub src: NodeId,
    pub dst: NodeId,
    /// Edge feature vector (may be empty for unattributed graphs).
    pub features: Vec<f32>,
}

/// Arrival of new dynamic features at a node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeEvent {
    pub t: Timestamp,
    pub node: NodeId,
    pub features: Vec<f32>,
}

/// Union of the two event kinds, ordered by time (ties: edge before node,
/// then insertion order — a total order that iteration relies on).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Edge(EdgeEvent),
    Node(NodeEvent),
}

impl Event {
    /// Timestamp of the event.
    pub fn t(&self) -> Timestamp {
        match self {
            Event::Edge(e) => e.t,
            Event::Node(n) => n.t,
        }
    }

    /// True for edge events.
    pub fn is_edge(&self) -> bool {
        matches!(self, Event::Edge(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_accessors() {
        let e = Event::Edge(EdgeEvent { t: 5, src: 1, dst: 2, features: vec![] });
        let n = Event::Node(NodeEvent { t: 7, node: 3, features: vec![1.0] });
        assert_eq!(e.t(), 5);
        assert_eq!(n.t(), 7);
        assert!(e.is_edge());
        assert!(!n.is_edge());
    }
}
