//! Graph discretization (paper Definition 3.5, Table 5).
//!
//! `ψ_r : (G, τ) -> (Ĝ, τ̂)` maps a temporal graph to a coarser granularity
//! τ̂ ≥ τ, groups events into the equivalence classes induced by τ̂ on
//! `(bucket, src, dst)`, and reduces each class to one representative event
//! with the class's reduction `r` applied to edge features. Node events
//! ride along with `Last` semantics: one event per `(bucket, node)` class
//! carrying the class's latest feature row.
//!
//! Two implementations live here:
//!
//! * [`discretize`] — TGM's **vectorized** path: one run-based pass to
//!   compute bucket keys ([`crate::kernels::bucket_keys`]), an index sort
//!   over packed keys, and a single grouped-reduction scan on the
//!   [`crate::kernels`] lane ops plus contiguous row copies. No per-event
//!   allocation, cache-friendly columnar access. This is the implementation behind the
//!   paper's 49–433× speedups (Table 5).
//! * [`discretize_utg`] — the **UTG-style baseline**: a per-event hash-map
//!   of per-class feature accumulator vectors, mirroring the
//!   Python-dictionary structure of the original UTG code (Huang et al.,
//!   2024). Kept as a first-class comparator for `benches/table5_*`.
//!
//! The vectorized core is exposed crate-internally as
//! [`discretize_columns`], which works over raw borrowed columns with an
//! explicit bucket origin — [`crate::graph::DtdgView`] reuses it per
//! sealed slice so the incremental materialized view is **bit-identical**
//! to a full-snapshot [`discretize`] call. That identity relies on the
//! class sort being a deterministic total order (the packed key is
//! tie-broken by original index), so per-class f32 accumulation always
//! runs in stream order no matter how the stream is sliced.

use crate::error::{Result, TgmError};
use crate::graph::segment::StorageSnapshot;
use crate::graph::storage::GraphStorage;
use crate::kernels;
use crate::util::{TimeGranularity, Timestamp};
use std::collections::HashMap;

/// Reduction operator `r` applied to each duplicate-edge equivalence class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Element-wise sum of edge features.
    Sum,
    /// Element-wise mean of edge features.
    Mean,
    /// Features of the latest event in the class.
    Last,
    /// Element-wise max of edge features.
    Max,
    /// Drop features; emit the multiplicity as a single "weight" feature.
    Count,
}

impl ReduceOp {
    /// Parse a CLI/config string.
    pub fn parse(s: &str) -> Result<ReduceOp> {
        match s.to_ascii_lowercase().as_str() {
            "sum" => Ok(ReduceOp::Sum),
            "mean" => Ok(ReduceOp::Mean),
            "last" => Ok(ReduceOp::Last),
            "max" => Ok(ReduceOp::Max),
            "count" => Ok(ReduceOp::Count),
            other => Err(TgmError::Config(format!("unknown reduce op `{other}`"))),
        }
    }
}

/// Validate that `target` is a wall-clock granularity at least as coarse
/// as `native`; returns the target's bucket width in seconds.
pub(crate) fn check_coarser_granularity(
    native: TimeGranularity,
    target: TimeGranularity,
) -> Result<i64> {
    if native == TimeGranularity::Event {
        return Err(TgmError::Time(
            "cannot discretize an event-ordered graph: no wall-clock granularity".into(),
        ));
    }
    if !target.is_coarser_or_equal(&native) {
        return Err(TgmError::Time(format!(
            "target granularity {} finer than native {}",
            target.as_str(),
            native.as_str()
        )));
    }
    target
        .seconds()
        .ok_or_else(|| TgmError::Time("target granularity must be wall-clock".into()))
}

fn check_coarser(storage: &GraphStorage, target: TimeGranularity) -> Result<i64> {
    check_coarser_granularity(storage.granularity(), target)
}

/// Borrowed raw event columns of one contiguous, time-sorted slice of a
/// stream — the unit [`discretize_columns`] operates on.
pub(crate) struct EventColumns<'a> {
    pub ts: &'a [Timestamp],
    pub src: &'a [u32],
    pub dst: &'a [u32],
    pub feat_dim: usize,
    pub feats: &'a [f32],
    pub node_ts: &'a [Timestamp],
    pub node_ids: &'a [u32],
    pub node_feat_dim: usize,
    pub node_feats: &'a [f32],
}

impl<'a> EventColumns<'a> {
    pub fn of(storage: &'a GraphStorage) -> EventColumns<'a> {
        EventColumns {
            ts: storage.edge_ts(),
            src: storage.edge_src(),
            dst: storage.edge_dst(),
            feat_dim: storage.edge_feat_dim(),
            feats: storage.edge_feats(),
            node_ts: storage.node_event_ts(),
            node_ids: storage.node_event_ids(),
            node_feat_dim: storage.node_feat_dim(),
            node_feats: storage.node_event_feats(),
        }
    }
}

/// Owned discretized columns produced by [`discretize_columns`], ready to
/// freeze into a [`GraphStorage`] segment.
pub(crate) struct DiscretizedColumns {
    pub ts: Vec<Timestamp>,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub out_dim: usize,
    pub feats: Vec<f32>,
    pub node_ts: Vec<Timestamp>,
    pub node_ids: Vec<u32>,
    pub node_feat_dim: usize,
    pub node_feats: Vec<f32>,
}

impl DiscretizedColumns {
    pub fn into_storage(
        self,
        num_nodes: usize,
        static_feat_dim: usize,
        static_feats: Vec<f32>,
        target: TimeGranularity,
    ) -> GraphStorage {
        GraphStorage::from_sorted_columns(
            self.ts,
            self.src,
            self.dst,
            self.out_dim,
            self.feats,
            self.node_ts,
            self.node_ids,
            self.node_feat_dim,
            self.node_feats,
            num_nodes,
            static_feat_dim,
            static_feats,
            target,
        )
    }
}

/// Vectorized discretization core over raw columns with an explicit
/// bucket origin `t0` and width `secs` (already validated against the
/// native granularity by the caller).
///
/// Edge timestamps must be `>= t0` (the origin is the stream's first
/// edge timestamp); node-event timestamps may precede it, so their keys
/// are sorted as signed tuples instead of the packed unsigned word.
/// Output rows come out in `(bucket, src, dst)` order — which **is**
/// timestamp order, since every row's timestamp is its bucket start —
/// so no final re-sort is needed.
pub(crate) fn discretize_columns(
    cols: &EventColumns<'_>,
    target: TimeGranularity,
    secs: i64,
    t0: Timestamp,
    reduce: ReduceOp,
) -> Result<DiscretizedColumns> {
    let ts = cols.ts;
    let src = cols.src;
    let dst = cols.dst;
    let n = ts.len();

    // Pass 1: bucket of every event (run-based over the sorted column).
    let mut buckets: Vec<i64> = Vec::new();
    kernels::bucket_keys(ts, t0, secs, &mut buckets);
    debug_assert!(n == 0 || buckets[0] >= 0, "edge timestamps precede the bucket origin");

    // Pass 2: index sort by packed (bucket, src, dst) key, tie-broken by
    // the original index. The tiebreak makes the order a deterministic
    // *total* order: within a class, events stay in stream order, so
    // order-sensitive f32 folds (Sum/Mean) give the same bits whether a
    // class is reduced from a full coalesced snapshot or from a slice of
    // it (the incremental-view identity depends on this).
    let mut order: Vec<u32> = (0..n as u32).collect();
    let key = |i: u32| -> u128 {
        let i = i as usize;
        ((buckets[i] as u128) << 64) | ((src[i] as u128) << 32) | dst[i] as u128
    };
    order.sort_unstable_by_key(|&i| (key(i), i));

    // Pass 3: grouped reduction scan.
    let d = cols.feat_dim;
    let out_dim = match reduce {
        ReduceOp::Count => 1,
        _ => d,
    };
    let mut out_ts: Vec<Timestamp> = Vec::new();
    let mut out_src: Vec<u32> = Vec::new();
    let mut out_dst: Vec<u32> = Vec::new();
    let mut out_feats: Vec<f32> = Vec::new();
    // For Last the per-class representative rows are collected as indices
    // and pulled in one batched row gather after the scan.
    let mut last_idx: Vec<u32> = Vec::new();
    let mut acc: Vec<f32> = vec![0.0; d];

    let mut g = 0usize;
    while g < n {
        let head = order[g] as usize;
        let head_key = key(order[g]);
        let mut end = g + 1;
        while end < n && key(order[end]) == head_key {
            end += 1;
        }
        let count = (end - g) as f32;
        let bucket = buckets[head];
        out_ts.push(target.bucket_start(bucket, t0)?);
        out_src.push(src[head]);
        out_dst.push(dst[head]);
        match reduce {
            ReduceOp::Count => out_feats.push(count),
            ReduceOp::Last => {
                // The index tiebreak sorted the class by original index,
                // so the latest event is simply the group's last entry.
                last_idx.push(order[end - 1]);
            }
            ReduceOp::Sum | ReduceOp::Mean => {
                acc.iter_mut().for_each(|a| *a = 0.0);
                for &i in &order[g..end] {
                    let i = i as usize;
                    kernels::add_assign_f32(&mut acc, &cols.feats[i * d..(i + 1) * d]);
                }
                if reduce == ReduceOp::Mean {
                    acc.iter_mut().for_each(|a| *a /= count);
                }
                out_feats.extend_from_slice(&acc);
            }
            ReduceOp::Max => {
                acc.iter_mut().for_each(|a| *a = f32::NEG_INFINITY);
                for &i in &order[g..end] {
                    let i = i as usize;
                    kernels::max_assign_f32(&mut acc, &cols.feats[i * d..(i + 1) * d]);
                }
                out_feats.extend_from_slice(&acc);
            }
        }
        g = end;
    }
    if reduce == ReduceOp::Last && d > 0 {
        // Every slot is live, so a straight contiguous row copy beats the
        // masked gather kernel (no mask to allocate or test).
        out_feats.reserve(last_idx.len() * d);
        for &i in &last_idx {
            let i = i as usize;
            out_feats.extend_from_slice(&cols.feats[i * d..(i + 1) * d]);
        }
    }
    debug_assert!(out_ts.windows(2).all(|w| w[0] <= w[1]));

    // Node events: one representative per (bucket, node) class with the
    // class's latest feature row (`Last` semantics regardless of the edge
    // reduce op — node state is a signal, not a count).
    let nn = cols.node_ts.len();
    let nd = cols.node_feat_dim;
    let mut node_out_ts: Vec<Timestamp> = Vec::new();
    let mut node_out_ids: Vec<u32> = Vec::new();
    let mut node_out_feats: Vec<f32> = Vec::new();
    if nn > 0 {
        let mut nbuckets: Vec<i64> = Vec::new();
        kernels::bucket_keys(cols.node_ts, t0, secs, &mut nbuckets);
        let mut norder: Vec<u32> = (0..nn as u32).collect();
        // Node events may predate the first edge, so buckets can be
        // negative: sort signed tuples rather than a packed word.
        norder.sort_unstable_by_key(|&i| (nbuckets[i as usize], cols.node_ids[i as usize], i));
        let mut nlast: Vec<u32> = Vec::new();
        let mut g = 0usize;
        while g < nn {
            let head = norder[g] as usize;
            let (hb, hid) = (nbuckets[head], cols.node_ids[head]);
            let mut end = g + 1;
            while end < nn {
                let j = norder[end] as usize;
                if nbuckets[j] != hb || cols.node_ids[j] != hid {
                    break;
                }
                end += 1;
            }
            node_out_ts.push(target.bucket_start(hb, t0)?);
            node_out_ids.push(hid);
            nlast.push(norder[end - 1]);
            g = end;
        }
        if nd > 0 {
            node_out_feats.reserve(nlast.len() * nd);
            for &i in &nlast {
                let i = i as usize;
                node_out_feats.extend_from_slice(&cols.node_feats[i * nd..(i + 1) * nd]);
            }
        }
        debug_assert!(node_out_ts.windows(2).all(|w| w[0] <= w[1]));
    }

    Ok(DiscretizedColumns {
        ts: out_ts,
        src: out_src,
        dst: out_dst,
        out_dim,
        feats: out_feats,
        node_ts: node_out_ts,
        node_ids: node_out_ids,
        node_feat_dim: nd,
        node_feats: node_out_feats,
    })
}

/// Vectorized discretization: TGM's fast path.
///
/// Complexity: `O(distinct buckets)` divisions + `O(E log E)` index sort +
/// `O(E · d)` grouped reduction; zero per-event heap allocation. The
/// input snapshot is coalesced first (free for single-segment snapshots,
/// i.e. every one-shot dataset), so the scan runs over contiguous columns.
/// Node events are carried through with `Last` semantics per
/// `(bucket, node)` class; static node features pass through unchanged.
pub fn discretize(
    snapshot: &StorageSnapshot,
    target: TimeGranularity,
    reduce: ReduceOp,
) -> Result<GraphStorage> {
    let storage = snapshot.coalesce();
    let storage = storage.as_ref();
    let secs = check_coarser(storage, target)?;
    let t0 = storage.start_time();
    let out = discretize_columns(&EventColumns::of(storage), target, secs, t0, reduce)?;
    Ok(out.into_storage(
        storage.num_nodes(),
        storage.static_feat_dim(),
        storage.static_feats().to_vec(),
        target,
    ))
}

/// UTG-style baseline discretization (comparator for Table 5).
///
/// Faithfully mirrors the reference UTG implementation's access pattern:
/// iterate events one at a time, key a hash map on `(bucket, src, dst)`,
/// and append each event's feature vector to a per-class growable list;
/// finally walk the map, reduce each list, and sort the output. The
/// per-event boxed allocations and pointer-chasing hash lookups are the
/// costs TGM's vectorized path eliminates. Node events get the same
/// `Last`-per-`(bucket, node)` treatment as [`discretize`].
pub fn discretize_utg(
    snapshot: &StorageSnapshot,
    target: TimeGranularity,
    reduce: ReduceOp,
) -> Result<GraphStorage> {
    let storage = snapshot.coalesce();
    let storage = storage.as_ref();
    let secs = check_coarser(storage, target)?;
    let t0 = storage.start_time();
    let d = storage.edge_feat_dim();

    // Python-dict-of-lists shape: each class owns a Vec of owned rows.
    #[allow(clippy::type_complexity)]
    let mut classes: HashMap<(i64, u32, u32), Vec<Vec<f32>>> = HashMap::new();
    for i in 0..storage.num_edges() {
        let bucket = (storage.edge_ts()[i] - t0).div_euclid(secs);
        let key = (bucket, storage.edge_src()[i], storage.edge_dst()[i]);
        classes.entry(key).or_default().push(storage.edge_feat_row(i).to_vec());
    }

    let out_dim = match reduce {
        ReduceOp::Count => 1,
        _ => d,
    };
    let mut rows: Vec<(Timestamp, u32, u32, Vec<f32>)> = Vec::with_capacity(classes.len());
    for ((bucket, s, t), feats) in classes {
        let count = feats.len() as f32;
        let reduced: Vec<f32> = match reduce {
            ReduceOp::Count => vec![count],
            ReduceOp::Last => feats.last().unwrap().clone(),
            ReduceOp::Sum | ReduceOp::Mean => {
                let mut acc = vec![0.0f32; d];
                for row in &feats {
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a += x;
                    }
                }
                if reduce == ReduceOp::Mean {
                    acc.iter_mut().for_each(|a| *a /= count);
                }
                acc
            }
            ReduceOp::Max => {
                let mut acc = vec![f32::NEG_INFINITY; d];
                for row in &feats {
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a = a.max(x);
                    }
                }
                acc
            }
        };
        rows.push((target.bucket_start(bucket, t0)?, s, t, reduced));
    }
    rows.sort_by_key(|r| (r.0, r.1, r.2));

    let m = rows.len();
    let mut ts = Vec::with_capacity(m);
    let mut src = Vec::with_capacity(m);
    let mut dst = Vec::with_capacity(m);
    let mut fx = Vec::with_capacity(m * out_dim);
    for (t, s, dd, f) in rows {
        ts.push(t);
        src.push(s);
        dst.push(dd);
        fx.extend_from_slice(&f);
    }

    // Node events, dict-style: latest row per (bucket, node) class.
    let nd = storage.node_feat_dim();
    let mut node_classes: HashMap<(i64, u32), Vec<f32>> = HashMap::new();
    for i in 0..storage.num_node_events() {
        let bucket = (storage.node_event_ts()[i] - t0).div_euclid(secs);
        node_classes
            .insert((bucket, storage.node_event_ids()[i]), storage.node_event_feat_row(i).to_vec());
    }
    let mut node_rows: Vec<(Timestamp, u32, Vec<f32>)> = Vec::with_capacity(node_classes.len());
    for ((bucket, id), f) in node_classes {
        node_rows.push((target.bucket_start(bucket, t0)?, id, f));
    }
    node_rows.sort_by_key(|r| (r.0, r.1));
    let mut nts = Vec::with_capacity(node_rows.len());
    let mut nid = Vec::with_capacity(node_rows.len());
    let mut nfx = Vec::with_capacity(node_rows.len() * nd);
    for (t, id, f) in node_rows {
        nts.push(t);
        nid.push(id);
        nfx.extend_from_slice(&f);
    }

    Ok(GraphStorage::from_sorted_columns(
        ts,
        src,
        dst,
        out_dim,
        fx,
        nts,
        nid,
        nd,
        nfx,
        storage.num_nodes(),
        storage.static_feat_dim(),
        storage.static_feats().to_vec(),
        target,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::{EdgeEvent, NodeEvent};
    use crate::util::Rng;

    fn edge(t: Timestamp, src: u32, dst: u32, f: f32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![f, 2.0 * f] }
    }

    fn hourly_graph() -> StorageSnapshot {
        // Duplicate (0,1) within the first hour, one (1,2) in hour 1.
        let edges = vec![
            edge(0, 0, 1, 1.0),
            edge(600, 0, 1, 3.0),
            edge(1200, 2, 3, 5.0),
            edge(4000, 1, 2, 7.0),
        ];
        GraphStorage::from_events(edges, vec![], 4, None, Some(TimeGranularity::Second))
            .unwrap()
            .into_snapshot()
    }

    #[test]
    fn mean_reduction_collapses_duplicates() {
        let g = hourly_graph();
        let h = discretize(&g, TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        assert_eq!(h.num_edges(), 3);
        assert_eq!(h.granularity(), TimeGranularity::Hour);
        // (0,1) class reduced: mean of [1,2] and [3,6] = [2,4].
        let i = (0..3).find(|&i| h.edge_src()[i] == 0 && h.edge_dst()[i] == 1).unwrap();
        assert_eq!(h.edge_feat_row(i), &[2.0, 4.0]);
        // Representative timestamp is the bucket start.
        assert_eq!(h.edge_ts()[i], 0);
        let j = (0..3).find(|&i| h.edge_src()[i] == 1).unwrap();
        assert_eq!(h.edge_ts()[j], 3600);
    }

    #[test]
    fn sum_last_max_count() {
        let g = hourly_graph();
        let sum = discretize(&g, TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        let i = (0..3).find(|&i| sum.edge_src()[i] == 0 && sum.edge_dst()[i] == 1).unwrap();
        assert_eq!(sum.edge_feat_row(i), &[4.0, 8.0]);

        let last = discretize(&g, TimeGranularity::Hour, ReduceOp::Last).unwrap();
        assert_eq!(last.edge_feat_row(i), &[3.0, 6.0]);

        let mx = discretize(&g, TimeGranularity::Hour, ReduceOp::Max).unwrap();
        assert_eq!(mx.edge_feat_row(i), &[3.0, 6.0]);

        let cnt = discretize(&g, TimeGranularity::Hour, ReduceOp::Count).unwrap();
        assert_eq!(cnt.edge_feat_dim(), 1);
        assert_eq!(cnt.edge_feat_row(i), &[2.0]);
    }

    #[test]
    fn reduce_op_parse_round_trips_and_rejects_unknown() {
        for (s, op) in [
            ("sum", ReduceOp::Sum),
            ("MEAN", ReduceOp::Mean),
            ("Last", ReduceOp::Last),
            ("max", ReduceOp::Max),
            ("count", ReduceOp::Count),
        ] {
            assert_eq!(ReduceOp::parse(s).unwrap(), op);
        }
        let err = ReduceOp::parse("median").unwrap_err();
        assert!(matches!(err, TgmError::Config(_)), "expected Config error, got {err:?}");
        assert!(err.to_string().contains("median"));
    }

    #[test]
    fn rejects_finer_target_and_event_graphs() {
        let g = hourly_graph();
        let daily = discretize(&g, TimeGranularity::Day, ReduceOp::Mean).unwrap().into_snapshot();
        assert_eq!(daily.num_edges(), 3); // all distinct (s,d) pairs, one day
        // Finer than native of the daily graph:
        assert!(discretize(&daily, TimeGranularity::Hour, ReduceOp::Mean).is_err());
    }

    #[test]
    fn node_events_are_bucketed_with_last_semantics() {
        // Regression: node events used to be silently dropped from the
        // coarse graph. Two updates of node 1 in hour 0 must collapse to
        // the later one at the bucket start; node 2's hour-1 update (and
        // one *before* the first edge, in a negative bucket) survive.
        let edges = vec![edge(100, 0, 1, 1.0), edge(5000, 1, 2, 2.0)];
        let nodes = vec![
            NodeEvent { t: 50, node: 2, features: vec![9.0] },
            NodeEvent { t: 200, node: 1, features: vec![1.5] },
            NodeEvent { t: 900, node: 1, features: vec![2.5] },
            NodeEvent { t: 4200, node: 2, features: vec![3.5] },
        ];
        let g = GraphStorage::from_events(edges, nodes, 3, None, Some(TimeGranularity::Second))
            .unwrap()
            .into_snapshot();
        for f in [discretize, discretize_utg] {
            let h = f(&g, TimeGranularity::Hour, ReduceOp::Sum).unwrap();
            assert_eq!(h.num_node_events(), 3, "one per (bucket, node) class");
            // t0 = 100, so t=50 lands in bucket -1 (start -3500), the two
            // node-1 updates collapse into bucket 0 (start 100) keeping
            // the later features, node 2's second update is bucket 1.
            assert_eq!(h.node_event_ts(), &[-3500, 100, 3700]);
            assert_eq!(h.node_event_ids(), &[2, 1, 2]);
            assert_eq!(h.node_event_feats(), &[9.0, 2.5, 3.5]);
            assert_eq!(h.node_feat_dim(), 1);
        }
    }

    #[test]
    fn static_feats_pass_through() {
        let edges = vec![edge(0, 0, 1, 1.0), edge(4000, 1, 2, 2.0)];
        let g = GraphStorage::from_events(
            edges,
            vec![],
            3,
            Some((2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0])),
            Some(TimeGranularity::Second),
        )
        .unwrap()
        .into_snapshot();
        let h = discretize(&g, TimeGranularity::Hour, ReduceOp::Last).unwrap();
        assert_eq!(h.static_feat_dim(), 2);
        assert_eq!(h.static_feats(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn vectorized_matches_utg_baseline() {
        // Property: both implementations agree on random graphs for every
        // reduction op.
        let mut rng = Rng::new(2024);
        for trial in 0..5 {
            let edges: Vec<EdgeEvent> = (0..400)
                .map(|_| {
                    edge(
                        rng.range(0, 100_000),
                        rng.below(20) as u32,
                        rng.below(20) as u32,
                        rng.f32() * 10.0,
                    )
                })
                .collect();
            let nodes: Vec<NodeEvent> = (0..60)
                .map(|_| NodeEvent {
                    t: rng.range(0, 100_000),
                    node: rng.below(20) as u32,
                    features: vec![rng.f32()],
                })
                .collect();
            let g = GraphStorage::from_events(edges, nodes, 20, None, Some(TimeGranularity::Second))
                .unwrap()
                .into_snapshot();
            for op in [ReduceOp::Sum, ReduceOp::Mean, ReduceOp::Last, ReduceOp::Max, ReduceOp::Count]
            {
                let a = discretize(&g, TimeGranularity::Hour, op).unwrap();
                let b = discretize_utg(&g, TimeGranularity::Hour, op).unwrap();
                assert_eq!(a.num_edges(), b.num_edges(), "trial {trial} op {op:?}");
                // Align rows by (t, src, dst) triple for comparison.
                let key = |s: &GraphStorage, i: usize| (s.edge_ts()[i], s.edge_src()[i], s.edge_dst()[i]);
                let mut ia: Vec<usize> = (0..a.num_edges()).collect();
                let mut ib: Vec<usize> = (0..b.num_edges()).collect();
                ia.sort_by_key(|&i| key(&a, i));
                ib.sort_by_key(|&i| key(&b, i));
                for (&x, &y) in ia.iter().zip(&ib) {
                    assert_eq!(key(&a, x), key(&b, y));
                    let fa = a.edge_feat_row(x);
                    let fb = b.edge_feat_row(y);
                    for (u, v) in fa.iter().zip(fb) {
                        assert!((u - v).abs() < 1e-4, "op {op:?}: {u} vs {v}");
                    }
                }
                assert_eq!(a.node_event_ts(), b.node_event_ts(), "trial {trial} op {op:?}");
                assert_eq!(a.node_event_ids(), b.node_event_ids());
                assert_eq!(a.node_event_feats(), b.node_event_feats());
            }
        }
    }

    #[test]
    fn idempotent_at_same_granularity_when_no_duplicates() {
        let edges = vec![edge(0, 0, 1, 1.0), edge(3600, 1, 2, 2.0), edge(7200, 2, 0, 3.0)];
        let g = GraphStorage::from_events(edges, vec![], 3, None, Some(TimeGranularity::Hour))
            .unwrap()
            .into_snapshot();
        let h = discretize(&g, TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        assert_eq!(h.num_edges(), 3);
        assert_eq!(h.edge_ts(), g.edge_ts());
        assert_eq!(h.edge_src(), g.edge_src());
    }
}
