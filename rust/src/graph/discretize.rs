//! Graph discretization (paper Definition 3.5, Table 5).
//!
//! `ψ_r : (G, τ) -> (Ĝ, τ̂)` maps a temporal graph to a coarser granularity
//! τ̂ ≥ τ, groups events into the equivalence classes induced by τ̂ on
//! `(bucket, src, dst)`, and reduces each class to one representative event
//! with the class's reduction `r` applied to edge features.
//!
//! Two implementations live here:
//!
//! * [`discretize`] — TGM's **vectorized** path: one pass to compute bucket
//!   keys, an index sort over packed keys, and a single grouped-reduction
//!   scan. No per-event allocation, cache-friendly columnar access. This is
//!   the implementation behind the paper's 49–433× speedups (Table 5).
//! * [`discretize_utg`] — the **UTG-style baseline**: a per-event hash-map
//!   of per-class feature accumulator vectors, mirroring the
//!   Python-dictionary structure of the original UTG code (Huang et al.,
//!   2024). Kept as a first-class comparator for `benches/table5_*`.

use crate::error::{Result, TgmError};
use crate::graph::segment::StorageSnapshot;
use crate::graph::storage::GraphStorage;
use crate::util::{TimeGranularity, Timestamp};
use std::collections::HashMap;

/// Reduction operator `r` applied to each duplicate-edge equivalence class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Element-wise sum of edge features.
    Sum,
    /// Element-wise mean of edge features.
    Mean,
    /// Features of the latest event in the class.
    Last,
    /// Element-wise max of edge features.
    Max,
    /// Drop features; emit the multiplicity as a single "weight" feature.
    Count,
}

impl ReduceOp {
    /// Parse a CLI/config string.
    pub fn parse(s: &str) -> Result<ReduceOp> {
        match s.to_ascii_lowercase().as_str() {
            "sum" => Ok(ReduceOp::Sum),
            "mean" => Ok(ReduceOp::Mean),
            "last" => Ok(ReduceOp::Last),
            "max" => Ok(ReduceOp::Max),
            "count" => Ok(ReduceOp::Count),
            other => Err(TgmError::Config(format!("unknown reduce op `{other}`"))),
        }
    }
}

fn check_coarser(storage: &GraphStorage, target: TimeGranularity) -> Result<i64> {
    let native = storage.granularity();
    if native == TimeGranularity::Event {
        return Err(TgmError::Time(
            "cannot discretize an event-ordered graph: no wall-clock granularity".into(),
        ));
    }
    if !target.is_coarser_or_equal(&native) {
        return Err(TgmError::Time(format!(
            "target granularity {} finer than native {}",
            target.as_str(),
            native.as_str()
        )));
    }
    target
        .seconds()
        .ok_or_else(|| TgmError::Time("target granularity must be wall-clock".into()))
}

/// Vectorized discretization: TGM's fast path.
///
/// Complexity: `O(E)` key computation + `O(E log E)` index sort +
/// `O(E · d)` grouped reduction; zero per-event heap allocation. The
/// input snapshot is coalesced first (free for single-segment snapshots,
/// i.e. every one-shot dataset), so the scan runs over contiguous columns.
pub fn discretize(
    snapshot: &StorageSnapshot,
    target: TimeGranularity,
    reduce: ReduceOp,
) -> Result<GraphStorage> {
    let storage = snapshot.coalesce();
    let storage = storage.as_ref();
    let secs = check_coarser(storage, target)?;
    let t0 = storage.start_time();
    let ts = storage.edge_ts();
    let src = storage.edge_src();
    let dst = storage.edge_dst();
    let n = ts.len();

    // Pass 1: bucket of every event (vectorized over the columnar layout).
    let mut buckets: Vec<i64> = Vec::with_capacity(n);
    for &t in ts {
        buckets.push((t - t0).div_euclid(secs));
    }

    // Pass 2: index sort by packed (bucket, src, dst) key. Timestamps are
    // already sorted, so the sort is nearly-ordered on the leading key; we
    // use an unstable pattern-defeating sort over u128 packed keys, which
    // is allocation-free and branch-cheap.
    let mut order: Vec<u32> = (0..n as u32).collect();
    let key = |i: u32| -> u128 {
        let i = i as usize;
        ((buckets[i] as u128) << 64) | ((src[i] as u128) << 32) | dst[i] as u128
    };
    order.sort_unstable_by_key(|&i| key(i));

    // Pass 3: grouped reduction scan.
    let d = storage.edge_feat_dim();
    let out_dim = match reduce {
        ReduceOp::Count => 1,
        _ => d,
    };
    let mut out_ts: Vec<Timestamp> = Vec::new();
    let mut out_src: Vec<u32> = Vec::new();
    let mut out_dst: Vec<u32> = Vec::new();
    let mut out_feats: Vec<f32> = Vec::new();
    let mut acc: Vec<f32> = vec![0.0; d];

    let mut g = 0usize;
    while g < n {
        let head = order[g] as usize;
        let head_key = key(order[g]);
        let mut end = g + 1;
        while end < n && key(order[end]) == head_key {
            end += 1;
        }
        let count = (end - g) as f32;
        let bucket = buckets[head];
        out_ts.push(target.bucket_start(bucket, t0)?);
        out_src.push(src[head]);
        out_dst.push(dst[head]);
        match reduce {
            ReduceOp::Count => out_feats.push(count),
            ReduceOp::Last => {
                // Sort is unstable on equal keys; pick the max original
                // index explicitly (events were time-sorted).
                let last = order[g..end].iter().map(|&i| i as usize).max().unwrap();
                out_feats.extend_from_slice(storage.edge_feat_row(last));
            }
            ReduceOp::Sum | ReduceOp::Mean => {
                acc.iter_mut().for_each(|a| *a = 0.0);
                for &i in &order[g..end] {
                    let row = storage.edge_feat_row(i as usize);
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a += x;
                    }
                }
                if reduce == ReduceOp::Mean {
                    acc.iter_mut().for_each(|a| *a /= count);
                }
                out_feats.extend_from_slice(&acc);
            }
            ReduceOp::Max => {
                acc.iter_mut().for_each(|a| *a = f32::NEG_INFINITY);
                for &i in &order[g..end] {
                    let row = storage.edge_feat_row(i as usize);
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a = a.max(x);
                    }
                }
                out_feats.extend_from_slice(&acc);
            }
        }
        g = end;
    }

    // The grouped output is sorted by (bucket, src, dst); re-sort columns
    // by timestamp only (stable) to restore the storage invariant.
    let m = out_ts.len();
    let mut perm: Vec<u32> = (0..m as u32).collect();
    perm.sort_by_key(|&i| out_ts[i as usize]);
    let ts2: Vec<Timestamp> = perm.iter().map(|&i| out_ts[i as usize]).collect();
    let src2: Vec<u32> = perm.iter().map(|&i| out_src[i as usize]).collect();
    let dst2: Vec<u32> = perm.iter().map(|&i| out_dst[i as usize]).collect();
    let mut feats2: Vec<f32> = Vec::with_capacity(m * out_dim);
    for &i in &perm {
        let i = i as usize;
        feats2.extend_from_slice(&out_feats[i * out_dim..(i + 1) * out_dim]);
    }

    Ok(GraphStorage::from_sorted_columns(
        ts2,
        src2,
        dst2,
        out_dim,
        feats2,
        Vec::new(),
        Vec::new(),
        0,
        Vec::new(),
        storage.num_nodes(),
        storage.static_feat_dim(),
        storage.static_feats().to_vec(),
        target,
    ))
}

/// UTG-style baseline discretization (comparator for Table 5).
///
/// Faithfully mirrors the reference UTG implementation's access pattern:
/// iterate events one at a time, key a hash map on `(bucket, src, dst)`,
/// and append each event's feature vector to a per-class growable list;
/// finally walk the map, reduce each list, and sort the output. The
/// per-event boxed allocations and pointer-chasing hash lookups are the
/// costs TGM's vectorized path eliminates.
pub fn discretize_utg(
    snapshot: &StorageSnapshot,
    target: TimeGranularity,
    reduce: ReduceOp,
) -> Result<GraphStorage> {
    let storage = snapshot.coalesce();
    let storage = storage.as_ref();
    let secs = check_coarser(storage, target)?;
    let t0 = storage.start_time();
    let d = storage.edge_feat_dim();

    // Python-dict-of-lists shape: each class owns a Vec of owned rows.
    #[allow(clippy::type_complexity)]
    let mut classes: HashMap<(i64, u32, u32), Vec<Vec<f32>>> = HashMap::new();
    for i in 0..storage.num_edges() {
        let bucket = (storage.edge_ts()[i] - t0).div_euclid(secs);
        let key = (bucket, storage.edge_src()[i], storage.edge_dst()[i]);
        classes.entry(key).or_default().push(storage.edge_feat_row(i).to_vec());
    }

    let out_dim = match reduce {
        ReduceOp::Count => 1,
        _ => d,
    };
    let mut rows: Vec<(Timestamp, u32, u32, Vec<f32>)> = Vec::with_capacity(classes.len());
    for ((bucket, s, t), feats) in classes {
        let count = feats.len() as f32;
        let reduced: Vec<f32> = match reduce {
            ReduceOp::Count => vec![count],
            ReduceOp::Last => feats.last().unwrap().clone(),
            ReduceOp::Sum | ReduceOp::Mean => {
                let mut acc = vec![0.0f32; d];
                for row in &feats {
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a += x;
                    }
                }
                if reduce == ReduceOp::Mean {
                    acc.iter_mut().for_each(|a| *a /= count);
                }
                acc
            }
            ReduceOp::Max => {
                let mut acc = vec![f32::NEG_INFINITY; d];
                for row in &feats {
                    for (a, &x) in acc.iter_mut().zip(row) {
                        *a = a.max(x);
                    }
                }
                acc
            }
        };
        rows.push((target.bucket_start(bucket, t0)?, s, t, reduced));
    }
    rows.sort_by_key(|r| (r.0, r.1, r.2));

    let m = rows.len();
    let mut ts = Vec::with_capacity(m);
    let mut src = Vec::with_capacity(m);
    let mut dst = Vec::with_capacity(m);
    let mut fx = Vec::with_capacity(m * out_dim);
    for (t, s, dd, f) in rows {
        ts.push(t);
        src.push(s);
        dst.push(dd);
        fx.extend_from_slice(&f);
    }
    Ok(GraphStorage::from_sorted_columns(
        ts,
        src,
        dst,
        out_dim,
        fx,
        Vec::new(),
        Vec::new(),
        0,
        Vec::new(),
        storage.num_nodes(),
        storage.static_feat_dim(),
        storage.static_feats().to_vec(),
        target,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::EdgeEvent;
    use crate::util::Rng;

    fn edge(t: Timestamp, src: u32, dst: u32, f: f32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![f, 2.0 * f] }
    }

    fn hourly_graph() -> StorageSnapshot {
        // Duplicate (0,1) within the first hour, one (1,2) in hour 1.
        let edges = vec![
            edge(0, 0, 1, 1.0),
            edge(600, 0, 1, 3.0),
            edge(1200, 2, 3, 5.0),
            edge(4000, 1, 2, 7.0),
        ];
        GraphStorage::from_events(edges, vec![], 4, None, Some(TimeGranularity::Second))
            .unwrap()
            .into_snapshot()
    }

    #[test]
    fn mean_reduction_collapses_duplicates() {
        let g = hourly_graph();
        let h = discretize(&g, TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        assert_eq!(h.num_edges(), 3);
        assert_eq!(h.granularity(), TimeGranularity::Hour);
        // (0,1) class reduced: mean of [1,2] and [3,6] = [2,4].
        let i = (0..3).find(|&i| h.edge_src()[i] == 0 && h.edge_dst()[i] == 1).unwrap();
        assert_eq!(h.edge_feat_row(i), &[2.0, 4.0]);
        // Representative timestamp is the bucket start.
        assert_eq!(h.edge_ts()[i], 0);
        let j = (0..3).find(|&i| h.edge_src()[i] == 1).unwrap();
        assert_eq!(h.edge_ts()[j], 3600);
    }

    #[test]
    fn sum_last_max_count() {
        let g = hourly_graph();
        let sum = discretize(&g, TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        let i = (0..3).find(|&i| sum.edge_src()[i] == 0 && sum.edge_dst()[i] == 1).unwrap();
        assert_eq!(sum.edge_feat_row(i), &[4.0, 8.0]);

        let last = discretize(&g, TimeGranularity::Hour, ReduceOp::Last).unwrap();
        assert_eq!(last.edge_feat_row(i), &[3.0, 6.0]);

        let mx = discretize(&g, TimeGranularity::Hour, ReduceOp::Max).unwrap();
        assert_eq!(mx.edge_feat_row(i), &[3.0, 6.0]);

        let cnt = discretize(&g, TimeGranularity::Hour, ReduceOp::Count).unwrap();
        assert_eq!(cnt.edge_feat_dim(), 1);
        assert_eq!(cnt.edge_feat_row(i), &[2.0]);
    }

    #[test]
    fn rejects_finer_target_and_event_graphs() {
        let g = hourly_graph();
        let daily = discretize(&g, TimeGranularity::Day, ReduceOp::Mean).unwrap().into_snapshot();
        assert_eq!(daily.num_edges(), 3); // all distinct (s,d) pairs, one day
        // Finer than native of the daily graph:
        assert!(discretize(&daily, TimeGranularity::Hour, ReduceOp::Mean).is_err());
    }

    #[test]
    fn vectorized_matches_utg_baseline() {
        // Property: both implementations agree on random graphs for every
        // reduction op.
        let mut rng = Rng::new(2024);
        for trial in 0..5 {
            let edges: Vec<EdgeEvent> = (0..400)
                .map(|_| {
                    edge(
                        rng.range(0, 100_000),
                        rng.below(20) as u32,
                        rng.below(20) as u32,
                        rng.f32() * 10.0,
                    )
                })
                .collect();
            let g = GraphStorage::from_events(edges, vec![], 20, None, Some(TimeGranularity::Second))
                .unwrap()
                .into_snapshot();
            for op in [ReduceOp::Sum, ReduceOp::Mean, ReduceOp::Last, ReduceOp::Max, ReduceOp::Count]
            {
                let a = discretize(&g, TimeGranularity::Hour, op).unwrap();
                let b = discretize_utg(&g, TimeGranularity::Hour, op).unwrap();
                assert_eq!(a.num_edges(), b.num_edges(), "trial {trial} op {op:?}");
                // Align rows by (t, src, dst) triple for comparison.
                let key = |s: &GraphStorage, i: usize| (s.edge_ts()[i], s.edge_src()[i], s.edge_dst()[i]);
                let mut ia: Vec<usize> = (0..a.num_edges()).collect();
                let mut ib: Vec<usize> = (0..b.num_edges()).collect();
                ia.sort_by_key(|&i| key(&a, i));
                ib.sort_by_key(|&i| key(&b, i));
                for (&x, &y) in ia.iter().zip(&ib) {
                    assert_eq!(key(&a, x), key(&b, y));
                    let fa = a.edge_feat_row(x);
                    let fb = b.edge_feat_row(y);
                    for (u, v) in fa.iter().zip(fb) {
                        assert!((u - v).abs() < 1e-4, "op {op:?}: {u} vs {v}");
                    }
                }
            }
        }
    }

    #[test]
    fn idempotent_at_same_granularity_when_no_duplicates() {
        let edges = vec![edge(0, 0, 1, 1.0), edge(3600, 1, 2, 2.0), edge(7200, 2, 0, 3.0)];
        let g = GraphStorage::from_events(edges, vec![], 3, None, Some(TimeGranularity::Hour))
            .unwrap()
            .into_snapshot();
        let h = discretize(&g, TimeGranularity::Hour, ReduceOp::Mean).unwrap();
        assert_eq!(h.num_edges(), 3);
        assert_eq!(h.edge_ts(), g.edge_ts());
        assert_eq!(h.edge_src(), g.edge_src());
    }
}
