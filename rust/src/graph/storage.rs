//! Immutable, time-sorted COO segment storage (paper §4, "Graph Storage").
//!
//! The backend is a columnar structure-of-arrays: edge timestamps, sources,
//! destinations and a flattened edge-feature matrix, all sorted by
//! timestamp (stable, so same-timestamp events keep insertion order).
//! Node events live in a parallel set of sorted columns. A *cached
//! timestamp index* (unique timestamp → first event offset) accelerates
//! time-slicing and recent-neighbor retrieval: lookups are a binary search
//! over unique timestamps instead of the full event array.
//!
//! A `GraphStorage` is read-only after construction, which makes readers
//! concurrency-safe by construction. Since the segmented-storage refactor
//! it plays the role of **one sealed segment**: the streaming layer
//! ([`super::segment::SegmentedStorage`]) stacks several of these behind
//! an immutable [`super::segment::StorageSnapshot`] that exposes the same
//! read API over logical offsets, so everything downstream (views,
//! loaders, hooks) works identically on one-shot and streamed graphs.

use crate::error::{Result, TgmError};
use crate::graph::events::{EdgeEvent, NodeEvent, NodeId};
use crate::graph::segment::StorageSnapshot;
use crate::persist::mmap::MappedSlice;
use crate::util::{infer_native_granularity, TimeGranularity, Timestamp};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, OnceLock};

/// One immutable column, either owned on the heap (the default) or
/// served zero-copy from an mmap'd sealed segment file
/// (`SegmentBacking::Mmap` — see [`crate::persist`]). Dereferences to a
/// plain slice, so every read path is backing-agnostic.
pub(crate) enum Col<T> {
    Heap(Vec<T>),
    Mapped(MappedSlice<T>),
}

impl<T: Copy> std::ops::Deref for Col<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Col::Heap(v) => v,
            Col::Mapped(m) => m.as_slice(),
        }
    }
}

impl<T> From<Vec<T>> for Col<T> {
    fn from(v: Vec<T>) -> Col<T> {
        Col::Heap(v)
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for Col<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Col::Heap(v) => write!(f, "Col::Heap({} elems)", v.len()),
            Col::Mapped(m) => write!(f, "Col::Mapped({} elems)", m.as_slice().len()),
        }
    }
}

/// Immutable columnar storage for one temporal graph.
#[derive(Debug)]
pub struct GraphStorage {
    // --- edge events, sorted by ts (stable) ---
    ts: Col<Timestamp>,
    src: Col<NodeId>,
    dst: Col<NodeId>,
    edge_feat_dim: usize,
    edge_feats: Col<f32>,
    // --- node events, sorted by ts (stable) ---
    node_ev_ts: Col<Timestamp>,
    node_ev_id: Col<NodeId>,
    node_feat_dim: usize,
    node_ev_feats: Col<f32>,
    // --- static node features ---
    static_feat_dim: usize,
    static_feats: Vec<f32>,
    // --- metadata ---
    num_nodes: usize,
    granularity: TimeGranularity,
    /// Cached index: (unique timestamp, offset of its first edge event).
    ts_index: Vec<(Timestamp, u32)>,
    /// Lazily built per-node index into the node-event columns (positions
    /// are ascending, hence time-sorted). Makes
    /// [`GraphStorage::latest_node_features_before`] an `O(log k)` lookup
    /// instead of a reverse linear scan over all node events.
    node_index: OnceLock<HashMap<NodeId, Vec<u32>>>,
}

impl GraphStorage {
    /// Build storage from (possibly unsorted) edge and node events.
    ///
    /// `num_nodes` must exceed every referenced node id. If `granularity`
    /// is `None`, the native granularity is inferred from edge timestamps
    /// (paper §3).
    pub fn from_events(
        mut edges: Vec<EdgeEvent>,
        mut node_events: Vec<NodeEvent>,
        num_nodes: usize,
        static_feats: Option<(usize, Vec<f32>)>,
        granularity: Option<TimeGranularity>,
    ) -> Result<GraphStorage> {
        if edges.is_empty() {
            return Err(TgmError::Graph("graph must contain at least one edge event".into()));
        }
        edges.sort_by_key(|e| e.t);
        node_events.sort_by_key(|e| e.t);

        let edge_feat_dim = edges[0].features.len();
        let node_feat_dim = node_events.first().map_or(0, |e| e.features.len());

        let n = edges.len();
        let mut ts = Vec::with_capacity(n);
        let mut src = Vec::with_capacity(n);
        let mut dst = Vec::with_capacity(n);
        let mut edge_feats = Vec::with_capacity(n * edge_feat_dim);
        for e in &edges {
            if e.src as usize >= num_nodes || e.dst as usize >= num_nodes {
                return Err(TgmError::Graph(format!(
                    "edge ({}, {}) references node >= num_nodes={num_nodes}",
                    e.src, e.dst
                )));
            }
            if e.features.len() != edge_feat_dim {
                return Err(TgmError::Graph(format!(
                    "inconsistent edge feature dim: {} vs {edge_feat_dim}",
                    e.features.len()
                )));
            }
            ts.push(e.t);
            src.push(e.src);
            dst.push(e.dst);
            edge_feats.extend_from_slice(&e.features);
        }

        let mut node_ev_ts = Vec::with_capacity(node_events.len());
        let mut node_ev_id = Vec::with_capacity(node_events.len());
        let mut node_ev_feats = Vec::with_capacity(node_events.len() * node_feat_dim);
        for e in &node_events {
            if e.node as usize >= num_nodes {
                return Err(TgmError::Graph(format!(
                    "node event references node {} >= num_nodes={num_nodes}",
                    e.node
                )));
            }
            if e.features.len() != node_feat_dim {
                return Err(TgmError::Graph(format!(
                    "inconsistent node feature dim: {} vs {node_feat_dim}",
                    e.features.len()
                )));
            }
            node_ev_ts.push(e.t);
            node_ev_id.push(e.node);
            node_ev_feats.extend_from_slice(&e.features);
        }

        let (static_feat_dim, static_feats) = match static_feats {
            Some((dim, feats)) => {
                if feats.len() != dim * num_nodes {
                    return Err(TgmError::Graph(format!(
                        "static feature matrix has {} values, expected {}",
                        feats.len(),
                        dim * num_nodes
                    )));
                }
                (dim, feats)
            }
            None => (0, Vec::new()),
        };

        let granularity = granularity.unwrap_or_else(|| infer_native_granularity(&ts));
        let ts_index = build_ts_index(&ts);

        Ok(GraphStorage {
            ts: ts.into(),
            src: src.into(),
            dst: dst.into(),
            edge_feat_dim,
            edge_feats: edge_feats.into(),
            node_ev_ts: node_ev_ts.into(),
            node_ev_id: node_ev_id.into(),
            node_feat_dim,
            node_ev_feats: node_ev_feats.into(),
            static_feat_dim,
            static_feats,
            num_nodes,
            granularity,
            ts_index,
            node_index: OnceLock::new(),
        })
    }

    /// Build directly from sorted columns (used by discretization and
    /// segment compaction, which produce already-sorted output). Callers
    /// must guarantee both timestamp columns are non-decreasing; this is
    /// checked in debug builds.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_sorted_columns(
        ts: Vec<Timestamp>,
        src: Vec<NodeId>,
        dst: Vec<NodeId>,
        edge_feat_dim: usize,
        edge_feats: Vec<f32>,
        node_ev_ts: Vec<Timestamp>,
        node_ev_id: Vec<NodeId>,
        node_feat_dim: usize,
        node_ev_feats: Vec<f32>,
        num_nodes: usize,
        static_feat_dim: usize,
        static_feats: Vec<f32>,
        granularity: TimeGranularity,
    ) -> GraphStorage {
        debug_assert!(ts.windows(2).all(|w| w[0] <= w[1]), "columns must be time-sorted");
        debug_assert!(
            node_ev_ts.windows(2).all(|w| w[0] <= w[1]),
            "node-event columns must be time-sorted"
        );
        let ts_index = build_ts_index(&ts);
        GraphStorage {
            ts: ts.into(),
            src: src.into(),
            dst: dst.into(),
            edge_feat_dim,
            edge_feats: edge_feats.into(),
            node_ev_ts: node_ev_ts.into(),
            node_ev_id: node_ev_id.into(),
            node_feat_dim,
            node_ev_feats: node_ev_feats.into(),
            static_feat_dim,
            static_feats,
            num_nodes,
            granularity,
            ts_index,
            node_index: OnceLock::new(),
        }
    }

    /// Build from already-validated, already-sorted backed columns — the
    /// zero-copy entry point for mmap-served sealed segment files
    /// ([`crate::persist::format::map_segment`]). The acceleration
    /// indices are rebuilt on the heap (they are small); the event
    /// columns stay wherever their [`Col`] backing puts them.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_backed_columns(
        ts: Col<Timestamp>,
        src: Col<NodeId>,
        dst: Col<NodeId>,
        edge_feat_dim: usize,
        edge_feats: Col<f32>,
        node_ev_ts: Col<Timestamp>,
        node_ev_id: Col<NodeId>,
        node_feat_dim: usize,
        node_ev_feats: Col<f32>,
        num_nodes: usize,
        granularity: TimeGranularity,
    ) -> GraphStorage {
        let ts_index = build_ts_index(&ts);
        GraphStorage {
            ts,
            src,
            dst,
            edge_feat_dim,
            edge_feats,
            node_ev_ts,
            node_ev_id,
            node_feat_dim,
            node_ev_feats,
            static_feat_dim: 0,
            static_feats: Vec::new(),
            num_nodes,
            granularity,
            ts_index,
            node_index: OnceLock::new(),
        }
    }

    /// True when the event columns are served from an mmap'd segment
    /// file rather than heap copies.
    pub fn is_mapped(&self) -> bool {
        matches!(self.ts, Col::Mapped(_))
    }

    /// Wrap in an `Arc` for sharing with views.
    pub fn into_shared(self) -> Arc<GraphStorage> {
        Arc::new(self)
    }

    /// Wrap as a single-segment [`StorageSnapshot`] — the type views,
    /// loaders and hooks read from.
    pub fn into_snapshot(self) -> StorageSnapshot {
        StorageSnapshot::from_storage(self)
    }

    /// Wrap as a shared single-segment snapshot.
    pub fn into_shared_snapshot(self) -> Arc<StorageSnapshot> {
        Arc::new(self.into_snapshot())
    }

    // ------------------------------------------------------------------
    // metadata
    // ------------------------------------------------------------------

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.ts.len()
    }

    pub fn num_node_events(&self) -> usize {
        self.node_ev_ts.len()
    }

    pub fn edge_feat_dim(&self) -> usize {
        self.edge_feat_dim
    }

    pub fn node_feat_dim(&self) -> usize {
        self.node_feat_dim
    }

    pub fn static_feat_dim(&self) -> usize {
        self.static_feat_dim
    }

    /// Native time granularity of the stored graph.
    pub fn granularity(&self) -> TimeGranularity {
        self.granularity
    }

    /// Timestamp of the first edge event.
    pub fn start_time(&self) -> Timestamp {
        self.ts[0]
    }

    /// Timestamp of the last edge event.
    pub fn end_time(&self) -> Timestamp {
        *self.ts.last().unwrap()
    }

    /// Number of distinct edge timestamps ("unique steps" in Table 13).
    pub fn num_unique_timestamps(&self) -> usize {
        self.ts_index.len()
    }

    // ------------------------------------------------------------------
    // columnar accessors (zero-copy)
    // ------------------------------------------------------------------

    pub fn edge_ts(&self) -> &[Timestamp] {
        &self.ts
    }

    pub fn edge_src(&self) -> &[NodeId] {
        &self.src
    }

    pub fn edge_dst(&self) -> &[NodeId] {
        &self.dst
    }

    /// Flattened edge feature matrix (`num_edges x edge_feat_dim`).
    pub fn edge_feats(&self) -> &[f32] {
        &self.edge_feats
    }

    /// Feature row of edge `i` (empty slice when unattributed).
    pub fn edge_feat_row(&self, i: usize) -> &[f32] {
        &self.edge_feats[i * self.edge_feat_dim..(i + 1) * self.edge_feat_dim]
    }

    pub fn node_event_ts(&self) -> &[Timestamp] {
        &self.node_ev_ts
    }

    pub fn node_event_ids(&self) -> &[NodeId] {
        &self.node_ev_id
    }

    pub fn node_event_feats(&self) -> &[f32] {
        &self.node_ev_feats
    }

    pub fn node_event_feat_row(&self, i: usize) -> &[f32] {
        &self.node_ev_feats[i * self.node_feat_dim..(i + 1) * self.node_feat_dim]
    }

    /// Static node feature matrix (`num_nodes x static_feat_dim`).
    pub fn static_feats(&self) -> &[f32] {
        &self.static_feats
    }

    // ------------------------------------------------------------------
    // time queries (binary search over the cached index)
    // ------------------------------------------------------------------

    /// Index range of edge events with `t0 <= t < t1`.
    ///
    /// Uses the cached unique-timestamp index — two binary searches over
    /// `O(U)` unique timestamps — when it actually shrinks the search
    /// space. With near-unique timestamps (U ≈ E, e.g. wiki's 152k steps
    /// over 157k events) the indirection costs more than it saves
    /// (measured in `benches/ablations.rs`), so we fall back to a direct
    /// search over the raw column.
    pub fn edge_range(&self, t0: Timestamp, t1: Timestamp) -> Range<usize> {
        if t1 <= t0 {
            return 0..0;
        }
        self.edge_lower_bound(t0)..self.edge_lower_bound(t1)
    }

    /// Offset of the first edge event with timestamp `>= t` (also the
    /// segment-local entry point for [`StorageSnapshot`] range mapping).
    pub fn edge_lower_bound(&self, t: Timestamp) -> usize {
        if self.ts_index.len() * 4 > self.ts.len() * 3 {
            self.ts.partition_point(|&u| u < t)
        } else {
            self.index_lower_bound(t)
        }
    }

    /// Offset of the first edge with timestamp >= t.
    fn index_lower_bound(&self, t: Timestamp) -> usize {
        let pos = self.ts_index.partition_point(|&(u, _)| u < t);
        if pos == self.ts_index.len() {
            self.ts.len()
        } else {
            self.ts_index[pos].1 as usize
        }
    }

    /// Index range of node events with `t0 <= t < t1` (plain binary search;
    /// node events are typically far fewer than edges).
    pub fn node_event_range(&self, t0: Timestamp, t1: Timestamp) -> Range<usize> {
        if t1 <= t0 {
            return 0..0;
        }
        self.node_event_lower_bound(t0)..self.node_event_lower_bound(t1)
    }

    /// Offset of the first node event with timestamp `>= t`.
    pub fn node_event_lower_bound(&self, t: Timestamp) -> usize {
        self.node_ev_ts.partition_point(|&u| u < t)
    }

    /// Lazily built per-node positions into the node-event columns.
    fn node_index(&self) -> &HashMap<NodeId, Vec<u32>> {
        self.node_index.get_or_init(|| {
            let mut index: HashMap<NodeId, Vec<u32>> = HashMap::new();
            for (i, &n) in self.node_ev_id.iter().enumerate() {
                index.entry(n).or_default().push(i as u32);
            }
            index
        })
    }

    /// Latest dynamic feature row for `node` strictly before `t`, falling
    /// back to `None` when no node event precedes `t`.
    ///
    /// `O(log k)` in the node's own event count `k` via the lazily built
    /// per-node index (the positions are ascending, hence time-sorted),
    /// replacing the old `O(num_node_events)` reverse linear scan.
    pub fn latest_node_features_before(&self, node: NodeId, t: Timestamp) -> Option<&[f32]> {
        let positions = self.node_index().get(&node)?;
        let cut = positions.partition_point(|&i| self.node_ev_ts[i as usize] < t);
        if cut == 0 {
            None
        } else {
            Some(self.node_event_feat_row(positions[cut - 1] as usize))
        }
    }

    /// Total bytes held by this storage (memory accounting, Table 10).
    pub fn byte_size(&self) -> usize {
        self.ts.len() * 8
            + self.src.len() * 4
            + self.dst.len() * 4
            + self.edge_feats.len() * 4
            + self.node_ev_ts.len() * 8
            + self.node_ev_id.len() * 4
            + self.node_ev_feats.len() * 4
            + self.static_feats.len() * 4
            + self.ts_index.len() * 12
    }
}

/// Build the cached unique-timestamp index from a sorted timestamp column.
fn build_ts_index(ts: &[Timestamp]) -> Vec<(Timestamp, u32)> {
    let mut index = Vec::new();
    let mut prev: Option<Timestamp> = None;
    for (i, &t) in ts.iter().enumerate() {
        if prev != Some(t) {
            index.push((t, i as u32));
            prev = Some(t);
        }
    }
    index
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(t: Timestamp, src: NodeId, dst: NodeId) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![t as f32] }
    }

    fn sample() -> GraphStorage {
        // Unsorted on purpose; duplicates at t=10.
        let edges = vec![edge(20, 2, 3), edge(10, 0, 1), edge(10, 1, 2), edge(40, 3, 0)];
        let nodes = vec![
            NodeEvent { t: 15, node: 1, features: vec![1.0, 2.0] },
            NodeEvent { t: 35, node: 1, features: vec![3.0, 4.0] },
        ];
        GraphStorage::from_events(edges, nodes, 4, None, None).unwrap()
    }

    #[test]
    fn construction_sorts_and_indexes() {
        let g = sample();
        assert_eq!(g.edge_ts(), &[10, 10, 20, 40]);
        assert_eq!(g.edge_src(), &[0, 1, 2, 3]);
        assert_eq!(g.num_unique_timestamps(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.start_time(), 10);
        assert_eq!(g.end_time(), 40);
        // Feature rows follow the sort.
        assert_eq!(g.edge_feat_row(0), &[10.0]);
        assert_eq!(g.edge_feat_row(3), &[40.0]);
    }

    #[test]
    fn edge_range_boundaries() {
        let g = sample();
        assert_eq!(g.edge_range(10, 11), 0..2);
        assert_eq!(g.edge_range(10, 10), 0..0); // empty interval
        assert_eq!(g.edge_range(0, 100), 0..4);
        assert_eq!(g.edge_range(11, 20), 2..2);
        assert_eq!(g.edge_range(11, 21), 2..3);
        assert_eq!(g.edge_range(41, 50), 4..4);
        assert_eq!(g.edge_range(20, 10), 0..0); // inverted interval
    }

    #[test]
    fn edge_range_matches_linear_scan() {
        // Property check: index-based range == brute-force filter.
        let mut rng = crate::util::Rng::new(123);
        let edges: Vec<EdgeEvent> =
            (0..500).map(|_| edge(rng.range(0, 50), 0, 1)).collect();
        let g = GraphStorage::from_events(edges, vec![], 2, None, None).unwrap();
        for _ in 0..200 {
            let a = rng.range(-5, 60);
            let b = rng.range(-5, 60);
            let r = g.edge_range(a, b);
            let expect =
                g.edge_ts().iter().filter(|&&t| t >= a && t < b).count();
            assert_eq!(r.len(), expect, "range [{a},{b})");
            for i in r {
                assert!(g.edge_ts()[i] >= a && g.edge_ts()[i] < b);
            }
        }
    }

    #[test]
    fn node_event_queries() {
        let g = sample();
        assert_eq!(g.node_event_range(0, 100), 0..2);
        assert_eq!(g.node_event_range(16, 100), 1..2);
        assert_eq!(g.latest_node_features_before(1, 15), None);
        assert_eq!(g.latest_node_features_before(1, 16).unwrap(), &[1.0, 2.0]);
        assert_eq!(g.latest_node_features_before(1, 100).unwrap(), &[3.0, 4.0]);
        assert_eq!(g.latest_node_features_before(0, 100), None);
    }

    #[test]
    fn validation_errors() {
        // Node id out of range.
        assert!(GraphStorage::from_events(vec![edge(1, 0, 9)], vec![], 4, None, None).is_err());
        // Inconsistent feature dims.
        let bad = vec![
            EdgeEvent { t: 1, src: 0, dst: 1, features: vec![1.0] },
            EdgeEvent { t: 2, src: 0, dst: 1, features: vec![1.0, 2.0] },
        ];
        assert!(GraphStorage::from_events(bad, vec![], 2, None, None).is_err());
        // Empty graph.
        assert!(GraphStorage::from_events(vec![], vec![], 2, None, None).is_err());
        // Static feature size mismatch.
        assert!(GraphStorage::from_events(
            vec![edge(1, 0, 1)],
            vec![],
            2,
            Some((3, vec![0.0; 5])),
            None
        )
        .is_err());
    }

    #[test]
    fn granularity_inferred() {
        let edges = vec![edge(0, 0, 1), edge(3600, 1, 0), edge(7200, 0, 1)];
        let g = GraphStorage::from_events(edges, vec![], 2, None, None).unwrap();
        assert_eq!(g.granularity(), TimeGranularity::Hour);
    }
}
