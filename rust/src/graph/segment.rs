//! Segmented append-only storage with epoch snapshots (streaming
//! ingestion).
//!
//! The paper assumes a read-only event log; this module lifts that
//! restriction without giving up any of its concurrency guarantees. A
//! [`SegmentedStorage`] is a stack of **sealed segments** — each one an
//! ordinary immutable [`GraphStorage`] (the existing SoA layout with its
//! own timestamp index) — plus one **mutable active segment** that accepts
//! [`SegmentedStorage::append`]. The active segment is sealed (sorted and
//! frozen into a new `GraphStorage`) when it reaches the [`SealPolicy`]
//! size/span threshold or on an explicit [`SegmentedStorage::seal`].
//!
//! Readers never see the mutable state: [`SegmentedStorage::snapshot`]
//! returns an [`Arc<StorageSnapshot>`] — an immutable, versioned view over
//! the sealed segments plus a frozen copy of the current active tail. The
//! snapshot exposes the `GraphStorage` read API over **logical offsets**
//! (global indices into the concatenation of its segments), so `DGraph`
//! views, the batch planner, `materialize_window` and the prefetch loader
//! all work unchanged on a graph that keeps growing while it trains.
//!
//! Ordering invariants that make the logical-offset layer a plain
//! concatenation:
//!
//! * within the active segment, out-of-order appends are allowed and are
//!   stably sorted at seal time (same semantics as
//!   [`GraphStorage::from_events`]);
//! * appends older than the last *sealed* timestamp of their kind are
//!   rejected with [`TgmError::StaleAppend`], so sealed segments cover
//!   non-overlapping, non-decreasing time spans and the concatenated
//!   columns are globally time-sorted.
//!
//! Because an event stream fed in the same order produces the same stable
//! sort, a fully appended-then-sealed stream yields byte-identical batches
//! to a one-shot [`GraphStorage::from_events`] build (pinned by the
//! determinism tests here and in `tests/integration.rs`).
//!
//! [`SegmentedStorage::compact`] merges the sealed segments (their columns
//! are already globally sorted, so the merge is a linear concatenation)
//! into a single segment, bounding per-read segment fan-out; the
//! `streaming` case in `benches/ablations.rs` tracks the segmented-read
//! overhead against the compacted baseline. Compaction is invoked
//! synchronously (e.g. between training windows via
//! [`SegmentedStorage::maybe_compact`]) to keep the pipeline
//! deterministic; nothing in the design prevents moving it to a background
//! thread later, since it only touches sealed (immutable) segments.

use crate::error::{Result, TgmError};
use crate::graph::discretize::ReduceOp;
use crate::graph::dtdg::{check_view_target, DtdgHandle, DtdgView};
use crate::graph::events::{EdgeEvent, Event, NodeEvent, NodeId};
use crate::graph::storage::GraphStorage;
use crate::kernels;
use crate::persist::format::read_segment_backed;
use crate::persist::wal::WalSync;
use crate::persist::{plan_tiered_run, Durability, DurabilityPolicy, SegmentBacking, StoreMeta};
use crate::util::{granularity_for_min_gap, min_positive_gap, TimeGranularity, Timestamp};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Global allocator for store and segment ids. Ids are never reused, so
/// caches keyed on them (adjacency, inferred destination ranges) cannot
/// false-hit the way the old pointer-address fingerprints could when an
/// allocation was recycled.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

pub(crate) fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Bookkeeping derivable from a sealed-segment stack (boundary gaps,
/// last sealed timestamps, feature dims). Recomputed rather than
/// persisted: recovery, replica bootstrap, and replica compaction
/// deltas all rebuild it from the segments themselves.
struct SealedInvariants {
    min_sealed_gap: Option<i64>,
    last_sealed_edge_ts: Option<Timestamp>,
    last_sealed_node_ts: Option<Timestamp>,
    edge_feat_dim: Option<usize>,
    node_feat_dim: Option<usize>,
}

impl SealedInvariants {
    fn derive(sealed: &[Arc<GraphStorage>]) -> SealedInvariants {
        let mut min_sealed_gap: Option<i64> = None;
        let mut last_sealed_edge_ts: Option<Timestamp> = None;
        let mut last_sealed_node_ts: Option<Timestamp> = None;
        let mut edge_feat_dim = None;
        let mut node_feat_dim = None;
        for seg in sealed {
            let ts = seg.edge_ts();
            let mut gap = min_positive_gap(ts);
            if let (Some(last), Some(&first)) = (last_sealed_edge_ts, ts.first()) {
                let boundary = first - last;
                if boundary > 0 {
                    gap = Some(gap.map_or(boundary, |g: i64| g.min(boundary)));
                }
            }
            min_sealed_gap = SegmentedStorage::fold_gap(min_sealed_gap, gap);
            last_sealed_edge_ts =
                Some(last_sealed_edge_ts.map_or(seg.end_time(), |l| l.max(seg.end_time())));
            if let Some(&last) = seg.node_event_ts().last() {
                last_sealed_node_ts =
                    Some(last_sealed_node_ts.map_or(last, |l: Timestamp| l.max(last)));
            }
            edge_feat_dim.get_or_insert(seg.edge_feat_dim());
            if node_feat_dim.is_none() && seg.num_node_events() > 0 {
                node_feat_dim = Some(seg.node_feat_dim());
            }
        }
        SealedInvariants {
            min_sealed_gap,
            last_sealed_edge_ts,
            last_sealed_node_ts,
            edge_feat_dim,
            node_feat_dim,
        }
    }
}

/// Identity of one immutable snapshot: the owning store's id plus the
/// store's monotonic generation at snapshot time. Two snapshots with the
/// same `SnapshotId` are guaranteed to hold identical data.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SnapshotId {
    /// Globally unique id of the producing store (or standalone storage).
    pub store: u64,
    /// Monotonic mutation counter of the store at snapshot time.
    pub generation: u64,
}

/// When the active segment seals automatically.
#[derive(Debug, Clone)]
pub struct SealPolicy {
    /// Seal once the active segment buffers this many events (edge plus
    /// node events — a node-event-heavy stream must not grow the active
    /// segment unboundedly just because edges are rare).
    pub max_events: usize,
    /// Seal once the active segment's timestamps (edge *and* node
    /// events) span more than this many native time units
    /// (`None` = unbounded).
    pub max_span: Option<i64>,
    /// Hard cap on node events buffered while the active segment holds
    /// **no edge** (a segment needs at least one edge to carry a time
    /// span, so edge-free node events cannot seal; this bound turns the
    /// would-be unbounded buffer into a typed
    /// [`TgmError::Backpressure`] error).
    pub max_pending_node_events: usize,
}

impl Default for SealPolicy {
    fn default() -> Self {
        SealPolicy { max_events: 4096, max_span: None, max_pending_node_events: 65_536 }
    }
}

impl SealPolicy {
    /// Policy sealing after `n` buffered events, otherwise default.
    pub fn by_events(n: usize) -> SealPolicy {
        SealPolicy { max_events: n, ..Default::default() }
    }

    /// Additionally seal once the active span exceeds `span` time units.
    pub fn with_max_span(mut self, span: i64) -> SealPolicy {
        self.max_span = Some(span);
        self
    }

    /// Set the edge-free pending node-event cap.
    pub fn with_node_event_cap(mut self, cap: usize) -> SealPolicy {
        self.max_pending_node_events = cap.max(1);
        self
    }
}

/// Append-only segmented storage: sealed immutable segments + one mutable
/// active segment. Produces immutable [`StorageSnapshot`]s for readers.
pub struct SegmentedStorage {
    num_nodes: usize,
    policy: SealPolicy,
    /// Explicit granularity override (`with_granularity`). When unset,
    /// granularity is inferred from the *whole* stream seen so far via
    /// the incrementally folded [`Self::min_sealed_gap`], matching what
    /// `GraphStorage::from_events` would infer over the same prefix — so
    /// it may refine (grow finer) as bursts give way to spaced events.
    fixed_granularity: Option<TimeGranularity>,
    /// Minimum positive adjacent gap across the globally sorted sealed
    /// stream (segment-internal gaps + inter-segment boundary gaps).
    min_sealed_gap: Option<i64>,
    static_feat_dim: usize,
    static_feats: Arc<Vec<f32>>,
    sealed: Vec<Arc<GraphStorage>>,
    sealed_ids: Vec<u64>,
    active_edges: Vec<EdgeEvent>,
    active_nodes: Vec<NodeEvent>,
    /// Edge/node feature dims, fixed by the first appended event of each
    /// kind.
    edge_feat_dim: Option<usize>,
    node_feat_dim: Option<usize>,
    /// Min/max edge timestamp of the active segment (span sealing).
    active_min_t: Option<Timestamp>,
    active_max_t: Option<Timestamp>,
    /// Newest timestamp ever sealed, per event kind; older appends are
    /// rejected so sealed segments stay globally time-sorted.
    last_sealed_edge_ts: Option<Timestamp>,
    last_sealed_node_ts: Option<Timestamp>,
    store_id: u64,
    generation: u64,
    /// Memoized snapshot of the current generation (tail freezes are a
    /// copy; repeated `snapshot()` calls without writes reuse it).
    cached_snapshot: Option<(u64, Arc<StorageSnapshot>)>,
    /// Cumulative bytes of merged segments written by compaction
    /// (full or tiered) — the write-amplification numerator tracked by
    /// `ablation.persist`.
    compaction_bytes: u64,
    /// Disk-side state when durability is enabled (see [`crate::persist`]):
    /// appends are WAL-recorded before acknowledgment, seals write
    /// immutable segment files, compactions replace them atomically.
    durability: Option<Durability>,
    /// Registered DTDG materialized views, refreshed incrementally on
    /// every seal (see [`crate::graph::dtdg`]).
    dtdg: Vec<DtdgView>,
}

impl SegmentedStorage {
    /// Empty store over `num_nodes` ids with the given seal policy.
    pub fn new(num_nodes: usize, policy: SealPolicy) -> SegmentedStorage {
        SegmentedStorage {
            num_nodes,
            policy,
            fixed_granularity: None,
            min_sealed_gap: None,
            static_feat_dim: 0,
            static_feats: Arc::new(Vec::new()),
            sealed: Vec::new(),
            sealed_ids: Vec::new(),
            active_edges: Vec::new(),
            active_nodes: Vec::new(),
            edge_feat_dim: None,
            node_feat_dim: None,
            active_min_t: None,
            active_max_t: None,
            last_sealed_edge_ts: None,
            last_sealed_node_ts: None,
            store_id: next_id(),
            generation: 0,
            cached_snapshot: None,
            compaction_bytes: 0,
            durability: None,
            dtdg: Vec::new(),
        }
    }

    /// Fix the native granularity up front. Without this, granularity is
    /// inferred from all edge timestamps appended so far (exactly as
    /// `GraphStorage::from_events` would infer it over the same stream)
    /// and may refine as more data arrives. On an already-durable store
    /// the manifest is refreshed in place (a refresh failure poisons
    /// durability rather than silently diverging memory from disk).
    pub fn with_granularity(mut self, g: TimeGranularity) -> SegmentedStorage {
        self.fixed_granularity = Some(g);
        self.refresh_durable_metadata();
        self
    }

    /// Attach a static node-feature matrix (`num_nodes x dim`).
    pub fn with_static_feats(mut self, dim: usize, feats: Vec<f32>) -> Result<SegmentedStorage> {
        if feats.len() != dim * self.num_nodes {
            return Err(TgmError::Graph(format!(
                "static feature matrix has {} values, expected {}",
                feats.len(),
                dim * self.num_nodes
            )));
        }
        self.static_feat_dim = dim;
        self.static_feats = Arc::new(feats);
        self.refresh_durable_metadata();
        Ok(self)
    }

    /// Re-persist manifest-level metadata after a builder call on an
    /// already-durable store (`with_granularity`/`with_static_feats`
    /// after `with_durability`), so the directory always recovers to
    /// what memory serves. Infallible signature for the builder chain:
    /// a persistence failure poisons durability instead.
    fn refresh_durable_metadata(&mut self) {
        if let Some(mut d) = self.durability.take() {
            let res = d.refresh_metadata(&self.store_meta(self.generation));
            if res.is_err() {
                d.poison("failed to persist a metadata change");
            }
            self.durability = Some(d);
        }
    }

    /// Enable durability (see [`crate::persist`]): every subsequent
    /// append is WAL-recorded before it is acknowledged, every seal
    /// writes an immutable on-disk segment file, and compactions replace
    /// segment files atomically. Must be called on a store that has not
    /// ingested anything yet; metadata builders
    /// ([`SegmentedStorage::with_granularity`],
    /// [`SegmentedStorage::with_static_feats`]) may run before or after
    /// — later calls refresh the manifest in place. Use
    /// [`crate::persist::recover`] to reopen a directory that already
    /// holds a store.
    pub fn with_durability(mut self, policy: DurabilityPolicy) -> Result<SegmentedStorage> {
        if self.generation != 0
            || !self.sealed.is_empty()
            || !self.active_edges.is_empty()
            || !self.active_nodes.is_empty()
        {
            return Err(TgmError::Persist(
                "durability must be enabled on an empty store (before any append/seal); \
                 recover an existing directory with persist::recover"
                    .into(),
            ));
        }
        let meta = StoreMeta {
            num_nodes: self.num_nodes,
            fixed_granularity: self.fixed_granularity,
            static_feat_dim: self.static_feat_dim,
            static_feats: self.static_feats.as_slice(),
            generation: 0,
        };
        let durability = Durability::init(policy, &meta)?;
        self.durability = Some(durability);
        Ok(self)
    }

    /// Rebuild a store from recovered parts (the [`crate::persist::recover`]
    /// entry point; everything derivable from the sealed segments —
    /// boundary gaps, last sealed timestamps, feature dims — is
    /// recomputed here rather than persisted).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_recovered(
        num_nodes: usize,
        policy: SealPolicy,
        fixed_granularity: Option<TimeGranularity>,
        static_feat_dim: usize,
        static_feats: Vec<f32>,
        sealed: Vec<Arc<GraphStorage>>,
        generation: u64,
        durability: Durability,
    ) -> SegmentedStorage {
        let inv = SealedInvariants::derive(&sealed);
        let SealedInvariants {
            min_sealed_gap,
            last_sealed_edge_ts,
            last_sealed_node_ts,
            edge_feat_dim,
            node_feat_dim,
        } = inv;
        let sealed_ids = sealed.iter().map(|_| next_id()).collect();
        SegmentedStorage {
            num_nodes,
            policy,
            fixed_granularity,
            min_sealed_gap,
            static_feat_dim,
            static_feats: Arc::new(static_feats),
            sealed,
            sealed_ids,
            active_edges: Vec::new(),
            active_nodes: Vec::new(),
            edge_feat_dim,
            node_feat_dim,
            active_min_t: None,
            active_max_t: None,
            last_sealed_edge_ts,
            last_sealed_node_ts,
            store_id: next_id(),
            generation,
            cached_snapshot: None,
            compaction_bytes: 0,
            durability: Some(durability),
            dtdg: Vec::new(),
        }
    }

    /// Rebuild a read-only replica store from fetched parts (the
    /// [`crate::replica`] bootstrap path). Same derivation as
    /// [`SegmentedStorage::from_recovered`], but with no durability:
    /// a replica's on-disk state is owned by the replica itself
    /// (fetched files named by primary segment seq), so the store
    /// must never write a WAL or seal segments of its own.
    pub(crate) fn from_replica_parts(
        num_nodes: usize,
        fixed_granularity: Option<TimeGranularity>,
        static_feat_dim: usize,
        static_feats: Vec<f32>,
        sealed: Vec<Arc<GraphStorage>>,
        generation: u64,
    ) -> SegmentedStorage {
        let SealedInvariants {
            min_sealed_gap,
            last_sealed_edge_ts,
            last_sealed_node_ts,
            edge_feat_dim,
            node_feat_dim,
        } = SealedInvariants::derive(&sealed);
        let sealed_ids = sealed.iter().map(|_| next_id()).collect();
        SegmentedStorage {
            num_nodes,
            policy: SealPolicy::default(),
            fixed_granularity,
            min_sealed_gap,
            static_feat_dim,
            static_feats: Arc::new(static_feats),
            sealed,
            sealed_ids,
            active_edges: Vec::new(),
            active_nodes: Vec::new(),
            edge_feat_dim,
            node_feat_dim,
            active_min_t: None,
            active_max_t: None,
            last_sealed_edge_ts,
            last_sealed_node_ts,
            store_id: next_id(),
            generation,
            cached_snapshot: None,
            compaction_bytes: 0,
            durability: None,
            dtdg: Vec::new(),
        }
    }

    // ------------------------------------------------------------------
    // replica apply path (see `crate::replica`)
    // ------------------------------------------------------------------

    /// Drop the replayed WAL tail: the primary sealed, so every event
    /// the replica replayed this epoch is contained in the sealed
    /// segment it is about to install.
    pub(crate) fn replica_clear_tail(&mut self) {
        self.active_edges.clear();
        self.active_nodes.clear();
        self.active_min_t = None;
        self.active_max_t = None;
    }

    /// Install a fetched sealed segment at the top of the stack,
    /// folding the same boundary-gap / last-timestamp bookkeeping the
    /// primary's own seal performed, so replica snapshots infer the
    /// identical granularity (byte-identical batches).
    pub(crate) fn replica_install_sealed(&mut self, seg: Arc<GraphStorage>) {
        let ts = seg.edge_ts();
        let mut gap = min_positive_gap(ts);
        if let (Some(last), Some(&first)) = (self.last_sealed_edge_ts, ts.first()) {
            let boundary = first - last;
            if boundary > 0 {
                gap = Some(gap.map_or(boundary, |g: i64| g.min(boundary)));
            }
        }
        self.min_sealed_gap = Self::fold_gap(self.min_sealed_gap, gap);
        self.last_sealed_edge_ts =
            Some(self.last_sealed_edge_ts.map_or(seg.end_time(), |l| l.max(seg.end_time())));
        if let Some(&last) = seg.node_event_ts().last() {
            self.last_sealed_node_ts =
                Some(self.last_sealed_node_ts.map_or(last, |l: Timestamp| l.max(last)));
        }
        self.edge_feat_dim.get_or_insert(seg.edge_feat_dim());
        if self.node_feat_dim.is_none() && seg.num_node_events() > 0 {
            self.node_feat_dim = Some(seg.node_feat_dim());
        }
        self.sealed.push(seg);
        self.sealed_ids.push(next_id());
        self.generation += 1;
    }

    /// Recompute sealed-stack bookkeeping from scratch. Replica path
    /// after a compaction delta: the merged segment may fold events
    /// from seals this replica never saw individually (a seal and a
    /// compaction landing between two polls), so the incremental
    /// update in [`SegmentedStorage::replica_install_sealed`] cannot
    /// cover it.
    pub(crate) fn replica_recompute_sealed_invariants(&mut self) {
        let inv = SealedInvariants::derive(&self.sealed);
        self.min_sealed_gap = inv.min_sealed_gap;
        self.last_sealed_edge_ts = inv.last_sealed_edge_ts;
        self.last_sealed_node_ts = inv.last_sealed_node_ts;
        if let Some(d) = inv.edge_feat_dim {
            self.edge_feat_dim.get_or_insert(d);
        }
        if let Some(d) = inv.node_feat_dim {
            self.node_feat_dim.get_or_insert(d);
        }
        self.cached_snapshot = None;
    }

    /// Pin the mutation counter to the primary's. Replica generations
    /// are derived (manifest anchor + applied WAL-tail length), not
    /// counted locally, so a replica snapshot's `SnapshotId.generation`
    /// matches the primary's for the same logical content.
    pub(crate) fn set_replica_generation(&mut self, generation: u64) {
        if self.generation != generation {
            self.cached_snapshot = None;
            self.generation = generation;
        }
    }

    // ------------------------------------------------------------------
    // metadata
    // ------------------------------------------------------------------

    /// True when this store persists itself (see
    /// [`SegmentedStorage::with_durability`]).
    pub fn is_durable(&self) -> bool {
        self.durability.is_some()
    }

    /// Directory backing this store when durability is enabled.
    pub fn durable_dir(&self) -> Option<&std::path::Path> {
        self.durability.as_ref().map(|d| d.dir())
    }

    /// The sealed (immutable) segments and their never-reused ids — the
    /// background compactor's scan point.
    pub fn sealed_segments(&self) -> (Vec<Arc<GraphStorage>>, Vec<u64>) {
        (self.sealed.clone(), self.sealed_ids.clone())
    }

    /// Publish the recovery-time (deferred) WAL at its real path; no-op
    /// for non-durable stores and committed logs (see
    /// [`crate::persist::recover`]).
    pub(crate) fn commit_recovered_wal(&mut self) -> Result<()> {
        match self.durability.as_mut() {
            Some(d) => d.commit_wal(),
            None => Ok(()),
        }
    }

    /// True when a failed durable operation has poisoned the store (the
    /// background compactor checks this before doing any merge work).
    pub(crate) fn durability_poisoned(&self) -> bool {
        self.durability.as_ref().is_some_and(Durability::is_poisoned)
    }

    /// Group-commit barrier: block until every append acknowledged so
    /// far is power-loss durable. One fsync covers the whole window, so
    /// calling this once per ingest chunk amortizes what
    /// `DurabilityPolicy::with_fsync` pays per record. No-op for
    /// non-durable stores and non-group policies (their appends are
    /// already as durable as configured). A failed barrier poisons the
    /// store — the sync state of buffered records is unknown.
    pub fn sync_wal(&mut self) -> Result<()> {
        match self.durability.as_mut() {
            Some(d) => d.sync_wal(),
            None => Ok(()),
        }
    }

    /// Cloneable group-commit barrier handle ([`WalSync`]), for callers
    /// that append under a lock and want to wait for durability
    /// *outside* it (the serving layer's ingest path). `None` unless
    /// `DurabilityPolicy::with_group_commit` is active.
    pub fn wal_sync(&self) -> Option<WalSync> {
        self.durability.as_ref().and_then(Durability::wal_sync)
    }

    /// Poison durable state from outside the store (the serving layer's
    /// out-of-lock barrier failed: buffered records' sync state is
    /// unknown, so later acknowledgments would be unsound).
    pub(crate) fn poison_durability(&mut self, why: &str) {
        if let Some(d) = self.durability.as_mut() {
            d.poison(why);
        }
    }

    /// Cumulative bytes of merged segment data written by compaction
    /// (full + tiered): the write-amplification numerator
    /// (`ablation.persist` divides it by ingested bytes).
    pub fn compaction_bytes(&self) -> u64 {
        self.compaction_bytes
    }

    /// Manifest metadata for a durable operation that will leave the
    /// store at `generation`.
    fn store_meta(&self, generation: u64) -> StoreMeta<'_> {
        StoreMeta {
            num_nodes: self.num_nodes,
            fixed_granularity: self.fixed_granularity,
            static_feat_dim: self.static_feat_dim,
            static_feats: self.static_feats.as_slice(),
            generation,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// Number of sealed (immutable) segments.
    pub fn num_sealed_segments(&self) -> usize {
        self.sealed.len()
    }

    /// Edge events buffered in the mutable active segment.
    pub fn pending_edges(&self) -> usize {
        self.active_edges.len()
    }

    /// Node events buffered in the mutable active segment.
    pub fn pending_node_events(&self) -> usize {
        self.active_nodes.len()
    }

    /// Total edge events (sealed + active).
    pub fn total_edges(&self) -> usize {
        self.sealed.iter().map(|s| s.num_edges()).sum::<usize>() + self.active_edges.len()
    }

    /// Monotonic mutation counter (bumps on append/seal/compact).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Newest sealed edge timestamp, if any segment has been sealed.
    pub fn last_sealed_edge_ts(&self) -> Option<Timestamp> {
        self.last_sealed_edge_ts
    }

    // ------------------------------------------------------------------
    // writes
    // ------------------------------------------------------------------

    /// Append one event. Returns `true` when the append triggered an
    /// automatic seal of the active segment.
    pub fn append(&mut self, ev: Event) -> Result<bool> {
        match ev {
            Event::Edge(e) => self.append_edge(e),
            Event::Node(n) => self.append_node_event(n),
        }
    }

    /// Append one edge event (see [`SegmentedStorage::append`]).
    pub fn append_edge(&mut self, e: EdgeEvent) -> Result<bool> {
        self.append_edge_with(e, true)
    }

    /// Recovery-time append: identical bookkeeping, but neither
    /// auto-seals nor enforces admission policy. Recovery replays the
    /// surviving WAL tail into a *deferred* log; a seal mid-replay
    /// would reset the live WAL under the original (still-needed) one,
    /// so any seal the replayed tail warrants is applied by
    /// [`SegmentedStorage::seal_if_due`] only after the rewritten log
    /// is committed. And the events were all admitted (and
    /// acknowledged) pre-crash, so the go-forward policy's
    /// backpressure cap must not reject them — it applies to *new*
    /// appends only (see [`crate::persist::recover`]).
    pub(crate) fn replay_append(&mut self, ev: Event) -> Result<()> {
        match ev {
            Event::Edge(e) => self.append_edge_with(e, false).map(|_| ()),
            Event::Node(n) => self.append_node_event_with(n, false).map(|_| ()),
        }
    }

    /// Seal now if the active segment has outgrown the policy (the
    /// deferred counterpart of the auto-seal that
    /// [`SegmentedStorage::replay_append`] suppressed).
    pub(crate) fn seal_if_due(&mut self) -> Result<bool> {
        if !self.active_edges.is_empty() && self.should_seal() {
            self.seal()
        } else {
            Ok(false)
        }
    }

    /// `live` marks a fresh (non-replay) append: only live appends
    /// auto-seal and enforce the admission policy.
    fn append_edge_with(&mut self, e: EdgeEvent, live: bool) -> Result<bool> {
        if e.src as usize >= self.num_nodes || e.dst as usize >= self.num_nodes {
            return Err(TgmError::Graph(format!(
                "edge ({}, {}) references node >= num_nodes={}",
                e.src, e.dst, self.num_nodes
            )));
        }
        if let Some(last) = self.last_sealed_edge_ts {
            if e.t < last {
                return Err(TgmError::StaleAppend(format!(
                    "edge event at t={} precedes the last sealed edge timestamp {last}",
                    e.t
                )));
            }
        }
        match self.edge_feat_dim {
            Some(d) => {
                if e.features.len() != d {
                    return Err(TgmError::Graph(format!(
                        "inconsistent edge feature dim: {} vs {d}",
                        e.features.len()
                    )));
                }
            }
            None => self.edge_feat_dim = Some(e.features.len()),
        }
        // Durable stores acknowledge only what the WAL holds: record
        // (and flush) before the in-memory append becomes visible.
        if let Some(d) = self.durability.as_mut() {
            d.record_edge(&e)?;
        }
        self.active_min_t = Some(self.active_min_t.map_or(e.t, |m| m.min(e.t)));
        self.active_max_t = Some(self.active_max_t.map_or(e.t, |m| m.max(e.t)));
        self.active_edges.push(e);
        self.generation += 1;
        if live && self.should_seal() {
            // The event is already durably recorded and retained, so a
            // failing auto-seal must not retract the acknowledgment
            // (`Ok` from append <=> the event survives): the seal
            // failure poisons durable state (buffer restored) and
            // surfaces on the next durable operation instead.
            return Ok(self.seal().unwrap_or(false));
        }
        Ok(false)
    }

    /// Append one node (dynamic-feature) event. Node events count toward
    /// the [`SealPolicy`] size/span thresholds like edge events, so a
    /// node-event-heavy stream still seals; returns `true` when the
    /// append triggered an automatic seal. A segment needs at least one
    /// edge to seal, so with an edge-free active segment node events
    /// stay pending — bounded by
    /// [`SealPolicy::max_pending_node_events`], past which the append is
    /// rejected with [`TgmError::Backpressure`].
    pub fn append_node_event(&mut self, e: NodeEvent) -> Result<bool> {
        self.append_node_event_with(e, true)
    }

    fn append_node_event_with(&mut self, e: NodeEvent, live: bool) -> Result<bool> {
        if e.node as usize >= self.num_nodes {
            return Err(TgmError::Graph(format!(
                "node event references node {} >= num_nodes={}",
                e.node, self.num_nodes
            )));
        }
        if let Some(last) = self.last_sealed_node_ts {
            if e.t < last {
                return Err(TgmError::StaleAppend(format!(
                    "node event at t={} precedes the last sealed node-event timestamp {last}",
                    e.t
                )));
            }
        }
        // Backpressure is admission policy for live appends only:
        // recovery replay carries events that were already admitted
        // (and acknowledged) pre-crash, possibly under a looser cap.
        if live
            && self.active_edges.is_empty()
            && self.active_nodes.len() >= self.policy.max_pending_node_events
        {
            return Err(TgmError::Backpressure(format!(
                "{} node events are already pending with no edge to seal behind \
                 (SealPolicy::max_pending_node_events = {}); ingest an edge or raise the cap",
                self.active_nodes.len(),
                self.policy.max_pending_node_events
            )));
        }
        match self.node_feat_dim {
            Some(d) => {
                if e.features.len() != d {
                    return Err(TgmError::Graph(format!(
                        "inconsistent node feature dim: {} vs {d}",
                        e.features.len()
                    )));
                }
            }
            None => self.node_feat_dim = Some(e.features.len()),
        }
        if let Some(d) = self.durability.as_mut() {
            d.record_node(&e)?;
        }
        // Node events participate in the active span: a node event
        // outside the edge span must still be able to trip `max_span`.
        self.active_min_t = Some(self.active_min_t.map_or(e.t, |m| m.min(e.t)));
        self.active_max_t = Some(self.active_max_t.map_or(e.t, |m| m.max(e.t)));
        self.active_nodes.push(e);
        self.generation += 1;
        if live && !self.active_edges.is_empty() && self.should_seal() {
            // See append_edge_with: the acknowledgment stands even when
            // the triggered seal fails.
            return Ok(self.seal().unwrap_or(false));
        }
        Ok(false)
    }

    fn should_seal(&self) -> bool {
        if self.active_edges.len() + self.active_nodes.len() >= self.policy.max_events {
            return true;
        }
        if let (Some(span), Some(lo), Some(hi)) =
            (self.policy.max_span, self.active_min_t, self.active_max_t)
        {
            if hi - lo > span {
                return true;
            }
        }
        false
    }

    /// Minimum positive gap a batch of (about-to-be-appended) edges
    /// contributes to the globally sorted stream: its internal gaps plus
    /// the boundary gap against the last sealed edge timestamp.
    fn gap_contribution(&self, edges: &[EdgeEvent]) -> Option<i64> {
        let mut ts: Vec<Timestamp> = edges.iter().map(|e| e.t).collect();
        ts.sort_unstable();
        let mut gap = min_positive_gap(&ts);
        if let (Some(last), Some(&first)) = (self.last_sealed_edge_ts, ts.first()) {
            let boundary = first - last;
            if boundary > 0 {
                gap = Some(gap.map_or(boundary, |g| g.min(boundary)));
            }
        }
        gap
    }

    fn fold_gap(a: Option<i64>, b: Option<i64>) -> Option<i64> {
        match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, None) => x,
            (None, y) => y,
        }
    }

    /// Granularity given an extra (tail) gap contribution on top of the
    /// sealed stream's folded minimum gap.
    fn granularity_with(&self, extra: Option<i64>) -> TimeGranularity {
        self.fixed_granularity
            .unwrap_or_else(|| granularity_for_min_gap(Self::fold_gap(self.min_sealed_gap, extra)))
    }

    /// Native granularity inferred (or fixed) for the stream so far.
    pub fn granularity(&self) -> TimeGranularity {
        self.granularity_with(None)
    }

    /// Seal the active segment: stably sort it by time and freeze it into
    /// a new immutable [`GraphStorage`]. Returns `false` (and keeps any
    /// buffered node events pending) when no edge events are buffered — a
    /// segment needs at least one edge to carry a time span.
    ///
    /// On a durable store the segment file, manifest and WAL reset are
    /// written **before** the in-memory commit; if that IO fails the
    /// error is returned, the events stay safe on disk, and the store's
    /// durability is **poisoned** — every later append/seal/compact
    /// fails with [`TgmError::Persist`] instead of acknowledging writes
    /// that memory and disk no longer agree on. Reopen the directory
    /// with [`crate::persist::recover`].
    pub fn seal(&mut self) -> Result<bool> {
        if self.active_edges.is_empty() {
            return Ok(false);
        }
        let edges = std::mem::take(&mut self.active_edges);
        let nodes = std::mem::take(&mut self.active_nodes);
        let contribution = self.gap_contribution(&edges);
        let folded = Self::fold_gap(self.min_sealed_gap, contribution);
        let g = self.fixed_granularity.unwrap_or_else(|| granularity_for_min_gap(folded));
        let mut seg = GraphStorage::from_events(edges, nodes, self.num_nodes, None, Some(g))?;
        if let Some(mut d) = self.durability.take() {
            let res = d.persist_seal(&seg, &self.store_meta(self.generation + 1));
            if res.is_err() {
                // The on-disk protocol stopped partway: acknowledging
                // further appends could silently diverge memory from
                // disk, so every later durable operation fails until
                // the operator reopens the directory with
                // persist::recover. The consumed buffer is restored
                // (sorted) so in-flight snapshots keep serving every
                // acknowledged event in the meantime.
                d.poison("a durable seal failed mid-protocol");
                self.restore_active_from(&seg);
            }
            let backing = d.backing();
            self.durability = Some(d);
            let path = res?;
            if backing == SegmentBacking::Mmap {
                // Serve the just-written file from the page cache and
                // drop the heap copy. The bytes are identical by the
                // encode round trip; if the reopen fails for any reason
                // the (equivalent) heap segment stands in.
                if let Ok(mapped) = read_segment_backed(&path, backing) {
                    seg = mapped;
                }
            }
        }
        self.min_sealed_gap = folded;
        self.last_sealed_edge_ts =
            Some(self.last_sealed_edge_ts.map_or(seg.end_time(), |l| l.max(seg.end_time())));
        if let Some(&last) = seg.node_event_ts().last() {
            self.last_sealed_node_ts =
                Some(self.last_sealed_node_ts.map_or(last, |l| l.max(last)));
        }
        self.sealed.push(Arc::new(seg));
        self.sealed_ids.push(next_id());
        self.active_min_t = None;
        self.active_max_t = None;
        self.generation += 1;
        self.refresh_dtdg_views();
        Ok(true)
    }

    /// Register an incrementally-maintained DTDG materialized view at
    /// `target` granularity with reduction `reduce` (see
    /// [`crate::graph::dtdg`]). The view catches up on already-sealed
    /// data immediately and refreshes on every subsequent seal,
    /// publishing `Arc<StorageSnapshot>` generations through the
    /// returned handle's [`SnapshotCell`]. Refresh failures (e.g. the
    /// stream's inferred granularity is still event-ordered) never fail
    /// a seal; they are recorded on the handle and retried.
    pub fn register_dtdg_view(
        &mut self,
        target: TimeGranularity,
        reduce: ReduceOp,
    ) -> Result<DtdgHandle> {
        check_view_target(target)?;
        let view = DtdgView::new(target, reduce);
        let handle = view.handle();
        self.dtdg.push(view);
        self.refresh_dtdg_views();
        Ok(handle)
    }

    /// Refresh every registered DTDG view against the sealed stream.
    /// Runs automatically at the end of each successful seal; calling it
    /// when nothing new sealed is a cheap no-op (compaction installs in
    /// particular change segment boundaries but not the logical stream,
    /// so views need no rebuild after them).
    pub fn refresh_dtdg_views(&mut self) {
        if self.dtdg.is_empty() {
            return;
        }
        let native = self.granularity_with(None);
        let num_nodes = self.num_nodes;
        let static_feat_dim = self.static_feat_dim;
        let static_feats = Arc::clone(&self.static_feats);
        let sealed = &self.sealed;
        for view in &mut self.dtdg {
            view.refresh_recording(sealed, native, num_nodes, static_feat_dim, &static_feats);
        }
    }

    /// Number of registered DTDG views.
    pub fn num_dtdg_views(&self) -> usize {
        self.dtdg.len()
    }

    /// Test hook: make every registered view's next refresh fail after
    /// its consumption bookkeeping (simulating a reduce failure
    /// mid-refresh; see the sticky-error regression test in
    /// [`crate::graph::dtdg`]).
    #[cfg(test)]
    pub(crate) fn fail_next_dtdg_refresh(&mut self) {
        for view in &mut self.dtdg {
            view.fail_next = true;
        }
    }

    /// Rebuild the active buffers from a segment a failed durable seal
    /// could not persist. The events come back time-sorted (the stable
    /// sort already ran), which a later successful seal treats exactly
    /// like the original insertion order.
    fn restore_active_from(&mut self, seg: &GraphStorage) {
        for i in 0..seg.num_edges() {
            self.active_edges.push(EdgeEvent {
                t: seg.edge_ts()[i],
                src: seg.edge_src()[i],
                dst: seg.edge_dst()[i],
                features: seg.edge_feat_row(i).to_vec(),
            });
        }
        for i in 0..seg.num_node_events() {
            self.active_nodes.push(NodeEvent {
                t: seg.node_event_ts()[i],
                node: seg.node_event_ids()[i],
                features: seg.node_event_feat_row(i).to_vec(),
            });
        }
        let mut lo = seg.start_time();
        let mut hi = seg.end_time();
        if let (Some(&first), Some(&last)) =
            (seg.node_event_ts().first(), seg.node_event_ts().last())
        {
            lo = lo.min(first);
            hi = hi.max(last);
        }
        self.active_min_t = Some(self.active_min_t.map_or(lo, |m| m.min(lo)));
        self.active_max_t = Some(self.active_max_t.map_or(hi, |m| m.max(hi)));
    }

    /// Merge all sealed segments (and, implicitly, their per-segment
    /// indices: the next [`crate::graph::AdjacencyCache`] lookup builds
    /// one index for the merged segment) into a single segment. The
    /// active segment is untouched. Returns `false` when there is nothing
    /// to merge. Durable stores write the merged file and replace the
    /// manifest before the in-memory swap; the
    /// [`crate::persist::Compactor`] performs the same merge off the
    /// write path on a background thread — tiered by default
    /// ([`SegmentedStorage::compact_tiered`] is the synchronous
    /// equivalent), which keeps write amplification O(log n) where this
    /// full merge is O(n) per round.
    pub fn compact(&mut self) -> Result<bool> {
        if self.sealed.len() <= 1 {
            return Ok(false);
        }
        let g = self.granularity_with(None);
        let merged = merge_segments(&self.sealed, self.num_nodes, g, 0, Vec::new());
        let ids = self.sealed_ids.clone();
        self.install_compacted(merged, &ids, None)
    }

    /// One round of **tiered** compaction: pick the lowest-level run of
    /// `>= fanout` size-adjacent sealed segments
    /// ([`crate::persist::plan_tiered_run`]), merge just that run, and
    /// install it in place. Each event is rewritten at most once per
    /// size level, so sustained ingest pays O(log n) write
    /// amplification instead of the full merge's O(n) per round.
    /// Returns the merged bytes written, or `None` when no run is
    /// currently eligible (call again after more seals). Loop until
    /// `None` to reach the tiering fixpoint.
    pub fn compact_tiered(&mut self, fanout: usize) -> Result<Option<usize>> {
        let sizes: Vec<usize> = self.sealed.iter().map(|s| s.byte_size()).collect();
        let Some(run) = plan_tiered_run(&sizes, fanout) else {
            return Ok(None);
        };
        let g = self.granularity_with(None);
        let merged =
            merge_segments(&self.sealed[run.clone()], self.num_nodes, g, 0, Vec::new());
        let bytes = merged.byte_size();
        let ids = self.sealed_ids[run].to_vec();
        let installed = self.install_compacted(merged, &ids, None)?;
        Ok(installed.then_some(bytes))
    }

    /// Install `merged` as the replacement for the contiguous run of
    /// sealed segments whose ids are exactly `replaced_ids`. Written
    /// for the background compactor: the caller merged (and, for
    /// durable stores, pre-wrote + synced to `prewritten`) without
    /// holding the writer lock, so this call is O(1) plus a rename +
    /// manifest replace. The run is located **by id** (ids are never
    /// reused), so concurrent seals appending behind it — or another
    /// compaction shifting its position — are handled: the install
    /// succeeds iff the exact run still exists contiguously, and
    /// returns `Ok(false)` (discarding `prewritten`) otherwise.
    pub fn install_compacted(
        &mut self,
        merged: GraphStorage,
        replaced_ids: &[u64],
        prewritten: Option<&std::path::Path>,
    ) -> Result<bool> {
        let discard = |p: Option<&std::path::Path>| {
            if let Some(p) = p {
                let _ = std::fs::remove_file(p);
            }
        };
        let len = replaced_ids.len();
        let start = if len <= 1 || self.sealed_ids.len() < len {
            None
        } else {
            self.sealed_ids.windows(len).position(|w| w == replaced_ids)
        };
        let Some(start) = start else {
            discard(prewritten);
            return Ok(false);
        };
        let mut merged = merged;
        if let Some(mut d) = self.durability.take() {
            let res = d.persist_compaction(
                &merged,
                start,
                len,
                prewritten,
                &self.store_meta(self.generation + 1),
            );
            let backing = d.backing();
            self.durability = Some(d);
            match res {
                Ok(path) => {
                    if backing == SegmentBacking::Mmap {
                        // Serve the merged file from the page cache;
                        // the heap merge output drops here. Identical
                        // bytes either way, so a failed reopen just
                        // keeps the heap copy.
                        if let Ok(mapped) = read_segment_backed(&path, backing) {
                            merged = mapped;
                        }
                    }
                }
                Err(e) => {
                    // Nothing was installed; don't leak the pre-synced
                    // merge output (a no-op if the failure came after
                    // the rename — the path no longer exists then).
                    discard(prewritten);
                    return Err(e);
                }
            }
        } else {
            discard(prewritten);
        }
        self.compaction_bytes += merged.byte_size() as u64;
        self.sealed.splice(start..start + len, [Arc::new(merged)]);
        self.sealed_ids.splice(start..start + len, [next_id()]);
        self.generation += 1;
        Ok(true)
    }

    /// Compact when more than `max_sealed` sealed segments have piled up.
    pub fn maybe_compact(&mut self, max_sealed: usize) -> Result<bool> {
        if self.sealed.len() > max_sealed.max(1) {
            self.compact()
        } else {
            Ok(false)
        }
    }

    // ------------------------------------------------------------------
    // reads
    // ------------------------------------------------------------------

    /// Immutable, versioned view over the sealed segments plus a frozen
    /// copy of the current active tail. Cheap when nothing changed since
    /// the last call (memoized per generation); otherwise the only copy
    /// made is the active tail's events.
    pub fn snapshot(&mut self) -> Result<Arc<StorageSnapshot>> {
        if let Some((gen, snap)) = &self.cached_snapshot {
            if *gen == self.generation {
                return Ok(Arc::clone(snap));
            }
        }
        let mut segments = self.sealed.clone();
        let mut ids = self.sealed_ids.clone();
        // Granularity covers the tail too, so a snapshot always matches
        // what `from_events` would infer over the full stream so far.
        let g = self.granularity_with(self.gap_contribution(&self.active_edges));
        if !self.active_edges.is_empty() {
            let tail = GraphStorage::from_events(
                self.active_edges.clone(),
                self.active_nodes.clone(),
                self.num_nodes,
                None,
                Some(g),
            )?;
            segments.push(Arc::new(tail));
            ids.push(next_id());
        }
        if segments.is_empty() {
            return Err(TgmError::Graph(
                "cannot snapshot an empty segmented storage (append at least one edge)".into(),
            ));
        }
        let snap = Arc::new(StorageSnapshot::from_parts(
            segments,
            ids,
            self.num_nodes,
            g,
            self.static_feat_dim,
            Arc::clone(&self.static_feats),
            SnapshotId { store: self.store_id, generation: self.generation },
        ));
        self.cached_snapshot = Some((self.generation, Arc::clone(&snap)));
        Ok(snap)
    }

    /// Snapshot the current generation and publish it into `cell` (the
    /// serving layer's atomic swap point): readers already pinned to an
    /// older generation keep it; new pins observe this one.
    pub fn publish_to(&mut self, cell: &SnapshotCell) -> Result<Arc<StorageSnapshot>> {
        let snap = self.snapshot()?;
        cell.publish(Arc::clone(&snap));
        Ok(snap)
    }
}

/// Atomic publication point for [`StorageSnapshot`] generations.
///
/// A writer ([`SegmentedStorage::publish_to`]) swaps in new generations;
/// readers [`SnapshotCell::pin`] the latest at request time and keep the
/// returned `Arc` for the whole request, so a concurrent swap never
/// tears an in-flight read — the reader finishes its pinned generation,
/// the next request observes the new one. Cloning the cell clones the
/// *handle*; all clones share one slot.
#[derive(Clone, Default)]
pub struct SnapshotCell {
    slot: Arc<std::sync::RwLock<Option<Arc<StorageSnapshot>>>>,
}

impl SnapshotCell {
    /// Empty cell (nothing published yet).
    pub fn new() -> SnapshotCell {
        SnapshotCell::default()
    }

    /// Atomically replace the published snapshot.
    pub fn publish(&self, snap: Arc<StorageSnapshot>) {
        *self.slot.write().unwrap_or_else(|e| e.into_inner()) = Some(snap);
    }

    /// Pin the latest published generation (`None` before the first
    /// publish). The returned `Arc` stays byte-stable forever.
    pub fn pin(&self) -> Option<Arc<StorageSnapshot>> {
        self.slot.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Generation of the currently published snapshot, if any.
    pub fn generation(&self) -> Option<u64> {
        self.slot.read().unwrap_or_else(|e| e.into_inner()).as_ref().map(|s| s.generation())
    }
}

/// Concatenate globally time-sorted segments into one `GraphStorage`
/// (shared with the background compactor, which merges off the write
/// path).
pub(crate) fn merge_segments(
    segments: &[Arc<GraphStorage>],
    num_nodes: usize,
    granularity: TimeGranularity,
    static_feat_dim: usize,
    static_feats: Vec<f32>,
) -> GraphStorage {
    let e: usize = segments.iter().map(|s| s.num_edges()).sum();
    let ne: usize = segments.iter().map(|s| s.num_node_events()).sum();
    let d = segments.first().map_or(0, |s| s.edge_feat_dim());
    let nd = segments
        .iter()
        .find(|s| s.num_node_events() > 0)
        .map_or(0, |s| s.node_feat_dim());
    let mut ts = Vec::with_capacity(e);
    let mut src = Vec::with_capacity(e);
    let mut dst = Vec::with_capacity(e);
    let mut feats = Vec::with_capacity(e * d);
    let mut nts = Vec::with_capacity(ne);
    let mut nid = Vec::with_capacity(ne);
    let mut nfeats = Vec::with_capacity(ne * nd);
    for s in segments {
        ts.extend_from_slice(s.edge_ts());
        src.extend_from_slice(s.edge_src());
        dst.extend_from_slice(s.edge_dst());
        feats.extend_from_slice(s.edge_feats());
        nts.extend_from_slice(s.node_event_ts());
        nid.extend_from_slice(s.node_event_ids());
        nfeats.extend_from_slice(s.node_event_feats());
    }
    GraphStorage::from_sorted_columns(
        ts,
        src,
        dst,
        d,
        feats,
        nts,
        nid,
        nd,
        nfeats,
        num_nodes,
        static_feat_dim,
        static_feats,
        granularity,
    )
}

/// Immutable, versioned view over one or more time-sorted segments,
/// exposing the [`GraphStorage`] read API through a logical-offset layer.
///
/// Logical edge index `i` addresses the `i`-th event of the concatenation
/// of all segments; because sealed segments cover non-decreasing time
/// spans, the concatenated timestamp column is globally sorted and every
/// time query resolves to one contiguous logical range.
#[derive(Debug, Clone)]
pub struct StorageSnapshot {
    segments: Vec<Arc<GraphStorage>>,
    /// Globally unique, never-reused segment ids (adjacency-cache keys).
    segment_ids: Vec<u64>,
    /// Prefix sums of segment edge counts (`len == segments.len() + 1`).
    edge_bases: Vec<usize>,
    /// Prefix sums of segment node-event counts.
    node_bases: Vec<usize>,
    num_nodes: usize,
    granularity: TimeGranularity,
    static_feat_dim: usize,
    static_feats: Arc<Vec<f32>>,
    id: SnapshotId,
}

impl StorageSnapshot {
    /// Wrap a single standalone storage (one-shot datasets). The snapshot
    /// gets a fresh store id and generation 0. Static features stay in
    /// the wrapped segment (no copy); [`Self::static_feats`] falls back
    /// to it.
    pub fn from_storage(storage: GraphStorage) -> StorageSnapshot {
        let static_feat_dim = storage.static_feat_dim();
        let num_nodes = storage.num_nodes();
        let granularity = storage.granularity();
        StorageSnapshot::from_parts(
            vec![Arc::new(storage)],
            vec![next_id()],
            num_nodes,
            granularity,
            static_feat_dim,
            Arc::new(Vec::new()),
            SnapshotId { store: next_id(), generation: 0 },
        )
    }

    pub(crate) fn from_parts(
        segments: Vec<Arc<GraphStorage>>,
        segment_ids: Vec<u64>,
        num_nodes: usize,
        granularity: TimeGranularity,
        static_feat_dim: usize,
        static_feats: Arc<Vec<f32>>,
        id: SnapshotId,
    ) -> StorageSnapshot {
        debug_assert_eq!(segments.len(), segment_ids.len());
        let mut edge_bases = Vec::with_capacity(segments.len() + 1);
        let mut node_bases = Vec::with_capacity(segments.len() + 1);
        let (mut e, mut ne) = (0usize, 0usize);
        edge_bases.push(0);
        node_bases.push(0);
        for s in &segments {
            e += s.num_edges();
            ne += s.num_node_events();
            edge_bases.push(e);
            node_bases.push(ne);
        }
        StorageSnapshot {
            segments,
            segment_ids,
            edge_bases,
            node_bases,
            num_nodes,
            granularity,
            static_feat_dim,
            static_feats,
            id,
        }
    }

    /// Wrap in an `Arc` for sharing with views.
    pub fn into_shared(self) -> Arc<StorageSnapshot> {
        Arc::new(self)
    }

    // ------------------------------------------------------------------
    // identity & segments
    // ------------------------------------------------------------------

    /// Versioned identity (cache key: replaces pointer fingerprints).
    pub fn id(&self) -> SnapshotId {
        self.id
    }

    /// Generation of the producing store at snapshot time.
    pub fn generation(&self) -> u64 {
        self.id.generation
    }

    /// Number of segments behind this snapshot.
    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Segments whose columns are served zero-copy from an mmap'd file
    /// (`SegmentBacking::Mmap`; the frozen active tail is always heap).
    pub fn num_mapped_segments(&self) -> usize {
        self.segments.iter().filter(|s| s.is_mapped()).count()
    }

    /// The underlying immutable segments, oldest first.
    pub fn segments(&self) -> &[Arc<GraphStorage>] {
        &self.segments
    }

    /// Globally unique segment ids, parallel to [`Self::segments`].
    pub fn segment_ids(&self) -> &[u64] {
        &self.segment_ids
    }

    /// Logical edge offset of segment `s`'s first event.
    pub fn segment_edge_base(&self, s: usize) -> usize {
        self.edge_bases[s]
    }

    /// Coalesce into one contiguous `GraphStorage`. Free for
    /// single-segment snapshots that already carry the static features
    /// and the snapshot's granularity (the common one-shot dataset case);
    /// otherwise a linear merge.
    pub fn coalesce(&self) -> Arc<GraphStorage> {
        if self.segments.len() == 1
            && self.segments[0].static_feat_dim() == self.static_feat_dim
            && self.segments[0].granularity() == self.granularity
        {
            return Arc::clone(&self.segments[0]);
        }
        Arc::new(merge_segments(
            &self.segments,
            self.num_nodes,
            self.granularity,
            self.static_feat_dim,
            self.static_feats().to_vec(),
        ))
    }

    // ------------------------------------------------------------------
    // metadata (mirrors GraphStorage)
    // ------------------------------------------------------------------

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.edge_bases.last().copied().unwrap_or(0)
    }

    pub fn num_node_events(&self) -> usize {
        self.node_bases.last().copied().unwrap_or(0)
    }

    pub fn edge_feat_dim(&self) -> usize {
        self.segments[0].edge_feat_dim()
    }

    pub fn node_feat_dim(&self) -> usize {
        self.segments
            .iter()
            .find(|s| s.num_node_events() > 0)
            .map_or(0, |s| s.node_feat_dim())
    }

    pub fn static_feat_dim(&self) -> usize {
        self.static_feat_dim
    }

    /// Static node feature matrix (`num_nodes x static_feat_dim`).
    /// Owned by the snapshot for streamed stores; single-segment wraps of
    /// a standalone storage delegate to the segment's matrix (no copy).
    pub fn static_feats(&self) -> &[f32] {
        if self.static_feats.is_empty() && self.static_feat_dim > 0 {
            return self.segments[0].static_feats();
        }
        &self.static_feats
    }

    /// Native time granularity (shared by all segments).
    pub fn granularity(&self) -> TimeGranularity {
        self.granularity
    }

    /// Timestamp of the first edge event.
    pub fn start_time(&self) -> Timestamp {
        self.segments[0].start_time()
    }

    /// Timestamp of the last edge event.
    pub fn end_time(&self) -> Timestamp {
        self.segments.last().unwrap().end_time()
    }

    /// Number of distinct edge timestamps across all segments (boundary
    /// timestamps shared by adjacent segments are counted once).
    pub fn num_unique_timestamps(&self) -> usize {
        let mut total = 0usize;
        let mut prev: Option<Timestamp> = None;
        for s in &self.segments {
            total += s.num_unique_timestamps();
            if prev == Some(s.start_time()) {
                total -= 1;
            }
            prev = Some(s.end_time());
        }
        total
    }

    /// Total bytes held by the snapshot's segments.
    pub fn byte_size(&self) -> usize {
        self.segments.iter().map(|s| s.byte_size()).sum::<usize>()
            + self.static_feats.len() * 4
            + (self.edge_bases.len() + self.node_bases.len()) * 8
    }

    // ------------------------------------------------------------------
    // logical-offset layer
    // ------------------------------------------------------------------

    /// Segment index owning logical edge offset `i`.
    fn edge_segment_of(&self, i: usize) -> usize {
        debug_assert!(i < self.num_edges());
        self.edge_bases.partition_point(|&b| b <= i) - 1
    }

    fn node_segment_of(&self, i: usize) -> usize {
        debug_assert!(i < self.num_node_events());
        self.node_bases.partition_point(|&b| b <= i) - 1
    }

    /// Source node of the logical `i`-th edge event.
    pub fn edge_src_at(&self, i: usize) -> NodeId {
        let s = self.edge_segment_of(i);
        self.segments[s].edge_src()[i - self.edge_bases[s]]
    }

    /// Destination node of the logical `i`-th edge event.
    pub fn edge_dst_at(&self, i: usize) -> NodeId {
        let s = self.edge_segment_of(i);
        self.segments[s].edge_dst()[i - self.edge_bases[s]]
    }

    /// Timestamp of the logical `i`-th edge event.
    pub fn edge_ts_at(&self, i: usize) -> Timestamp {
        let s = self.edge_segment_of(i);
        self.segments[s].edge_ts()[i - self.edge_bases[s]]
    }

    /// Feature row of the logical `i`-th edge event.
    pub fn edge_feat_row(&self, i: usize) -> &[f32] {
        let s = self.edge_segment_of(i);
        self.segments[s].edge_feat_row(i - self.edge_bases[s])
    }

    /// Batch feature-row gather into a dense arena: for every slot `o`
    /// with `mask[o] > 0.0`, copy the feature row of logical edge
    /// `eidx[o]` into `out[o * d..(o + 1) * d]` (`d` =
    /// [`Self::edge_feat_dim`]); masked-off slots are left untouched.
    ///
    /// Single-segment snapshots (every one-shot dataset) run the whole
    /// gather as one [`crate::kernels::gather_rows_masked_f32`] call
    /// straight over the segment's (possibly mmap-backed) column;
    /// multi-segment snapshots resolve the owning segment per slot.
    pub fn gather_edge_feat_rows(&self, eidx: &[u32], mask: &[f32], out: &mut [f32]) {
        let d = self.edge_feat_dim();
        if d == 0 {
            return;
        }
        if self.segments.len() == 1 {
            kernels::gather_rows_masked_f32(self.segments[0].edge_feats(), d, eidx, mask, out);
            return;
        }
        assert_eq!(eidx.len(), mask.len(), "eidx/mask length mismatch");
        assert!(out.len() >= mask.len() * d, "output arena too small");
        for (o, (&m, &e)) in mask.iter().zip(eidx.iter()).enumerate() {
            if m > 0.0 {
                out[o * d..(o + 1) * d].copy_from_slice(self.edge_feat_row(e as usize));
            }
        }
    }

    /// `(timestamp, node)` of the logical `i`-th node event.
    pub fn node_event_at(&self, i: usize) -> (Timestamp, NodeId) {
        let s = self.node_segment_of(i);
        let local = i - self.node_bases[s];
        (self.segments[s].node_event_ts()[local], self.segments[s].node_event_ids()[local])
    }

    /// Feature row of the logical `i`-th node event.
    pub fn node_event_feat_row(&self, i: usize) -> &[f32] {
        let s = self.node_segment_of(i);
        self.segments[s].node_event_feat_row(i - self.node_bases[s])
    }

    /// Map a logical edge range onto per-segment slices: yields
    /// `(segment, local_range)` pairs in logical order. This is the bulk
    /// read path (`materialize_window`, stats, target construction).
    pub fn edge_chunks(&self, range: Range<usize>) -> Vec<(&GraphStorage, Range<usize>)> {
        let mut out = Vec::new();
        let mut lo = range.start;
        while lo < range.end {
            let s = self.edge_bases.partition_point(|&b| b <= lo) - 1;
            let base = self.edge_bases[s];
            let seg = self.segments[s].as_ref();
            let hi = range.end.min(base + seg.num_edges());
            out.push((seg, (lo - base)..(hi - base)));
            lo = hi;
        }
        out
    }

    /// Map a logical node-event range onto per-segment slices.
    pub fn node_event_chunks(&self, range: Range<usize>) -> Vec<(&GraphStorage, Range<usize>)> {
        let mut out = Vec::new();
        let mut lo = range.start;
        while lo < range.end {
            let s = self.node_bases.partition_point(|&b| b <= lo) - 1;
            let base = self.node_bases[s];
            let seg = self.segments[s].as_ref();
            let hi = range.end.min(base + seg.num_node_events());
            out.push((seg, (lo - base)..(hi - base)));
            lo = hi;
        }
        out
    }

    // ------------------------------------------------------------------
    // full-column copies (compat / tests; hot paths use the chunk APIs)
    // ------------------------------------------------------------------

    /// Copy one per-event column over a logical edge range, chunked by
    /// segment (also backs the [`crate::graph::DGraph`] window accessors).
    pub fn copy_edge_column<T, F>(&self, range: Range<usize>, col: F) -> Vec<T>
    where
        T: Copy,
        F: for<'a> Fn(&'a GraphStorage) -> &'a [T],
    {
        let mut out = Vec::with_capacity(range.len());
        for (seg, local) in self.edge_chunks(range) {
            out.extend_from_slice(&col(seg)[local]);
        }
        out
    }

    /// Concatenated edge timestamp column (a copy for multi-segment
    /// snapshots; prefer [`Self::edge_chunks`] on hot paths).
    pub fn edge_ts(&self) -> Vec<Timestamp> {
        self.copy_edge_column(0..self.num_edges(), GraphStorage::edge_ts)
    }

    /// Concatenated edge source column.
    pub fn edge_src(&self) -> Vec<NodeId> {
        self.copy_edge_column(0..self.num_edges(), GraphStorage::edge_src)
    }

    /// Concatenated edge destination column.
    pub fn edge_dst(&self) -> Vec<NodeId> {
        self.copy_edge_column(0..self.num_edges(), GraphStorage::edge_dst)
    }

    /// Concatenated flattened edge feature matrix.
    pub fn edge_feats(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_edges() * self.edge_feat_dim());
        for s in &self.segments {
            out.extend_from_slice(s.edge_feats());
        }
        out
    }

    // ------------------------------------------------------------------
    // time queries
    // ------------------------------------------------------------------

    /// Logical offset of the first edge event with timestamp `>= t`.
    pub fn edge_lower_bound(&self, t: Timestamp) -> usize {
        // Segment end times are non-decreasing, so the first segment that
        // can contain `t` is found by binary search.
        let s = self.segments.partition_point(|seg| seg.end_time() < t);
        if s == self.segments.len() {
            return self.num_edges();
        }
        self.edge_bases[s] + self.segments[s].edge_lower_bound(t)
    }

    /// Logical index range of edge events with `t0 <= t < t1`.
    pub fn edge_range(&self, t0: Timestamp, t1: Timestamp) -> Range<usize> {
        if t1 <= t0 {
            return 0..0;
        }
        self.edge_lower_bound(t0)..self.edge_lower_bound(t1)
    }

    /// Logical offset of the first node event with timestamp `>= t`.
    pub fn node_event_lower_bound(&self, t: Timestamp) -> usize {
        // Node events are sparse; a linear scan over segments suffices
        // (segments with no node events are skipped).
        for (s, seg) in self.segments.iter().enumerate() {
            let Some(&last) = seg.node_event_ts().last() else { continue };
            if last < t {
                continue;
            }
            return self.node_bases[s] + seg.node_event_lower_bound(t);
        }
        self.num_node_events()
    }

    /// Logical index range of node events with `t0 <= t < t1`.
    pub fn node_event_range(&self, t0: Timestamp, t1: Timestamp) -> Range<usize> {
        if t1 <= t0 {
            return 0..0;
        }
        self.node_event_lower_bound(t0)..self.node_event_lower_bound(t1)
    }

    /// Latest dynamic feature row for `node` strictly before `t` (newest
    /// segment first; `O(segments + log k)` via the per-segment per-node
    /// indices).
    pub fn latest_node_features_before(&self, node: NodeId, t: Timestamp) -> Option<&[f32]> {
        for seg in self.segments.iter().rev() {
            if let Some(row) = seg.latest_node_features_before(node, t) {
                return Some(row);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(t: Timestamp, src: NodeId, dst: NodeId) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![t as f32, src as f32] }
    }

    /// A deterministic event stream with duplicate timestamps and bursts.
    fn stream(n: usize) -> Vec<EdgeEvent> {
        (0..n)
            .map(|i| edge((i as i64 / 3) * 10, (i % 5) as u32, 5 + (i % 3) as u32))
            .collect()
    }

    fn build_segmented(events: &[EdgeEvent], seal_every: usize) -> SegmentedStorage {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(seal_every));
        for e in events {
            st.append_edge(e.clone()).unwrap();
        }
        st
    }

    #[test]
    fn appended_stream_matches_from_events() {
        let events = stream(100);
        let reference =
            GraphStorage::from_events(events.clone(), vec![], 8, None, None).unwrap();
        let mut st = build_segmented(&events, 16);
        st.seal().unwrap();
        assert!(st.num_sealed_segments() > 4, "want several segments");
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.num_edges(), reference.num_edges());
        assert_eq!(snap.edge_ts(), reference.edge_ts());
        assert_eq!(snap.edge_src(), reference.edge_src());
        assert_eq!(snap.edge_dst(), reference.edge_dst());
        assert_eq!(snap.edge_feats(), reference.edge_feats());
        assert_eq!(snap.start_time(), reference.start_time());
        assert_eq!(snap.end_time(), reference.end_time());
        assert_eq!(snap.num_unique_timestamps(), reference.num_unique_timestamps());
    }

    #[test]
    fn batch_feat_gather_matches_per_row_lookups() {
        let events = stream(60);
        // One single-segment and one multi-segment snapshot: both paths.
        for seal_every in [100usize, 9] {
            let mut st = build_segmented(&events, seal_every);
            st.seal().unwrap();
            let snap = st.snapshot().unwrap();
            let d = snap.edge_feat_dim();
            assert_eq!(d, 2);
            let eidx: Vec<u32> = (0..snap.num_edges() as u32).rev().collect();
            let mask: Vec<f32> =
                (0..eidx.len()).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
            let mut out = vec![0.0f32; eidx.len() * d];
            snap.gather_edge_feat_rows(&eidx, &mask, &mut out);
            for (o, (&m, &e)) in mask.iter().zip(eidx.iter()).enumerate() {
                let want: Vec<f32> = if m > 0.0 {
                    snap.edge_feat_row(e as usize).to_vec()
                } else {
                    vec![0.0; d]
                };
                assert_eq!(&out[o * d..(o + 1) * d], &want[..], "slot {o} seal {seal_every}");
            }
        }
    }

    #[test]
    fn snapshot_includes_frozen_tail() {
        let events = stream(50);
        let mut st = build_segmented(&events, 32); // 32 sealed + 18 active
        assert_eq!(st.num_sealed_segments(), 1);
        assert_eq!(st.pending_edges(), 18);
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.num_edges(), 50, "tail must be frozen into the snapshot");
        assert_eq!(snap.num_segments(), 2);
        let reference = GraphStorage::from_events(events, vec![], 8, None, None).unwrap();
        assert_eq!(snap.edge_ts(), reference.edge_ts());
    }

    #[test]
    fn logical_time_queries_match_single_storage() {
        let events = stream(120);
        let reference =
            GraphStorage::from_events(events.clone(), vec![], 8, None, None).unwrap();
        let mut st = build_segmented(&events, 13);
        let snap = st.snapshot().unwrap();
        for t0 in (-10i64..420).step_by(7) {
            for span in [0i64, 5, 10, 50, 1000] {
                let a = reference.edge_range(t0, t0 + span);
                let b = snap.edge_range(t0, t0 + span);
                assert_eq!(a, b, "range [{t0}, {})", t0 + span);
            }
        }
        for i in 0..reference.num_edges() {
            assert_eq!(snap.edge_ts_at(i), reference.edge_ts()[i]);
            assert_eq!(snap.edge_src_at(i), reference.edge_src()[i]);
            assert_eq!(snap.edge_dst_at(i), reference.edge_dst()[i]);
            assert_eq!(snap.edge_feat_row(i), reference.edge_feat_row(i));
        }
    }

    #[test]
    fn edge_chunks_tile_ranges() {
        let events = stream(90);
        let mut st = build_segmented(&events, 17);
        let snap = st.snapshot().unwrap();
        for (lo, hi) in [(0usize, 90usize), (5, 40), (16, 18), (89, 90), (30, 30)] {
            let chunks = snap.edge_chunks(lo..hi);
            let total: usize = chunks.iter().map(|(_, r)| r.len()).sum();
            assert_eq!(total, hi - lo, "chunks must tile [{lo}, {hi})");
            // Chunk contents match per-index reads.
            let mut i = lo;
            for (seg, r) in chunks {
                for local in r {
                    assert_eq!(seg.edge_ts()[local], snap.edge_ts_at(i));
                    i += 1;
                }
            }
        }
    }

    #[test]
    fn out_of_order_within_active_sorts_on_seal() {
        let mut st = SegmentedStorage::new(4, SealPolicy::default());
        st.append_edge(edge(30, 0, 1)).unwrap();
        st.append_edge(edge(10, 1, 2)).unwrap();
        st.append_edge(edge(20, 2, 3)).unwrap();
        st.seal().unwrap();
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.edge_ts(), vec![10, 20, 30]);
    }

    #[test]
    fn stale_appends_rejected_with_typed_error() {
        let mut st = SegmentedStorage::new(4, SealPolicy::default());
        st.append_edge(edge(10, 0, 1)).unwrap();
        st.append_edge(edge(30, 1, 2)).unwrap();
        st.seal().unwrap();
        // Older than the last sealed edge timestamp: rejected.
        let err = st.append_edge(edge(29, 0, 1)).unwrap_err();
        assert!(matches!(err, TgmError::StaleAppend(_)), "{err}");
        // Equal to the boundary: accepted (stable order keeps it after).
        st.append_edge(edge(30, 2, 3)).unwrap();
        st.seal().unwrap();
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.edge_ts(), vec![10, 30, 30]);
        assert_eq!(snap.edge_src_at(1), 1, "sealed event stays first at the tied boundary");
    }

    #[test]
    fn snapshot_isolation_under_concurrent_writes() {
        let events = stream(60);
        let mut st = build_segmented(&events[..40], 16);
        let old = st.snapshot().unwrap();
        let old_ts = old.edge_ts();
        let old_gen = old.generation();
        // Writer keeps appending and sealing a new generation.
        for e in &events[40..] {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        let new = st.snapshot().unwrap();
        assert!(new.generation() > old_gen);
        assert_eq!(new.num_edges(), 60);
        // The old snapshot is untouched: same length, same bytes.
        assert_eq!(old.num_edges(), 40);
        assert_eq!(old.edge_ts(), old_ts);
        assert_ne!(old.id(), new.id());
    }

    #[test]
    fn snapshot_memoized_per_generation() {
        let mut st = build_segmented(&stream(20), 8);
        let a = st.snapshot().unwrap();
        let b = st.snapshot().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "no writes -> same snapshot");
        st.append_edge(edge(1000, 0, 1)).unwrap();
        let c = st.snapshot().unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.num_edges(), 21);
    }

    #[test]
    fn auto_seal_on_size_and_span() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(3));
        assert!(!st.append_edge(edge(1, 0, 1)).unwrap());
        assert!(!st.append_edge(edge(2, 0, 1)).unwrap());
        assert!(st.append_edge(edge(3, 0, 1)).unwrap(), "size threshold seals");
        assert_eq!(st.num_sealed_segments(), 1);
        assert_eq!(st.pending_edges(), 0);

        let mut st2 =
            SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX).with_max_span(100));
        assert!(!st2.append_edge(edge(0, 0, 1)).unwrap());
        assert!(!st2.append_edge(edge(100, 0, 1)).unwrap());
        assert!(st2.append_edge(edge(101, 0, 1)).unwrap(), "span threshold seals");
    }

    #[test]
    fn compaction_preserves_content() {
        let events = stream(80);
        let mut st = build_segmented(&events, 11);
        st.seal().unwrap();
        let before = st.snapshot().unwrap();
        let before_ts = before.edge_ts();
        let segs = st.num_sealed_segments();
        assert!(segs > 3);
        assert!(st.compact().unwrap());
        assert_eq!(st.num_sealed_segments(), 1);
        let after = st.snapshot().unwrap();
        assert_eq!(after.num_segments(), 1);
        assert_eq!(after.edge_ts(), before_ts);
        assert_eq!(after.edge_src(), before.edge_src());
        assert_eq!(after.edge_feats(), before.edge_feats());
        assert_ne!(before.id(), after.id(), "compaction is a new generation");
        // Nothing further to compact.
        assert!(!st.compact().unwrap());
    }

    /// The background compactor installs its merge through
    /// `install_compacted`: a stale scanned prefix (somebody else
    /// compacted first) must be discarded, a matching one must swap in
    /// byte-identically.
    #[test]
    fn install_compacted_checks_the_scanned_prefix() {
        let events = stream(60);
        let mut st = build_segmented(&events, 10);
        assert_eq!(st.num_sealed_segments(), 6);
        let (segs, ids) = st.sealed_segments();
        let g = st.granularity();

        // Stale prefix ids: refused, nothing changes.
        let stale = vec![ids[1], ids[0]];
        let partial = merge_segments(&segs[..2], 8, g, 0, Vec::new());
        assert!(!st.install_compacted(partial, &stale, None).unwrap());
        assert_eq!(st.num_sealed_segments(), 6);

        // Matching prefix: installed, bytes preserved, new generation.
        let before = st.snapshot().unwrap();
        let merged = merge_segments(&segs, 8, g, 0, Vec::new());
        assert!(st.install_compacted(merged, &ids, None).unwrap());
        assert_eq!(st.num_sealed_segments(), 1);
        let after = st.snapshot().unwrap();
        assert_eq!(after.edge_ts(), before.edge_ts());
        assert!(after.generation() > before.generation());

        // A single-segment prefix is nothing to compact.
        let (solo_segs, solo_ids) = st.sealed_segments();
        let solo = merge_segments(&solo_segs, 8, g, 0, Vec::new());
        assert!(!st.install_compacted(solo, &solo_ids, None).unwrap());
    }

    /// Tiered compaction reaches its fixpoint with the same bytes the
    /// full merge produces, while rewriting fewer of them per round.
    #[test]
    fn tiered_compaction_converges_to_the_same_bytes() {
        let events = stream(120);
        let mut full = build_segmented(&events, 10);
        let mut tiered = build_segmented(&events, 10);
        assert_eq!(full.num_sealed_segments(), 12);
        assert!(full.compact().unwrap());
        while tiered.compact_tiered(3).unwrap().is_some() {}
        // Fixpoint reached: equal-size leftovers are fewer than fanout.
        assert!(tiered.num_sealed_segments() < 12);
        let a = full.snapshot().unwrap();
        let b = tiered.snapshot().unwrap();
        assert_eq!(a.edge_ts(), b.edge_ts());
        assert_eq!(a.edge_src(), b.edge_src());
        assert_eq!(a.edge_dst(), b.edge_dst());
        assert_eq!(a.edge_feats(), b.edge_feats());
        // Both counters moved; the write-amp accounting is exposed.
        assert!(full.compaction_bytes() > 0);
        assert!(tiered.compaction_bytes() > 0);
    }

    #[test]
    fn node_events_stream_and_lookup_across_segments() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2));
        st.append_node_event(NodeEvent { t: 5, node: 1, features: vec![1.0] }).unwrap();
        st.append_edge(edge(10, 0, 1)).unwrap(); // 1 node + 1 edge: seals segment 1
        st.append_edge(edge(20, 1, 2)).unwrap();
        st.append_node_event(NodeEvent { t: 25, node: 1, features: vec![2.0] }).unwrap(); // seals 2
        st.append_edge(edge(30, 2, 3)).unwrap();
        st.append_edge(edge(40, 3, 0)).unwrap(); // seals segment 3
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.num_node_events(), 2);
        assert_eq!(snap.node_event_range(0, 100), 0..2);
        assert_eq!(snap.node_event_range(6, 100), 1..2);
        assert_eq!(snap.node_event_at(0), (5, 1));
        assert_eq!(snap.node_event_at(1), (25, 1));
        assert_eq!(snap.latest_node_features_before(1, 6).unwrap(), &[1.0]);
        assert_eq!(snap.latest_node_features_before(1, 100).unwrap(), &[2.0]);
        assert_eq!(snap.latest_node_features_before(1, 5), None);
        assert_eq!(snap.latest_node_features_before(0, 100), None);
        // Stale node-event appends are rejected once sealed.
        let err = st.append_node_event(NodeEvent { t: 1, node: 0, features: vec![0.0] });
        assert!(matches!(err.unwrap_err(), TgmError::StaleAppend(_)));
    }

    #[test]
    fn empty_and_node_only_states() {
        let mut st = SegmentedStorage::new(4, SealPolicy::default());
        assert!(st.snapshot().is_err(), "empty store has no snapshot");
        assert!(!st.seal().unwrap(), "empty seal is a no-op");
        // Node events alone do not seal; they wait for an edge.
        st.append_node_event(NodeEvent { t: 1, node: 0, features: vec![] }).unwrap();
        assert!(!st.seal().unwrap());
        assert_eq!(st.pending_node_events(), 1);
        st.append_edge(edge(2, 0, 1)).unwrap();
        assert!(st.seal().unwrap());
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.num_node_events(), 1);
        assert_eq!(snap.num_edges(), 1);
    }

    #[test]
    fn append_validation() {
        let mut st = SegmentedStorage::new(4, SealPolicy::default());
        // Out-of-range node id.
        assert!(st.append_edge(edge(1, 0, 9)).is_err());
        // Inconsistent feature dims (first append fixes the dim).
        st.append_edge(EdgeEvent { t: 1, src: 0, dst: 1, features: vec![1.0] }).unwrap();
        assert!(st
            .append_edge(EdgeEvent { t: 2, src: 0, dst: 1, features: vec![1.0, 2.0] })
            .is_err());
    }

    #[test]
    fn from_storage_snapshot_round_trip() {
        let reference =
            GraphStorage::from_events(stream(30), vec![], 8, Some((2, vec![0.5; 16])), None)
                .unwrap();
        let n = reference.num_edges();
        let snap = reference.into_snapshot();
        assert_eq!(snap.num_segments(), 1);
        assert_eq!(snap.num_edges(), n);
        assert_eq!(snap.static_feat_dim(), 2);
        assert_eq!(snap.static_feats().len(), 16);
        // Single-segment coalesce is free (same allocation).
        let co = snap.coalesce();
        assert!(Arc::ptr_eq(&co, &snap.segments()[0]));
    }

    #[test]
    fn granularity_refines_with_the_stream_like_from_events() {
        // First segment is one burst of ties: a prefix-only inference
        // would pin the event-ordered granularity forever. The store must
        // instead track the whole stream, exactly like `from_events`.
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(3));
        for _ in 0..3 {
            st.append_edge(edge(100, 0, 1)).unwrap(); // auto-seals at 3
        }
        assert_eq!(st.snapshot().unwrap().granularity(), TimeGranularity::Event);
        // Spaced events arrive: inference refines to the minute unit.
        st.append_edge(edge(160, 1, 2)).unwrap();
        st.append_edge(edge(220, 2, 3)).unwrap();
        st.seal().unwrap();
        let snap = st.snapshot().unwrap();
        let all = vec![edge(100, 0, 1), edge(100, 0, 1), edge(100, 0, 1), edge(160, 1, 2), edge(220, 2, 3)];
        let reference = GraphStorage::from_events(all, vec![], 4, None, None).unwrap();
        assert_eq!(snap.granularity(), reference.granularity());
        assert_eq!(snap.granularity(), TimeGranularity::Minute);
        // The tail contributes to inference before sealing, too.
        let mut st2 = SegmentedStorage::new(4, SealPolicy::default());
        st2.append_edge(edge(0, 0, 1)).unwrap();
        st2.append_edge(edge(3600, 1, 2)).unwrap();
        assert_eq!(st2.snapshot().unwrap().granularity(), TimeGranularity::Hour);
    }

    #[test]
    fn snapshot_ids_are_unique_across_stores() {
        let mut a = build_segmented(&stream(10), 4);
        let mut b = build_segmented(&stream(10), 4);
        assert_ne!(a.snapshot().unwrap().id(), b.snapshot().unwrap().id());
    }

    #[test]
    fn snapshot_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<StorageSnapshot>();
        assert_send_sync::<Arc<StorageSnapshot>>();
        assert_send_sync::<SegmentedStorage>();
        assert_send_sync::<SnapshotCell>();
    }

    /// Regression: `should_seal` used to count only edge events, so a
    /// node-event-heavy stream never tripped `max_events` and the active
    /// segment grew without bound.
    #[test]
    fn node_events_count_toward_the_seal_threshold() {
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(4));
        st.append_edge(edge(10, 0, 1)).unwrap();
        assert!(!st.append_node_event(NodeEvent { t: 11, node: 0, features: vec![] }).unwrap());
        assert!(!st.append_node_event(NodeEvent { t: 12, node: 1, features: vec![] }).unwrap());
        // The 4th buffered event is a node event: it must seal.
        assert!(st.append_node_event(NodeEvent { t: 13, node: 2, features: vec![] }).unwrap());
        assert_eq!(st.num_sealed_segments(), 1);
        assert_eq!(st.pending_edges(), 0);
        assert_eq!(st.pending_node_events(), 0);
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.num_edges(), 1);
        assert_eq!(snap.num_node_events(), 3);
    }

    /// Regression: an edge-free active segment cannot seal, so pending
    /// node events must hit a typed backpressure cap instead of growing
    /// forever.
    #[test]
    fn edge_free_node_events_hit_the_backpressure_cap() {
        let mut st =
            SegmentedStorage::new(4, SealPolicy::by_events(2).with_node_event_cap(3));
        for t in 0..3 {
            st.append_node_event(NodeEvent { t, node: 0, features: vec![] }).unwrap();
        }
        let err = st
            .append_node_event(NodeEvent { t: 9, node: 0, features: vec![] })
            .unwrap_err();
        assert!(matches!(err, TgmError::Backpressure(_)), "{err}");
        // An edge unblocks the buffer: it seals (4 pending >= 2) and
        // subsequent node events append again.
        assert!(st.append_edge(edge(10, 0, 1)).unwrap());
        st.append_node_event(NodeEvent { t: 11, node: 1, features: vec![] }).unwrap();
        st.append_edge(edge(12, 1, 2)).unwrap();
        assert_eq!(st.snapshot().unwrap().num_node_events(), 4);
    }

    /// Regression: `max_span` used to watch only edge timestamps, so a
    /// node event far outside the edge span landed in a segment whose
    /// recorded span excluded it instead of tripping the seal.
    #[test]
    fn node_event_timestamps_fold_into_the_active_span() {
        let mut st = SegmentedStorage::new(
            4,
            SealPolicy::by_events(usize::MAX).with_max_span(100),
        );
        assert!(!st.append_edge(edge(0, 0, 1)).unwrap());
        // An edge-only tracker would see span 0 here; the node event at
        // t=150 stretches it past 100 and must seal.
        assert!(st.append_node_event(NodeEvent { t: 150, node: 1, features: vec![] }).unwrap());
        assert_eq!(st.num_sealed_segments(), 1);
        // The span tracker reset with the seal: fresh appends start over.
        assert!(!st.append_edge(edge(200, 1, 2)).unwrap());
        assert!(!st.append_edge(edge(290, 2, 3)).unwrap());
        assert!(st.append_edge(edge(301, 3, 0)).unwrap(), "span threshold re-arms after seal");
    }

    #[test]
    fn snapshot_cell_publishes_atomically_and_pins_stably() {
        let cell = SnapshotCell::new();
        assert!(cell.pin().is_none());
        assert!(cell.generation().is_none());
        let mut st = build_segmented(&stream(30), 8);
        let first = st.publish_to(&cell).unwrap();
        let pinned = cell.pin().unwrap();
        assert!(Arc::ptr_eq(&first, &pinned));
        let pinned_ts = pinned.edge_ts();

        // Writer publishes a newer generation through a cloned handle.
        let handle = cell.clone();
        st.append_edge(edge(10_000, 0, 1)).unwrap();
        let second = st.publish_to(&handle).unwrap();
        assert!(second.generation() > pinned.generation());
        assert_eq!(cell.generation(), Some(second.generation()));
        // The old pin is untouched; a fresh pin sees the new generation.
        assert_eq!(pinned.edge_ts(), pinned_ts);
        assert_eq!(cell.pin().unwrap().num_edges(), 31);
    }
}
