//! EdgeBank (Poursafaei et al., 2022): non-parametric link-prediction
//! baseline. Memorizes observed edges and predicts 1 for previously seen
//! (src, dst) pairs. Two memory modes from the paper: unlimited (all
//! history) and time-window (only edges within a trailing window).

use crate::util::Timestamp;
use std::collections::HashMap;

/// Memory policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeBankMode {
    /// Remember every edge ever seen (Table 14 "Memory Mode: Unlimited").
    Unlimited,
    /// Remember edges whose last occurrence is within the window.
    TimeWindow(i64),
}

/// The EdgeBank predictor.
#[derive(Debug, Clone)]
pub struct EdgeBank {
    mode: EdgeBankMode,
    /// (src, dst) -> last seen timestamp.
    memory: HashMap<(u32, u32), Timestamp>,
}

impl EdgeBank {
    /// Empty bank with the given memory mode.
    pub fn new(mode: EdgeBankMode) -> EdgeBank {
        EdgeBank { mode, memory: HashMap::new() }
    }

    /// Absorb a batch of observed edges.
    pub fn update(&mut self, src: &[u32], dst: &[u32], ts: &[Timestamp]) {
        for i in 0..src.len() {
            self.memory.insert((src[i], dst[i]), ts[i]);
        }
    }

    /// Score a candidate link at time `t`: 1.0 if remembered, else 0.0.
    pub fn score(&self, src: u32, dst: u32, t: Timestamp) -> f64 {
        match self.memory.get(&(src, dst)) {
            None => 0.0,
            Some(&last) => match self.mode {
                EdgeBankMode::Unlimited => 1.0,
                EdgeBankMode::TimeWindow(w) => {
                    if t - last <= w {
                        1.0
                    } else {
                        0.0
                    }
                }
            },
        }
    }

    /// Number of remembered pairs.
    pub fn len(&self) -> usize {
        self.memory.len()
    }

    /// True when nothing has been memorized yet.
    pub fn is_empty(&self) -> bool {
        self.memory.is_empty()
    }

    /// Forget everything (epoch/split reset).
    pub fn reset(&mut self) {
        self.memory.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_remembers_forever() {
        let mut eb = EdgeBank::new(EdgeBankMode::Unlimited);
        eb.update(&[1, 2], &[10, 20], &[100, 200]);
        assert_eq!(eb.score(1, 10, 1_000_000), 1.0);
        assert_eq!(eb.score(1, 20, 1_000_000), 0.0);
        assert_eq!(eb.len(), 2);
    }

    #[test]
    fn window_mode_expires() {
        let mut eb = EdgeBank::new(EdgeBankMode::TimeWindow(50));
        eb.update(&[1], &[10], &[100]);
        assert_eq!(eb.score(1, 10, 120), 1.0);
        assert_eq!(eb.score(1, 10, 151), 0.0);
        // Re-observation refreshes the window.
        eb.update(&[1], &[10], &[160]);
        assert_eq!(eb.score(1, 10, 200), 1.0);
    }

    #[test]
    fn reset_clears() {
        let mut eb = EdgeBank::new(EdgeBankMode::Unlimited);
        eb.update(&[1], &[2], &[3]);
        eb.reset();
        assert!(eb.is_empty());
        assert_eq!(eb.score(1, 2, 10), 0.0);
    }
}
