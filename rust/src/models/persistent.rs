//! Persistent Forecast (paper Appendix D): predicts that the future
//! equals the most recent observation. Strong baseline for dynamic node
//! property prediction (Table 4) and graph property prediction (Table 7).

use std::collections::HashMap;

/// Node-property persistent forecaster: last observed distribution wins.
#[derive(Debug, Clone, Default)]
pub struct PersistentForecast {
    last: HashMap<u32, Vec<f64>>,
    num_classes: usize,
}

impl PersistentForecast {
    /// Forecaster over `num_classes` property classes.
    pub fn new(num_classes: usize) -> PersistentForecast {
        PersistentForecast { last: HashMap::new(), num_classes }
    }

    /// Record the observed property vector for `node`.
    pub fn observe(&mut self, node: u32, value: &[f64]) {
        debug_assert_eq!(value.len(), self.num_classes);
        self.last.insert(node, value.to_vec());
    }

    /// Predict `node`'s next property vector (uniform if never seen).
    pub fn predict(&self, node: u32) -> Vec<f64> {
        self.last
            .get(&node)
            .cloned()
            .unwrap_or_else(|| vec![1.0 / self.num_classes as f64; self.num_classes])
    }

    /// Clear state.
    pub fn reset(&mut self) {
        self.last.clear();
    }
}

/// Graph-property persistent forecaster: predicts the previous label.
#[derive(Debug, Clone, Default)]
pub struct PersistentGraphForecast {
    last_label: Option<f64>,
}

impl PersistentGraphForecast {
    /// Fresh forecaster.
    pub fn new() -> PersistentGraphForecast {
        PersistentGraphForecast::default()
    }

    /// Predict the next label (0.5 before any observation), then record
    /// the true label.
    pub fn predict_then_observe(&mut self, truth: f64) -> f64 {
        let pred = self.last_label.unwrap_or(0.5);
        self.last_label = Some(truth);
        pred
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_pf_returns_last_seen() {
        let mut pf = PersistentForecast::new(3);
        assert_eq!(pf.predict(7), vec![1.0 / 3.0; 3]);
        pf.observe(7, &[0.5, 0.25, 0.25]);
        assert_eq!(pf.predict(7), vec![0.5, 0.25, 0.25]);
        pf.observe(7, &[0.0, 1.0, 0.0]);
        assert_eq!(pf.predict(7), vec![0.0, 1.0, 0.0]);
        pf.reset();
        assert_eq!(pf.predict(7), vec![1.0 / 3.0; 3]);
    }

    #[test]
    fn graph_pf_lags_by_one() {
        let mut pf = PersistentGraphForecast::new();
        assert_eq!(pf.predict_then_observe(1.0), 0.5);
        assert_eq!(pf.predict_then_observe(0.0), 1.0);
        assert_eq!(pf.predict_then_observe(1.0), 0.0);
    }
}
