//! Non-parametric baselines implemented natively in Rust (no artifacts):
//! EdgeBank and Persistent Forecast. Learned models live in the AOT
//! artifacts and are driven through [`crate::runtime`].

pub mod edgebank;
pub mod persistent;

pub use edgebank::{EdgeBank, EdgeBankMode};
pub use persistent::{PersistentForecast, PersistentGraphForecast};
