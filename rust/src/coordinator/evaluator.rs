//! Evaluation: TGB protocols for link (one-vs-many MRR), node (NDCG@10)
//! and graph (AUC) tasks, plus the EdgeBank/Persistent-Forecast baseline
//! evaluators and the DyGLib-style *naive* eval mode used by Table 9.

use crate::coordinator::packing::{self, ModelFamily, Packed};
use crate::coordinator::targets;
use crate::error::{Result, TgmError};
use crate::graph::{DGraph, MergedAdjacency, Task};
use crate::hooks::batch::attr;
use crate::loader::{BatchBy, DGDataLoader, PrefetchLoader};
use crate::models::{EdgeBank, PersistentGraphForecast};
use crate::util::stats;
use crate::util::Tensor;
use std::sync::Arc;

use super::trainer::Pipeline;

/// Evaluation summary (one metric per task).
#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    /// Mean reciprocal rank (link tasks).
    pub mrr: Option<f64>,
    /// Mean NDCG@10 (node tasks).
    pub ndcg: Option<f64>,
    /// AUC (graph tasks).
    pub auc: Option<f64>,
    /// Number of scored queries.
    pub queries: usize,
    /// Wall-clock seconds.
    pub seconds: f64,
}

/// Which split to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Val,
    Test,
}

impl Pipeline<'_> {
    fn split_view(&self, split: Split) -> DGraph {
        match split {
            Split::Val => self.splits.val.clone(),
            Split::Test => self.splits.test.clone(),
        }
    }

    /// Evaluate with the TGM fast path (dedup + sample-once-per-batch).
    pub fn evaluate(&mut self, split: Split) -> Result<EvalReport> {
        let t0 = std::time::Instant::now();
        let mut report = match (self.data.task(), self.pack.family) {
            (Task::LinkPrediction, ModelFamily::Snapshot) => self.eval_link_snapshot(split),
            (Task::LinkPrediction, _) => self.eval_link_ctdg(split),
            (Task::NodeProperty, ModelFamily::Snapshot) => self.eval_node_snapshot(split),
            (Task::NodeProperty, _) => self.eval_node_ctdg(split),
            (Task::GraphProperty, ModelFamily::Snapshot) => self.eval_graph_snapshot(split),
            (task, fam) => Err(TgmError::Config(format!(
                "unsupported eval combination {task:?}/{fam:?}"
            ))),
        }?;
        report.seconds = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Score MRR rows from a `[B, C]` score tensor (column 0 = positive).
    fn mrr_rows(scores: &Tensor, valid_rows: usize, c: usize, out: &mut Vec<f64>) -> Result<()> {
        let s = scores.as_f32()?;
        for i in 0..valid_rows {
            let row = &s[i * c..(i + 1) * c];
            let pos = row[0] as f64;
            let negs: Vec<f64> = row[1..].iter().map(|&x| x as f64).collect();
            out.push(stats::reciprocal_rank(pos, &negs));
        }
        Ok(())
    }

    fn eval_link_ctdg(&mut self, split: Split) -> Result<EvalReport> {
        let by = BatchBy::Events(self.runtime.profile.b);
        self.evaluate_link_with(split, by)
    }

    /// Link evaluation with an explicit batching strategy (RQ3/Table 8:
    /// fixed-size vs fixed-duration evaluation batches). Oversized
    /// time buckets are chunked to the profile's batch envelope.
    pub fn evaluate_link_with(&mut self, split: Split, by: BatchBy) -> Result<EvalReport> {
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let profile = self.runtime.profile.clone();
        let c = profile.c;
        let has_update = self.runtime.spec.artifacts.contains_key("update");

        let t_start = std::time::Instant::now();
        let mut rrs = Vec::new();
        // The val recipe (eval negatives -> dedup -> unique lookup) is
        // fully stateless, so the entire materialization overlaps with
        // predict/update execution on the worker pool.
        let cfg = self.prefetch_config().with_event_cap(profile.b);
        let mut loader = PrefetchLoader::new(view, by, &mut self.manager, cfg)?;
        loop {
            let t_load = std::time::Instant::now();
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            self.profiler.add("data_loading", t_load.elapsed());

            let real = batch.num_edges();
            let packed = self.profiler.record("packing", || {
                packing::pack_link_predict(&batch, &profile, &self.pack, &self.node_feats)
            })?;
            let out =
                self.profiler.record("predict_execute", || self.runtime.run("predict", &packed))?;
            let scores = out
                .tensors
                .get("scores")
                .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?;
            Self::mrr_rows(scores, real, c, &mut rrs)?;

            // Memory/sketch models absorb the revealed edges after
            // prediction (streaming protocol).
            if has_update {
                let upd = Self::pack_update_only(&batch, &profile)?;
                self.profiler.record("update_execute", || self.runtime.run("update", &upd))?;
            }
        }
        let pstats = loader.stats();
        drop(loader);
        self.profiler.add_overlap(pstats.worker_busy, pstats.consumer_blocked);
        self.profiler.add_materialization(pstats.mat_batches, pstats.mat_bytes, pstats.mat_cycles);
        self.drain_hook_timings_pub();
        Ok(EvalReport {
            mrr: Some(stats::mean(&rrs)),
            queries: rrs.len(),
            seconds: t_start.elapsed().as_secs_f64(),
            ..Default::default()
        })
    }

    /// Minimal pack for `update` artifacts (src/dst/t/valid/edge_feats).
    fn pack_update_only(
        batch: &crate::hooks::MaterializedBatch,
        profile: &crate::runtime::Profile,
    ) -> Result<Packed> {
        let mut out = Packed::new();
        let b = profile.b;
        let real = batch.num_edges();
        let mut src: Vec<i32> = batch.src.iter().map(|&x| x as i32).collect();
        src.resize(b, 0);
        let mut dst: Vec<i32> = batch.dst.iter().map(|&x| x as i32).collect();
        dst.resize(b, 0);
        let mut t: Vec<f32> = batch.ts.iter().map(|&x| x as f32).collect();
        t.resize(b, 0.0);
        let mut valid = vec![1.0f32; real.min(b)];
        valid.resize(b, 0.0);
        out.insert("src".into(), Tensor::i32(src, &[b])?);
        out.insert("dst".into(), Tensor::i32(dst, &[b])?);
        out.insert("t".into(), Tensor::f32(t, &[b])?);
        out.insert("valid".into(), Tensor::f32(valid, &[b])?);
        let ef = batch.get(attr::EDGE_FEATS)?;
        let d_in = if ef.shape().len() == 2 { ef.shape()[1] } else { 0 };
        let mut feats = vec![0.0f32; b * profile.d_edge];
        let copy = d_in.min(profile.d_edge);
        let src_f = ef.as_f32()?;
        for r in 0..real.min(b) {
            feats[r * profile.d_edge..r * profile.d_edge + copy]
                .copy_from_slice(&src_f[r * d_in..r * d_in + copy]);
        }
        out.insert("edge_feats".into(), Tensor::f32(feats, &[b, profile.d_edge])?);
        Ok(out)
    }

    fn eval_link_snapshot(&mut self, split: Split) -> Result<EvalReport> {
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let by = BatchBy::Time(self.cfg.granularity);
        let profile = self.runtime.profile.clone();
        let c = profile.c;

        let mut rrs = Vec::new();
        let mut prev_adj: Option<Packed> = None;
        let mut loader = DGDataLoader::new(view, by, &mut self.manager)?;
        loop {
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            let adj_pack = packing::pack_snapshot_adj(&batch, &profile, &self.node_feats)?;
            if let Some(prev) = prev_adj.take() {
                // Advance recurrent state on the previous snapshot, then
                // score this snapshot's edges one-vs-many.
                self.profiler.record("update_execute", || self.runtime.run("update", &prev))?;
                let mut qp = Packed::new();
                packing::add_cand_queries(&mut qp, &batch, &profile)?;
                let real = batch.num_edges().min(profile.b);
                let out =
                    self.profiler.record("predict_execute", || self.runtime.run("predict", &qp))?;
                let scores = out
                    .tensors
                    .get("scores")
                    .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?;
                Self::mrr_rows(scores, real, c, &mut rrs)?;
            }
            prev_adj = Some(adj_pack);
        }
        Ok(EvalReport { mrr: Some(stats::mean(&rrs)), queries: rrs.len(), ..Default::default() })
    }

    fn eval_node_ctdg(&mut self, split: Split) -> Result<EvalReport> {
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let by = BatchBy::Events(self.runtime.profile.b);
        let profile = self.runtime.profile.clone();
        let horizon = self.cfg.granularity.seconds().unwrap_or(86_400);
        let has_update = self.runtime.spec.artifacts.contains_key("update");

        let mut ndcgs = Vec::new();
        let mut loader = DGDataLoader::new(view, by, &mut self.manager)?;
        loop {
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            let (target, active) = targets::node_targets(
                self.data.storage(),
                &batch.src,
                batch.end,
                batch.end + horizon,
                &profile,
            )?;
            let packed =
                packing::pack_node_batch(&batch, &profile, &self.pack, &self.node_feats, None)?;
            let out =
                self.profiler.record("predict_execute", || self.runtime.run("predict", &packed))?;
            let scores = out
                .tensors
                .get("scores")
                .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?;
            let s = scores.as_f32()?;
            let t = target.as_f32()?;
            let p = profile.p;
            for i in 0..batch.num_edges().min(profile.b) {
                if active[i] > 0.0 {
                    let pred: Vec<f64> = s[i * p..(i + 1) * p].iter().map(|&x| x as f64).collect();
                    let tgt: Vec<f64> = t[i * p..(i + 1) * p].iter().map(|&x| x as f64).collect();
                    ndcgs.push(stats::ndcg_at_k(&pred, &tgt, 10));
                }
            }
            if has_update {
                let upd = Self::pack_update_only(&batch, &profile)?;
                self.profiler.record("update_execute", || self.runtime.run("update", &upd))?;
            }
        }
        Ok(EvalReport { ndcg: Some(stats::mean(&ndcgs)), queries: ndcgs.len(), ..Default::default() })
    }

    fn eval_node_snapshot(&mut self, split: Split) -> Result<EvalReport> {
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let by = BatchBy::Time(self.cfg.granularity);
        let profile = self.runtime.profile.clone();

        let mut ndcgs = Vec::new();
        let mut prev_adj: Option<Packed> = None;
        let mut loader = DGDataLoader::new(view, by, &mut self.manager)?;
        loop {
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            let adj_pack = packing::pack_snapshot_adj(&batch, &profile, &self.node_feats)?;
            if let Some(prev) = prev_adj.take() {
                self.profiler.record("update_execute", || self.runtime.run("update", &prev))?;
                let nodes =
                    targets::active_sources(self.data.storage(), batch.start, batch.end, profile.b);
                let (target, _) = targets::node_targets(
                    self.data.storage(),
                    &nodes,
                    batch.start,
                    batch.end,
                    &profile,
                )?;
                let mut qp = Packed::new();
                packing::add_node_queries(&mut qp, &nodes, None, &profile)?;
                let out =
                    self.profiler.record("predict_execute", || self.runtime.run("predict", &qp))?;
                let scores = out
                    .tensors
                    .get("scores")
                    .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?;
                let s = scores.as_f32()?;
                let t = target.as_f32()?;
                let p = profile.p;
                for i in 0..nodes.len() {
                    let pred: Vec<f64> = s[i * p..(i + 1) * p].iter().map(|&x| x as f64).collect();
                    let tgt: Vec<f64> = t[i * p..(i + 1) * p].iter().map(|&x| x as f64).collect();
                    ndcgs.push(stats::ndcg_at_k(&pred, &tgt, 10));
                }
            }
            prev_adj = Some(adj_pack);
        }
        Ok(EvalReport { ndcg: Some(stats::mean(&ndcgs)), queries: ndcgs.len(), ..Default::default() })
    }

    fn eval_graph_snapshot(&mut self, split: Split) -> Result<EvalReport> {
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let by = BatchBy::Time(self.cfg.granularity);
        let profile = self.runtime.profile.clone();

        let mut scores = Vec::new();
        let mut labels = Vec::new();
        let mut pending: Option<(Packed, usize)> = None;
        let mut loader = DGDataLoader::new(view, by, &mut self.manager)?;
        loop {
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            let adj_pack = packing::pack_snapshot_adj(&batch, &profile, &self.node_feats)?;
            let cur_edges = batch.num_edges();
            if let Some((prev, prev_edges)) = pending.take() {
                self.profiler.record("update_execute", || self.runtime.run("update", &prev))?;
                let out = self
                    .profiler
                    .record("predict_execute", || self.runtime.run("predict", &Packed::new()))?;
                let logit = out
                    .tensors
                    .get("scores")
                    .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?
                    .as_f32()?[0];
                scores.push(logit as f64);
                labels.push(targets::growth_label(prev_edges, cur_edges) > 0.5);
            }
            pending = Some((adj_pack, cur_edges));
        }
        Ok(EvalReport {
            auc: Some(stats::auc(&scores, &labels)),
            queries: scores.len(),
            ..Default::default()
        })
    }

    /// Expose hook-timing drain for eval paths.
    fn drain_hook_timings_pub(&mut self) {
        let timings: Vec<(&'static str, std::time::Duration)> =
            self.manager.timings().iter().map(|(k, v)| (*k, *v)).collect();
        for (name, d) in timings {
            self.profiler.add(name, d);
        }
        self.manager.reset_timings();
    }

    /// DyGLib-style naive evaluation (Table 9 comparator): re-sample a
    /// neighborhood for *every* (seed, candidate) slot instead of once
    /// per unique node. Produces identical MRR; only the data path cost
    /// differs.
    pub fn evaluate_link_naive(&mut self, split: Split) -> Result<EvalReport> {
        if self.pack.family != ModelFamily::CtdgNeighbors {
            return Err(TgmError::Config("naive eval requires a neighbor-based model".into()));
        }
        let t0 = std::time::Instant::now();
        self.manager.activate("val")?;
        let view = self.split_view(split);
        let profile = self.runtime.profile.clone();
        let (b, c, k) = (profile.b, profile.c, self.pack.k);
        let de = profile.d_edge;
        let adj = MergedAdjacency::build(self.data.storage());
        let storage = std::sync::Arc::clone(self.data.storage());
        let d_in = storage.edge_feat_dim();

        let mut rrs = Vec::new();
        let mut loader = DGDataLoader::new(view, BatchBy::Events(b), &mut self.manager)?;
        loop {
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            let real = batch.num_edges();
            let t_pack = std::time::Instant::now();
            let mut packed =
                packing::pack_link_predict(&batch, &profile, &self.pack, &self.node_feats)?;

            // Overwrite the dedup'd candidate rows with per-slot lookups
            // (the DyGLib access pattern: B*(C+1) independent samplings
            // with full-history copies).
            let cand = packed["cand"].as_i32()?.to_vec();
            let mut ids = vec![0i32; b * c * k];
            let mut dts = vec![0.0f32; b * c * k];
            let mut mask = vec![0.0f32; b * c * k];
            let mut feats = vec![0.0f32; b * c * k * de];
            for i in 0..real {
                let cut = batch.start;
                for j in 0..c {
                    let node = cand[i * c + j] as u32;
                    // Deliberate full copies (the baseline's cost model).
                    let (nbrs, times, eidx) = adj.neighbors_before(node, cut).to_vecs();
                    let avail = nbrs.len();
                    for slot in 0..k.min(avail) {
                        let src_i = avail - 1 - slot;
                        let o = (i * c + j) * k + slot;
                        ids[o] = nbrs[src_i] as i32;
                        dts[o] = (batch.ts[i] - times[src_i]).max(0) as f32;
                        mask[o] = 1.0;
                        let copy = d_in.min(de);
                        feats[o * de..o * de + copy].copy_from_slice(
                            &storage.edge_feat_row(eidx[src_i] as usize)[..copy],
                        );
                    }
                }
            }
            packed.insert("cand_nbr_ids".into(), Tensor::i32(ids, &[b * c, k])?);
            packed.insert("cand_nbr_dt".into(), Tensor::f32(dts, &[b * c, k])?);
            packed.insert("cand_nbr_mask".into(), Tensor::f32(mask, &[b * c, k])?);
            packed.insert("cand_nbr_feats".into(), Tensor::f32(feats, &[b * c, k, de])?);
            self.profiler.add("naive_packing", t_pack.elapsed());

            // DyGLib additionally re-invokes the model once per candidate
            // group instead of scoring all candidates in one batched
            // call; emulate that protocol cost: C executions, keeping
            // column j of the j-th run.
            let mut scores = vec![0.0f32; b * c];
            for j in 0..c {
                let out = self
                    .profiler
                    .record("predict_execute", || self.runtime.run("predict", &packed))?;
                let s = out
                    .tensors
                    .get("scores")
                    .ok_or_else(|| TgmError::Runtime("predict returned no scores".into()))?
                    .as_f32()?
                    .to_vec();
                for i in 0..b {
                    scores[i * c + j] = s[i * c + j];
                }
            }
            let scores = Tensor::f32(scores, &[b, c])?;
            Self::mrr_rows(&scores, real, c, &mut rrs)?;
        }
        Ok(EvalReport {
            mrr: Some(stats::mean(&rrs)),
            queries: rrs.len(),
            seconds: t0.elapsed().as_secs_f64(),
            ..Default::default()
        })
    }
}

/// Evaluate EdgeBank on a link split using the same one-vs-many protocol
/// (Tables 9/12 baseline rows). The bank is warmed on all events before
/// the split, then streams through it.
pub fn evaluate_edgebank(
    data: &crate::graph::DGData,
    view: &DGraph,
    mode: crate::models::EdgeBankMode,
    eval_negatives: usize,
    seed: u64,
) -> Result<EvalReport> {
    let t0 = std::time::Instant::now();
    let storage = data.storage();
    let mut bank = EdgeBank::new(mode);
    let warm = storage.edge_range(storage.start_time(), view.start_time());
    for (seg, local) in storage.edge_chunks(warm) {
        bank.update(
            &seg.edge_src()[local.clone()],
            &seg.edge_dst()[local.clone()],
            &seg.edge_ts()[local],
        );
    }

    let mut mgr = crate::hooks::HookManager::new();
    mgr.register_stateless(
        "val",
        Arc::new(crate::hooks::negatives::EvalNegativeSampler::new(
            DstRange::InferFromData,
            eval_negatives,
            seed,
        )),
    );
    mgr.activate("val")?;
    let mut rrs = Vec::new();
    let mut loader = DGDataLoader::new(view.clone(), BatchBy::Events(200), &mut mgr)?;
    loop {
        let Some(batch) = loader.next() else { break };
        let batch = batch?;
        let negs = batch.get(attr::EVAL_NEGATIVES)?;
        let q = negs.shape()[1];
        let nv = negs.as_i32()?;
        for i in 0..batch.num_edges() {
            let pos = bank.score(batch.src[i], batch.dst[i], batch.ts[i]);
            let neg_scores: Vec<f64> = (0..q)
                .map(|j| bank.score(batch.src[i], nv[i * q + j] as u32, batch.ts[i]))
                .collect();
            rrs.push(stats::reciprocal_rank(pos, &neg_scores));
        }
        bank.update(&batch.src, &batch.dst, &batch.ts);
    }
    Ok(EvalReport {
        // `None` (not a fake 0.0) when the split held no ranked edges, so
        // callers surface a typed error instead of a silent zero or panic.
        mrr: (!rrs.is_empty()).then(|| stats::mean(&rrs)),
        queries: rrs.len(),
        seconds: t0.elapsed().as_secs_f64(),
        ..Default::default()
    })
}

use crate::hooks::DstRange;

/// Persistent-forecast AUC on the graph-growth task (Table 7 baseline).
pub fn evaluate_persistent_graph(
    view: &DGraph,
    granularity: crate::util::TimeGranularity,
) -> Result<EvalReport> {
    let t0 = std::time::Instant::now();
    let mut mgr = crate::hooks::HookManager::new();
    mgr.register_stateless("val", Arc::new(crate::hooks::analytics::DegreeStatsHook));
    mgr.activate("val")?;
    let mut loader = DGDataLoader::new(view.clone(), BatchBy::Time(granularity), &mut mgr)?;
    let mut pf = PersistentGraphForecast::new();
    let mut scores = Vec::new();
    let mut labels = Vec::new();
    let mut prev_edges: Option<usize> = None;
    loop {
        let Some(batch) = loader.next() else { break };
        let batch = batch?;
        if let Some(pe) = prev_edges {
            let label = targets::growth_label(pe, batch.num_edges());
            scores.push(pf.predict_then_observe(label as f64));
            labels.push(label > 0.5);
        }
        prev_edges = Some(batch.num_edges());
    }
    Ok(EvalReport {
        auc: Some(stats::auc(&scores, &labels)),
        queries: scores.len(),
        seconds: t0.elapsed().as_secs_f64(),
        ..Default::default()
    })
}
