//! Batch packing: hook outputs -> fixed-shape artifact inputs.
//!
//! AOT artifacts are compiled against a static [`Profile`]; this module
//! pads ragged host batches into that envelope (zero padding + `valid`
//! masks), widens edge-feature dims, re-lays sampler segments
//! (`[src|dst|neg] x b_real` -> `[src|dst|neg] x B`), and fans the
//! dedup'd unique-node lookups out to per-slot candidate rows. Packers
//! emit a *superset* of tensors; `ModelRuntime::run` selects exactly the
//! inputs each artifact's manifest declares.

use crate::error::{Result, TgmError};
use crate::graph::StorageSnapshot;
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::eval_sampler as uq;
use crate::runtime::Profile;
use crate::util::Tensor;
use std::collections::HashMap;

/// Which input family a model consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    /// Neighbor-based CTDG (TGAT/TGN/GraphMixer/DyGFormer).
    CtdgNeighbors,
    /// TPNet: state-sketch only, no neighbor inputs.
    CtdgSketch,
    /// Dense-snapshot DTDG (GCN/GCLSTM/T-GCN).
    Snapshot,
}

/// Per-model packing configuration derived from the model name.
#[derive(Debug, Clone, Copy)]
pub struct PackConfig {
    pub family: ModelFamily,
    /// One-hop fan-out the model was compiled for (k or seq).
    pub k: usize,
    /// Two-hop fan-out (TGAT).
    pub k2: Option<usize>,
}

impl PackConfig {
    /// Derive packing needs from a model name + profile.
    pub fn for_model(name: &str, profile: &Profile) -> Result<PackConfig> {
        let arch = name.split('_').next().unwrap_or(name);
        let cfg = match arch {
            "tgat" => PackConfig {
                family: ModelFamily::CtdgNeighbors,
                k: profile.k,
                k2: Some(profile.k2),
            },
            "tgn" | "graphmixer" => {
                PackConfig { family: ModelFamily::CtdgNeighbors, k: profile.k, k2: None }
            }
            "dygformer" => {
                PackConfig { family: ModelFamily::CtdgNeighbors, k: profile.seq, k2: None }
            }
            "tpnet" => PackConfig { family: ModelFamily::CtdgSketch, k: 0, k2: None },
            "gcn" | "gclstm" | "tgcn" => {
                PackConfig { family: ModelFamily::Snapshot, k: 0, k2: None }
            }
            other => return Err(TgmError::Model(format!("unknown architecture `{other}`"))),
        };
        Ok(cfg)
    }
}

/// A packed batch ready for `ModelRuntime::run`.
pub type Packed = HashMap<String, Tensor>;

fn pad_ids(src: &[u32], b: usize) -> Vec<i32> {
    let mut v: Vec<i32> = src.iter().map(|&x| x as i32).collect();
    v.resize(b, 0);
    v
}

fn valid_mask(real: usize, b: usize) -> Vec<f32> {
    let mut v = vec![1.0f32; real.min(b)];
    v.resize(b, 0.0);
    v
}

/// Widen a `[rows, d_in]` feature block into `[rows_out, d_out]`.
fn widen_feats(data: &[f32], rows_in: usize, d_in: usize, rows_out: usize, d_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows_out * d_out];
    let copy = d_in.min(d_out);
    for r in 0..rows_in.min(rows_out) {
        out[r * d_out..r * d_out + copy].copy_from_slice(&data[r * d_in..r * d_in + copy]);
    }
    out
}

/// Pack the static node-feature matrix once per dataset.
pub fn pack_node_feats(storage: &StorageSnapshot, profile: &Profile) -> Result<Tensor> {
    if storage.num_nodes() > profile.n {
        return Err(TgmError::Model(format!(
            "dataset has {} nodes; profile `{}` supports {}",
            storage.num_nodes(),
            profile.name,
            profile.n
        )));
    }
    let data = widen_feats(
        storage.static_feats(),
        storage.num_nodes(),
        storage.static_feat_dim(),
        profile.n,
        profile.d_static,
    );
    Tensor::f32(data, &[profile.n, profile.d_static])
}

/// Re-lay a `[3*b_real, k, ...]` sampler output into `[3*b, k, ...]`
/// (each of the three seed segments padded independently to `b`).
fn relayout_segments_f32(data: &[f32], b_real: usize, b: usize, inner: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; 3 * b * inner];
    for seg in 0..3 {
        let src = seg * b_real * inner..(seg + 1) * b_real * inner;
        let dst = seg * b * inner..seg * b * inner + b_real * inner;
        out[dst].copy_from_slice(&data[src]);
    }
    out
}

fn relayout_segments_i32(data: &[i32], b_real: usize, b: usize, inner: usize) -> Vec<i32> {
    let mut out = vec![0i32; 3 * b * inner];
    for seg in 0..3 {
        let src = seg * b_real * inner..(seg + 1) * b_real * inner;
        let dst = seg * b * inner..seg * b * inner + b_real * inner;
        out[dst].copy_from_slice(&data[src]);
    }
    out
}

/// Shared seed columns: src/dst/t/valid (+ edge feats widened).
fn pack_seeds(out: &mut Packed, batch: &MaterializedBatch, profile: &Profile) -> Result<usize> {
    let b = profile.b;
    let real = batch.num_edges();
    if real > b {
        return Err(TgmError::Model(format!("batch has {real} edges; profile b={b}")));
    }
    out.insert("src".into(), Tensor::i32(pad_ids(&batch.src, b), &[b])?);
    out.insert("dst".into(), Tensor::i32(pad_ids(&batch.dst, b), &[b])?);
    let mut t: Vec<f32> = batch.ts.iter().map(|&x| x as f32).collect();
    t.resize(b, 0.0);
    out.insert("t".into(), Tensor::f32(t, &[b])?);
    out.insert("valid".into(), Tensor::f32(valid_mask(real, b), &[b])?);
    let ef = batch.get(attr::EDGE_FEATS)?;
    let d_in = if ef.shape().len() == 2 { ef.shape()[1] } else { 0 };
    out.insert(
        "edge_feats".into(),
        Tensor::f32(widen_feats(ef.as_f32()?, real, d_in, b, profile.d_edge), &[b, profile.d_edge])?,
    );
    Ok(real)
}

/// Pack one-hop (and optional two-hop) training neighbor tensors.
fn pack_train_neighbors(
    out: &mut Packed,
    batch: &MaterializedBatch,
    profile: &Profile,
    cfg: &PackConfig,
    b_real: usize,
) -> Result<()> {
    let (b, k, de) = (profile.b, cfg.k, profile.d_edge);
    let ids = batch.get(attr::NEIGHBORS)?;
    let s_real = ids.shape()[0];
    if s_real != 3 * b_real {
        return Err(TgmError::Model(format!(
            "sampler produced {s_real} rows; expected 3 x {b_real} (seed negatives enabled?)"
        )));
    }
    if ids.shape()[1] != k {
        return Err(TgmError::Model(format!(
            "sampler k={} but model compiled for k={k}",
            ids.shape()[1]
        )));
    }
    out.insert(
        "nbr_ids".into(),
        Tensor::i32(relayout_segments_i32(ids.as_i32()?, b_real, b, k), &[3 * b, k])?,
    );
    let dt = batch.get(attr::NEIGHBOR_TIMES)?;
    out.insert(
        "nbr_dt".into(),
        Tensor::f32(relayout_segments_f32(dt.as_f32()?, b_real, b, k), &[3 * b, k])?,
    );
    let mask = batch.get(attr::NEIGHBOR_MASK)?;
    out.insert(
        "nbr_mask".into(),
        Tensor::f32(relayout_segments_f32(mask.as_f32()?, b_real, b, k), &[3 * b, k])?,
    );
    let feats = batch.get(attr::NEIGHBOR_FEATS)?;
    let d_in = feats.shape()[2];
    // Widen dims first (row-major per (row,slot)), then re-lay segments.
    let widened = widen_feats(feats.as_f32()?, s_real * k, d_in, s_real * k, de);
    out.insert(
        "nbr_feats".into(),
        Tensor::f32(relayout_segments_f32(&widened, b_real, b, k * de), &[3 * b, k, de])?,
    );

    if let Some(k2) = cfg.k2 {
        let ids2 = batch.get(attr::NEIGHBORS_2)?;
        out.insert(
            "nbr2_ids".into(),
            Tensor::i32(relayout_segments_i32(ids2.as_i32()?, b_real, b, k * k2), &[3 * b * k, k2])?,
        );
        let dt2 = batch.get(attr::NEIGHBOR_TIMES_2)?;
        out.insert(
            "nbr2_dt".into(),
            Tensor::f32(relayout_segments_f32(dt2.as_f32()?, b_real, b, k * k2), &[3 * b * k, k2])?,
        );
        let mask2 = batch.get(attr::NEIGHBOR_MASK_2)?;
        out.insert(
            "nbr2_mask".into(),
            Tensor::f32(relayout_segments_f32(mask2.as_f32()?, b_real, b, k * k2), &[3 * b * k, k2])?,
        );
        let feats2 = batch.get(attr::NEIGHBOR_FEATS_2)?;
        let d2 = feats2.shape()[3];
        let widened2 = widen_feats(feats2.as_f32()?, s_real * k * k2, d2, s_real * k * k2, de);
        out.insert(
            "nbr2_feats".into(),
            Tensor::f32(
                relayout_segments_f32(&widened2, b_real, b, k * k2 * de),
                &[3 * b * k, k2, de],
            )?,
        );
    }
    Ok(())
}

/// Pack a CTDG link-prediction *training* batch.
pub fn pack_link_train(
    batch: &MaterializedBatch,
    profile: &Profile,
    cfg: &PackConfig,
    node_feats: &Tensor,
) -> Result<Packed> {
    let mut out = Packed::new();
    let b_real = pack_seeds(&mut out, batch, profile)?;
    let b = profile.b;
    let negs = batch.get(attr::NEGATIVES)?.as_i32()?;
    let mut neg = negs.to_vec();
    neg.resize(b, 0);
    out.insert("neg".into(), Tensor::i32(neg, &[b])?);
    out.insert("node_feats".into(), node_feats.clone());
    if cfg.family == ModelFamily::CtdgNeighbors {
        pack_train_neighbors(&mut out, batch, profile, cfg, b_real)?;
    }
    Ok(out)
}

/// Gather per-slot neighbor tensors from the dedup'd unique lookup.
struct UniqueFanout<'a> {
    k: usize,
    d: usize,
    de: usize,
    ids: &'a [i32],
    ts: &'a [f32],
    mask: &'a [f32],
    feats: &'a [f32],
    k2: usize,
    ids2: &'a [i32],
    ts2: &'a [f32],
    mask2: &'a [f32],
    feats2: &'a [f32],
}

impl UniqueFanout<'_> {
    /// Copy unique row `urow` into destination slot `slot` with delta
    /// times against prediction time `t_pred`.
    #[allow(clippy::too_many_arguments)]
    fn emit(
        &self,
        urow: usize,
        slot: usize,
        t_pred: f32,
        ids: &mut [i32],
        dt: &mut [f32],
        mask: &mut [f32],
        feats: &mut [f32],
        two_hop: Option<(&mut Vec<i32>, &mut Vec<f32>, &mut Vec<f32>, &mut Vec<f32>)>,
    ) {
        let (k, de) = (self.k, self.de);
        for j in 0..k {
            let u = urow * k + j;
            let o = slot * k + j;
            if self.mask[u] > 0.0 {
                ids[o] = self.ids[u];
                dt[o] = (t_pred - self.ts[u]).max(0.0);
                mask[o] = 1.0;
                let copy = self.d.min(de);
                feats[o * de..o * de + copy]
                    .copy_from_slice(&self.feats[u * self.d..u * self.d + copy]);
            }
        }
        if let Some((ids2, dt2, mask2, feats2)) = two_hop {
            let k2 = self.k2;
            for j in 0..k {
                let u1 = urow * k + j;
                let o1 = slot * k + j;
                for j2 in 0..k2 {
                    let u = u1 * k2 + j2;
                    let o = o1 * k2 + j2;
                    if self.mask2[u] > 0.0 {
                        ids2[o] = self.ids2[u];
                        // Hop-2 deltas are relative to the hop-1 time.
                        dt2[o] = (self.ts[u1] - self.ts2[u]).max(0.0);
                        mask2[o] = 1.0;
                        let copy = self.d.min(de);
                        feats2[o * de..o * de + copy]
                            .copy_from_slice(&self.feats2[u * self.d..u * self.d + copy]);
                    }
                }
            }
        }
    }
}

/// Pack a CTDG link-prediction *evaluation* batch (one-vs-many).
///
/// `cand[:, 0]` is the true destination; columns `1..C` are the
/// deterministic eval negatives. Candidate neighborhoods are fanned out
/// from the unique-node lookup (sample-once-per-batch, Table 9).
pub fn pack_link_predict(
    batch: &MaterializedBatch,
    profile: &Profile,
    cfg: &PackConfig,
    node_feats: &Tensor,
) -> Result<Packed> {
    let mut out = Packed::new();
    let b_real = pack_seeds(&mut out, batch, profile)?;
    let (b, c) = (profile.b, profile.c);
    let q = c - 1;
    out.insert("node_feats".into(), node_feats.clone());

    // Candidate matrix.
    let evals = batch.get(attr::EVAL_NEGATIVES)?;
    let eq = evals.shape()[1];
    if eq < q {
        return Err(TgmError::Model(format!("eval negatives {eq} < profile q={q}")));
    }
    let ev = evals.as_i32()?;
    let mut cand = vec![0i32; b * c];
    for i in 0..b_real {
        cand[i * c] = batch.dst[i] as i32;
        cand[i * c + 1..i * c + 1 + q].copy_from_slice(&ev[i * eq..i * eq + q]);
    }
    out.insert("cand".into(), Tensor::i32(cand.clone(), &[b, c])?);

    if cfg.family != ModelFamily::CtdgNeighbors {
        return Ok(out);
    }

    // Unique-node fanout.
    let k = cfg.k;
    let de = profile.d_edge;
    let uids = batch.get(uq::UNIQUE_NBR_IDS)?;
    let d = batch.get(uq::UNIQUE_NBR_FEATS)?.shape()[2];
    let k2 = cfg.k2.unwrap_or(0);
    let empty_i: Vec<i32> = vec![];
    let empty_f: Vec<f32> = vec![];
    let fan = UniqueFanout {
        k,
        d,
        de,
        ids: uids.as_i32()?,
        ts: batch.get(uq::UNIQUE_NBR_TS)?.as_f32()?,
        mask: batch.get(uq::UNIQUE_NBR_MASK)?.as_f32()?,
        feats: batch.get(uq::UNIQUE_NBR_FEATS)?.as_f32()?,
        k2,
        ids2: if k2 > 0 { batch.get(uq::UNIQUE_NBR2_IDS)?.as_i32()? } else { &empty_i },
        ts2: if k2 > 0 { batch.get(uq::UNIQUE_NBR2_TS)?.as_f32()? } else { &empty_f },
        mask2: if k2 > 0 { batch.get(uq::UNIQUE_NBR2_MASK)?.as_f32()? } else { &empty_f },
        feats2: if k2 > 0 { batch.get(uq::UNIQUE_NBR2_FEATS)?.as_f32()? } else { &empty_f },
    };

    // Inverse layout from DedupHook: [src(b_real) | dst(b_real) | evals(b_real*eq)].
    let inverse = batch.get(attr::UNIQUE_INVERSE)?.as_i32()?;

    let mut pack_rows = |rows: usize| {
        (
            vec![0i32; rows * k],
            vec![0.0f32; rows * k],
            vec![0.0f32; rows * k],
            vec![0.0f32; rows * k * de],
            vec![0i32; rows * k * k2],
            vec![0.0f32; rows * k * k2],
            vec![0.0f32; rows * k * k2],
            vec![0.0f32; rows * k * k2 * de],
        )
    };

    // src rows [B].
    let (mut si, mut sd, mut sm, mut sf, mut si2, mut sd2, mut sm2, mut sf2) = pack_rows(b);
    for i in 0..b_real {
        let t_pred = batch.ts[i] as f32;
        let two = (k2 > 0).then(|| (&mut si2, &mut sd2, &mut sm2, &mut sf2));
        fan.emit(inverse[i] as usize, i, t_pred, &mut si, &mut sd, &mut sm, &mut sf, two);
    }
    out.insert("src_nbr_ids".into(), Tensor::i32(si, &[b, k])?);
    out.insert("src_nbr_dt".into(), Tensor::f32(sd, &[b, k])?);
    out.insert("src_nbr_mask".into(), Tensor::f32(sm, &[b, k])?);
    out.insert("src_nbr_feats".into(), Tensor::f32(sf, &[b, k, de])?);
    if k2 > 0 {
        out.insert("src_nbr2_ids".into(), Tensor::i32(si2, &[b * k, k2])?);
        out.insert("src_nbr2_dt".into(), Tensor::f32(sd2, &[b * k, k2])?);
        out.insert("src_nbr2_mask".into(), Tensor::f32(sm2, &[b * k, k2])?);
        out.insert("src_nbr2_feats".into(), Tensor::f32(sf2, &[b * k, k2, de])?);
    }

    // cand rows [B*C]: slot (i, j) -> unique row of cand[i*c + j].
    let (mut ci, mut cd, mut cmk, mut cf, mut ci2, mut cd2, mut cm2, mut cf2) = pack_rows(b * c);
    for i in 0..b_real {
        let t_pred = batch.ts[i] as f32;
        for j in 0..c {
            let urow = if j == 0 {
                inverse[b_real + i] // dst segment
            } else {
                inverse[2 * b_real + i * eq + (j - 1)] // eval-negative segment
            } as usize;
            let slot = i * c + j;
            let two = (k2 > 0).then(|| (&mut ci2, &mut cd2, &mut cm2, &mut cf2));
            fan.emit(urow, slot, t_pred, &mut ci, &mut cd, &mut cmk, &mut cf, two);
        }
    }
    out.insert("cand_nbr_ids".into(), Tensor::i32(ci, &[b * c, k])?);
    out.insert("cand_nbr_dt".into(), Tensor::f32(cd, &[b * c, k])?);
    out.insert("cand_nbr_mask".into(), Tensor::f32(cmk, &[b * c, k])?);
    out.insert("cand_nbr_feats".into(), Tensor::f32(cf, &[b * c, k, de])?);
    if k2 > 0 {
        out.insert("cand_nbr2_ids".into(), Tensor::i32(ci2, &[b * c * k, k2])?);
        out.insert("cand_nbr2_dt".into(), Tensor::f32(cd2, &[b * c * k, k2])?);
        out.insert("cand_nbr2_mask".into(), Tensor::f32(cm2, &[b * c * k, k2])?);
        out.insert("cand_nbr2_feats".into(), Tensor::f32(cf2, &[b * c * k, k2, de])?);
    }
    Ok(out)
}

/// Pack a node-property batch (train when `target` given, else predict).
/// Node seeds are the batch's source nodes; neighbor rows come from the
/// sampler's src segment.
pub fn pack_node_batch(
    batch: &MaterializedBatch,
    profile: &Profile,
    cfg: &PackConfig,
    node_feats: &Tensor,
    target: Option<&Tensor>,
) -> Result<Packed> {
    let mut out = Packed::new();
    let b_real = pack_seeds(&mut out, batch, profile)?;
    let b = profile.b;
    out.insert("node_feats".into(), node_feats.clone());
    out.insert("nodes".into(), Tensor::i32(pad_ids(&batch.src, b), &[b])?);
    if let Some(t) = target {
        if t.shape() != [b, profile.p] {
            return Err(TgmError::Model(format!(
                "target shape {:?} != [{b}, {}]",
                t.shape(),
                profile.p
            )));
        }
        out.insert("target".into(), t.clone());
    }
    if cfg.family == ModelFamily::CtdgNeighbors {
        // Take only the src segment (first b_real rows) of the sampler.
        let (k, de) = (cfg.k, profile.d_edge);
        let ids = batch.get(attr::NEIGHBORS)?;
        let d_in = batch.get(attr::NEIGHBOR_FEATS)?.shape()[2];
        let take = |data: &[i32]| {
            let mut v = data[..b_real * k].to_vec();
            v.resize(b * k, 0);
            v
        };
        let take_f = |data: &[f32], inner: usize| {
            let mut v = data[..b_real * inner].to_vec();
            v.resize(b * inner, 0.0);
            v
        };
        out.insert("nbr_ids".into(), Tensor::i32(take(ids.as_i32()?), &[b, k])?);
        out.insert(
            "nbr_dt".into(),
            Tensor::f32(take_f(batch.get(attr::NEIGHBOR_TIMES)?.as_f32()?, k), &[b, k])?,
        );
        out.insert(
            "nbr_mask".into(),
            Tensor::f32(take_f(batch.get(attr::NEIGHBOR_MASK)?.as_f32()?, k), &[b, k])?,
        );
        let widened = widen_feats(
            batch.get(attr::NEIGHBOR_FEATS)?.as_f32()?,
            b_real * k,
            d_in,
            b * k,
            de,
        );
        out.insert("nbr_feats".into(), Tensor::f32(widened, &[b, k, de])?);
    }
    Ok(out)
}

/// Pack a snapshot adjacency (embedding the `n x n` hook output into the
/// profile's `N x N`).
pub fn pack_snapshot_adj(
    batch: &MaterializedBatch,
    profile: &Profile,
    node_feats: &Tensor,
) -> Result<Packed> {
    let adj = batch.get(attr::SNAPSHOT_ADJ)?;
    let n_in = adj.shape()[0];
    let n = profile.n;
    if n_in > n {
        return Err(TgmError::Model(format!("snapshot n={n_in} exceeds profile N={n}")));
    }
    let src = adj.as_f32()?;
    let mut data = vec![0.0f32; n * n];
    for r in 0..n_in {
        data[r * n..r * n + n_in].copy_from_slice(&src[r * n_in..(r + 1) * n_in]);
    }
    let mut out = Packed::new();
    out.insert("adj".into(), Tensor::f32(data, &[n, n])?);
    out.insert("node_feats".into(), node_feats.clone());
    Ok(out)
}

/// Add link queries (src/dst/neg/valid) from a *later* snapshot batch to
/// a snapshot-adjacency pack (DTDG training pairs).
pub fn add_link_queries(out: &mut Packed, query: &MaterializedBatch, profile: &Profile) -> Result<()> {
    let b = profile.b;
    let real = query.num_edges().min(b);
    out.insert("src".into(), Tensor::i32(pad_ids(&query.src[..real], b), &[b])?);
    out.insert("dst".into(), Tensor::i32(pad_ids(&query.dst[..real], b), &[b])?);
    let negs = query.get(attr::NEGATIVES)?.as_i32()?;
    let mut neg = negs[..real.min(negs.len())].to_vec();
    neg.resize(b, 0);
    out.insert("neg".into(), Tensor::i32(neg, &[b])?);
    out.insert("valid".into(), Tensor::f32(valid_mask(real, b), &[b])?);
    Ok(())
}

/// Add one-vs-many candidate queries from a later snapshot batch.
pub fn add_cand_queries(out: &mut Packed, query: &MaterializedBatch, profile: &Profile) -> Result<()> {
    let (b, c) = (profile.b, profile.c);
    let q = c - 1;
    let real = query.num_edges().min(b);
    out.insert("src".into(), Tensor::i32(pad_ids(&query.src[..real], b), &[b])?);
    let evals = query.get(attr::EVAL_NEGATIVES)?;
    let eq = evals.shape()[1];
    let ev = evals.as_i32()?;
    let mut cand = vec![0i32; b * c];
    for i in 0..real {
        cand[i * c] = query.dst[i] as i32;
        cand[i * c + 1..i * c + 1 + q.min(eq)].copy_from_slice(&ev[i * eq..i * eq + q.min(eq)]);
    }
    out.insert("cand".into(), Tensor::i32(cand, &[b, c])?);
    out.insert("valid".into(), Tensor::f32(valid_mask(real, b), &[b])?);
    Ok(())
}

/// Add node queries (+optional targets) to a snapshot pack.
pub fn add_node_queries(
    out: &mut Packed,
    nodes: &[u32],
    target: Option<&Tensor>,
    profile: &Profile,
) -> Result<()> {
    let b = profile.b;
    let real = nodes.len().min(b);
    out.insert("nodes".into(), Tensor::i32(pad_ids(&nodes[..real], b), &[b])?);
    out.insert("valid".into(), Tensor::f32(valid_mask(real, b), &[b])?);
    if let Some(t) = target {
        out.insert("target".into(), t.clone());
    }
    Ok(())
}

/// Add a scalar graph-property label.
pub fn add_graph_label(out: &mut Packed, label: f32) {
    out.insert("label".into(), Tensor::scalar_f32(label));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, GraphStorage};
    use crate::hooks::{HookContext, SamplerConfig};
    use crate::hooks::hook::{Hook, StatelessHook};

    fn profile() -> Profile {
        Profile {
            name: "tiny".into(),
            n: 16,
            b: 4,
            k: 3,
            k2: 2,
            seq: 4,
            c: 3,
            d_edge: 4,
            d_static: 4,
            p: 4,
        }
    }

    fn storage() -> crate::graph::StorageSnapshot {
        let edges = (0..20)
            .map(|i| EdgeEvent {
                t: i as i64,
                src: (i % 3) as u32,
                dst: 4 + (i % 2) as u32,
                features: vec![i as f32, 1.0],
            })
            .collect();
        GraphStorage::from_events(edges, vec![], 8, Some((2, vec![0.5; 16])), None)
            .unwrap()
            .into_snapshot()
    }

    fn batch(st: &crate::graph::StorageSnapshot, r: std::ops::Range<usize>) -> MaterializedBatch {
        let mut b = MaterializedBatch::new(st.edge_ts_at(r.start), st.edge_ts_at(r.end - 1) + 1);
        let n = r.len();
        for i in r {
            b.src.push(st.edge_src_at(i));
            b.dst.push(st.edge_dst_at(i));
            b.ts.push(st.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        let feats: Vec<f32> = b.edge_indices.iter().flat_map(|&i| st.edge_feat_row(i as usize).to_vec()).collect();
        b.set(attr::EDGE_FEATS, Tensor::f32(feats, &[n, 2]).unwrap());
        b
    }

    #[test]
    fn node_feats_padded_and_widened() {
        let st = storage();
        let p = profile();
        let t = pack_node_feats(&st, &p).unwrap();
        assert_eq!(t.shape(), &[16, 4]);
        let v = t.as_f32().unwrap();
        assert_eq!(v[0], 0.5); // real feature copied
        assert_eq!(v[2], 0.0); // widened dim zero
        assert_eq!(v[8 * 4], 0.0); // padded node rows zero
    }

    #[test]
    fn link_train_pack_shapes_and_masks() {
        let st = storage();
        let p = profile();
        let cfg = PackConfig::for_model("tgn_link", &p).unwrap();
        let ctx = HookContext::new(&st, "train");

        let mut b = batch(&st, 10..13); // 3 real edges < B=4
        b.set(attr::NEGATIVES, Tensor::i32(vec![5, 6, 7], &[3]).unwrap());
        let mut sampler = crate::hooks::RecencySampler::new(SamplerConfig {
            num_neighbors: 3,
            two_hop: None,
            include_features: true,
            seed_negatives: true,
        });
        // Warm with an earlier batch so neighborhoods are non-empty.
        let mut warm = batch(&st, 0..10);
        warm.set(attr::NEGATIVES, Tensor::i32(vec![5; 10], &[10]).unwrap());
        sampler.apply(&mut warm, &ctx).unwrap();
        sampler.apply(&mut b, &ctx).unwrap();

        let nf = pack_node_feats(&st, &p).unwrap();
        let packed = pack_link_train(&b, &p, &cfg, &nf).unwrap();
        assert_eq!(packed["src"].shape(), &[4]);
        assert_eq!(packed["nbr_ids"].shape(), &[12, 3]);
        assert_eq!(packed["nbr_feats"].shape(), &[12, 3, 4]);
        let valid = packed["valid"].as_f32().unwrap();
        assert_eq!(valid, &[1.0, 1.0, 1.0, 0.0]);
        // Segment re-layout: dst segment starts at row B=4, matching the
        // sampler's row b_real=3.
        let ids_in = b.get(attr::NEIGHBORS).unwrap().as_i32().unwrap();
        let ids_out = packed["nbr_ids"].as_i32().unwrap();
        assert_eq!(&ids_in[3 * 3..4 * 3], &ids_out[4 * 3..5 * 3]);
        // Padded row at end of src segment is zero.
        assert!(ids_out[3 * 3..4 * 3].iter().all(|&x| x == 0));
        // Mask padded rows are zero.
        let m = packed["nbr_mask"].as_f32().unwrap();
        assert!(m[3 * 3..4 * 3].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn link_predict_pack_fans_out_unique_rows() {
        let st = storage();
        let p = profile();
        let cfg = PackConfig::for_model("tgn_link", &p).unwrap();
        let ctx = HookContext::new(&st, "val");
        let mut b = batch(&st, 15..18);
        // Recipe steps: eval negatives -> dedup -> unique lookup.
        let h1 = crate::hooks::negatives::EvalNegativeSampler::new(
            crate::hooks::DstRange::Range(4, 8),
            2,
            1,
        );
        h1.apply(&mut b, &ctx).unwrap();
        let h2 = crate::hooks::dedup::DedupHook::new(false, true);
        h2.apply(&mut b, &ctx).unwrap();
        let h3 = crate::hooks::eval_sampler::UniqueRecencyLookup::new(3);
        h3.apply(&mut b, &ctx).unwrap();

        let nf = pack_node_feats(&st, &p).unwrap();
        let packed = pack_link_predict(&b, &p, &cfg, &nf).unwrap();
        assert_eq!(packed["cand"].shape(), &[4, 3]);
        assert_eq!(packed["cand_nbr_ids"].shape(), &[12, 3]);
        // cand[:,0] is the true destination.
        let cand = packed["cand"].as_i32().unwrap();
        assert_eq!(cand[0], b.dst[0] as i32);
        // Candidate slot 0's neighborhood equals dst's unique row.
        let inv = b.get(attr::UNIQUE_INVERSE).unwrap().as_i32().unwrap().to_vec();
        let urow = inv[3] as usize; // dst segment, i=0 (b_real = 3)
        let uids = b.get(uq::UNIQUE_NBR_IDS).unwrap().as_i32().unwrap().to_vec();
        let cids = packed["cand_nbr_ids"].as_i32().unwrap();
        let umask = b.get(uq::UNIQUE_NBR_MASK).unwrap().as_f32().unwrap().to_vec();
        for j in 0..3 {
            if umask[urow * 3 + j] > 0.0 {
                assert_eq!(cids[j], uids[urow * 3 + j]);
            }
        }
        // Delta times non-negative.
        assert!(packed["cand_nbr_dt"].as_f32().unwrap().iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn snapshot_pack_embeds_adjacency() {
        let st = storage();
        let p = profile();
        let ctx = HookContext::new(&st, "train");
        let mut b = batch(&st, 0..10);
        let hook = crate::hooks::analytics::SnapshotAdjHook;
        hook.apply(&mut b, &ctx).unwrap();
        let nf = pack_node_feats(&st, &p).unwrap();
        let mut packed = pack_snapshot_adj(&b, &p, &nf).unwrap();
        assert_eq!(packed["adj"].shape(), &[16, 16]);
        let a = packed["adj"].as_f32().unwrap();
        // Padded rows/cols zero.
        assert!(a[8 * 16 + 8] == 0.0);
        // Real diagonal nonzero (self-loops).
        assert!(a[0] > 0.0);

        let mut q = batch(&st, 10..13);
        q.set(attr::NEGATIVES, Tensor::i32(vec![1, 2, 3], &[3]).unwrap());
        add_link_queries(&mut packed, &q, &p).unwrap();
        assert_eq!(packed["src"].shape(), &[4]);
        add_graph_label(&mut packed, 1.0);
        assert_eq!(packed["label"].shape(), &[] as &[usize]);
    }

    #[test]
    fn oversized_batch_rejected() {
        let st = storage();
        let p = profile();
        let cfg = PackConfig::for_model("tpnet_link", &p).unwrap();
        let mut b = batch(&st, 0..10); // 10 > B=4
        b.set(attr::NEGATIVES, Tensor::i32(vec![0; 10], &[10]).unwrap());
        let nf = pack_node_feats(&st, &p).unwrap();
        assert!(pack_link_train(&b, &p, &cfg, &nf).is_err());
    }

    #[test]
    fn pack_config_families() {
        let p = profile();
        assert_eq!(PackConfig::for_model("tgat_link", &p).unwrap().k2, Some(2));
        assert_eq!(PackConfig::for_model("dygformer_link", &p).unwrap().k, p.seq);
        assert_eq!(PackConfig::for_model("tpnet_link", &p).unwrap().family, ModelFamily::CtdgSketch);
        assert_eq!(PackConfig::for_model("gclstm_node", &p).unwrap().family, ModelFamily::Snapshot);
        assert!(PackConfig::for_model("bogus_x", &p).is_err());
    }
}
