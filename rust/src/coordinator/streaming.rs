//! Online-learning driver: interleaved ingestion and time-driven training
//! over successive storage snapshots.
//!
//! [`StreamingTrainer`] closes the loop the segmented storage layer opens:
//! each cycle it (1) pulls a chunk of events from an
//! [`crate::io::EventSource`] and appends them into its
//! [`SegmentedStorage`], (2) seals the active segment and optionally
//! compacts, (3) takes an immutable snapshot, and (4) drives the hook
//! recipe over the **newly revealed time window** `[trained_until, end)`
//! in event-ordered batches, handing each hooked batch to the caller's
//! training callback. Because every cycle trains on a frozen snapshot,
//! readers are isolated from the writer by construction; because windows
//! tile the timeline, every event is trained on exactly once, in order.
//!
//! The driver is model-agnostic: the callback receives fully hooked
//! [`MaterializedBatch`]es, so it can run a heuristic model (EdgeBank in
//! `examples/streaming_ingestion.rs`, doing prequential test-then-train
//! MRR), an AOT runtime artifact, or plain analytics. The stream is one
//! logical epoch: stateful hooks (e.g. the recency sampler) keep their
//! state across cycles, and per-batch RNG seeds keep advancing across
//! cycle boundaries (a cumulative index offset, so stateless hooks never
//! replay the same pseudo-random stream each cycle).

use crate::error::Result;
use crate::graph::{DGraph, DtdgHandle, ReduceOp, SegmentedStorage};
use crate::hooks::manager::HookManager;
use crate::hooks::MaterializedBatch;
use crate::io::stream::EventSource;
use crate::loader::{BatchBy, DGDataLoader};
use crate::serving::{TenantId, TenantRouter};
use crate::util::{TimeGranularity, Timestamp};
use std::sync::Arc;

/// Streaming-loop configuration.
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// Events pulled from the source per cycle.
    pub ingest_chunk: usize,
    /// Events per training batch within a cycle's window.
    pub batch_events: usize,
    /// Compact once more than this many sealed segments have piled up
    /// (bounds per-read segment fan-out).
    pub compact_after: usize,
    /// Hook-manager key activated for the training pass.
    pub train_key: String,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            ingest_chunk: 512,
            batch_events: 128,
            compact_after: 8,
            train_key: "train".into(),
        }
    }
}

/// What one ingest→seal→snapshot→train cycle did.
#[derive(Debug, Clone)]
pub struct CycleReport {
    /// 0-based cycle ordinal.
    pub cycle: usize,
    /// Events appended this cycle.
    pub ingested: usize,
    /// Training batches produced from the new window.
    pub batches: usize,
    /// The time window `[t0, t1)` trained this cycle.
    pub window: (Timestamp, Timestamp),
    /// Sealed segments behind the snapshot after this cycle.
    pub sealed_segments: usize,
    /// Snapshot generation trained against.
    pub generation: u64,
}

/// Interleaves event ingestion with training over successive snapshots.
pub struct StreamingTrainer<S: EventSource> {
    store: SegmentedStorage,
    source: S,
    cfg: StreamingConfig,
    /// Exclusive end of the last trained window.
    trained_until: Option<Timestamp>,
    cycles: usize,
    /// Batches produced so far across all cycles: the stream is one
    /// logical epoch, so per-batch RNG seeds keep advancing instead of
    /// restarting at plan index 0 every cycle.
    batches_done: usize,
}

impl<S: EventSource> StreamingTrainer<S> {
    /// Bind a store, an event source and a config.
    pub fn new(store: SegmentedStorage, source: S, cfg: StreamingConfig) -> StreamingTrainer<S> {
        StreamingTrainer { store, source, cfg, trained_until: None, cycles: 0, batches_done: 0 }
    }

    /// Resume over a store that already holds data — typically one
    /// rebuilt by [`crate::persist::recover`] after a crash. Everything
    /// already ingested counts as trained: the watermark starts at the
    /// store's newest timestamp (held back, exactly as if those events
    /// had streamed through [`StreamingTrainer::run_cycle`]), so
    /// subsequent cycles train only newly revealed windows and no event
    /// is retrained after a restart. The source must be positioned past
    /// the recovered prefix; batch numbering restarts at 0, so per-batch
    /// RNG streams restart with the new process.
    pub fn resume(
        mut store: SegmentedStorage,
        source: S,
        cfg: StreamingConfig,
    ) -> Result<StreamingTrainer<S>> {
        let trained_until =
            if store.total_edges() > 0 { Some(store.snapshot()?.end_time()) } else { None };
        Ok(StreamingTrainer { store, source, cfg, trained_until, cycles: 0, batches_done: 0 })
    }

    /// The underlying segmented store.
    pub fn store(&self) -> &SegmentedStorage {
        &self.store
    }

    /// Mutable access (e.g. to force a `compact()` between cycles).
    pub fn store_mut(&mut self) -> &mut SegmentedStorage {
        &mut self.store
    }

    /// Cycles completed so far.
    pub fn cycles(&self) -> usize {
        self.cycles
    }

    /// Run one ingest→seal→snapshot→train cycle. Returns `None` when the
    /// source yielded nothing this cycle and no new window remains to
    /// train — which for a transiently quiet live source just means "call
    /// again later"; nothing is lost.
    ///
    /// Watermark semantics: while the source may still deliver events at
    /// the newest timestamp (appends equal to the sealed boundary are
    /// legal), that timestamp is held back. It is flushed only when the
    /// source *provably* has nothing left (`remaining() == Some(0)`) or
    /// via an explicit [`StreamingTrainer::finish`] — never on a merely
    /// empty chunk, so a live source that stalls and resumes at the
    /// boundary timestamp still gets every event trained exactly once.
    pub fn run_cycle(
        &mut self,
        manager: &mut HookManager,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Option<CycleReport>> {
        let chunk = self.source.next_chunk(self.cfg.ingest_chunk);
        let ingested = chunk.len();
        for ev in chunk {
            self.store.append(ev)?;
        }
        // Group-commit stores acknowledge per chunk: one fsync covers
        // everything appended above (no-op otherwise).
        self.store.sync_wal()?;
        self.store.seal()?;
        self.store.maybe_compact(self.cfg.compact_after)?;

        let drained = self.source.remaining() == Some(0);
        if self.store.total_edges() == 0 {
            // Nothing ingested yet and the source gave nothing.
            return Ok(if ingested == 0 { None } else { Some(self.empty_report(ingested)) });
        }
        let snap = self.store.snapshot()?;
        let end = if drained { snap.end_time() + 1 } else { snap.end_time() };
        let start = self.trained_until.unwrap_or_else(|| snap.start_time());
        if start >= end {
            // No new time revealed.
            return Ok(if ingested == 0 { None } else { Some(self.empty_report(ingested)) });
        }
        let by = BatchBy::Events(self.cfg.batch_events);
        let report = self.train_window(manager, &snap, by, start, end, ingested, &mut on_batch)?;
        Ok(Some(report))
    }

    /// Flush the watermark-held tail window: train everything up to and
    /// including the newest ingested timestamp. Call once no further
    /// events will ever arrive (sources that report `remaining()` are
    /// flushed automatically; [`StreamingTrainer::run`] calls this).
    /// Returns `None` when there was nothing left to train.
    pub fn finish(
        &mut self,
        manager: &mut HookManager,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Option<CycleReport>> {
        if self.store.total_edges() == 0 {
            return Ok(None);
        }
        self.store.seal()?;
        let snap = self.store.snapshot()?;
        let end = snap.end_time() + 1;
        let start = self.trained_until.unwrap_or_else(|| snap.start_time());
        if start >= end {
            return Ok(None);
        }
        let by = BatchBy::Events(self.cfg.batch_events);
        let report = self.train_window(manager, &snap, by, start, end, 0, &mut on_batch)?;
        Ok(Some(report))
    }

    /// Register a DTDG materialized view on the underlying store and
    /// return its handle. The view is refreshed incrementally by every
    /// seal the ingest loop triggers, so
    /// [`StreamingTrainer::run_cycle_time_driven`] can train off it
    /// without ever rescanning the base stream.
    pub fn attach_dtdg(&mut self, target: TimeGranularity, reduce: ReduceOp) -> Result<DtdgHandle> {
        self.store.register_dtdg_view(target, reduce)
    }

    /// Time-driven counterpart of [`StreamingTrainer::run_cycle`]: ingest
    /// a chunk, seal (which incrementally refreshes `view`), then train
    /// one batch per **complete** coarse bucket of the materialized DTDG
    /// view instead of event-ordered batches of the base stream.
    ///
    /// Watermark semantics mirror the event-driven loop, but the held-back
    /// unit is the trailing *partial bucket* rather than the newest
    /// timestamp: only buckets strictly before
    /// [`DtdgHandle::complete_until`] are trained (their reductions can
    /// never change), so every bucket is trained exactly once, in order,
    /// with its final reduced features. The partial bucket is flushed when
    /// the source provably drains or via
    /// [`StreamingTrainer::finish_time_driven`]. Use one driving mode per
    /// trainer — both share the same trained-watermark.
    pub fn run_cycle_time_driven(
        &mut self,
        manager: &mut HookManager,
        view: &DtdgHandle,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Option<CycleReport>> {
        let chunk = self.source.next_chunk(self.cfg.ingest_chunk);
        let ingested = chunk.len();
        for ev in chunk {
            self.store.append(ev)?;
        }
        self.store.sync_wal()?;
        self.store.seal()?;
        self.store.maybe_compact(self.cfg.compact_after)?;

        let drained = self.source.remaining() == Some(0);
        let more = |this: &mut Self| {
            Ok(if ingested == 0 { None } else { Some(this.empty_report(ingested)) })
        };
        let Some(snap) = view.pin() else {
            // View not published yet (no sealed edge, or the view is
            // stalled on a granularity error — see `DtdgHandle::last_error`).
            return more(self);
        };
        let end = if drained {
            // Source provably empty: flush the trailing partial bucket too.
            snap.end_time() + 1
        } else {
            match view.complete_until() {
                Some(cut) => cut,
                None => return more(self),
            }
        };
        let start = self.trained_until.unwrap_or_else(|| snap.start_time());
        if start >= end {
            return more(self);
        }
        let by = BatchBy::Time(view.target());
        let report = self.train_window(manager, &snap, by, start, end, ingested, &mut on_batch)?;
        Ok(Some(report))
    }

    /// Time-driven counterpart of [`StreamingTrainer::finish`]: seal
    /// whatever is still pending (refreshing the view) and train the
    /// remaining buckets — including the trailing partial one, whose
    /// reduction is final once no further events will arrive. Returns
    /// `None` when there was nothing left to train.
    pub fn finish_time_driven(
        &mut self,
        manager: &mut HookManager,
        view: &DtdgHandle,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Option<CycleReport>> {
        if self.store.total_edges() == 0 {
            return Ok(None);
        }
        self.store.seal()?;
        let Some(snap) = view.pin() else {
            return Ok(None);
        };
        let end = snap.end_time() + 1;
        let start = self.trained_until.unwrap_or_else(|| snap.start_time());
        if start >= end {
            return Ok(None);
        }
        let by = BatchBy::Time(view.target());
        let report = self.train_window(manager, &snap, by, start, end, 0, &mut on_batch)?;
        Ok(Some(report))
    }

    /// Drain the source time-driven: run cycles until a chunk comes back
    /// empty, then flush the partial-bucket tail. Returns one report per
    /// cycle.
    pub fn run_time_driven(
        &mut self,
        manager: &mut HookManager,
        view: &DtdgHandle,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Vec<CycleReport>> {
        let mut reports = Vec::new();
        while let Some(r) = self.run_cycle_time_driven(manager, view, &mut on_batch)? {
            reports.push(r);
        }
        if let Some(r) = self.finish_time_driven(manager, view, &mut on_batch)? {
            reports.push(r);
        }
        Ok(reports)
    }

    /// Drive the hook recipe over `[start, end)` of `snap` with the given
    /// batching strategy and advance the trained watermark and cumulative
    /// batch counter. (`cfg.batch_events` caps batch size in both modes:
    /// it is the batch size for event iteration and the event cap that
    /// splits oversized buckets for time iteration.)
    fn train_window(
        &mut self,
        manager: &mut HookManager,
        snap: &Arc<crate::graph::StorageSnapshot>,
        by: BatchBy,
        start: Timestamp,
        end: Timestamp,
        ingested: usize,
        on_batch: &mut impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<CycleReport> {
        manager.activate(&self.cfg.train_key)?;
        let view = DGraph::slice_of(Arc::clone(snap), start, end)?;
        let mut loader = DGDataLoader::new(view, by, manager)?
            .with_event_cap(self.cfg.batch_events)
            .with_index_offset(self.batches_done);
        let mut batches = 0usize;
        while let Some(batch) = loader.next() {
            on_batch(&batch?)?;
            batches += 1;
        }
        drop(loader);
        self.batches_done += batches;
        self.trained_until = Some(end);
        let report = CycleReport {
            cycle: self.cycles,
            ingested,
            batches,
            window: (start, end),
            sealed_segments: self.store.num_sealed_segments(),
            generation: snap.generation(),
        };
        self.cycles += 1;
        Ok(report)
    }

    fn empty_report(&mut self, ingested: usize) -> CycleReport {
        let report = CycleReport {
            cycle: self.cycles,
            ingested,
            batches: 0,
            window: (0, 0),
            sealed_segments: self.store.num_sealed_segments(),
            generation: self.store.generation(),
        };
        self.cycles += 1;
        report
    }

    /// Drain the source: run cycles until a chunk comes back empty, then
    /// flush the watermark tail. Returns one report per cycle.
    pub fn run(
        &mut self,
        manager: &mut HookManager,
        mut on_batch: impl FnMut(&MaterializedBatch) -> Result<()>,
    ) -> Result<Vec<CycleReport>> {
        let mut reports = Vec::new();
        while let Some(r) = self.run_cycle(manager, &mut on_batch)? {
            reports.push(r);
        }
        if let Some(r) = self.finish(manager, &mut on_batch)? {
            reports.push(r);
        }
        Ok(reports)
    }
}

/// What one tenant did during one multi-tenant ingest cycle.
#[derive(Debug, Clone)]
pub struct TenantCycleReport {
    /// Which tenant this row describes.
    pub tenant: TenantId,
    /// Events appended this cycle (0 on an error row: a failing chunk's
    /// partial-append count is not reported).
    pub ingested: usize,
    /// Generation published after the cycle (0 if nothing is published
    /// yet — e.g. the tenant has only edge-free node events).
    pub generation: u64,
    /// Sealed segments behind the tenant's writer after the cycle.
    pub sealed_segments: usize,
    /// Edge events still buffered in the tenant's active segment.
    pub pending_edges: usize,
    /// The error that terminated this tenant's ingestion, if any. A
    /// failing tenant's source is dropped from subsequent cycles (its
    /// stream position has advanced past the failed chunk, so resuming
    /// would leave a gap); every other tenant keeps cycling.
    pub error: Option<String>,
}

/// Round-robin per-tenant ingest cycles over a shared [`TenantRouter`]:
/// each cycle pulls one chunk per tenant from that tenant's own
/// [`EventSource`], appends it through the tenant's writer (auto-sealing
/// and compacting per the tenant's policies), and publishes a fresh
/// snapshot generation so concurrent serving picks it up on the next
/// pin. Tenants are fully independent: one tenant's backlog, policy, or
/// append error never blocks or halts the others — an ingest failure
/// becomes an error row in that cycle's reports
/// ([`TenantCycleReport::error`]) and retires only the failing tenant's
/// source, while every other tenant keeps cycling to completion.
/// Error semantics for the failing tenant: events of its chunk before
/// the offending one are appended, the rest of that chunk is dropped
/// (the source has already advanced), so the error is terminal for that
/// tenant's stream — recoverable flows should drive
/// [`crate::serving::TenantHandle::ingest`] directly with their own
/// retry buffer.
///
/// This is the multi-graph counterpart of [`StreamingTrainer`]'s
/// ingest half; serving happens elsewhere, against pinned snapshots, so
/// the ingestor thread and any number of serving threads only meet at
/// each tenant's publication cell.
pub struct MultiTenantIngestor<S: EventSource> {
    router: Arc<TenantRouter>,
    streams: Vec<(TenantId, S)>,
    chunk: usize,
}

impl<S: EventSource> MultiTenantIngestor<S> {
    /// Bind a router and a per-cycle, per-tenant chunk size.
    pub fn new(router: Arc<TenantRouter>, chunk: usize) -> MultiTenantIngestor<S> {
        MultiTenantIngestor { router, streams: Vec::new(), chunk: chunk.max(1) }
    }

    /// Attach a tenant's event source. The tenant must already be
    /// registered with the router.
    pub fn add_stream(&mut self, id: impl Into<TenantId>, source: S) -> Result<()> {
        let id = id.into();
        self.router.tenant(&id)?;
        self.streams.push((id, source));
        Ok(())
    }

    /// The shared router.
    pub fn router(&self) -> &Arc<TenantRouter> {
        &self.router
    }

    /// Run one ingest cycle across all tenants. Returns `None` when
    /// every still-attached source yielded an empty chunk (for replay
    /// sources: all drained; for live sources: call again later). A
    /// failing tenant produces an error row and is detached; the cycle
    /// itself only errs on infrastructure-level failures (currently
    /// none), so healthy tenants are never halted by a sick one.
    pub fn run_cycle(&mut self) -> Result<Option<Vec<TenantCycleReport>>> {
        let mut reports = Vec::new();
        let mut failed: Vec<TenantId> = Vec::new();
        let mut any = false;
        for (id, source) in &mut self.streams {
            let chunk = source.next_chunk(self.chunk);
            if chunk.is_empty() {
                continue;
            }
            any = true;
            match Self::ingest_one(&self.router, id, chunk) {
                Ok(r) => reports.push(r),
                Err(e) => {
                    // Per-tenant isolation: report the failure in-band
                    // (best-effort metadata) and retire only this
                    // tenant's source.
                    let h = self.router.tenant(id).ok();
                    reports.push(TenantCycleReport {
                        tenant: id.clone(),
                        ingested: 0,
                        generation: h
                            .as_ref()
                            .and_then(|h| h.published_generation())
                            .unwrap_or(0),
                        sealed_segments: h.as_ref().map_or(0, |h| h.num_sealed_segments()),
                        pending_edges: h.as_ref().map_or(0, |h| h.pending_edges()),
                        error: Some(e.to_string()),
                    });
                    failed.push(id.clone());
                }
            }
        }
        if !failed.is_empty() {
            self.streams.retain(|(id, _)| !failed.contains(id));
        }
        Ok(if any { Some(reports) } else { None })
    }

    /// One tenant's slice of a cycle: append the chunk, publish a fresh
    /// generation (once the tenant has any edge), report.
    fn ingest_one(
        router: &TenantRouter,
        id: &TenantId,
        chunk: Vec<crate::graph::Event>,
    ) -> Result<TenantCycleReport> {
        let handle = router.tenant(id)?;
        let ingested = handle.ingest(chunk)?;
        let generation = if handle.total_edges() > 0 {
            handle.publish()?.generation()
        } else {
            handle.published_generation().unwrap_or(0)
        };
        Ok(TenantCycleReport {
            tenant: id.clone(),
            ingested,
            generation,
            sealed_segments: handle.num_sealed_segments(),
            pending_edges: handle.pending_edges(),
            error: None,
        })
    }

    /// Drain every source, cycling until all are empty or retired.
    /// Returns one report row per (cycle, active tenant), error rows
    /// included — a failing tenant never halts the healthy ones.
    pub fn run_to_completion(&mut self) -> Result<Vec<TenantCycleReport>> {
        let mut all = Vec::new();
        while let Some(mut rows) = self.run_cycle()? {
            all.append(&mut rows);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::SealPolicy;
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::io::stream::ReplaySource;
    use crate::serving::TenantConfig;

    #[test]
    fn multi_tenant_ingest_cycles_publish_per_tenant_generations() {
        let mut router = TenantRouter::new();
        let seeds = [11u64, 12, 13];
        let datasets: Vec<_> =
            seeds.iter().map(|&s| gen::by_name("wiki", 0.05, s).unwrap()).collect();
        for (i, d) in datasets.iter().enumerate() {
            router
                .add_tenant(
                    format!("t{i}"),
                    TenantConfig::new(d.storage().num_nodes())
                        .with_seal(SealPolicy::by_events(150))
                        .with_granularity(d.storage().granularity()),
                )
                .unwrap();
        }
        let router = Arc::new(router);
        let mut ingestor = MultiTenantIngestor::new(Arc::clone(&router), 200);
        for (i, d) in datasets.iter().enumerate() {
            ingestor.add_stream(format!("t{i}"), ReplaySource::from_data(d)).unwrap();
        }
        // Unknown tenants are rejected up front.
        assert!(ingestor
            .add_stream("ghost", ReplaySource::new(vec![]))
            .is_err());

        let rows = ingestor.run_to_completion().unwrap();
        assert!(rows.len() >= datasets.len() * 2, "want multiple cycles per tenant");
        for (i, d) in datasets.iter().enumerate() {
            let id = crate::serving::TenantId::from(format!("t{i}"));
            let total: usize =
                rows.iter().filter(|r| r.tenant == id).map(|r| r.ingested).sum();
            assert_eq!(
                total,
                d.storage().num_edges() + d.storage().num_node_events(),
                "tenant {i} must ingest its whole stream"
            );
            // Every tenant finished published, with all its edges visible.
            let snap = router.pin(&id).unwrap();
            assert_eq!(snap.num_edges(), d.storage().num_edges());
            assert_eq!(snap.edge_ts(), d.storage().edge_ts());
            // Generations advanced across cycles.
            let gens: Vec<u64> =
                rows.iter().filter(|r| r.tenant == id).map(|r| r.generation).collect();
            assert!(gens.windows(2).all(|w| w[0] < w[1]), "{gens:?}");
        }
    }

    #[test]
    fn one_tenants_failure_does_not_halt_the_others() {
        use crate::graph::{EdgeEvent, Event};
        use crate::serving::TenantId;
        use crate::util::TimeGranularity;

        let edge = |t: i64| {
            Event::Edge(EdgeEvent { t, src: 0, dst: 1, features: vec![] })
        };
        let mut router = TenantRouter::new();
        for (name, seal) in
            [("good", SealPolicy::default()), ("bad", SealPolicy::by_events(1))]
        {
            router
                .add_tenant(
                    name,
                    crate::serving::TenantConfig::new(4)
                        .with_seal(seal)
                        .with_granularity(TimeGranularity::Second),
                )
                .unwrap();
        }
        let router = Arc::new(router);
        let mut ing = MultiTenantIngestor::new(Arc::clone(&router), 2);
        ing.add_stream("good", ReplaySource::new((0..6).map(|i| edge(i * 10)).collect()))
            .unwrap();
        // The bad tenant seals per event, so its second (older) edge is
        // a stale append: terminal for `bad`, invisible to `good`.
        ing.add_stream("bad", ReplaySource::new(vec![edge(100), edge(10)])).unwrap();

        let rows = ing.run_to_completion().unwrap();
        let bad: Vec<_> = rows.iter().filter(|r| r.tenant == TenantId::from("bad")).collect();
        assert_eq!(bad.len(), 1, "one error row, then the bad tenant is retired");
        let msg = bad[0].error.as_deref().unwrap();
        assert!(msg.contains("stale"), "{msg}");

        // The healthy tenant drained its whole stream regardless.
        let good_total: usize = rows
            .iter()
            .filter(|r| r.tenant == TenantId::from("good"))
            .map(|r| r.ingested)
            .sum();
        assert_eq!(good_total, 6);
        assert!(rows
            .iter()
            .filter(|r| r.tenant == TenantId::from("good"))
            .all(|r| r.error.is_none()));
        assert_eq!(router.pin(&TenantId::from("good")).unwrap().num_edges(), 6);
    }

    #[test]
    fn cycles_tile_the_stream_exactly_once() {
        let data = gen::by_name("wiki", 0.05, 5).unwrap();
        let total_edges = data.storage().num_edges();
        let store = SegmentedStorage::new(
            data.storage().num_nodes(),
            SealPolicy::by_events(200),
        );
        let source = ReplaySource::from_data(&data);
        let cfg = StreamingConfig {
            ingest_chunk: 300,
            batch_events: 64,
            compact_after: 4,
            train_key: "train".into(),
        };
        let mut trainer = StreamingTrainer::new(store, source, cfg);
        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();

        let mut seen_edges = 0usize;
        let mut last_t = i64::MIN;
        let reports = trainer
            .run(&mut manager, |batch| {
                seen_edges += batch.num_edges();
                for &t in &batch.ts {
                    assert!(t >= last_t, "batches must advance in time");
                    last_t = t;
                }
                assert!(batch.has(crate::hooks::attr::NEGATIVES));
                assert!(batch.has(crate::hooks::attr::NEIGHBORS));
                Ok(())
            })
            .unwrap();
        assert!(reports.len() > 1, "want multiple cycles");
        assert_eq!(seen_edges, total_edges, "every edge trains exactly once");
        let ingested: usize = reports.iter().map(|r| r.ingested).sum();
        assert_eq!(ingested, total_edges);
        // Windows tile without overlap.
        for w in reports.windows(2) {
            if w[0].batches > 0 && w[1].batches > 0 {
                assert_eq!(w[0].window.1, w[1].window.0);
            }
        }
        // Compaction kept segment fan-out bounded.
        assert!(reports.iter().all(|r| r.sealed_segments <= 5));
    }

    #[test]
    fn resume_trains_only_newly_revealed_windows() {
        let data = gen::by_name("wiki", 0.05, 8).unwrap();
        let total = data.storage().num_edges();
        let total_events = total + data.storage().num_node_events();
        let mut source = ReplaySource::from_data(&data);

        // Pre-crash life: ~60% of the stream is ingested (and, under
        // resume semantics, counted as trained up to the held-back
        // boundary timestamp).
        let prefix = source.next_chunk((total * 3) / 5);
        let ingested_prefix = prefix.len();
        let mut store = SegmentedStorage::new(
            data.storage().num_nodes(),
            SealPolicy::by_events(200),
        )
        .with_granularity(data.storage().granularity());
        for ev in prefix {
            store.append(ev).unwrap();
        }
        let boundary = store.snapshot().unwrap().end_time();

        let cfg = StreamingConfig {
            ingest_chunk: 300,
            batch_events: 64,
            compact_after: 4,
            train_key: "train".into(),
        };
        let mut trainer = StreamingTrainer::resume(store, source, cfg).unwrap();
        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        let mut seen = 0usize;
        let reports = trainer
            .run(&mut manager, |b| {
                for &t in &b.ts {
                    assert!(t >= boundary, "resume must not retrain pre-boundary windows");
                }
                seen += b.num_edges();
                Ok(())
            })
            .unwrap();
        // Exactly the boundary-and-later events train (boundary ties
        // were held back by the watermark, so they train now — once).
        let expect = data.storage().edge_ts().iter().filter(|&&t| t >= boundary).count();
        assert_eq!(seen, expect);
        assert!(seen < total, "the pre-boundary prefix must not retrain");
        let ingested: usize = reports.iter().map(|r| r.ingested).sum();
        assert_eq!(ingested + ingested_prefix, total_events);

        // Resuming an empty store degrades to a fresh trainer.
        let empty = SegmentedStorage::new(4, SealPolicy::default());
        let t2 =
            StreamingTrainer::resume(empty, ReplaySource::new(vec![]), StreamingConfig::default());
        assert!(t2.is_ok());
    }

    #[test]
    fn time_driven_cycles_train_each_bucket_exactly_once() {
        use crate::graph::{EdgeEvent, Event, ReduceOp};
        use crate::util::TimeGranularity;

        // One edge every 10 minutes starting at t=1000: five hour-buckets
        // relative to the first edge, six edges each. The (src, dst)
        // pattern cycles through three pairs, so every bucket reduces to
        // exactly 3 coarse edges with Sum feature [2.0].
        let events: Vec<Event> = (0..30i64)
            .map(|i| {
                Event::Edge(EdgeEvent {
                    t: 1000 + i * 600,
                    src: (i % 3) as u32,
                    dst: ((i + 1) % 3) as u32,
                    features: vec![1.0],
                })
            })
            .collect();
        let store = SegmentedStorage::new(3, SealPolicy::by_events(7))
            .with_granularity(TimeGranularity::Second);
        let cfg = StreamingConfig {
            ingest_chunk: 5,
            batch_events: 64,
            compact_after: 4,
            train_key: "train".into(),
        };
        let mut trainer = StreamingTrainer::new(store, ReplaySource::new(events), cfg);
        let view = trainer.attach_dtdg(TimeGranularity::Hour, ReduceOp::Sum).unwrap();
        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();

        let mut windows: Vec<(i64, i64)> = Vec::new();
        let mut coarse_edges = 0usize;
        let reports = trainer
            .run_time_driven(&mut manager, &view, |b| {
                assert!(b.end - b.start <= 3600, "one bucket per batch: {:?}", (b.start, b.end));
                assert_eq!(b.num_edges(), 3, "each bucket reduces to its 3 classes");
                windows.push((b.start, b.end));
                coarse_edges += b.num_edges();
                Ok(())
            })
            .unwrap();

        assert!(
            reports.iter().filter(|r| r.batches > 0).count() > 1,
            "training must happen across multiple cycles, not one flush"
        );
        assert_eq!(windows.len(), 5, "five buckets, each trained exactly once");
        assert_eq!(coarse_edges, 15);
        assert!(windows.windows(2).all(|w| w[0].1 <= w[1].0), "bucket windows tile in order");
        // The refresh watermark froze everything up to the last full bucket.
        assert_eq!(view.complete_until(), Some(1000 + 4 * 3600));
        assert!(view.refreshes() > 1, "the view refreshed incrementally, seal by seal");
        // The trained view matches the one-shot discretization of the base
        // stream — same coarse edge count, fully reduced features.
        let full = crate::graph::discretize(
            &trainer.store_mut().snapshot().unwrap(),
            TimeGranularity::Hour,
            ReduceOp::Sum,
        )
        .unwrap();
        assert_eq!(full.num_edges(), 15);
        assert!(full.edge_feats().iter().all(|&f| f == 2.0));
    }

    #[test]
    fn single_cycle_matches_one_shot_loader() {
        // Ingest everything in one cycle: the streamed batches must be
        // byte-identical to a serial loader over the one-shot dataset.
        let data = gen::by_name("wiki", 0.05, 6).unwrap();
        let n = data.storage().num_edges();

        let mut m1 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m1.activate("train").unwrap();
        let mut serial =
            DGDataLoader::new(data.full(), BatchBy::Events(100), &mut m1).unwrap();
        let expect = serial.collect_all().unwrap();

        let store = SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::default())
            .with_granularity(data.storage().granularity());
        let source = ReplaySource::from_data(&data);
        let cfg = StreamingConfig {
            ingest_chunk: usize::MAX,
            batch_events: 100,
            compact_after: 8,
            train_key: "train".into(),
        };
        let mut trainer = StreamingTrainer::new(store, source, cfg);
        let mut manager = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        let mut got: Vec<MaterializedBatch> = Vec::new();
        let reports = trainer.run(&mut manager, |b| {
            got.push(b.clone());
            Ok(())
        });
        let reports = reports.unwrap();
        assert_eq!(reports.iter().map(|r| r.ingested).sum::<usize>(), n);
        assert_eq!(got.len(), expect.len());
        for (a, b) in expect.iter().zip(&got) {
            assert_eq!((a.start, a.end), (b.start, b.end));
            assert_eq!(a.src, b.src);
            assert_eq!(a.dst, b.dst);
            assert_eq!(a.ts, b.ts);
            assert_eq!(a.edge_indices, b.edge_indices);
            assert_eq!(a.attr_names(), b.attr_names());
            for name in a.attr_names() {
                assert_eq!(a.get(name).unwrap(), b.get(name).unwrap(), "attr `{name}`");
            }
        }
    }
}
