//! Runtime-breakdown profiler (paper Appendix A.3, Table 11).
//!
//! Accumulates wall-clock per pipeline category so `tgm profile` and the
//! `table11_profile` bench can print the same decomposition the paper
//! reports for TGAT (data loading / hooks / forward / backward / ...).

use crate::loader::LatencyHistogram;
use crate::obs::{Label, MetricValue, RegistrySnapshot};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Category timer.
#[derive(Debug, Default, Clone)]
pub struct Profiler {
    totals: HashMap<&'static str, Duration>,
    started: Option<Instant>,
    /// Prefetch overlap accounting: total worker-side materialization
    /// time vs how much of it leaked into the consumer's critical path.
    overlap_busy: Duration,
    overlap_blocked: Duration,
    /// Per-batch materialization raw-speed counters: batches built,
    /// bytes of batch arenas produced, and cycles spent building them
    /// ([`crate::kernels::cycles`] — rdtsc ticks on x86_64).
    mat_batches: u64,
    mat_bytes: u64,
    mat_cycles: u64,
    /// Per-request-class serving latency (e.g. "point" / "scan"),
    /// merged from [`crate::loader::QosStats`] histograms. Keyed on an
    /// owned [`Label`] so dynamic class names (per-tenant rows, registry
    /// metric names) work alongside the `&'static str` literals the
    /// call sites pass.
    latency: HashMap<Label, LatencyHistogram>,
}

impl Profiler {
    /// Fresh profiler.
    pub fn new() -> Profiler {
        Profiler::default()
    }

    /// Record one prefetch run: workers spent `busy` materializing, of
    /// which only `blocked` stalled the consumer. The difference is hook
    /// time hidden behind engine execution — the pipeline's win over the
    /// serial loader.
    pub fn add_overlap(&mut self, busy: Duration, blocked: Duration) {
        self.overlap_busy += busy;
        self.overlap_blocked += blocked;
    }

    /// Record batch-materialization raw-speed counters: `batches`
    /// built, `bytes` of batch arenas produced, `cycles` spent building
    /// them (rdtsc ticks on x86_64, monotonic nanoseconds elsewhere —
    /// see [`crate::kernels::cycles`]).
    pub fn add_materialization(&mut self, batches: u64, bytes: u64, cycles: u64) {
        self.mat_batches += batches;
        self.mat_bytes += bytes;
        self.mat_cycles += cycles;
    }

    /// `(batches, bytes, cycles)` accumulated by
    /// [`Self::add_materialization`]; `None` before any batch was
    /// recorded.
    pub fn materialization(&self) -> Option<(u64, u64, u64)> {
        if self.mat_batches == 0 {
            None
        } else {
            Some((self.mat_batches, self.mat_bytes, self.mat_cycles))
        }
    }

    /// Fold one request class's latency histogram into the profiler
    /// (repeat per class; histograms merge across calls). `class` is a
    /// stable label — use [`crate::loader::RequestClass::label`] when
    /// reporting pool stats.
    pub fn add_request_latency(&mut self, class: impl Into<Label>, hist: &LatencyHistogram) {
        self.latency.entry(class.into()).or_default().merge(hist);
    }

    /// Fold a registry snapshot's latency histograms into the per-class
    /// rows: the pool's `tgm_point_latency_us` / `tgm_scan_latency_us`
    /// series land under their familiar "point" / "scan" classes, every
    /// other histogram under its metric name. Counters and gauges are
    /// skipped — they have no duration to fold.
    pub fn fold_registry(&mut self, snap: &RegistrySnapshot) {
        for m in &snap.metrics {
            if let MetricValue::Histogram(h) = &m.value {
                let class = match m.name.as_str() {
                    "tgm_point_latency_us" => Label::from("point"),
                    "tgm_scan_latency_us" => Label::from("scan"),
                    other => Label::from(other),
                };
                self.add_request_latency(class, h);
            }
        }
    }

    /// The merged latency histogram of `class`, if any samples were
    /// recorded.
    pub fn request_latency(&self, class: &str) -> Option<&LatencyHistogram> {
        self.latency.get(class).filter(|h| !h.is_empty())
    }

    /// `(worker_busy, consumer_blocked, hidden)` if any prefetch run was
    /// recorded; `hidden = busy - blocked` clamped at zero.
    pub fn overlap(&self) -> Option<(Duration, Duration, Duration)> {
        if self.overlap_busy.is_zero() && self.overlap_blocked.is_zero() {
            None
        } else {
            let hidden = self.overlap_busy.saturating_sub(self.overlap_blocked);
            Some((self.overlap_busy, self.overlap_blocked, hidden))
        }
    }

    /// Initial prefetch window suggested by the overlap recorded so far:
    /// the consumer-blocked share of worker-busy time, mapped linearly
    /// into `[2, 16]` — a fully overlapped pipeline (nothing leaked into
    /// the critical path) needs only a shallow window, a consumer that
    /// mostly waited wants workers running far ahead. `None` before any
    /// prefetch run was recorded. Seeds
    /// [`crate::loader::QueueDepth::Adaptive`]'s floor for the next
    /// epoch; the per-stream tuner refines from there.
    pub fn suggested_queue_depth(&self) -> Option<usize> {
        if self.overlap_busy.is_zero() && self.overlap_blocked.is_zero() {
            return None;
        }
        let busy = self.overlap_busy.as_secs_f64();
        let ratio = if busy <= 0.0 {
            1.0
        } else {
            (self.overlap_blocked.as_secs_f64() / busy).clamp(0.0, 1.0)
        };
        Some(2 + (ratio * 14.0).round() as usize)
    }

    /// Time a closure under a category.
    pub fn record<T>(&mut self, category: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.totals.entry(category).or_default() += t0.elapsed();
        out
    }

    /// Add an externally measured duration.
    pub fn add(&mut self, category: &'static str, d: Duration) {
        *self.totals.entry(category).or_default() += d;
    }

    /// Start the wall-clock for percentage reporting.
    pub fn start_wall(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Total across categories.
    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Duration of one category.
    pub fn get(&self, category: &str) -> Duration {
        self.totals.get(category).copied().unwrap_or_default()
    }

    /// (category, seconds, percent) rows, descending, plus an "other"
    /// row when wall-clock exceeds the categorized total.
    pub fn report(&self) -> Vec<(String, f64, f64)> {
        let wall = self
            .started
            .map(|s| s.elapsed())
            .unwrap_or_else(|| self.total())
            .max(self.total());
        let denom = wall.as_secs_f64().max(1e-12);
        let mut rows: Vec<(String, f64, f64)> = self
            .totals
            .iter()
            .map(|(k, v)| (k.to_string(), v.as_secs_f64(), 100.0 * v.as_secs_f64() / denom))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let categorized: f64 = rows.iter().map(|r| r.1).sum();
        if wall.as_secs_f64() > categorized {
            let other = wall.as_secs_f64() - categorized;
            rows.push(("other".into(), other, 100.0 * other / denom));
        }
        rows
    }

    /// Clear all counters.
    pub fn reset(&mut self) {
        self.totals.clear();
        self.started = None;
        self.overlap_busy = Duration::ZERO;
        self.overlap_blocked = Duration::ZERO;
        self.mat_batches = 0;
        self.mat_bytes = 0;
        self.mat_cycles = 0;
        self.latency.clear();
    }
}

impl std::fmt::Display for Profiler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{:<24} {:>10} {:>8}", "category", "seconds", "percent")?;
        for (name, secs, pct) in self.report() {
            writeln!(f, "{name:<24} {secs:>10.4} {pct:>7.2}%")?;
        }
        if let Some((busy, blocked, hidden)) = self.overlap() {
            writeln!(
                f,
                "prefetch overlap: workers busy {:.4}s, consumer blocked {:.4}s, hidden {:.4}s ({:.0}% overlapped)",
                busy.as_secs_f64(),
                blocked.as_secs_f64(),
                hidden.as_secs_f64(),
                100.0 * hidden.as_secs_f64() / busy.as_secs_f64().max(1e-12)
            )?;
        }
        if let Some((batches, bytes, cycles)) = self.materialization() {
            writeln!(
                f,
                "materialization: {batches} batches, {:.1} KB/batch, {:.2} cycles/byte",
                (bytes as f64 / batches as f64) / 1024.0,
                cycles as f64 / (bytes as f64).max(1.0)
            )?;
        }
        let mut classes: Vec<&Label> = self.latency.keys().collect();
        classes.sort();
        for class in classes {
            let h = &self.latency[class];
            if h.is_empty() {
                continue;
            }
            writeln!(
                f,
                "latency[{class}]: p50={}us p99={}us max={}us (n={})",
                h.percentile_us(50.0),
                h.percentile_us(99.0),
                h.max_us(),
                h.count()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut p = Profiler::new();
        p.record("a", || std::thread::sleep(Duration::from_millis(12)));
        p.record("b", || std::thread::sleep(Duration::from_millis(4)));
        p.record("a", || std::thread::sleep(Duration::from_millis(4)));
        let rows = p.report();
        assert_eq!(rows[0].0, "a");
        assert!(rows[0].1 >= 0.015);
        let pct_sum: f64 = rows.iter().map(|r| r.2).sum();
        assert!((pct_sum - 100.0).abs() < 1.0, "{pct_sum}");
        assert!(p.get("a") > p.get("b"));
    }

    #[test]
    fn closure_value_passes_through() {
        let mut p = Profiler::new();
        let v = p.record("x", || 42);
        assert_eq!(v, 42);
        p.reset();
        assert_eq!(p.total(), Duration::ZERO);
    }

    #[test]
    fn suggested_depth_tracks_the_blocked_share() {
        let mut p = Profiler::new();
        assert_eq!(p.suggested_queue_depth(), None, "no overlap recorded yet");
        // Fully overlapped: shallow window.
        p.add_overlap(Duration::from_millis(100), Duration::ZERO);
        assert_eq!(p.suggested_queue_depth(), Some(2));
        // Mostly blocked: deep window.
        p.add_overlap(Duration::ZERO, Duration::from_millis(400));
        assert_eq!(p.suggested_queue_depth(), Some(16));
        p.reset();
        assert_eq!(p.suggested_queue_depth(), None);
    }

    #[test]
    fn overlap_clamps_and_resets() {
        let mut p = Profiler::new();
        assert!(p.overlap().is_none());
        p.add_overlap(Duration::from_millis(100), Duration::from_millis(30));
        p.add_overlap(Duration::from_millis(50), Duration::from_millis(90));
        let (busy, blocked, hidden) = p.overlap().unwrap();
        assert_eq!(busy, Duration::from_millis(150));
        assert_eq!(blocked, Duration::from_millis(120));
        assert_eq!(hidden, Duration::from_millis(30));
        // Blocked beyond busy never goes negative.
        p.add_overlap(Duration::ZERO, Duration::from_millis(500));
        assert_eq!(p.overlap().unwrap().2, Duration::ZERO);
        p.reset();
        assert!(p.overlap().is_none());
        assert!(format!("{p}").contains("category"));
    }

    #[test]
    fn request_latency_rows_merge_and_report() {
        let mut p = Profiler::new();
        assert!(p.request_latency("point").is_none());
        let mut h = LatencyHistogram::new();
        for us in [5u64, 9, 2000] {
            h.record_us(us);
        }
        p.add_request_latency("point", &h);
        // A second merge folds into the same class row.
        p.add_request_latency("point", &h);
        p.add_request_latency("scan", &h);
        assert_eq!(p.request_latency("point").unwrap().count(), 6);
        let shown = format!("{p}");
        assert!(shown.contains("latency[point]: p50="), "{shown}");
        assert!(shown.contains("latency[scan]:"), "{shown}");
        assert!(shown.contains("p99="), "{shown}");
        p.reset();
        assert!(p.request_latency("point").is_none());
        assert!(!format!("{p}").contains("latency["));
    }

    #[test]
    fn fold_registry_maps_pool_series_to_point_and_scan_rows() {
        use crate::obs::{MetricSnapshot, MetricValue, RegistrySnapshot};
        let mut h = LatencyHistogram::new();
        for us in [3u64, 40, 500] {
            h.record_us(us);
        }
        let snap = RegistrySnapshot {
            metrics: vec![
                MetricSnapshot {
                    name: "tgm_point_latency_us".to_string(),
                    labels: vec![("pool".to_string(), "0".to_string())],
                    value: MetricValue::Histogram(h.clone()),
                },
                MetricSnapshot {
                    name: "tgm_scan_latency_us".to_string(),
                    labels: vec![],
                    value: MetricValue::Histogram(h.clone()),
                },
                MetricSnapshot {
                    name: "tgm_seal_duration_us".to_string(),
                    labels: vec![],
                    value: MetricValue::Histogram(h.clone()),
                },
                MetricSnapshot {
                    name: "tgm_wal_appends_total".to_string(),
                    labels: vec![],
                    value: MetricValue::Counter(7),
                },
            ],
        };
        let mut p = Profiler::new();
        p.fold_registry(&snap);
        // Two pool series fold under their familiar class names; other
        // histograms keep their metric name; counters are skipped.
        assert_eq!(p.request_latency("point").unwrap().count(), 3);
        assert_eq!(p.request_latency("scan").unwrap().count(), 3);
        assert_eq!(p.request_latency("tgm_seal_duration_us").unwrap().count(), 3);
        assert!(p.request_latency("tgm_wal_appends_total").is_none());
        // Folding the same snapshot again merges into the same rows.
        p.fold_registry(&snap);
        assert_eq!(p.request_latency("point").unwrap().count(), 6);
    }

    #[test]
    fn materialization_counters_accumulate_and_reset() {
        let mut p = Profiler::new();
        assert!(p.materialization().is_none());
        p.add_materialization(2, 4096, 20_000);
        p.add_materialization(1, 2048, 10_000);
        assert_eq!(p.materialization(), Some((3, 6144, 30_000)));
        let shown = format!("{p}");
        assert!(shown.contains("materialization: 3 batches"), "{shown}");
        assert!(shown.contains("cycles/byte"), "{shown}");
        p.reset();
        assert!(p.materialization().is_none());
    }
}
