//! Task target construction (node / graph property prediction).
//!
//! TGB's node-property tasks (Trade, Genre) predict each node's
//! *interaction distribution over property classes in the next period*.
//! Items are hashed into `P` classes; a node's target is the normalized
//! class histogram of its interactions inside a future window. Graph
//! property targets (RQ1) label whether the next snapshot grows.

use crate::error::Result;
use crate::graph::StorageSnapshot;
use crate::runtime::Profile;
use crate::util::{Tensor, Timestamp};

/// Deterministic item -> property-class hash.
pub fn property_class(item: u32, p: usize) -> usize {
    (item as u64).wrapping_mul(2654435761) as usize % p
}

/// Normalized class histogram of `node`'s interactions in `[t0, t1)`.
pub fn node_target(
    storage: &StorageSnapshot,
    node: u32,
    t0: Timestamp,
    t1: Timestamp,
    p: usize,
) -> Vec<f32> {
    let mut hist = vec![0.0f32; p];
    let range = storage.edge_range(t0, t1);
    let mut total = 0.0f32;
    for (seg, local) in storage.edge_chunks(range) {
        let src = &seg.edge_src()[local.clone()];
        let dst = &seg.edge_dst()[local];
        for i in 0..src.len() {
            if src[i] == node {
                hist[property_class(dst[i], p)] += 1.0;
                total += 1.0;
            }
        }
    }
    if total > 0.0 {
        hist.iter_mut().for_each(|h| *h /= total);
    }
    hist
}

/// Batched targets tensor `[B, P]` for `nodes` over a future window.
/// Returns the tensor plus a per-node "has future activity" mask.
pub fn node_targets(
    storage: &StorageSnapshot,
    nodes: &[u32],
    t0: Timestamp,
    t1: Timestamp,
    profile: &Profile,
) -> Result<(Tensor, Vec<f32>)> {
    let p = profile.p;
    let b = profile.b;
    let mut data = vec![0.0f32; b * p];
    let mut active = vec![0.0f32; b];

    // One pass over the window: per-node histograms.
    let range = storage.edge_range(t0, t1);
    let mut row_of = std::collections::HashMap::with_capacity(nodes.len());
    for (row, &n) in nodes.iter().enumerate().take(b) {
        row_of.entry(n).or_insert(row);
    }
    for (seg, local) in storage.edge_chunks(range) {
        let src = &seg.edge_src()[local.clone()];
        let dst = &seg.edge_dst()[local];
        for i in 0..src.len() {
            if let Some(&row) = row_of.get(&src[i]) {
                data[row * p + property_class(dst[i], p)] += 1.0;
                active[row] = 1.0;
            }
        }
    }
    // Normalize + copy shared rows for duplicate nodes.
    for (row, &n) in nodes.iter().enumerate().take(b) {
        let canon = row_of[&n];
        if canon != row {
            let (a, b2) = (canon * p, row * p);
            let src_row: Vec<f32> = data[a..a + p].to_vec();
            data[b2..b2 + p].copy_from_slice(&src_row);
            active[row] = active[canon];
        }
    }
    for row in 0..b {
        let total: f32 = data[row * p..(row + 1) * p].iter().sum();
        if total > 0.0 {
            data[row * p..(row + 1) * p].iter_mut().for_each(|v| *v /= total);
        }
    }
    Ok((Tensor::f32(data, &[b, p])?, active))
}

/// Distinct source nodes active in `[t0, t1)`, in first-seen order.
pub fn active_sources(
    storage: &StorageSnapshot,
    t0: Timestamp,
    t1: Timestamp,
    cap: usize,
) -> Vec<u32> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    'chunks: for (seg, local) in storage.edge_chunks(storage.edge_range(t0, t1)) {
        for &s in &seg.edge_src()[local] {
            if seen.insert(s) {
                out.push(s);
                if out.len() >= cap {
                    break 'chunks;
                }
            }
        }
    }
    out
}

/// RQ1 label: does the next snapshot have strictly more edges?
pub fn growth_label(cur_edges: usize, next_edges: usize) -> f32 {
    if next_edges > cur_edges {
        1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeEvent;

    fn storage() -> StorageSnapshot {
        // node 0 interacts with items 4,5,4 in [0,30); node 1 with 5.
        let edges = vec![
            EdgeEvent { t: 0, src: 0, dst: 4, features: vec![] },
            EdgeEvent { t: 10, src: 0, dst: 5, features: vec![] },
            EdgeEvent { t: 20, src: 0, dst: 4, features: vec![] },
            EdgeEvent { t: 25, src: 1, dst: 5, features: vec![] },
            EdgeEvent { t: 40, src: 1, dst: 4, features: vec![] },
        ];
        crate::graph::GraphStorage::from_events(edges, vec![], 6, None, None)
            .unwrap()
            .into_snapshot()
    }

    fn profile() -> Profile {
        Profile {
            name: "t".into(),
            n: 8,
            b: 4,
            k: 2,
            k2: 2,
            seq: 2,
            c: 2,
            d_edge: 1,
            d_static: 1,
            p: 4,
        }
    }

    #[test]
    fn single_node_target_normalized() {
        let st = storage();
        let t = node_target(&st, 0, 0, 30, 4);
        assert!((t.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        let c4 = property_class(4, 4);
        let c5 = property_class(5, 4);
        assert!((t[c4] - 2.0 / 3.0).abs() < 1e-6 || c4 == c5);
        // Node with no activity -> zero vector.
        let z = node_target(&st, 3, 0, 30, 4);
        assert!(z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn batched_targets_match_single() {
        let st = storage();
        let p = profile();
        let (t, active) = node_targets(&st, &[0, 1, 3], 0, 30, &p).unwrap();
        assert_eq!(t.shape(), &[4, 4]);
        let rows = t.as_f32().unwrap();
        let single0 = node_target(&st, 0, 0, 30, 4);
        assert_eq!(&rows[0..4], single0.as_slice());
        assert_eq!(active, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn duplicate_nodes_share_rows() {
        let st = storage();
        let p = profile();
        let (t, _) = node_targets(&st, &[0, 0], 0, 30, &p).unwrap();
        let rows = t.as_f32().unwrap();
        assert_eq!(&rows[0..4], &rows[4..8]);
    }

    #[test]
    fn active_sources_ordered_and_capped() {
        let st = storage();
        assert_eq!(active_sources(&st, 0, 50, 10), vec![0, 1]);
        assert_eq!(active_sources(&st, 0, 50, 1), vec![0]);
        assert_eq!(active_sources(&st, 35, 50, 10), vec![1]);
    }

    #[test]
    fn growth() {
        assert_eq!(growth_label(5, 6), 1.0);
        assert_eq!(growth_label(5, 5), 0.0);
        assert_eq!(growth_label(5, 2), 0.0);
    }
}
