//! L3 coordinator: batch packing, training orchestration (epoch and
//! streaming), evaluation protocols, task targets, and the
//! runtime-breakdown profiler.

pub mod evaluator;
pub mod packing;
pub mod profiler;
pub mod streaming;
pub mod targets;
pub mod trainer;

pub use evaluator::{evaluate_edgebank, evaluate_persistent_graph, EvalReport, Split};
pub use packing::{ModelFamily, PackConfig, Packed};
pub use profiler::Profiler;
pub use streaming::{
    CycleReport, MultiTenantIngestor, StreamingConfig, StreamingTrainer, TenantCycleReport,
};
pub use trainer::{EpochReport, Pipeline, PipelineConfig};
