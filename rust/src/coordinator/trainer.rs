//! Training coordination: the paper's Fig. 5 workflow, natively in Rust.
//!
//! A [`Pipeline`] binds a dataset, a hook recipe, and a compiled model
//! runtime, then drives epochs:
//!
//! * **CTDG tasks** iterate by events (fixed-size batches); memory/sketch
//!   state updates happen inside the AOT `train` artifact.
//! * **DTDG tasks** iterate by time (one batch per snapshot bucket) and
//!   train on (snapshot_t, queries_{t+1}) pairs; recurrent state advances
//!   inside the artifact with truncated BPTT.
//!
//! Everything is instrumented through [`super::profiler::Profiler`] so
//! Table 11's breakdown can be reproduced.

use crate::coordinator::packing::{self, ModelFamily, PackConfig, Packed};
use crate::coordinator::profiler::Profiler;
use crate::coordinator::targets;
use crate::error::{Result, TgmError};
use crate::graph::{DGData, Splits, Task};
use crate::hooks::recipes::{RecipeConfig, RecipeRegistry, SamplerKind, RECIPE_TGB_LINK};
use crate::hooks::{DstRange, HookEntry, HookManager};
use crate::loader::{BatchBy, DGDataLoader, PrefetchConfig, PrefetchLoader};
use crate::runtime::{ModelRuntime, XlaEngine};
use crate::util::{Tensor, TimeGranularity};
use std::sync::Arc;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Artifact model name, e.g. `tgat_link`, `gclstm_node`.
    pub model: String,
    /// Neighbor sampler implementation (Recency is TGM's default;
    /// Naive is the DyGLib-style baseline for benches).
    pub sampler: SamplerKind,
    /// Snapshot granularity for DTDG models.
    pub granularity: TimeGranularity,
    /// RNG seed for hooks.
    pub seed: u64,
    /// Worker threads for the prefetching batch pipeline (0 = serial
    /// materialization on the training thread). Output is identical for
    /// any value; only the hook/compute overlap changes.
    pub prefetch_workers: usize,
}

impl PipelineConfig {
    /// Defaults for a model name.
    pub fn new(model: impl Into<String>) -> PipelineConfig {
        PipelineConfig {
            model: model.into(),
            sampler: SamplerKind::Recency,
            granularity: TimeGranularity::Day,
            seed: 0,
            prefetch_workers: 2,
        }
    }
}

/// Per-epoch training report.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub mean_loss: f64,
    pub batches: usize,
    pub seconds: f64,
}

/// A bound (dataset × recipe × model runtime) workflow.
pub struct Pipeline<'e> {
    pub runtime: ModelRuntime<'e>,
    pub pack: PackConfig,
    pub manager: HookManager,
    pub node_feats: Tensor,
    pub data: DGData,
    pub splits: Splits,
    pub cfg: PipelineConfig,
    pub profiler: Profiler,
    pub loss_history: Vec<f64>,
}

impl<'e> Pipeline<'e> {
    /// Build a pipeline: loads the model, validates the profile against
    /// the dataset, and wires the task-appropriate hook recipe.
    pub fn new(engine: &'e XlaEngine, data: DGData, cfg: PipelineConfig) -> Result<Pipeline<'e>> {
        let runtime = engine.load_model(&cfg.model)?;
        let profile = runtime.profile.clone();
        let pack = PackConfig::for_model(&cfg.model, &profile)?;
        let node_feats = packing::pack_node_feats(data.storage(), &profile)?;
        let splits = data.split()?;

        let rc = RecipeConfig {
            sampler: cfg.sampler,
            num_neighbors: pack.k.max(1),
            two_hop: pack.k2,
            include_features: true,
            dst_range: DstRange::InferFromData,
            eval_negatives: profile.c - 1,
            seed: cfg.seed,
        };
        let manager = match (data.task(), pack.family) {
            (Task::LinkPrediction, ModelFamily::CtdgNeighbors) => {
                RecipeRegistry::build_with(RECIPE_TGB_LINK, &rc)?
            }
            (Task::LinkPrediction, ModelFamily::CtdgSketch) => {
                // TPNet needs negatives but no neighborhoods; both
                // samplers are stateless, so the full data path
                // prefetches on workers.
                let mut m = HookManager::new();
                m.register_stateless(
                    "train",
                    Arc::new(crate::hooks::negatives::NegativeSampler::new(rc.dst_range, rc.seed)),
                );
                m.register_stateless(
                    "val",
                    Arc::new(crate::hooks::negatives::EvalNegativeSampler::new(
                        rc.dst_range,
                        rc.eval_negatives,
                        rc.seed,
                    )),
                );
                m
            }
            (Task::NodeProperty, ModelFamily::CtdgNeighbors) => {
                let mut m = HookManager::new();
                let sc = crate::hooks::SamplerConfig {
                    num_neighbors: rc.num_neighbors,
                    two_hop: rc.two_hop,
                    include_features: true,
                    seed_negatives: false,
                };
                let mk = || -> HookEntry {
                    match cfg.sampler {
                        SamplerKind::Recency => HookEntry::Stateful(Box::new(
                            crate::hooks::RecencySampler::new(sc.clone()),
                        )),
                        SamplerKind::Uniform => HookEntry::Stateless(Arc::new(
                            crate::hooks::UniformSampler::new(sc.clone(), cfg.seed),
                        )),
                        SamplerKind::Naive => HookEntry::Stateless(Arc::new(
                            crate::hooks::NaiveSampler::new(sc.clone()),
                        )),
                    }
                };
                m.register_entry("train", mk());
                m.register_entry("val", mk());
                m
            }
            (_, ModelFamily::Snapshot) => {
                let mut m = HookManager::new();
                m.register_stateless("train", Arc::new(crate::hooks::analytics::SnapshotAdjHook));
                m.register_stateless("val", Arc::new(crate::hooks::analytics::SnapshotAdjHook));
                if data.task() == Task::LinkPrediction {
                    m.register_stateless(
                        "train",
                        Arc::new(crate::hooks::negatives::NegativeSampler::new(
                            rc.dst_range,
                            rc.seed,
                        )),
                    );
                    m.register_stateless(
                        "val",
                        Arc::new(crate::hooks::negatives::EvalNegativeSampler::new(
                            rc.dst_range,
                            rc.eval_negatives,
                            rc.seed,
                        )),
                    );
                }
                m
            }
            (task, fam) => {
                return Err(TgmError::Config(format!(
                    "unsupported task/family combination: {task:?} / {fam:?}"
                )))
            }
        };

        Ok(Pipeline {
            runtime,
            pack,
            manager,
            node_feats,
            data,
            splits,
            cfg,
            profiler: Profiler::new(),
            loss_history: Vec::new(),
        })
    }

    /// Batch-size-B event iteration strategy for CTDG models.
    fn event_batching(&self) -> BatchBy {
        BatchBy::Events(self.runtime.profile.b)
    }

    /// Prefetch config for the next pass: worker count from the pipeline
    /// config, and — once a pass has recorded overlap — the adaptive
    /// window's floor seeded from the profiler's observed
    /// consumer-blocked vs worker-busy ratio. Output is identical for
    /// any depth; only the hook/compute overlap changes.
    pub(crate) fn prefetch_config(&self) -> PrefetchConfig {
        let mut cfg = PrefetchConfig::default().with_workers(self.cfg.prefetch_workers);
        if let Some(depth) = self.profiler.suggested_queue_depth() {
            cfg = cfg.with_queue(crate::loader::QueueDepth::Adaptive {
                min: depth,
                max: depth.max(32),
            });
        }
        cfg
    }

    /// Train one epoch over the training split. Returns loss stats.
    pub fn train_epoch(&mut self) -> Result<EpochReport> {
        let t0 = std::time::Instant::now();
        let report = match self.pack.family {
            ModelFamily::Snapshot => self.train_epoch_snapshot(),
            _ => self.train_epoch_ctdg(),
        }?;
        self.loss_history.push(report.mean_loss);
        Ok(EpochReport { seconds: t0.elapsed().as_secs_f64(), ..report })
    }

    fn train_epoch_ctdg(&mut self) -> Result<EpochReport> {
        self.manager.activate("train")?;
        let view = self.splits.train.clone();
        let by = self.event_batching();
        let task = self.data.task();
        let profile = self.runtime.profile.clone();
        let horizon = self.cfg.granularity.seconds().unwrap_or(86_400);

        let mut losses = Vec::new();
        // Prefetch: stateless hooks run on workers and overlap with the
        // engine execution below; the stateful phase is applied in batch
        // order inside `next()`. Output is identical to the serial path.
        let cfg = self.prefetch_config();
        let mut loader = PrefetchLoader::new(view, by, &mut self.manager, cfg)?;
        loop {
            let t_load = std::time::Instant::now();
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            self.profiler.add("data_loading", t_load.elapsed());

            let packed = match task {
                Task::LinkPrediction => self.profiler.record("packing", || {
                    packing::pack_link_train(&batch, &profile, &self.pack, &self.node_feats)
                })?,
                Task::NodeProperty => {
                    let t_pack = std::time::Instant::now();
                    let (target, active) = targets::node_targets(
                        self.data.storage(),
                        &batch.src,
                        batch.end,
                        batch.end + horizon,
                        &profile,
                    )?;
                    let mut packed = packing::pack_node_batch(
                        &batch,
                        &profile,
                        &self.pack,
                        &self.node_feats,
                        Some(&target),
                    )?;
                    // Only nodes with future activity contribute loss.
                    let valid = packed["valid"].as_f32()?.to_vec();
                    let merged: Vec<f32> =
                        valid.iter().zip(&active).map(|(&v, &a)| v * a).collect();
                    packed.insert("valid".into(), Tensor::f32(merged, &[profile.b])?);
                    self.profiler.add("packing", t_pack.elapsed());
                    packed
                }
                Task::GraphProperty => {
                    return Err(TgmError::Config(
                        "graph property task requires a snapshot model".into(),
                    ))
                }
            };
            let out = self.profiler.record("train_execute", || self.runtime.run("train", &packed))?;
            if let Some(loss) = out.loss {
                losses.push(loss as f64);
            }
        }
        let pstats = loader.stats();
        drop(loader);
        self.profiler.add_overlap(pstats.worker_busy, pstats.consumer_blocked);
        self.profiler.add_materialization(pstats.mat_batches, pstats.mat_bytes, pstats.mat_cycles);
        self.drain_hook_timings();
        Ok(EpochReport {
            mean_loss: crate::util::stats::mean(&losses),
            batches: losses.len(),
            seconds: 0.0,
        })
    }

    fn train_epoch_snapshot(&mut self) -> Result<EpochReport> {
        self.manager.activate("train")?;
        let view = self.splits.train.clone();
        let by = BatchBy::Time(self.cfg.granularity);
        let task = self.data.task();
        let profile = self.runtime.profile.clone();

        let mut losses = Vec::new();
        let mut prev: Option<(Packed, usize)> = None;
        let mut loader = DGDataLoader::new(view, by, &mut self.manager)?;
        loop {
            let t_load = std::time::Instant::now();
            let Some(batch) = loader.next() else { break };
            let batch = batch?;
            self.profiler.add("data_loading", t_load.elapsed());

            let t_pack = std::time::Instant::now();
            let adj_pack =
                packing::pack_snapshot_adj(&batch, &profile, &self.node_feats)?;
            let cur_edges = batch.num_edges();

            if let Some((mut train_pack, prev_edges)) = prev.take() {
                match task {
                    Task::LinkPrediction => {
                        packing::add_link_queries(&mut train_pack, &batch, &profile)?
                    }
                    Task::NodeProperty => {
                        let nodes =
                            targets::active_sources(self.data.storage(), batch.start, batch.end, profile.b);
                        let (target, _) = targets::node_targets(
                            self.data.storage(),
                            &nodes,
                            batch.start,
                            batch.end,
                            &profile,
                        )?;
                        packing::add_node_queries(&mut train_pack, &nodes, Some(&target), &profile)?;
                    }
                    Task::GraphProperty => {
                        packing::add_graph_label(
                            &mut train_pack,
                            targets::growth_label(prev_edges, cur_edges),
                        );
                    }
                }
                self.profiler.add("packing", t_pack.elapsed());
                let out =
                    self.profiler.record("train_execute", || self.runtime.run("train", &train_pack))?;
                if let Some(loss) = out.loss {
                    losses.push(loss as f64);
                }
            } else {
                self.profiler.add("packing", t_pack.elapsed());
            }
            prev = Some((adj_pack, cur_edges));
        }
        self.drain_hook_timings();
        Ok(EpochReport {
            mean_loss: crate::util::stats::mean(&losses),
            batches: losses.len(),
            seconds: 0.0,
        })
    }

    /// Fold the hook manager's per-hook timings into the profiler.
    fn drain_hook_timings(&mut self) {
        let timings: Vec<(&'static str, std::time::Duration)> =
            self.manager.timings().iter().map(|(k, v)| (*k, *v)).collect();
        for (name, d) in timings {
            self.profiler.add(name, d);
        }
        self.manager.reset_timings();
    }

    /// Train for `epochs` epochs, resetting hook state between epochs
    /// (paper Fig. 5: `manager.reset_state()`).
    pub fn fit(&mut self, epochs: usize) -> Result<Vec<EpochReport>> {
        let mut reports = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            self.manager.reset_state();
            reports.push(self.train_epoch()?);
        }
        Ok(reports)
    }
}
