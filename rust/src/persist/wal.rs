//! Write-ahead log for the active segment.
//!
//! Every acknowledged append to a durable [`crate::graph::SegmentedStorage`]
//! is written (and flushed to the OS — optionally fsync'd) to the WAL
//! *before* the in-memory append happens, so an acknowledged event
//! survives a process kill. Sealing moves the buffered events into an
//! immutable segment file, after which the WAL is reset to a fresh
//! *epoch* (see below) — the log only ever holds the active segment's
//! tail, so it stays small.
//!
//! ## File layout
//!
//! A fixed header — magic `TGMWAL01`, `u32` format version, `u64`
//! epoch — followed by self-delimiting records:
//!
//! ```text
//! [kind u8][len u32][payload len bytes][fnv1a u64 over kind+payload]
//! ```
//!
//! Kinds: `0` = edge event, `1` = node event. The header is written via
//! tmp-file + rename, so it is never torn; records are appended in
//! place.
//!
//! ## Torn vs corrupt tails
//!
//! [`read_wal`] distinguishes two failure shapes:
//!
//! * a **torn tail** — the file ends mid-record (the writer was killed
//!   between acknowledging event *k* and finishing the write of event
//!   *k+1*, or the tail never reached disk). The partial record was, by
//!   construction, never acknowledged: it is dropped, and recovery
//!   yields exactly the acknowledged prefix.
//! * a **corrupt record** — a record is complete per its length field
//!   but fails its checksum (bit rot, manual tampering). This is not a
//!   crash artifact; it surfaces as a typed [`TgmError::Persist`] so the
//!   operator sees the damage instead of silently losing suffix data.
//!
//! ## Epochs
//!
//! Seals write the segment file, then the manifest (which records
//! `wal_epoch = E + 1`), then reset the WAL with header epoch `E + 1`.
//! A crash between the manifest write and the WAL reset leaves a WAL at
//! epoch `E` whose events are already inside the just-sealed segment
//! file; recovery sees `header.epoch < manifest.wal_epoch` and discards
//! the stale log instead of double-appending. Any other epoch mismatch
//! is corruption and errors out.
//!
//! ## Group commit
//!
//! Per-record fsync (`DurabilityPolicy::with_fsync`) costs one disk
//! round-trip per append. Group commit
//! (`DurabilityPolicy::with_group_commit`) amortizes it with a
//! **leader-follower commit window**: appends write their record into
//! the OS and register with a shared [`WalSync`] window instead of
//! syncing; a caller needing durability invokes [`WalSync::barrier`],
//! which elects the first arrival as *leader* — it snapshots the window
//! high-water mark, fsyncs once, and wakes every follower whose records
//! that single sync covered. Acknowledgment (the barrier returning
//! `Ok`) therefore happens only after the group's sync lands, while N
//! concurrent appenders — or one appender batching a chunk — pay ~1
//! fsync per window instead of N. A seal rotates the window's epoch:
//! records buffered at rotation are durable through the sealed segment
//! file itself, so pre-rotation barriers complete without re-syncing.

use crate::error::{Result, TgmError};
use crate::graph::events::{EdgeEvent, Event, NodeEvent};
use crate::obs::{self, Counter, Histogram};
use crate::persist::format::{
    checksum, checksum_seeded, sync_parent_dir, tmp_sibling, Dec, FORMAT_VERSION,
};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

const WAL_MAGIC: &[u8; 8] = b"TGMWAL01";
/// magic + version + epoch. Also the byte offset of the first record —
/// where a tailing reader ([`read_wal_tail`]) starts a fresh epoch.
pub(crate) const HEADER_LEN: usize = 8 + 4 + 8;

const KIND_EDGE: u8 = 0;
const KIND_NODE: u8 = 1;

/// Process-wide WAL metric handles, resolved once: the append hot path
/// bumps shared cells and never touches the registry map.
struct WalMetrics {
    appends: Counter,
    fsyncs: Counter,
    group_window: Histogram,
}

fn wal_metrics() -> &'static WalMetrics {
    static M: OnceLock<WalMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = obs::registry();
        WalMetrics {
            appends: r.counter("tgm_wal_appends_total", &[]),
            fsyncs: r.counter("tgm_wal_fsyncs_total", &[]),
            group_window: r.histogram("tgm_wal_group_window_records", &[]),
        }
    })
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Event> {
    let mut d = Dec::new(payload, "wal record");
    let ev = match kind {
        KIND_EDGE => {
            let t = d.i64()?;
            let src = d.u32()?;
            let dst = d.u32()?;
            let n = d.u32()?;
            let features = d.f32s(n as u64)?;
            Event::Edge(EdgeEvent { t, src, dst, features })
        }
        KIND_NODE => {
            let t = d.i64()?;
            let node = d.u32()?;
            let n = d.u32()?;
            let features = d.f32s(n as u64)?;
            Event::Node(NodeEvent { t, node, features })
        }
        other => {
            return Err(TgmError::Persist(format!("wal record has unknown kind {other}")));
        }
    };
    d.done()?;
    Ok(ev)
}

/// Per-record durability behavior of a [`WalWriter`].
enum SyncMode {
    /// Flush to the OS only (process-kill safety).
    Flush,
    /// fsync after every record (power-loss safety, one IO per append).
    Each,
    /// Register with a shared leader-follower commit window; durability
    /// lands at the next [`WalSync::barrier`] (or seal).
    Group(Arc<GroupShared>),
}

/// Shared state of one group-commit window (see module docs).
struct GroupShared {
    inner: Mutex<GroupInner>,
    cv: Condvar,
}

struct GroupInner {
    /// The live log (swapped on every epoch rotation).
    file: Arc<File>,
    /// Epoch the window is counting for.
    epoch: u64,
    /// Records written (buffered) into the current epoch's log.
    written: u64,
    /// Records covered by a completed fsync of the current epoch's log.
    synced: u64,
    /// A leader is currently fsyncing (followers wait on the condvar).
    leading: bool,
    /// Completed group fsyncs (observability: `<<` appends under load).
    syncs: u64,
    /// Sticky first fsync failure: every subsequent barrier fails fast
    /// (the caller's store poisons itself on that error).
    error: Option<String>,
}

impl GroupShared {
    fn lock(&self) -> std::sync::MutexGuard<'_, GroupInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cloneable, thread-safe barrier handle over a WAL's group-commit
/// window ([`WalWriter::enable_group_commit`]).
#[derive(Clone)]
pub struct WalSync {
    shared: Arc<GroupShared>,
}

impl WalSync {
    /// Block until every record appended to the window so far is
    /// durable. The first caller in a window becomes the leader and
    /// issues one fsync for the whole group; followers wait for that
    /// sync (or a covering later one / an epoch rotation, whose seal
    /// already made their records durable) and never touch the disk.
    pub fn barrier(&self) -> Result<()> {
        let mut g = self.shared.lock();
        let (target_epoch, target) = (g.epoch, g.written);
        loop {
            if let Some(e) = &g.error {
                return Err(TgmError::Persist(format!("a group-commit fsync failed: {e}")));
            }
            if g.epoch != target_epoch || g.synced >= target {
                return Ok(());
            }
            if g.leading {
                g = self
                    .shared
                    .cv
                    .wait(g)
                    .unwrap_or_else(|p| p.into_inner());
                continue;
            }
            g.leading = true;
            let covered = g.written;
            let prev_synced = g.synced;
            let file = Arc::clone(&g.file);
            drop(g);
            let window = covered.saturating_sub(prev_synced);
            let span =
                obs::span("persist", "wal_sync").with_detail(format!("window={window}"));
            let res = file.sync_data();
            drop(span);
            g = self.shared.lock();
            g.leading = false;
            match res {
                Ok(()) => {
                    if g.epoch == target_epoch {
                        g.synced = g.synced.max(covered);
                    }
                    g.syncs += 1;
                    let m = wal_metrics();
                    m.fsyncs.inc();
                    m.group_window.record_us(window);
                }
                Err(e) => g.error = Some(e.to_string()),
            }
            self.shared.cv.notify_all();
        }
    }

    /// Completed group fsyncs so far (monotonic; far fewer than appends
    /// under batching — the whole point).
    pub fn group_syncs(&self) -> u64 {
        self.shared.lock().syncs
    }
}

/// Append-side handle over the active segment's log.
pub struct WalWriter {
    path: PathBuf,
    file: Arc<File>,
    epoch: u64,
    mode: SyncMode,
    /// True while the log still lives at the tmp sibling (deferred
    /// creation, see [`WalWriter::create_deferred`]): `path` itself is
    /// untouched until [`WalWriter::commit`].
    pending: bool,
    /// Reusable record buffer: records encode in place, so the ingest
    /// hot path makes zero steady-state allocations per append.
    scratch: Vec<u8>,
}

impl WalWriter {
    fn create_inner(path: &Path, epoch: u64, fsync: bool, deferred: bool) -> Result<WalWriter> {
        let tmp = tmp_sibling(path);
        let mut file = File::create(&tmp)?;
        let mut header = Vec::with_capacity(HEADER_LEN);
        header.extend_from_slice(WAL_MAGIC);
        header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        header.extend_from_slice(&epoch.to_le_bytes());
        file.write_all(&header)?;
        file.sync_data()?;
        if !deferred {
            std::fs::rename(&tmp, path)?;
            sync_parent_dir(path)?;
        }
        // After the rename the inode is the one `file` already holds, so
        // the handle keeps appending to the live log.
        Ok(WalWriter {
            path: path.to_path_buf(),
            file: Arc::new(file),
            epoch,
            mode: if fsync { SyncMode::Each } else { SyncMode::Flush },
            pending: deferred,
            scratch: Vec::new(),
        })
    }

    /// Create a fresh WAL at `path` with the given epoch, atomically
    /// replacing whatever was there (tmp header + rename), and return an
    /// append handle positioned after the header.
    pub fn create(path: &Path, epoch: u64, fsync: bool) -> Result<WalWriter> {
        WalWriter::create_inner(path, epoch, fsync, false)
    }

    /// Create a fresh WAL whose bytes accumulate at the tmp sibling;
    /// whatever currently lives at `path` is untouched until
    /// [`WalWriter::commit`] renames the new log over it. Recovery
    /// replays the surviving tail through this, so a second crash
    /// mid-replay still finds the original (complete) log on disk.
    pub fn create_deferred(path: &Path, epoch: u64, fsync: bool) -> Result<WalWriter> {
        WalWriter::create_inner(path, epoch, fsync, true)
    }

    /// Publish a deferred log at its real path (no-op for committed
    /// logs, including any log [`WalWriter::reset`] has re-created).
    pub fn commit(&mut self) -> Result<()> {
        if self.pending {
            self.file.sync_data()?;
            std::fs::rename(tmp_sibling(&self.path), &self.path)?;
            sync_parent_dir(&self.path)?;
            self.pending = false;
        }
        Ok(())
    }

    /// Re-open an existing WAL for appending (recovery replays records
    /// through a fresh [`WalWriter::create`] instead, so this is only
    /// used by tests).
    #[cfg(test)]
    pub fn open_append(path: &Path, epoch: u64, fsync: bool) -> Result<WalWriter> {
        let file = std::fs::OpenOptions::new().append(true).open(path)?;
        Ok(WalWriter {
            path: path.to_path_buf(),
            file: Arc::new(file),
            epoch,
            mode: if fsync { SyncMode::Each } else { SyncMode::Flush },
            pending: false,
            scratch: Vec::new(),
        })
    }

    /// Change the per-append fsync policy (flush-only vs per-record
    /// fsync). Recovery replays into the deferred log with fsync off —
    /// the original log remains the durable copy until
    /// [`WalWriter::commit`] syncs once — and then restores the store's
    /// policy for live appends (or upgrades to group commit via
    /// [`WalWriter::enable_group_commit`]).
    pub fn set_fsync(&mut self, fsync: bool) {
        self.mode = if fsync { SyncMode::Each } else { SyncMode::Flush };
    }

    /// Switch this log to group-commit mode and return the shared
    /// barrier handle (see the module docs). Subsequent appends register
    /// with the window instead of fsyncing; [`WalSync::barrier`] makes
    /// them durable with one fsync per window. Epoch rotations
    /// ([`WalWriter::reset`]) carry the window over to the fresh log.
    pub fn enable_group_commit(&mut self) -> WalSync {
        let shared = Arc::new(GroupShared {
            inner: Mutex::new(GroupInner {
                file: Arc::clone(&self.file),
                epoch: self.epoch,
                written: 0,
                synced: 0,
                leading: false,
                syncs: 0,
                error: None,
            }),
            cv: Condvar::new(),
        });
        self.mode = SyncMode::Group(Arc::clone(&shared));
        WalSync { shared }
    }

    /// Current WAL epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Path of the live log.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Durably record one event. Returns only after the bytes reached
    /// the OS (or the disk, with fsync on): an `Ok(())` here is what
    /// makes the subsequent in-memory append *acknowledged*.
    pub fn append(&mut self, ev: &Event) -> Result<()> {
        match ev {
            Event::Edge(e) => self.append_edge(e),
            Event::Node(n) => self.append_node(n),
        }
    }

    /// [`WalWriter::append`] for a borrowed edge event: encodes straight
    /// into the reusable scratch buffer (no per-append allocation).
    pub fn append_edge(&mut self, e: &EdgeEvent) -> Result<()> {
        self.begin_record(KIND_EDGE);
        self.scratch.extend_from_slice(&e.t.to_le_bytes());
        self.scratch.extend_from_slice(&e.src.to_le_bytes());
        self.scratch.extend_from_slice(&e.dst.to_le_bytes());
        self.scratch.extend_from_slice(&(e.features.len() as u32).to_le_bytes());
        for &f in &e.features {
            self.scratch.extend_from_slice(&f.to_le_bytes());
        }
        self.finish_record(KIND_EDGE)
    }

    /// [`WalWriter::append`] for a borrowed node event.
    pub fn append_node(&mut self, n: &NodeEvent) -> Result<()> {
        self.begin_record(KIND_NODE);
        self.scratch.extend_from_slice(&n.t.to_le_bytes());
        self.scratch.extend_from_slice(&n.node.to_le_bytes());
        self.scratch.extend_from_slice(&(n.features.len() as u32).to_le_bytes());
        for &f in &n.features {
            self.scratch.extend_from_slice(&f.to_le_bytes());
        }
        self.finish_record(KIND_NODE)
    }

    /// Start a record in the scratch buffer (length patched at finish).
    fn begin_record(&mut self, kind: u8) {
        self.scratch.clear();
        self.scratch.push(kind);
        self.scratch.extend_from_slice(&[0u8; 4]);
    }

    /// Patch the length prefix, append the checksum, and write the
    /// whole record in one `write_all`.
    fn finish_record(&mut self, kind: u8) -> Result<()> {
        let len = (self.scratch.len() - 5) as u32;
        self.scratch[1..5].copy_from_slice(&len.to_le_bytes());
        let sum = checksum_seeded(checksum(&[kind]), &self.scratch[5..]);
        self.scratch.extend_from_slice(&sum.to_le_bytes());
        (&*self.file).write_all(&self.scratch)?;
        wal_metrics().appends.inc();
        match &self.mode {
            SyncMode::Flush => {}
            SyncMode::Each => {
                self.file.sync_data()?;
                wal_metrics().fsyncs.inc();
            }
            SyncMode::Group(shared) => shared.lock().written += 1,
        }
        Ok(())
    }

    /// Truncate to a fresh log at `epoch` (called after a seal has made
    /// the buffered events durable inside a segment file). In group
    /// mode the commit window rotates with the log: buffered records of
    /// the outgoing epoch are durable through the sealed segment file,
    /// so waiters on them complete without another fsync.
    pub fn reset(&mut self, epoch: u64) -> Result<()> {
        let mut fresh = WalWriter::create(&self.path, epoch, false)?;
        fresh.mode = match &self.mode {
            SyncMode::Flush => SyncMode::Flush,
            SyncMode::Each => SyncMode::Each,
            SyncMode::Group(shared) => {
                let mut g = shared.lock();
                g.file = Arc::clone(&fresh.file);
                g.epoch = epoch;
                g.written = 0;
                g.synced = 0;
                drop(g);
                shared.cv.notify_all();
                SyncMode::Group(Arc::clone(shared))
            }
        };
        *self = fresh;
        Ok(())
    }
}

/// Everything recovery learns from one WAL file.
#[derive(Debug)]
pub struct WalContents {
    /// Epoch recorded in the header.
    pub epoch: u64,
    /// Complete, checksum-valid records in append order.
    pub events: Vec<Event>,
    /// True when a torn (incomplete) trailing record was dropped.
    pub torn_tail: bool,
    /// Bytes past the last complete record (0 when not torn). A genuine
    /// crash can only tear the final in-flight record, so this is
    /// normally smaller than one record; a much larger value suggests a
    /// corrupted length prefix mid-file masquerading as a tear — the
    /// one corruption shape a per-record checksum cannot separate from
    /// truncation. Surfaced so operators can alert on it.
    pub dropped_bytes: usize,
}

/// Upper bound on a single record's payload; a length prefix above this
/// is treated as corruption (typed error) rather than a torn tail.
const MAX_RECORD_PAYLOAD: usize = 1 << 30;

/// Read a WAL file: the acknowledged prefix plus its epoch. A torn tail
/// is dropped (see module docs); a corrupt complete record is a typed
/// error.
pub fn read_wal(path: &Path) -> Result<WalContents> {
    let bytes = std::fs::read(path)
        .map_err(|e| TgmError::Persist(format!("cannot read wal {}: {e}", path.display())))?;
    let epoch = parse_header(&bytes)?;
    let (events, pos, torn_tail) = parse_records(&bytes, HEADER_LEN)?;
    Ok(WalContents { epoch, events, torn_tail, dropped_bytes: bytes.len() - pos })
}

/// Validate the fixed WAL header and return its epoch.
fn parse_header(bytes: &[u8]) -> Result<u64> {
    if bytes.len() < HEADER_LEN {
        return Err(TgmError::Persist(format!(
            "wal header torn ({} of {HEADER_LEN} bytes)",
            bytes.len()
        )));
    }
    if &bytes[..8] != WAL_MAGIC {
        return Err(TgmError::Persist("wal has wrong magic (not a TGM wal)".into()));
    }
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    if version != FORMAT_VERSION {
        return Err(TgmError::Persist(format!(
            "wal format version {version} unsupported (this build reads {FORMAT_VERSION})"
        )));
    }
    Ok(u64::from_le_bytes([
        bytes[12], bytes[13], bytes[14], bytes[15], bytes[16], bytes[17], bytes[18], bytes[19],
    ]))
}

/// Parse complete records from `start` to the end of `bytes`: the
/// decoded events, the offset one past the last complete record, and
/// whether trailing bytes form an incomplete (torn/in-flight) record.
fn parse_records(bytes: &[u8], start: usize) -> Result<(Vec<Event>, usize, bool)> {
    let mut events = Vec::new();
    let mut pos = start;
    let mut torn_tail = false;
    while pos < bytes.len() {
        // kind + len prefix.
        if pos + 5 > bytes.len() {
            torn_tail = true;
            break;
        }
        let kind = bytes[pos];
        let len = u32::from_le_bytes([
            bytes[pos + 1],
            bytes[pos + 2],
            bytes[pos + 3],
            bytes[pos + 4],
        ]) as usize;
        if len > MAX_RECORD_PAYLOAD {
            return Err(TgmError::Persist(format!(
                "wal record {} declares an absurd {len}-byte payload (corrupt length prefix)",
                events.len()
            )));
        }
        let body_end = pos + 5 + len;
        let rec_end = body_end + 8;
        if rec_end > bytes.len() {
            torn_tail = true;
            break;
        }
        let payload = &bytes[pos + 5..body_end];
        let stored = u64::from_le_bytes([
            bytes[body_end],
            bytes[body_end + 1],
            bytes[body_end + 2],
            bytes[body_end + 3],
            bytes[body_end + 4],
            bytes[body_end + 5],
            bytes[body_end + 6],
            bytes[body_end + 7],
        ]);
        if checksum_seeded(checksum(&[kind]), payload) != stored {
            return Err(TgmError::Persist(format!(
                "wal record {} failed its checksum (corrupt log, not a torn tail)",
                events.len()
            )));
        }
        events.push(decode_payload(kind, payload)?);
        pos = rec_end;
    }
    Ok((events, pos, torn_tail))
}

/// One incremental read of a live, still-growing WAL (the replica
/// tailing path — see [`crate::replica`]).
#[derive(Debug)]
pub struct WalTail {
    /// Epoch in the file's header at read time (the primary's reset
    /// atomically replaces the file, so a read observes exactly one
    /// epoch's bytes).
    pub epoch: u64,
    /// Complete, checksum-valid records from the requested offset, in
    /// append order. Empty when the header epoch differs from the
    /// expected one — the **epoch fence**: records of another epoch are
    /// never surfaced against a stale cursor, so a tailing reader can
    /// never double-apply across a seal window.
    pub events: Vec<Event>,
    /// Offset one past the last complete record — the next read's
    /// cursor. Unchanged from the request when the fence tripped.
    pub end_offset: usize,
    /// Trailing bytes form an incomplete record. On a live log this is
    /// an in-flight append, not damage: re-read from `end_offset` once
    /// the writer finishes it.
    pub torn_tail: bool,
}

/// Tail a WAL from a byte cursor: parse only the complete records in
/// `bytes[offset..]`, for a reader that polls a live log.
///
/// * A header epoch other than `expected_epoch` returns **no** events
///   (fenced) with the observed epoch, letting the caller reconcile the
///   manifest first — after a seal, the cursor restarts at the fresh
///   epoch's [`WalTail::end_offset`].
/// * An incomplete trailing record sets [`WalTail::torn_tail`] and is
///   left for the next poll; a checksum-failing complete record is a
///   typed error, exactly as in [`read_wal`].
/// * `offset` must lie on a record boundary previously returned by this
///   function (or be the fresh-epoch start); an offset past the end of
///   the file is a typed error, since an epoch's log only ever grows.
pub fn read_wal_tail(path: &Path, expected_epoch: u64, offset: usize) -> Result<WalTail> {
    let bytes = std::fs::read(path)
        .map_err(|e| TgmError::Persist(format!("cannot read wal {}: {e}", path.display())))?;
    let epoch = parse_header(&bytes)?;
    if epoch != expected_epoch {
        return Ok(WalTail { epoch, events: Vec::new(), end_offset: offset, torn_tail: false });
    }
    let start = offset.max(HEADER_LEN);
    if start > bytes.len() {
        return Err(TgmError::Persist(format!(
            "wal tail cursor {start} is past the end of {} ({} bytes at epoch {epoch}) — \
             the log shrank within an epoch",
            path.display(),
            bytes.len()
        )));
    }
    let (events, end_offset, torn_tail) = parse_records(&bytes, start)?;
    Ok(WalTail { epoch, events, end_offset, torn_tail })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tgm_wal_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn edge(t: i64) -> Event {
        Event::Edge(EdgeEvent { t, src: 1, dst: 2, features: vec![t as f32, 0.5] })
    }

    fn node(t: i64) -> Event {
        Event::Node(NodeEvent { t, node: 3, features: vec![-1.0] })
    }

    #[test]
    fn wal_round_trip_and_reset() {
        let path = dir().join("wal_round_trip.log");
        let mut w = WalWriter::create(&path, 1, false).unwrap();
        let evs = vec![edge(10), node(11), edge(12)];
        for e in &evs {
            w.append(e).unwrap();
        }
        let c = read_wal(&path).unwrap();
        assert_eq!(c.epoch, 1);
        assert!(!c.torn_tail);
        assert_eq!(c.events, evs);
        // Reset starts a fresh epoch with no records.
        w.reset(2).unwrap();
        let c = read_wal(&path).unwrap();
        assert_eq!(c.epoch, 2);
        assert!(c.events.is_empty());
        // And the handle keeps appending into the fresh log.
        w.append(&edge(20)).unwrap();
        assert_eq!(read_wal(&path).unwrap().events, vec![edge(20)]);
    }

    #[test]
    fn torn_tails_drop_only_the_unacknowledged_record() {
        let path = dir().join("wal_torn.log");
        let mut w = WalWriter::create(&path, 1, false).unwrap();
        for t in 0..5 {
            w.append(&edge(t)).unwrap();
        }
        drop(w);
        let full = std::fs::read(&path).unwrap();
        // Truncate at every byte offset: recovery must always yield the
        // prefix of records fully contained in the surviving bytes.
        let rec_len = (full.len() - HEADER_LEN) / 5;
        for cut in HEADER_LEN..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let c = read_wal(&path).unwrap();
            let complete = (cut - HEADER_LEN) / rec_len;
            assert_eq!(c.events.len(), complete, "cut at {cut}");
            assert_eq!(c.torn_tail, (cut - HEADER_LEN) % rec_len != 0, "cut at {cut}");
            assert_eq!(c.dropped_bytes, (cut - HEADER_LEN) % rec_len, "cut at {cut}");
            for (i, e) in c.events.iter().enumerate() {
                assert_eq!(e, &edge(i as i64));
            }
        }
        // Cutting into the header is a typed error.
        std::fs::write(&path, &full[..HEADER_LEN - 1]).unwrap();
        assert!(matches!(read_wal(&path).unwrap_err(), TgmError::Persist(_)));
    }

    /// A flipped high bit in a length prefix must read as corruption,
    /// not as a torn tail silently swallowing every later record.
    #[test]
    fn absurd_length_prefix_is_corruption_not_a_tear() {
        let path = dir().join("wal_absurd_len.log");
        let mut w = WalWriter::create(&path, 1, false).unwrap();
        for t in 0..4 {
            w.append(&edge(t)).unwrap();
        }
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Record 1's length prefix starts one record past the header,
        // one byte in (after the kind byte); set its high bytes.
        let rec_len = (bytes.len() - HEADER_LEN) / 4;
        let len_at = HEADER_LEN + rec_len + 1;
        bytes[len_at + 3] = 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_wal(&path).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("length"), "{err}");
    }

    #[test]
    fn corrupt_records_are_rejected_not_dropped() {
        let path = dir().join("wal_corrupt.log");
        let mut w = WalWriter::create(&path, 1, true).unwrap();
        w.append(&edge(1)).unwrap();
        w.append(&edge(2)).unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a payload byte of the first record (complete record, bad
        // checksum): corruption, not a torn tail.
        bytes[HEADER_LEN + 6] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_wal(&path).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn deferred_creation_leaves_the_original_log_until_commit() {
        let path = dir().join("wal_deferred.log");
        let mut original = WalWriter::create(&path, 4, false).unwrap();
        original.append(&edge(1)).unwrap();
        original.append(&edge(2)).unwrap();
        drop(original);

        // A deferred rewrite accumulates at the tmp sibling; the real
        // log still reads the original contents (a crash here would
        // re-run recovery against it).
        let mut rewrite = WalWriter::create_deferred(&path, 4, false).unwrap();
        rewrite.append(&edge(1)).unwrap();
        let c = read_wal(&path).unwrap();
        assert_eq!(c.events, vec![edge(1), edge(2)], "original must be untouched");

        // Commit publishes the rewrite atomically; further appends land
        // in the committed log. A second commit is a no-op.
        rewrite.append(&edge(2)).unwrap();
        rewrite.commit().unwrap();
        rewrite.append(&edge(3)).unwrap();
        rewrite.commit().unwrap();
        let c = read_wal(&path).unwrap();
        assert_eq!(c.events, vec![edge(1), edge(2), edge(3)]);
        assert_eq!(c.epoch, 4);
    }

    #[test]
    fn group_commit_batches_fsyncs_behind_one_barrier() {
        let path = dir().join("wal_group.log");
        let mut w = WalWriter::create(&path, 1, true).unwrap();
        let sync = w.enable_group_commit();
        for t in 0..100 {
            w.append(&edge(t)).unwrap(); // registers, does not fsync
        }
        assert_eq!(sync.group_syncs(), 0, "no barrier yet, no fsync yet");
        sync.barrier().unwrap();
        assert_eq!(sync.group_syncs(), 1, "one fsync covered all 100 appends");
        // An already-covered barrier is free.
        sync.barrier().unwrap();
        assert_eq!(sync.group_syncs(), 1);
        // New appends need (exactly) one more.
        w.append(&edge(100)).unwrap();
        sync.barrier().unwrap();
        assert_eq!(sync.group_syncs(), 2);
        assert_eq!(read_wal(&path).unwrap().events.len(), 101);
    }

    #[test]
    fn group_commit_window_rotates_with_the_epoch() {
        let path = dir().join("wal_group_rotate.log");
        let mut w = WalWriter::create(&path, 1, true).unwrap();
        let sync = w.enable_group_commit();
        w.append(&edge(1)).unwrap();
        // A reset (post-seal) rotates the window: the outgoing epoch's
        // records are durable via the sealed segment, so a barrier after
        // rotation has nothing to sync.
        w.reset(2).unwrap();
        sync.barrier().unwrap();
        assert_eq!(sync.group_syncs(), 0, "rotation covered the old epoch without a sync");
        // The fresh epoch's appends flow through the same window.
        w.append(&edge(2)).unwrap();
        sync.barrier().unwrap();
        assert_eq!(sync.group_syncs(), 1);
        let c = read_wal(&path).unwrap();
        assert_eq!(c.epoch, 2);
        assert_eq!(c.events, vec![edge(2)]);
    }

    /// Concurrent appenders sharing one window: every barrier returns
    /// only after its records are synced, and the total fsync count
    /// stays well below the append count (the leader-follower win).
    #[test]
    fn group_commit_is_safe_and_batched_across_threads() {
        let path = dir().join("wal_group_threads.log");
        let mut w = WalWriter::create(&path, 1, true).unwrap();
        let sync = w.enable_group_commit();
        let writer = std::sync::Mutex::new(w);
        let per_thread = 25usize;
        let threads = 4usize;
        std::thread::scope(|scope| {
            for k in 0..threads {
                let writer = &writer;
                let sync = sync.clone();
                scope.spawn(move || {
                    for i in 0..per_thread {
                        let t = (k * per_thread + i) as i64;
                        writer
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .append(&edge(t))
                            .unwrap();
                        // Batch of 5: barrier after every 5th append.
                        if i % 5 == 4 {
                            sync.barrier().unwrap();
                        }
                    }
                    sync.barrier().unwrap();
                });
            }
        });
        let c = read_wal(&path).unwrap();
        assert_eq!(c.events.len(), threads * per_thread);
        let syncs = sync.group_syncs();
        assert!(syncs >= 1);
        assert!(
            syncs <= (threads * per_thread) as u64,
            "syncs ({syncs}) must never exceed appends"
        );
    }

    #[test]
    fn tail_reads_resume_from_the_cursor_and_fence_on_epoch_change() {
        let path = dir().join("wal_tail.log");
        let mut w = WalWriter::create(&path, 1, false).unwrap();
        w.append(&edge(1)).unwrap();
        w.append(&edge(2)).unwrap();
        let t1 = read_wal_tail(&path, 1, HEADER_LEN).unwrap();
        assert_eq!(t1.events, vec![edge(1), edge(2)]);
        assert!(!t1.torn_tail);
        // Nothing new: the same cursor yields nothing and stays put.
        let t2 = read_wal_tail(&path, 1, t1.end_offset).unwrap();
        assert!(t2.events.is_empty());
        assert_eq!(t2.end_offset, t1.end_offset);
        // New appends surface from the cursor only (no re-delivery).
        w.append(&node(3)).unwrap();
        let t3 = read_wal_tail(&path, 1, t1.end_offset).unwrap();
        assert_eq!(t3.events, vec![node(3)]);
        // A reset (seal) fences the stale cursor: observed epoch comes
        // back, no events, cursor untouched — even though the fresh
        // file is shorter than the cursor.
        w.reset(2).unwrap();
        let t4 = read_wal_tail(&path, 1, t3.end_offset).unwrap();
        assert_eq!(t4.epoch, 2);
        assert!(t4.events.is_empty());
        assert_eq!(t4.end_offset, t3.end_offset);
        // Restarting at the fresh epoch's start picks up its records.
        w.append(&edge(9)).unwrap();
        let t5 = read_wal_tail(&path, 2, HEADER_LEN).unwrap();
        assert_eq!(t5.events, vec![edge(9)]);
    }

    #[test]
    fn tail_reads_leave_inflight_records_for_the_next_poll() {
        let path = dir().join("wal_tail_torn.log");
        let mut w = WalWriter::create(&path, 1, false).unwrap();
        w.append(&edge(1)).unwrap();
        w.append(&edge(2)).unwrap();
        drop(w);
        let full = std::fs::read(&path).unwrap();
        let rec_len = (full.len() - HEADER_LEN) / 2;
        // Simulate an in-flight append: first record complete, second
        // only half-written.
        std::fs::write(&path, &full[..HEADER_LEN + rec_len + rec_len / 2]).unwrap();
        let t = read_wal_tail(&path, 1, HEADER_LEN).unwrap();
        assert_eq!(t.events, vec![edge(1)]);
        assert!(t.torn_tail);
        assert_eq!(t.end_offset, HEADER_LEN + rec_len);
        // The writer finishes the record: the same cursor now sees it.
        std::fs::write(&path, &full).unwrap();
        let t = read_wal_tail(&path, 1, t.end_offset).unwrap();
        assert_eq!(t.events, vec![edge(2)]);
        assert!(!t.torn_tail);
        // A cursor past the end of a matching-epoch log is corruption.
        std::fs::write(&path, &full[..HEADER_LEN + rec_len]).unwrap();
        let err = read_wal_tail(&path, 1, full.len()).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
    }

    #[test]
    fn open_append_continues_an_existing_log() {
        let path = dir().join("wal_append.log");
        let mut w = WalWriter::create(&path, 3, false).unwrap();
        w.append(&edge(1)).unwrap();
        drop(w);
        let mut w = WalWriter::open_append(&path, 3, false).unwrap();
        w.append(&edge(2)).unwrap();
        assert_eq!(w.epoch(), 3);
        assert_eq!(w.path(), path.as_path());
        let c = read_wal(&path).unwrap();
        assert_eq!(c.events, vec![edge(1), edge(2)]);
    }
}
