//! Read-only memory mapping for sealed segment files.
//!
//! The heap read path ([`super::format::decode_segment`]) copies every
//! column out of the file; on recovery that means re-allocating the
//! whole store even though the bytes are already sitting in the kernel
//! page cache. [`Mmap`] maps a sealed file read-only instead, and
//! [`MappedSlice`] exposes a typed column as a plain `&[T]` straight
//! over the mapping — zero copies, zero steady-state heap, and pages
//! that the kernel can evict and fault back on demand. Sealed segment
//! files are immutable by construction (compaction replaces them
//! wholesale via rename), so a private read-only mapping can never
//! observe a torn update.
//!
//! The mapping is created through a direct `mmap(2)`/`munmap(2)` FFI
//! declaration — the crate stays dependency-free offline — and is only
//! compiled on 64-bit unix targets; everywhere else
//! [`supported`] reports `false` and callers fall back to the heap
//! decoder (byte-identical serving either way, pinned by the
//! `SegmentBacking` tests).

use crate::error::{Result, TgmError};
use std::path::Path;
use std::sync::Arc;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// True when this build can serve mmap-backed segments (64-bit unix).
pub fn supported() -> bool {
    cfg!(all(unix, target_pointer_width = "64"))
}

/// A read-only, whole-file memory mapping. Immutable for its lifetime;
/// unmapped on drop.
pub struct Mmap {
    ptr: *const u8,
    len: usize,
}

// Safety: the mapping is PROT_READ over an immutable sealed file and is
// never mutated or remapped after construction; concurrent reads from
// any thread are therefore safe, and the unmap happens exactly once via
// the owning Arc's final drop.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only in its entirety.
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn open(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path).map_err(|e| {
            TgmError::Persist(format!("cannot open {} for mapping: {e}", path.display()))
        })?;
        let len = file.metadata()?.len();
        let len = usize::try_from(len).map_err(|_| {
            TgmError::Persist(format!("{} is too large to map", path.display()))
        })?;
        if len == 0 {
            return Err(TgmError::Persist(format!(
                "{} is empty (segment files are never empty)",
                path.display()
            )));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(TgmError::Persist(format!(
                "mmap of {} failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            )));
        }
        // The fd can close now: the mapping keeps the inode alive.
        Ok(Mmap { ptr: ptr as *const u8, len })
    }

    /// Unsupported-platform stub (callers should consult [`supported`]
    /// and fall back to the heap decoder).
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn open(path: &Path) -> Result<Mmap> {
        Err(TgmError::Persist(format!(
            "mmap-backed segments are not supported on this platform ({})",
            path.display()
        )))
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        // Safety: ptr/len describe a live PROT_READ mapping for as long
        // as `self` exists.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Never true: zero-length files refuse to map.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        unsafe {
            let _ = sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap({} bytes)", self.len)
    }
}

/// One typed column served directly from a shared [`Mmap`]: a byte
/// offset + element count, validated against bounds and alignment at
/// construction so [`MappedSlice::as_slice`] is branch-free.
pub struct MappedSlice<T> {
    map: Arc<Mmap>,
    offset: usize,
    len: usize,
    _ty: std::marker::PhantomData<T>,
}

impl<T: Copy> MappedSlice<T> {
    /// View `len` elements of `T` at byte `offset` of `map`. Typed error
    /// when the range leaves the mapping or the offset is misaligned
    /// for `T` (mmap bases are page-aligned, so file-relative alignment
    /// is mapping-relative alignment).
    pub(crate) fn new(map: Arc<Mmap>, offset: usize, len: usize) -> Result<MappedSlice<T>> {
        let end = len
            .checked_mul(std::mem::size_of::<T>())
            .and_then(|b| offset.checked_add(b));
        if !end.is_some_and(|e| e <= map.len()) {
            return Err(TgmError::Persist(format!(
                "mapped column [{offset}, +{len} x {}B] leaves the {}-byte mapping",
                std::mem::size_of::<T>(),
                map.len()
            )));
        }
        if offset % std::mem::align_of::<T>() != 0 {
            return Err(TgmError::Persist(format!(
                "mapped column at byte offset {offset} is misaligned for a {}-byte element",
                std::mem::align_of::<T>()
            )));
        }
        Ok(MappedSlice { map, offset, len, _ty: std::marker::PhantomData })
    }

    /// The column as a plain slice over the page cache.
    pub fn as_slice(&self) -> &[T] {
        // Safety: bounds and alignment were validated in `new`; T is a
        // plain-old-data numeric type (the callers instantiate i64, u32
        // and f32 only), for which any bit pattern is a valid value;
        // the backing mapping is immutable and outlives `self` via the
        // shared Arc.
        unsafe {
            let base = self.map.bytes().as_ptr().add(self.offset);
            std::slice::from_raw_parts(base as *const T, self.len)
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for MappedSlice<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MappedSlice({} elems at +{})", self.len, self.offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tgm_mmap_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(tag);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn maps_round_trip_bytes() {
        if !supported() {
            return;
        }
        let data: Vec<u8> = (0..=255u8).collect();
        let path = test_file("round_trip.bin", &data);
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.len(), 256);
        assert!(!map.is_empty());
        assert_eq!(map.bytes(), &data[..]);
    }

    #[test]
    fn typed_slices_validate_bounds_and_alignment() {
        if !supported() {
            return;
        }
        let vals: Vec<i64> = (0..32).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let path = test_file("typed.bin", &bytes);
        let map = Arc::new(Mmap::open(&path).unwrap());

        let col: MappedSlice<i64> = MappedSlice::new(Arc::clone(&map), 0, 32).unwrap();
        assert_eq!(col.as_slice(), &vals[..]);
        let tail: MappedSlice<i64> = MappedSlice::new(Arc::clone(&map), 8, 31).unwrap();
        assert_eq!(tail.as_slice(), &vals[1..]);
        // Out of bounds and misaligned views are typed errors.
        assert!(MappedSlice::<i64>::new(Arc::clone(&map), 0, 33).is_err());
        assert!(MappedSlice::<i64>::new(Arc::clone(&map), 4, 1).is_err());
        // Empty views at any valid offset are fine.
        let empty: MappedSlice<i64> = MappedSlice::new(Arc::clone(&map), 256, 0).unwrap();
        assert!(empty.as_slice().is_empty());
    }

    #[test]
    fn missing_and_empty_files_are_typed_errors() {
        if !supported() {
            return;
        }
        let missing = std::env::temp_dir().join("tgm_mmap_never_written.bin");
        assert!(matches!(Mmap::open(&missing).unwrap_err(), TgmError::Persist(_)));
        let path = test_file("empty.bin", &[]);
        assert!(matches!(Mmap::open(&path).unwrap_err(), TgmError::Persist(_)));
    }
}
